"""The paper's OWN model family: ResNet (He et al. 2016) for the faithful
reproduction path (Tables 1-3, Fig. 2).  A compact CIFAR-style ResNet keeps
the CPU benches tractable while exercising exactly the paper's four Fig. 1
cases (conv, conv+ReLU, residual+ReLU, residual w/o ReLU) and BN folding.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet-paper"
    stages: tuple = (16, 32, 64)   # channels per stage
    blocks_per_stage: int = 2
    n_classes: int = 10
    img_size: int = 32
    n_bits: int = 8
    tau: int = 4


CONFIG = ResNetConfig()
SMOKE_CONFIG = ResNetConfig(stages=(8, 16), blocks_per_stage=1, img_size=16)
