"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, qk_norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_ff=25600,
    vocab_size=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=256, head_dim=16)
