"""rwkv6-3b "Finch" [ssm] — 32L d_model=2560 (attn-free) d_ff=8960
vocab=65536, data-dependent decay. [arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=8960,
    vocab_size=65536, head_dim=64, act="relu_sq",
    # chunk 32: the chunked linear-attention form exponentiates the within-
    # chunk cumulative log-decay; with the per-token decay floor exp(-1.65)
    # this keeps every exp() < e^53 (finite in fp32).  See models/rwkv.py.
    ssm=SSMConfig(kind="rwkv6", chunk=32),
    sub_quadratic=True,
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, d_ff=256,
    vocab_size=256, head_dim=64)
