"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
(expert) vocab=49155, 40 experts top-8. [hf:ibm-granite/...; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab_size=49155, head_dim=64, rope_theta=1e4, tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8, d_expert=512, n_shared=0,
                  n_dense_layers=0, capacity_factor=1.25,
                  n_experts_padded=48),  # 48 % 16 == 0: EP stays valid
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
    vocab_size=256, head_dim=16,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=0,
                  n_dense_layers=0, capacity_factor=1.25),
)
