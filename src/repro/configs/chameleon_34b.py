"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 (early fusion: text + VQ image tokens share the table; the VQ
tokenizer frontend is a stub providing token ids). [arXiv:2405.09818]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab_size=65536, head_dim=128, qk_norm=True, rope_theta=1e4,
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16)
