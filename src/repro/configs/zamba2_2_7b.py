"""zamba2-2.7b [hybrid] — 54 Mamba2 blocks d_model=2560, ssm_state=64,
ONE shared GQA block (32H kv=32) applied every 6 blocks on
concat(h, embedding). d_ff=10240 (shared block MLP). [arXiv:2411.15242; hf]"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000, head_dim=80, rope_theta=1e4,
    ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2,
                  head_dim=64, chunk=256),
    hybrid=HybridConfig(attn_every=6, n_shared_blocks=1,
                        concat_embedding=True),
    sub_quadratic=True,
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, head_dim=16,
    ssm=SSMConfig(kind="mamba2", d_state=16, d_conv=4, expand=2,
                  head_dim=32, chunk=64),
    hybrid=HybridConfig(attn_every=2, n_shared_blocks=1,
                        concat_embedding=True),
)
