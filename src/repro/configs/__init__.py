"""Config registry: ``get_config(arch_id)`` and smoke-scale variants."""
from __future__ import annotations

import importlib

from repro.configs.base import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                                EncDecConfig, HybridConfig, ShapeConfig,
                                SHAPES)

ARCH_IDS = [
    "qwen3_1_7b", "deepseek_67b", "qwen3_32b", "llama3_2_1b",
    "deepseek_v3_671b", "granite_moe_3b_a800m", "whisper_large_v3",
    "rwkv6_3b", "chameleon_34b", "zamba2_2_7b", "resnet_paper",
]

# canonical CLI ids (dashes) -> module names
_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    mod_name = _ALIAS.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod_name = _ALIAS.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE_CONFIG


def supported_shapes(cfg: ModelConfig) -> list[str]:
    """Which assigned shape cells apply to this arch (DESIGN §4)."""
    names = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    if not cfg.sub_quadratic:
        names.remove("long_500k")  # quadratic attention at 524k: skipped
    return names


def depth_variants(cfg: ModelConfig) -> tuple[list[ModelConfig], list[float]]:
    """Reduced-depth variants + extrapolation weights for the roofline fit.

    Cost is affine in each stack's depth, so lowering 2-3 shallow variants
    (full width, scans unrolled) and combining with these weights
    reconstructs the full-depth cost exactly:  cost(true) = sum_i w_i c_i.
    """
    import dataclasses

    if cfg.family == "audio":
        e, dec = cfg.encdec.n_encoder_layers, cfg.n_layers
        mk = lambda ne, nd: dataclasses.replace(
            cfg, n_layers=nd,
            encdec=dataclasses.replace(cfg.encdec, n_encoder_layers=ne))
        return ([mk(2, 2), mk(2, 4), mk(4, 2)],
                [1.0 - (dec - 2) / 2 - (e - 2) / 2,
                 (dec - 2) / 2, (e - 2) / 2])
    if cfg.family == "moe" and cfg.moe.n_dense_layers:
        nd, nm = cfg.moe.n_dense_layers, cfg.n_layers - cfg.moe.n_dense_layers
        mk = lambda d_, m_: dataclasses.replace(
            cfg, n_layers=d_ + m_,
            moe=dataclasses.replace(cfg.moe, n_dense_layers=d_))
        return ([mk(1, 2), mk(1, 4), mk(2, 2)],
                [1.0 - (nm - 2) / 2 - (nd - 1),
                 (nm - 2) / 2, float(nd - 1)])
    if cfg.family == "hybrid":
        g = cfg.hybrid.attn_every
        n_groups = cfg.n_layers // g
        mk = lambda ng: dataclasses.replace(cfg, n_layers=ng * g)
        return [mk(1), mk(2)], [1.0 - (n_groups - 1), float(n_groups - 1)]
    # dense / vlm / ssm / moe-without-dense-prefix: single stack
    L = cfg.n_layers
    mk = lambda n: dataclasses.replace(cfg, n_layers=n)
    return [mk(2), mk(4)], [1.0 - (L - 2) / 2, (L - 2) / 2]
