"""Architecture configuration schema.

One frozen dataclass describes every assigned architecture; family-specific
sub-configs (MoE / MLA / SSM / enc-dec / hybrid) are optional fields.  All
configs are static and hashable so they can be jit static arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["MoEConfig", "MLAConfig", "SSMConfig", "EncDecConfig",
           "HybridConfig", "ModelConfig", "ShapeConfig", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    n_shared: int = 0              # always-on shared experts (DeepSeek-V3)
    n_dense_layers: int = 0        # leading layers that stay dense
    capacity_factor: float = 1.25
    router_dtype: str = "float32"  # router math stays high precision
    # pad the expert STACKS (not the router) to a multiple of the TP axis so
    # expert-parallel sharding stays valid when n_experts doesn't divide it
    # (§Perf iteration G1: granite's 40 experts on a 16-wide model axis);
    # dummy experts receive no tokens.
    n_experts_padded: Optional[int] = None

    @property
    def e_padded(self) -> int:
        return self.n_experts_padded or self.n_experts


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek Multi-head Latent Attention dims (arXiv:2412.19437)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / RWKV6 recurrence dims."""

    kind: str = "mamba2"           # 'mamba2' | 'rwkv6'
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256               # SSD / chunked-linear-attention chunk len
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int
    encoder_seq: int = 1500        # whisper: 30 s of audio at 50 Hz post-conv
    frontend: str = "stub"         # modality frontend is a stub per assignment


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: shared attention block every ``attn_every`` SSM blocks."""

    attn_every: int = 6
    n_shared_blocks: int = 1
    concat_embedding: bool = True  # shared block sees concat(h, h_embed)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | audio | ssm | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    act: str = "silu"              # silu (SwiGLU) | gelu | relu_sq (rwkv)
    tie_embeddings: bool = False
    attn_bias: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None
    hybrid: Optional[HybridConfig] = None
    dtype: str = "bfloat16"
    # which shapes this arch supports (long_500k only for sub-quadratic)
    sub_quadratic: bool = False
    # int8 KV cache (beyond-paper: Eq. 1 applied to the cache — halves HBM
    # cache reads at decode).  Fractional bits are static per config: post-
    # rope/qk-norm K and V are O(1)-ranged, n=4 keeps |x|<8 representable.
    kv_cache_bits: Optional[int] = None
    kv_cache_frac_bits: int = 4
    # int8 recurrent-state slabs (DESIGN §16): Eq. 1 applied to the O(1)
    # RWKV/Mamba sequence state on the fixed-slab substrate — the whole
    # slab requantizes ONCE per engine step on a per-slab po2 grid (the
    # paper's fewer-quantization-ops thesis at its strongest; decay math
    # stays fp32 per §4).  None keeps fp32 slabs (the parity oracle mode).
    state_bits: Optional[int] = None
    state_frac_bits: int = 4
    # attention implementation for the hot paths (DESIGN §2):
    #   'chunked' — pure-JAX online-softmax scan (reference, CPU-friendly)
    #   'flash'   — fused Pallas kernel; with an int8 KV cache the codes are
    #               dequantized in-register, so the bf16 KV never hits HBM
    attn_kernel: str = "chunked"
    # mesh axis the flash kernels shard over (DESIGN §8): KV heads (whole
    # GQA groups) are partitioned across this tensor axis via shard_map,
    # each shard running the Pallas kernel on its local heads with the
    # power-of-two KV scales resident.  The axis size must divide
    # n_kv_heads, and only 'model' is wired through the cache/activation
    # sharding rules — launch/steps raises NotImplementedError otherwise.
    attn_shard_axis: str = "model"
    # projection/MLP matmul path for the forward pass (DESIGN §13):
    #   'dense' — float matmuls; quantization behaviour follows the
    #             QuantContext mode (fp/fake quantize in float, int
    #             quantizes on the fly from float weights)
    #   'int8'  — true W8A8 deploy: weights are pre-quantized int8 codes
    #             (core.qmodel.quantize_params) with static po2 exponents,
    #             activations quantize at module boundaries, and every
    #             projection/MLP/head matmul runs int8 x int8 -> int32 with
    #             the fused bit-shift requant epilogue.  Requires a
    #             calibrated QuantContext in INT mode — launch/steps raises
    #             at build time otherwise.
    matmul_kernel: str = "dense"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding/head tables padded to 128 (MXU lanes + any TP axis
        <= 128).  Odd vocabs (granite 49155, whisper 51866) otherwise force
        replicated logits + a full logits-gradient all-reduce (12.9 GB/dev
        measured on granite train_4k — §Perf iteration G2).  Padded ids are
        never produced by the tokenizer stub; they act as dead classes."""
        return -(-self.vocab_size // 128) * 128

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced-config variant for CPU smoke tests."""
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
