"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff=2048(expert)
vocab=129280, MLA, 1 shared + 256 routed top-8. [arXiv:2412.19437; hf]

Notes: MLA makes n_kv_heads nominal (the cache is the 512-d latent);
first 3 layers are dense FFN (d_ff=18432 in the paper — expert-sized FFNs
with 1 shared expert approximate the dense layers here via n_dense_layers
using the dense MLP at moe.d_expert*9=18432).  MTP head omitted (training
objective detail, not serving-path structure) — DESIGN §7.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432,  # dense-layer FFN width (first 3 layers)
    vocab_size=129280, head_dim=128, rope_theta=1e4,
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  n_dense_layers=3, capacity_factor=1.25),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=192,
    vocab_size=256, head_dim=16,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=48, n_shared=1,
                  n_dense_layers=1, capacity_factor=1.25),
    mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
)
