"""whisper-large-v3 [audio] — enc-dec, 32L decoder d_model=1280 20H
d_ff=5120 vocab=51866; conv frontend STUBBED (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab_size=51866, head_dim=64, act="gelu", attn_bias=True,
    encdec=EncDecConfig(n_encoder_layers=32, encoder_seq=1500),
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, head_dim=16,
    encdec=EncDecConfig(n_encoder_layers=2, encoder_seq=64),
)
