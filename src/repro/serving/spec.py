"""Speculative decoding over the write-once int8-KV pool (DESIGN §11).

Verifying K drafted tokens in ONE paged step amortizes the per-step
launch and weight-read cost that dominates decode — but it forces the
paper's fewer-requant-ops dataflow to answer a question it never had:
what happens to KV codes that were quantized *tentatively* and then
rejected?  This module owns the two model-independent halves of the
answer; the rollback-safe pool semantics (``BlockPool.retract``, commit
publishing only accepted tokens) live in :mod:`repro.serving.kv_pool`.

* **Drafters** (host-side, plain numpy).  The deterministic default is
  :class:`NgramDrafter` — prompt-lookup/self-speculation: find the most
  recent earlier occurrence of the longest current suffix n-gram in the
  request's own token history and propose the tokens that followed it.
  Model-free, zero extra forward passes, and exact on the repetitive
  continuations greedy decoding converges to.  :class:`CallableDrafter`
  is the pluggable small-draft-model hook.

* **Verification** (:func:`verify_tokens`, pure jnp — fused into the
  engine's jitted verify step so one dispatch both scores the (B, K+1)
  chunk and resolves acceptance on device).  Greedy rows accept the
  longest draft prefix that matches the running argmax chain and emit
  the argmax correction at the first mismatch — token-identical to
  non-speculative greedy decode by construction.  Sampled rows run
  Leviathan/Chen-style rejection sampling: accept draft ``d_j`` with
  probability ``min(1, p_j(d_j)/q_j(d_j))``; on the first rejection,
  resample from the residual ``norm(max(p_j - q_j, 0))``; if every
  draft survives, sample one bonus token from the last position.  The
  self-drafter's q is a delta, for which the residual is exactly p with
  the rejected token masked out — the target distribution is preserved.
  (A non-delta draft model plugged through :class:`CallableDrafter`
  gets the same masked-residual resample, the standard approximation
  when only draft token ids — not full q distributions — cross the
  host boundary.)

Requant accounting stays honest (paper Table 5): every drafted row IS
quantized when the verify chunk scatters into the pool, so rejected
tokens' quantization ops count as *performed* — they are exactly the
waste the paper's scheme minimizes elsewhere, and the engine reports
them separately as ``requant_ops_wasted_speculation``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["NgramDrafter", "CallableDrafter", "DrafterStats",
           "resolve_drafter", "apply_top_k", "verify_tokens"]


# ---------------------------------------------------------------------------
# drafters (host-side)
# ---------------------------------------------------------------------------

class DrafterStats:
    """Draft-efficacy accounting (DESIGN §14): how often the drafter was
    asked, how many tokens it proposed, and how often it came up empty —
    an empty proposal means the request pays the full per-token decode
    rate for that step.  Surfaced through the engine's metrics registry
    as ``speculative.drafter_*``; acceptance lives with the engine (the
    drafter never sees the verifier's verdicts)."""

    __slots__ = ("calls", "proposed", "empty")

    def __init__(self):
        self.calls = 0
        self.proposed = 0
        self.empty = 0

    def reset(self) -> None:
        self.calls = 0
        self.proposed = 0
        self.empty = 0

class NgramDrafter:
    """Model-free n-gram / prompt-lookup self-drafter (deterministic).

    Proposes the ``k`` tokens that followed the most recent earlier
    occurrence of the longest matching suffix n-gram (``max_ngram`` down
    to ``min_ngram``) of the request's own history (prompt + generated).
    No extra forward passes, no state: determinism is what makes greedy
    speculative decode reproducible run to run.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(
                f"need 1 <= min_ngram <= max_ngram, got "
                f"[{min_ngram}, {max_ngram}]")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.stats = DrafterStats()

    def draft(self, history, k: int) -> np.ndarray:
        """Up to ``k`` proposed continuation tokens ([] when no n-gram of
        the history's suffix recurs earlier in the history)."""
        self.stats.calls += 1
        h = np.asarray(history, np.int32)
        n_hist = len(h)
        if k < 1 or n_hist < self.min_ngram + 1:
            self.stats.empty += 1
            return np.empty(0, np.int32)
        for n in range(min(self.max_ngram, n_hist - 1),
                       self.min_ngram - 1, -1):
            suffix = h[n_hist - n:]
            # windows over h[:-1]: candidate starts 0..n_hist-1-n, which
            # excludes the suffix itself and guarantees a continuation
            win = np.lib.stride_tricks.sliding_window_view(h[:-1], n)
            hits = np.flatnonzero((win == suffix).all(axis=1))
            if len(hits):
                i = int(hits[-1])              # most recent occurrence
                out = h[i + n:i + n + k].copy()
                self.stats.proposed += len(out)
                if not len(out):
                    self.stats.empty += 1
                return out
        self.stats.empty += 1
        return np.empty(0, np.int32)


class CallableDrafter:
    """Pluggable small-draft-model hook: wraps ``fn(history, k)`` -> token
    ids.  The callable may run an actual draft model (or an oracle in
    tests); whatever it proposes is truncated to ``k`` and verified by
    the target model — the engine's rollback machinery guarantees wrong
    drafts never publish to the prefix cache or corrupt the pool."""

    def __init__(self, fn):
        self.fn = fn
        self.stats = DrafterStats()

    def draft(self, history, k: int) -> np.ndarray:
        self.stats.calls += 1
        out = np.asarray(self.fn(history, k), np.int32).reshape(-1)[:k]
        self.stats.proposed += len(out)
        if not len(out):
            self.stats.empty += 1
        return out


def resolve_drafter(spec) -> object:
    """'ngram' | any object with a ``draft(history, k)`` method."""
    if isinstance(spec, str):
        if spec == "ngram":
            return NgramDrafter()
        raise ValueError(
            f"unknown drafter {spec!r} (have 'ngram'; or pass an object "
            f"with a draft(history, k) method, e.g. CallableDrafter)")
    if not callable(getattr(spec, "draft", None)):
        raise TypeError(f"drafter {spec!r} has no draft(history, k) method")
    return spec


# ---------------------------------------------------------------------------
# device-side verification (pure jnp, fused into the engine's jit)
# ---------------------------------------------------------------------------

def apply_top_k(logits: jax.Array, top_k: jax.Array,
                k_cap: Optional[int] = None) -> jax.Array:
    """Mask logits outside each row's top-k (top_k == 0 keeps the row's
    full vocabulary).  ``top_k`` broadcasts over ``logits.shape[:-1]``.

    Exactly-k semantics: ties at the k-th value break by lax.top_k's
    lowest-index-first order, so the candidate set never exceeds k (the
    old ``logits < kth`` comparison kept EVERY token tied at the
    threshold).  ``k_cap`` is a STATIC host-known bound on per-row k, so
    the partial sort is O(V log k_cap) instead of the full-vocab
    O(V log V) sort in the decode hot loop; it must dominate every
    per-row ``top_k`` (rows above it are effectively capped).
    """
    v = logits.shape[-1]
    cap = v if k_cap is None else max(min(int(k_cap), v), 1)
    flat = logits.reshape(-1, v)
    tk = jnp.broadcast_to(jnp.asarray(top_k, jnp.int32),
                          logits.shape[:-1]).reshape(-1)
    _, idx = jax.lax.top_k(flat, cap)                      # (R, cap)
    keep = jnp.arange(cap)[None, :] < tk[:, None]          # exactly k cols
    mask = jnp.zeros(flat.shape, bool).at[
        jnp.arange(flat.shape[0])[:, None], idx].set(keep)
    out = jnp.where(mask | (tk <= 0)[:, None], flat, -jnp.inf)
    return out.reshape(logits.shape)


def verify_tokens(logits: jax.Array, tokens: jax.Array, n_drafts: jax.Array,
                  key: jax.Array, temperatures: jax.Array,
                  top_k: Optional[jax.Array] = None,
                  k_cap: Optional[int] = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Resolve one speculative verify chunk on device.

    logits (B, K+1, V) from feeding ``tokens`` (B, K+1) — row layout
    ``[last committed token, d_1, ..., d_K]`` (positions past
    ``n_drafts[b]`` are padding and ignored); n_drafts (B,) int32;
    temperatures (B,) — 0 selects the greedy argmax chain for that row;
    top_k (B,) with static ``k_cap`` as in :func:`apply_top_k`.

    Returns ``(out_tokens (B, K+1), n_accepted (B,))``: row ``b`` emits
    ``out_tokens[b, :n_accepted[b] + 1]`` — the accepted draft prefix
    plus one correction (first rejection) or bonus (all accepted) token.
    Greedy rows reproduce non-speculative greedy decode token for token.
    """
    b, kp1, v = logits.shape
    k = kp1 - 1
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1)                   # (B, K+1)
    drafts = tokens[:, 1:]                                 # (B, K)
    proc = apply_top_k(logits, top_k[:, None], k_cap) \
        if top_k is not None else logits
    scaled = proc / jnp.maximum(temperatures, 1e-6)[:, None, None]
    logp = jax.nn.log_softmax(scaled, axis=-1)             # (B, K+1, V)
    ku, kr, kb = jax.random.split(key, 3)

    valid = jnp.arange(k)[None, :] < n_drafts[:, None]     # (B, K)
    p_draft = jnp.exp(jnp.take_along_axis(
        logp[:, :k], drafts[..., None], axis=-1))[..., 0]  # (B, K)
    accept = jnp.where((temperatures <= 0)[:, None],
                       drafts == greedy[:, :k],
                       jax.random.uniform(ku, (b, k)) < p_draft)
    ok = (accept & valid).astype(jnp.int32)
    n_acc = jnp.sum(jnp.cumprod(ok, axis=1), axis=1)       # (B,)

    # residual resample per draft position (delta-q: p minus the rejected
    # token, renormalized) and a bonus draw per position; the one draw
    # the row actually needs is selected below — fixed shapes keep this
    # a single fused executable
    res_logp = jnp.where(jax.nn.one_hot(drafts, v, dtype=bool),
                         -jnp.inf, logp[:, :k])
    resample = jax.random.categorical(
        kr, res_logp.reshape(b * k, v)).reshape(b, k) if k else \
        jnp.zeros((b, 0), jnp.int32)
    bonus = jax.random.categorical(
        kb, scaled.reshape(b * kp1, v)).reshape(b, kp1)

    rows = jnp.arange(b)
    rejected = n_acc < n_drafts
    # K == 0 (the unified ragged step's spec-off shape): there is nothing
    # to reject, so every row takes its bonus draw — indexing the
    # zero-width resample would be ill-formed even under a False where
    rep_sample = jnp.where(
        rejected, resample[rows, jnp.minimum(n_acc, k - 1)],
        bonus[rows, n_acc]) if k else bonus[rows, n_acc]
    rep = jnp.where(temperatures <= 0, greedy[rows, n_acc],
                    rep_sample).astype(jnp.int32)

    j = jnp.arange(kp1)[None, :]
    d_pad = jnp.concatenate([drafts, jnp.zeros((b, 1), drafts.dtype)],
                            axis=1)
    out = jnp.where(j < n_acc[:, None], d_pad,
                    jnp.where(j == n_acc[:, None], rep[:, None], 0))
    return out.astype(jnp.int32), n_acc.astype(jnp.int32)
