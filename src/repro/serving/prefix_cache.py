"""Content-addressed prefix cache over the paged int8-KV pool (DESIGN §10).

The paper's thesis — fewer quantization ops mean less information loss and
less energy (Eq. 1, Table 5) — made PR 3's KV blocks write-once with
immutable per-block power-of-two scale exponents.  Immutability is what
makes a block *content-addressable*: a full block's KV codes are a pure
function of (the prefix that preceded it, its own token ids, the Eq.-1
scale exponent), so a shared system prompt quantized once can serve every
request that reuses it with ZERO additional quantization ops.

Key derivation is a radix-style chained hash::

    key(block) = blake2b(key(parent) || scale_exp || block_token_ids)

so a block's identity encodes its WHOLE prefix — two blocks with the same
16 tokens but different histories never collide, and a lookup is a walk
down the chain that stops at the first miss (a broken chain can never hit
again later).  Only FULL blocks are addressable: a partial tail block's
content is still growing, so it stays private to its sequence.

This module is pure Python/numpy (no jax) and owns only the *naming*
layer: key<->block maps, per-sequence chain state for incremental
publishing, and hit/miss/COW accounting.  Reference counts, the idle-LRU
eviction set and the copy-on-write protocol live in
:class:`repro.serving.kv_pool.BlockPool`, which drives this cache through
``lookup`` / ``on_alloc`` / ``commit`` / ``release`` / ``forget``.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

__all__ = ["PrefixCache", "CacheStats", "block_key", "ROOT_KEY"]

# chain anchor for blocks with no parent (prefix starts at position 0);
# an arbitrary odd 64-bit constant, NOT a reachable blake2b output
ROOT_KEY = 0x9E3779B97F4A7C15


def block_key(parent_key: int, token_ids, scale_exp: int) -> int:
    """Chained content hash of one FULL block.

    Deterministic across processes (blake2b, not PYTHONHASHSEED-dependent
    ``hash()``), so cache behavior is reproducible run to run.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(int(parent_key).to_bytes(16, "little", signed=False))
    h.update(int(np.int32(scale_exp)).to_bytes(4, "little", signed=True))
    h.update(np.ascontiguousarray(
        np.asarray(token_ids, np.int32)).tobytes())
    return int.from_bytes(h.digest(), "little")


@dataclasses.dataclass
class CacheStats:
    """Hit/miss accounting at FULL-BLOCK granularity (partial tails are
    never looked up — they are not addressable)."""
    hits: int = 0              # full-block lookups served from cache
    misses: int = 0            # full-block lookups that missed
    hit_tokens: int = 0        # block_size * hits
    lookup_tokens: int = 0     # block_size * (hits + misses)
    cow_copies: int = 0        # shared blocks copied before a write
    published: int = 0         # blocks registered under a content key
    evictions: int = 0         # idle cached blocks reclaimed (LRU)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    @property
    def token_hit_rate(self) -> float:
        return self.hit_tokens / self.lookup_tokens \
            if self.lookup_tokens else 0.0


@dataclasses.dataclass
class _SeqChain:
    """Per-sequence incremental publishing state: the chain key reached so
    far and the token buffer of the block currently filling."""
    parent_key: int            # key of the last settled logical block
    scale_exp: int
    n_chained: int = 0         # logical blocks whose chain key is settled
    pos: int = 0               # absolute tokens recorded (committed)
    buf: list = dataclasses.field(default_factory=list)


class PrefixCache:
    """Key<->block naming layer; driven by :class:`BlockPool`."""

    def __init__(self, block_size: int):
        self.block_size = block_size
        self._by_key: dict[int, int] = {}      # content key -> pool block
        self._key_of: dict[int, int] = {}      # pool block -> content key
        self._seq: dict[int, _SeqChain] = {}
        self.stats = CacheStats()
        # optional obs hook (DESIGN §14): attached by the engine; every
        # emission is guarded on ``tracer is not None and tracer.enabled``
        self.tracer = None

    # -- queries ----------------------------------------------------------

    def __len__(self) -> int:
        return len(self._by_key)

    def key_of(self, block: int):
        """The content key a block is published under, or None."""
        return self._key_of.get(block)

    def is_published(self, block: int) -> bool:
        return block in self._key_of

    def lookup(self, token_ids, scale_exp: int
               ) -> tuple[list[int], list[int]]:
        """Longest cached chain of full blocks prefixing ``token_ids``.

        Returns (blocks, keys), both in logical order.  Pure query — no
        stats, no pinning; the pool counts hits/misses once, when a plan
        is actually consumed by an allocation (planning is retried every
        admission attempt while the head of the queue is blocked, and
        retries must not inflate the hit rate).
        """
        token_ids = np.asarray(token_ids, np.int32)
        blocks: list[int] = []
        keys: list[int] = []
        parent = ROOT_KEY
        bs = self.block_size
        for b in range(len(token_ids) // bs):
            key = block_key(parent, token_ids[b * bs:(b + 1) * bs],
                            scale_exp)
            blk = self._by_key.get(key)
            if blk is None:
                break
            blocks.append(blk)
            keys.append(key)
            parent = key
        return blocks, keys

    # -- lifecycle (called by BlockPool) ----------------------------------

    def on_alloc(self, seq_id: int, hit_keys: list[int], n_full_lookups: int,
                 scale_exp: int) -> None:
        """Record an allocation that attached ``hit_keys`` after looking up
        ``n_full_lookups`` full blocks, and start the sequence's chain."""
        bs = self.block_size
        self.stats.hits += len(hit_keys)
        self.stats.misses += n_full_lookups - len(hit_keys)
        self.stats.hit_tokens += len(hit_keys) * bs
        self.stats.lookup_tokens += n_full_lookups * bs
        tr = self.tracer
        if tr is not None and tr.enabled:
            # one summary event per CONSUMED lookup (planning retries are
            # side-effect free and never reach here, mirroring the stats)
            tr.event("cache.lookup", "cache", args={
                "seq": seq_id, "hit_blocks": len(hit_keys),
                "miss_blocks": n_full_lookups - len(hit_keys),
                "hit_tokens": len(hit_keys) * bs})
        self._seq[seq_id] = _SeqChain(
            parent_key=hit_keys[-1] if hit_keys else ROOT_KEY,
            scale_exp=scale_exp,
            n_chained=len(hit_keys),
            pos=len(hit_keys) * bs)

    def commit(self, pool, seq_id: int, start: int, token_ids) -> None:
        """Record that KV rows for ``token_ids`` at absolute positions
        ``start..start+len-1`` are now resident; publish every block this
        completes.  Re-commits of already-recorded positions (the COW
        re-feed of a fully-cached prompt's last token) are ignored — the
        rows are bit-identical by construction."""
        st = self._seq.get(seq_id)
        if st is None:
            return
        token_ids = np.asarray(token_ids, np.int32)
        if start > st.pos:
            raise AssertionError(
                f"seq {seq_id}: commit at {start} leaves a gap after "
                f"{st.pos} recorded tokens")
        if start + len(token_ids) <= st.pos:
            return
        st.buf.extend(int(t) for t in token_ids[st.pos - start:])
        st.pos += len(token_ids) - (st.pos - start)
        bs = self.block_size
        while len(st.buf) >= bs:
            blk_tokens = st.buf[:bs]
            del st.buf[:bs]
            key = block_key(st.parent_key, blk_tokens, st.scale_exp)
            blk = pool.seq_blocks(seq_id)[st.n_chained]
            # publish only private, never-published blocks; a concurrent
            # identical prompt may have published this key first, in which
            # case this sequence's physical copy simply stays anonymous
            if key not in self._by_key and blk not in self._key_of \
                    and pool.refcount[blk] == 1:
                self._by_key[key] = blk
                self._key_of[blk] = key
                self.stats.published += 1
                tr = self.tracer
                if tr is not None and tr.enabled:
                    tr.event("cache.publish", "cache", args={
                        "seq": seq_id, "block": blk,
                        "chain_idx": st.n_chained})
            st.parent_key = key
            st.n_chained += 1

    def assert_retractable(self, seq_id: int, n_tokens_keep: int) -> None:
        """Rollback safety (DESIGN §11): a sequence may only retract rows
        it never committed — the publish chain must not extend past the
        keep point, or a rejected speculative token could already have
        leaked into a content key."""
        st = self._seq.get(seq_id)
        if st is not None and st.pos > n_tokens_keep:
            raise AssertionError(
                f"seq {seq_id}: retract to {n_tokens_keep} rows but "
                f"{st.pos} tokens already committed")

    def release(self, seq_id: int) -> None:
        """Drop the sequence's chain state (its published blocks keep
        their keys — that is the whole point)."""
        self._seq.pop(seq_id, None)

    def forget(self, block: int) -> None:
        """Unregister an idle cached block being reclaimed (LRU evict)."""
        key = self._key_of.pop(block)
        del self._by_key[key]
        self.stats.evictions += 1
        tr = self.tracer
        if tr is not None and tr.enabled:
            tr.event("cache.forget", "cache", args={"block": block})

    def flush(self) -> int:
        """Drop every key (pool moves the idle blocks to the free stack);
        returns the number of keys dropped.  Chain state must be empty —
        flushing under live sequences would desync publishing."""
        assert not self._seq, "flush with live sequence chains"
        n = len(self._by_key)
        self._by_key.clear()
        self._key_of.clear()
        return n

    # -- invariants -------------------------------------------------------

    def check_invariants(self, pool) -> None:
        assert len(self._by_key) == len(self._key_of), \
            "key<->block maps out of sync"
        for key, blk in self._by_key.items():
            assert self._key_of.get(blk) == key, \
                f"block {blk} key mapping not bijective"
        for sid, st in self._seq.items():
            assert sid in pool.seq_ids(), f"chain for unknown seq {sid}"
            assert len(st.buf) < self.block_size
            assert st.pos == st.n_chained * self.block_size + len(st.buf)
