"""Fixed-size state-slab substrate for recurrent sequence state (§16).

Recurrent models (RWKV6, Mamba2, and the Mamba layers of zamba2's hybrid
stack) carry O(1) state per sequence — a ``(H, dk, dv)`` WKV matrix plus
token-shift streams, or a ``(H, P, N)`` SSD state plus a conv tail —
instead of a growing KV history.  The paper's dataflow thesis is at its
strongest here: the whole state is re-quantized ONCE per engine step on a
per-slab power-of-two grid (Eq. 1), versus one quantize per token per
layer for an attention KV append, and the requant count per token is
*independent of context length*.

:class:`StateSlabPool` is the allocator for that substrate: each live
sequence owns exactly ONE slab (a single-unit "table" on the shared
:class:`repro.serving.arena.Arena` core), slab 0 is the trash slab that
masked batch lanes read and write harmlessly, and the slab's scale
exponent is fixed at admission.  Slabs never extend, never COW, never
publish into a prefix cache — recurrent state is a lossy summary of the
prefix, not content-addressable codes — so :meth:`extend`,
:meth:`retract`, and :meth:`cow` raise ``BlockPoolError`` outright; the
scheduler-level guards (``grow_for_spec`` / COW on a fixed-state
sequence) give the same error a step earlier with scheduling context.

The device arrays live in ``models.model.init_paged_state`` (one
(L, S, ...) arena per state component); this module owns the map, in
plain Python/numpy, so the slab property tests run without a model.
"""
from __future__ import annotations

from repro.serving.arena import (Arena, BlockPoolError, PoolStats,
                                 TRASH_UNIT)

__all__ = ["StateSlabPool", "BlockPoolError", "PoolStats", "TRASH_SLAB"]

TRASH_SLAB = TRASH_UNIT


class StateSlabPool(Arena):
    """Fixed-capacity pool of whole-state slabs, one per live sequence.

    Invariants (checked by :meth:`check_invariants`):

    * slab 0 is the TRASH slab: never allocated, never freed.
    * free ∪ live partition the non-trash slabs (no cached tier —
      recurrent state is never shared or republished).
    * every live sequence owns exactly one slab; refcount is 0 or 1.
    * a slab's scale exponent is fixed from alloc to free: the state is
      requantized once per engine step onto the SAME po2 grid, so the
      exponent is per-sequence metadata, not per-write.
    """

    unit_noun = "slab"
    EVT_FREE = "pool.slab_free"
    EVT_EVICT = "pool.slab_evict"

    def __init__(self, num_slabs: int, *, scale_exp: int = 0):
        super().__init__(num_slabs, scale_exp=scale_exp)
        self.num_slabs = num_slabs

    # -- alloc / free -----------------------------------------------------

    def alloc_slab(self, seq_id: int, *, scale_exp: int | None = None) -> int:
        """Allocate the single state slab for a new sequence."""
        if seq_id in self._seqs:
            raise BlockPoolError(f"sequence {seq_id} already allocated")
        exp = self.default_scale_exp if scale_exp is None else scale_exp
        if not self._free:
            self.stats.alloc_failures += 1
            raise BlockPoolError(
                f"pool exhausted: need 1 slab, {self.n_free} allocatable")
        slab = self._take(exp)
        self._seqs[seq_id] = [slab]
        self._emit("pool.slab_alloc", {
            "seq": seq_id, "slab": slab, "free": self.n_free})
        return slab

    # -- views ------------------------------------------------------------

    def slab_of(self, seq_id: int) -> int:
        """The sequence's slab id (raises on unknown sequence)."""
        return self.seq_blocks(seq_id)[0]

    def slab_exp(self, seq_id: int) -> int:
        """The sequence's fixed Eq.-1 scale exponent."""
        return int(self.scale_exp[self.slab_of(seq_id)])

    # -- forbidden growing-substrate operations ---------------------------

    def extend(self, seq_id: int, n_tokens_total: int):
        raise BlockPoolError(
            f"state slabs are fixed-size: sequence {seq_id} cannot extend "
            f"(recurrent state does not grow with context)")

    def retract(self, seq_id: int, n_tokens_keep: int):
        raise BlockPoolError(
            f"state slabs are fixed-size: sequence {seq_id} cannot retract "
            f"(recurrent state cannot roll back rejected drafts)")

    def cow(self, seq_id: int, logical_idx: int):
        raise BlockPoolError(
            f"state slabs are never shared: COW of sequence {seq_id} is "
            f"meaningless (no prefix cache on the recurrent substrate)")

    # -- invariants -------------------------------------------------------

    def check_invariants(self) -> None:
        """Raises AssertionError on any broken slab-pool invariant."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate slabs on free list"
        assert TRASH_SLAB not in free, "trash slab on the free list"
        live: set[int] = set()
        for sid, slabs in self._seqs.items():
            assert len(slabs) == 1, f"seq {sid} owns {len(slabs)} slabs"
            slab = slabs[0]
            assert slab != TRASH_SLAB, f"seq {sid} owns the trash slab"
            assert slab not in live, f"slab {slab} owned by two sequences"
            assert self.refcount[slab] == 1, \
                f"slab {slab} refcount {self.refcount[slab]} != 1"
            live.add(slab)
        assert not (live & free), "live slab also free"
        assert live | free == set(range(1, self.num_slabs)), \
            "orphan slabs (neither free nor live)"
        assert (self.refcount <= 1).all(), "shared slab"
        assert self.stats.peak_live <= self.num_slabs - 1
