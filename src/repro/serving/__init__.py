"""Continuous-batching serving engine on a paged int8-KV block pool.

Three layers (DESIGN §9):

* :mod:`repro.serving.kv_pool`   — host-side block allocator over the
  device-resident pool (``models.model.init_paged_cache``): fixed-size
  blocks of int8 Eq.-1 codes + per-block power-of-two scale exponents,
  per-sequence block tables, alloc/extend/free/evict, utilization stats.
* :mod:`repro.serving.scheduler` — request lifecycle
  WAITING→PREFILL→DECODE→DONE, FCFS slot-based continuous batching,
  chunked prefill under a per-step token budget, recompute preemption
  (youngest-first, so the oldest request always progresses).
* :mod:`repro.serving.engine`    — the step loop: jitted paged
  prefill/decode with fixed slot shapes, greedy + temperature/top-k
  sampling, per-request stop/max-tokens, throughput + latency + hwcost
  report.
* :mod:`repro.serving.prefix_cache` — content-addressed prefix cache
  (DESIGN §10): full blocks keyed by a radix-style chained hash of
  (parent key, block token ids, scale exponent), shared read-only across
  sequences with per-block refcounts, copy-on-write on divergence, and
  LRU eviction of idle cached blocks only under allocation pressure.
* :mod:`repro.serving.spec`      — speculative decoding (DESIGN §11):
  model-free n-gram/prompt-lookup self-drafting (plus a pluggable
  draft-model hook) and the fused rejection-sampling verifier; the
  engine verifies K drafts in one (n_slots, K+1) paged step, commits
  only accepted tokens and retracts the rejected tail's blocks, so a
  rejected speculative row can never publish to the prefix cache.
* :mod:`repro.serving.arena` / :mod:`repro.serving.state_pool` /
  :mod:`repro.serving.substrate` — the substrate split (DESIGN §16):
  a shared fixed-capacity :class:`Arena` core underneath BOTH sequence
  substrates — the growing attention block tables above, and the
  fixed-size recurrent state slabs (:class:`StateSlabPool`) that serve
  RWKV6 / Mamba2 state, one quantized whole-state slab per sequence,
  re-quantized once per engine step.  ``substrate_for(cfg)`` is the
  single routing decision the pool, scheduler, and engine all consult.
"""
from repro.serving.arena import Arena, PoolStats
from repro.serving.engine import ServingEngine
from repro.serving.kv_pool import TRASH_BLOCK, BlockPool, BlockPoolError
from repro.serving.prefix_cache import CacheStats, PrefixCache
from repro.serving.scheduler import Request, RequestState, Scheduler
from repro.serving.spec import CallableDrafter, NgramDrafter
from repro.serving.state_pool import TRASH_SLAB, StateSlabPool
from repro.serving.substrate import (ATTENTION, HYBRID, RECURRENT,
                                     SubstrateSpec, substrate_for)

__all__ = ["ServingEngine", "BlockPool", "BlockPoolError", "CacheStats",
           "PrefixCache", "Request", "RequestState", "Scheduler",
           "CallableDrafter", "NgramDrafter", "Arena", "PoolStats",
           "StateSlabPool", "SubstrateSpec", "substrate_for",
           "ATTENTION", "RECURRENT", "HYBRID", "TRASH_BLOCK",
           "TRASH_SLAB"]
