"""Continuous-batching serving engine on a paged int8-KV block pool.

Three layers (DESIGN §9):

* :mod:`repro.serving.kv_pool`   — host-side block allocator over the
  device-resident pool (``models.model.init_paged_cache``): fixed-size
  blocks of int8 Eq.-1 codes + per-block power-of-two scale exponents,
  per-sequence block tables, alloc/extend/free/evict, utilization stats.
* :mod:`repro.serving.scheduler` — request lifecycle
  WAITING→PREFILL→DECODE→DONE, FCFS slot-based continuous batching,
  chunked prefill under a per-step token budget, recompute preemption
  (youngest-first, so the oldest request always progresses).
* :mod:`repro.serving.engine`    — the step loop: jitted paged
  prefill/decode with fixed slot shapes, greedy + temperature/top-k
  sampling, per-request stop/max-tokens, throughput + latency + hwcost
  report.
* :mod:`repro.serving.prefix_cache` — content-addressed prefix cache
  (DESIGN §10): full blocks keyed by a radix-style chained hash of
  (parent key, block token ids, scale exponent), shared read-only across
  sequences with per-block refcounts, copy-on-write on divergence, and
  LRU eviction of idle cached blocks only under allocation pressure.
* :mod:`repro.serving.spec`      — speculative decoding (DESIGN §11):
  model-free n-gram/prompt-lookup self-drafting (plus a pluggable
  draft-model hook) and the fused rejection-sampling verifier; the
  engine verifies K drafts in one (n_slots, K+1) paged step, commits
  only accepted tokens and retracts the rejected tail's blocks, so a
  rejected speculative row can never publish to the prefix cache.
"""
from repro.serving.engine import ServingEngine
from repro.serving.kv_pool import BlockPool, BlockPoolError
from repro.serving.prefix_cache import CacheStats, PrefixCache
from repro.serving.scheduler import Request, RequestState, Scheduler
from repro.serving.spec import CallableDrafter, NgramDrafter

__all__ = ["ServingEngine", "BlockPool", "BlockPoolError", "CacheStats",
           "PrefixCache", "Request", "RequestState", "Scheduler",
           "CallableDrafter", "NgramDrafter"]
