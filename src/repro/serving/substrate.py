"""Substrate protocol: what kind of sequence state an architecture keeps
(DESIGN §16).

A *substrate* is the contract between a model family and the serving
stack: how per-sequence state is stored (growing KV block tables vs a
fixed-size state slab), which scheduler moves are legal on it
(extend / speculative grow / COW vs snapshot-preemption), and which
engine features it supports.  ``substrate_for(cfg)`` is the single
routing decision; pool, scheduler, and engine all consult the same spec
instead of re-deriving family checks.

Three substrates exist:

* ``attention`` — dense/MoE/VLM transformers: per-token KV rows on the
  growing block-table substrate (:class:`~repro.serving.kv_pool.
  BlockPool`); supports speculative decoding, the content-addressed
  prefix cache, and the ragged unified step.
* ``recurrent`` — pure recurrent models (RWKV6): O(1) state on the
  fixed-slab substrate (:class:`~repro.serving.state_pool.
  StateSlabPool`); no spec (state cannot retract rejected drafts), no
  prefix cache (state is a lossy summary, not content-addressable), no
  ragged step (the batched recurrent step is already shape-stable).
* ``hybrid`` — zamba2-style stacks: Mamba layers on slabs AND the shared
  attention block on block tables, in the same jitted step.  The
  fixed-state restrictions win wherever they conflict (no spec / prefix
  cache / ragged), and preemption must recompute (the KV half recomputes
  anyway, re-deriving the state for free).
"""
from __future__ import annotations

import dataclasses

__all__ = ["SubstrateSpec", "ATTENTION", "RECURRENT", "HYBRID",
           "substrate_for"]


@dataclasses.dataclass(frozen=True)
class SubstrateSpec:
    """Static capabilities of a sequence-state substrate."""

    kind: str                    # 'attention' | 'recurrent' | 'hybrid'
    grows: bool                  # per-token KV rows → block tables grow
    fixed_state: bool            # owns a fixed-size state slab
    supports_spec: bool          # speculative decode (needs retract)
    supports_prefix_cache: bool  # content-addressed block sharing
    supports_ragged: bool        # flattened unified dispatch (DESIGN §12)

    @property
    def snapshot_preempt(self) -> bool:
        """Preemption saves/restores the slab instead of recomputing —
        only sound when the slab IS the whole sequence state (pure
        recurrent).  Hybrid must recompute: its KV half is dropped on
        eviction and re-prefilling re-derives the Mamba state anyway."""
        return self.fixed_state and not self.grows


ATTENTION = SubstrateSpec(
    kind="attention", grows=True, fixed_state=False,
    supports_spec=True, supports_prefix_cache=True, supports_ragged=True)

RECURRENT = SubstrateSpec(
    kind="recurrent", grows=False, fixed_state=True,
    supports_spec=False, supports_prefix_cache=False, supports_ragged=False)

HYBRID = SubstrateSpec(
    kind="hybrid", grows=True, fixed_state=True,
    supports_spec=False, supports_prefix_cache=False, supports_ragged=False)


def substrate_for(cfg) -> SubstrateSpec:
    """The serving substrate for a model config (by family)."""
    if cfg.family == "ssm":
        return RECURRENT
    if cfg.family == "hybrid":
        return HYBRID
    return ATTENTION
