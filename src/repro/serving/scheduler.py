"""Request lifecycle + slot-based continuous batching (DESIGN §9).

Pure host-side bookkeeping — no jax imports — so the fairness and
no-starvation property tests drive it with a fake model.

Lifecycle::

    WAITING --admit (FCFS, slot + blocks available)--> PREFILL
    PREFILL --chunked prefill done, first token sampled--> DECODE
    DECODE  --stop token / max-new-tokens / model-len--> DONE
    PREFILL/DECODE --pool pressure (recompute preemption)--> WAITING

Scheduling policy:

* **FCFS with head-of-line blocking**: requests admit strictly in arrival
  order; if the head of the queue doesn't fit (no free slot or not enough
  pool blocks) nothing behind it admits either.  A later small request can
  therefore never starve an earlier large one.
* **Chunked prefill**: prompts are fed in ``chunk``-token pieces under a
  per-engine-step token budget, so admitting a long prompt never stalls
  the decode batch for more than one chunk.
* **Recompute preemption, youngest first**: when a decode step cannot get
  a block, the most recently *admitted* request is evicted (its block
  references released, its prompt+generated tokens re-queued for
  re-prefill).  The oldest running request is only ever preempted when it
  is the sole runner, so the oldest request always makes progress — no
  livelock, no starvation.  Generated tokens survive preemption: the
  re-prefill feed is ``prompt + generated`` and decoding resumes where it
  left off.
* **Prefix-cache admission** (DESIGN §10): with the pool's
  content-addressed cache enabled, admission plans the feed against the
  cache, ATTACHES the longest cached full-block chain (shared, read-only,
  zero quantization ops) and starts chunked prefill at the first uncached
  token.  A fully-cached feed still re-feeds its last token (logits are
  needed to sample), which copy-on-writes the last shared block; a
  preempted request releases references instead of freeing, so its
  published blocks survive for the resume to re-attach.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

from repro.serving.kv_pool import BlockPool, BlockPoolError
from repro.serving.state_pool import StateSlabPool
from repro.serving.substrate import ATTENTION, SubstrateSpec

__all__ = ["Request", "RequestState", "Scheduler", "chunk_bucket"]


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


def chunk_bucket(n: int, chunk: int, *, floor: int = 4) -> int:
    """Shape bucket for a prefill piece of ``n`` real tokens: the full
    ``chunk`` when it fills one, else the smallest power of two >= n
    (floored) — so jit sees at most log2(chunk) distinct prefill widths."""
    if n >= chunk:
        return chunk
    b = floor
    while b < n:
        b <<= 1
    return min(b, chunk)


@dataclasses.dataclass
class Request:
    """One generation request plus its runtime bookkeeping."""

    rid: int
    prompt: np.ndarray                    # int32 token ids (immutable)
    max_new_tokens: int
    temperature: float = 0.0              # 0 -> greedy
    top_k: int = 0                        # 0 -> full vocab (engine hook)
    stop_token: Optional[int] = None
    arrival: float = 0.0                  # seconds on the engine clock

    # runtime (managed by the scheduler/engine)
    state: RequestState = RequestState.WAITING
    slot: Optional[int] = None
    generated: list = dataclasses.field(default_factory=list)
    feed: Optional[np.ndarray] = None     # tokens to (re-)prefill
    n_prefilled: int = 0                  # feed tokens whose KV is resident
    n_ctx: int = 0                        # KV rows live in the pool
    cached_tokens: int = 0                # prefill tokens skipped via cache
    # fixed-slab substrate (§16): host copy of the slab state captured at
    # preemption — resume restores it instead of recomputing the prefix
    snapshot: Optional[dict] = None
    preemptions: int = 0
    t_admit: Optional[float] = None
    t_first: Optional[float] = None       # first token sampled (TTFT)
    t_done: Optional[float] = None

    @property
    def n_generated(self) -> int:
        return len(self.generated)

    def finished_by(self, token: int, max_model_len: int) -> bool:
        """Would sampling ``token`` complete this request?"""
        if self.stop_token is not None and token == self.stop_token:
            return True
        if self.n_generated + 1 >= self.max_new_tokens:
            return True
        # +1: the next decode step would need to WRITE this token's KV row
        return len(self.prompt) + self.n_generated + 1 >= max_model_len


class Scheduler:
    """Slot-based continuous batching over the sequence-state substrates.

    ``substrate`` (DESIGN §16) selects which moves are legal: the growing
    attention substrate schedules over ``pool`` (a :class:`BlockPool`);
    fixed-state substrates additionally (hybrid) or exclusively
    (recurrent, ``pool=None``) admit against ``state_pool`` — one slab
    per live sequence, allocated at admission, never grown.  Preemption
    on the pure-recurrent substrate snapshots the slab (via the engine's
    ``snapshot_fn`` hook) so the resume restores O(1) state instead of
    recomputing the whole prefix."""

    def __init__(self, pool: Optional[BlockPool], *, n_slots: int,
                 chunk: int, max_model_len: int,
                 prefill_token_budget: Optional[int] = None,
                 state_pool: Optional[StateSlabPool] = None,
                 substrate: Optional[SubstrateSpec] = None):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        substrate = ATTENTION if substrate is None else substrate
        if pool is not None and \
                max_model_len > (pool.num_blocks - 1) * pool.block_size:
            raise ValueError(
                f"max_model_len {max_model_len} exceeds pool capacity "
                f"{(pool.num_blocks - 1) * pool.block_size} tokens — a "
                f"lone max-length request could deadlock")
        if substrate.grows and pool is None:
            raise ValueError(
                f"{substrate.kind} substrate grows block tables — needs a "
                f"BlockPool")
        if substrate.fixed_state and state_pool is None:
            raise ValueError(
                f"{substrate.kind} substrate keeps fixed-size state — "
                f"needs a StateSlabPool")
        self.pool = pool
        self.state_pool = state_pool
        self.substrate = substrate
        self.n_slots = n_slots
        self.chunk = chunk
        self.max_model_len = max_model_len
        self.prefill_token_budget = prefill_token_budget or chunk
        self.nbmax = (-(-max_model_len // pool.block_size)
                      if pool is not None else 0)
        self.waiting: list[Request] = []      # kept sorted by (arrival, rid)
        self.slots: list[Optional[Request]] = [None] * n_slots
        self.done: list[Request] = []
        self.admission_log: list[int] = []    # rids in admission order
        # optional obs hook (DESIGN §14): the engine attaches its Tracer.
        # Request-timeline marks (admit / preempt / done) are always-on
        # when a tracer is attached — they are a few floats per request
        # and the source of the report's trace-derived latency section;
        # ring events additionally check ``tracer.enabled``.
        self.tracer = None
        # engine hook (§16): captures a host snapshot of a request's slab
        # at preemption on snapshot-preempt substrates (pure recurrent)
        self.snapshot_fn = None

    # -- queue ------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) + req.max_new_tokens > self.max_model_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new_tokens} exceeds max_model_len "
                f"{self.max_model_len}")
        req.state = RequestState.WAITING
        self._enqueue(req)

    def _enqueue(self, req: Request) -> None:
        key = (req.arrival, req.rid)
        i = 0
        while i < len(self.waiting) and \
                (self.waiting[i].arrival, self.waiting[i].rid) <= key:
            i += 1
        self.waiting.insert(i, req)

    @property
    def idle(self) -> bool:
        return not self.waiting and all(s is None for s in self.slots)

    def active(self) -> list[Request]:
        return [r for r in self.slots if r is not None]

    # -- admission (FCFS, head-of-line blocking) --------------------------

    def admit(self, now: float) -> list[Request]:
        admitted = []
        while self.waiting:
            try:
                slot = self.slots.index(None)
            except ValueError:
                break
            req = self.waiting[0]
            req.feed = np.concatenate(
                [req.prompt, np.asarray(req.generated, np.int32)]) \
                if req.generated else req.prompt
            if self.state_pool is not None and self.state_pool.n_free < 1:
                break                         # no slab: FCFS head blocks
            plan = None
            if self.pool is not None:
                plan = self.pool.plan_seq(len(req.feed), token_ids=req.feed)
                if not plan.feasible:
                    break                     # head blocks the line: FCFS
            self.waiting.pop(0)
            if self.pool is not None:
                self.pool.alloc_seq(req.rid, len(req.feed), plan=plan)
            if self.state_pool is not None:
                self.state_pool.alloc_slab(req.rid)
            req.state = RequestState.PREFILL
            req.slot = slot
            if req.snapshot is not None:
                # fixed-slab resume (§16): the engine restores the host
                # snapshot into the fresh slab; prefill resumes at the
                # snapshot's absorbed-token count (always len(feed) - 1:
                # the last token is re-fed so the engine gets a logits
                # row to sample from, exactly like a fully-cached feed)
                hit = min(int(req.snapshot["n_ctx"]), len(req.feed) - 1)
            else:
                # cached-prefix fast path (DESIGN §10): KV rows for the
                # hit chain are already resident — chunked prefill starts
                # at the first uncached token.  A fully-cached feed still
                # re-feeds its last token (the engine needs its logits
                # row to sample), COWing the last shared block before
                # the write.
                hit = min(plan.hit_tokens, len(req.feed) - 1) \
                    if plan is not None else 0
                req.cached_tokens += hit
            req.n_prefilled = hit
            req.n_ctx = hit
            req.t_admit = now if req.t_admit is None else req.t_admit
            self.slots[slot] = req
            self.admission_log.append(req.rid)
            admitted.append(req)
            tr = self.tracer
            if tr is not None:
                tr.req_mark(req.rid, "admit", now)
                if tr.enabled:
                    # "order" pins the global admission index into the
                    # flight recorder's decision stream: a replay that
                    # admits the same rids in a different order diffs
                    # even if the ring dropped earlier events
                    tr.event("sched.admit", "sched", ts=now, args={
                        "rid": req.rid, "slot": slot,
                        "order": len(self.admission_log) - 1,
                        "feed_tokens": len(req.feed),
                        "cached_tokens": hit,
                        "resume": req.preemptions > 0})
        return admitted

    # -- prefill ----------------------------------------------------------

    def prefill_jobs(self) -> list[Request]:
        """PREFILL-state requests in admission (slot-stable FCFS) order."""
        jobs = [r for r in self.slots
                if r is not None and r.state is RequestState.PREFILL]
        jobs.sort(key=lambda r: (r.t_admit, r.rid))
        return jobs

    # -- decode -----------------------------------------------------------

    def decode_reqs(self) -> list[Request]:
        return [r for r in self.slots
                if r is not None and r.state is RequestState.DECODE]

    def mixed_work(self) -> list[Request]:
        """One MIXED work-list for a unified ragged step (DESIGN §12):
        every live request exactly once — PREFILL jobs first (admission
        order, each contributing one chunk), then DECODE requests (each
        contributing its fed token plus any speculative tail).  Replaces
        the phase-ordered prefill-then-decode dispatch; a request is in
        exactly one state, so the list length never exceeds n_slots."""
        return self.prefill_jobs() + self.decode_reqs()

    def grow_for_decode(self, req: Request, now: float,
                        n_tokens: int = 1) -> bool:
        """Ensure ``req`` owns blocks for KV rows ``n_ctx .. n_ctx +
        n_tokens - 1`` (the incoming token's position, plus the
        speculative tail when ``n_tokens > 1``).  On pool pressure, evict
        the youngest-admitted running request and retry; returns False
        iff ``req`` itself was the youngest and got preempted (skip its
        decode this step).  Non-growing substrates (§16) are a no-op:
        the state slab already holds every future token."""
        if not self.substrate.grows:
            return True
        while True:
            try:
                self.pool.extend(req.rid, req.n_ctx + n_tokens)
                return True
            except BlockPoolError:
                victim = max(self.active(),
                             key=lambda r: (r.t_admit, r.rid))
                self.preempt(victim, now)
                if victim is req:
                    return False

    def grow_for_spec(self, req: Request, now: float,
                      n_draft: int) -> Optional[int]:
        """Variable tokens-per-step growth for a speculative verify step
        writing ``1 + n_draft`` KV rows (DESIGN §11).  The speculative
        tail is OPTIONAL: under pool pressure the draft count degrades
        (fewer tokens verified this step) before any peer is preempted —
        only the mandatory single-token growth falls back to the §9
        youngest-first preemption retry.  Returns the granted draft
        count, or None iff ``req`` itself ended up preempted."""
        if self.substrate.fixed_state:
            raise BlockPoolError(
                f"speculative growth on the {self.substrate.kind} "
                f"substrate: sequence {req.rid} keeps fixed-size recurrent "
                f"state, which cannot retract rejected draft tokens "
                f"(spec decode needs the growing attention substrate)")
        bs = self.pool.block_size
        have = self.pool.n_blocks_of(req.rid) * bs
        spare = have + self.pool.n_free * bs - (req.n_ctx + 1)
        k = max(min(n_draft, spare), 0)
        tr = self.tracer
        if k < n_draft and tr is not None and tr.enabled:
            # pool pressure degraded the speculative tail: fewer tokens
            # verified this step instead of preempting a peer
            tr.event("sched.spec_degrade", "sched", ts=now, args={
                "rid": req.rid, "requested": n_draft, "granted": k})
        if not self.grow_for_decode(req, now, n_tokens=1 + k):
            return None
        return k

    def cow_for_prefill(self, req: Request, logical_idx: int,
                        now: float):
        """Copy-on-write the shared block at ``logical_idx`` before the
        engine writes KV rows into it, with the same youngest-first
        preemption retry as decode growth.  Returns the (src, dst) block
        pair — the ENGINE must copy the device rows — or None iff ``req``
        itself was preempted (skip its prefill this step)."""
        if self.substrate.fixed_state:
            raise BlockPoolError(
                f"copy-on-write on the {self.substrate.kind} substrate: "
                f"sequence {req.rid} owns a private state slab, never a "
                f"shared block (fixed-state substrates have no prefix "
                f"cache to COW from)")
        while True:
            try:
                return self.pool.cow(req.rid, logical_idx)
            except BlockPoolError:
                victim = max(self.active(),
                             key=lambda r: (r.t_admit, r.rid))
                tr = self.tracer
                if tr is not None and tr.enabled:
                    tr.event("sched.cow_retry", "sched", ts=now, args={
                        "rid": req.rid, "idx": logical_idx,
                        "victim": victim.rid})
                self.preempt(victim, now)
                if victim is req:
                    return None

    def preempt(self, req: Request, now: float) -> None:
        """Recompute preemption: release block references (the request's
        PUBLISHED blocks stay cached for the resume to re-attach), requeue
        (arrival order keeps its place near the front), keep generated
        tokens for the resume feed.

        Snapshot-preempt substrates (§16, pure recurrent) capture a host
        copy of the slab through the engine's ``snapshot_fn`` first: the
        O(1) state IS the whole prefix summary, so the resume restores it
        instead of re-prefilling hundreds of tokens."""
        snap = (self.substrate.snapshot_preempt
                and self.snapshot_fn is not None)
        if snap:
            req.snapshot = self.snapshot_fn(req)
        tr = self.tracer
        if tr is not None:
            tr.req_preempt(req.rid)
            if tr.enabled:
                args = {"rid": req.rid, "slot": req.slot,
                        "n_ctx": req.n_ctx,
                        "preemptions": req.preemptions + 1}
                if snap:
                    args["snapshot"] = True
                tr.event("sched.preempt", "sched", ts=now, args=args)
        if self.pool is not None:
            self.pool.evict(req.rid)
        if self.state_pool is not None:
            self.state_pool.evict(req.rid)
        self.slots[req.slot] = None
        req.slot = None
        req.state = RequestState.WAITING
        req.n_prefilled = 0
        req.n_ctx = 0
        req.preemptions += 1
        self._enqueue(req)

    def finish(self, req: Request, now: float) -> None:
        if self.pool is not None:
            self.pool.free_seq(req.rid)
        if self.state_pool is not None:
            self.state_pool.free_seq(req.rid)
        req.snapshot = None
        self.slots[req.slot] = None
        req.slot = None
        req.state = RequestState.DONE
        req.t_done = now
        self.done.append(req)
        tr = self.tracer
        if tr is not None:
            # the timeline's done mark reuses the SAME clock value as
            # req.t_done, so trace-derived TPOT/e2e reproduce the legacy
            # report's request-timestamp math exactly
            tr.req_done(req.rid, now, req.n_generated)
            if tr.enabled:
                tr.event("sched.finish", "sched", ts=now, args={
                    "rid": req.rid, "n_generated": req.n_generated})
