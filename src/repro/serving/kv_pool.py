"""Host-side allocator for the paged KV block pool (DESIGN §9, §10, §16).

The device arrays live in ``models.model.init_paged_cache`` (one
(L, NB, BS, KVH, D) arena per K and V); this module owns the *map*: which
pool blocks back which sequence, in which logical order, at which
power-of-two scale exponent, and — with the content-addressed prefix
cache enabled — which blocks are SHARED between sequences.  Everything
here is plain Python/numpy — no jax — so the scheduler property tests run
without a model.

Since PR 10 the allocator core (free stack, refcounts, per-unit scale
exponents, stats, tracer hook) lives in :class:`repro.serving.arena.Arena`
and is shared with the fixed-size state-slab substrate
(:class:`repro.serving.state_pool.StateSlabPool`); ``BlockPool`` is the
growing block-table substrate that layers the prefix cache, idle-LRU
reclaim, copy-on-write, and speculative retract on top.

Ownership model (DESIGN §10).  PR 3's one-owner rule is gone; every
non-trash block is in exactly one of three states:

* **free**    — refcount 0, no content key, on the LIFO free stack;
* **cached**  — refcount 0 but published under a content key: it stays
  resident (its int8 codes are reusable by any future sequence with the
  same prefix) on an idle-LRU and is reclaimed only under allocation
  pressure, oldest first;
* **live**    — refcount >= 1: referenced by that many sequences.  A
  block with refcount > 1 is necessarily published (sharing only ever
  happens through cache hits), and published blocks are IMMUTABLE — their
  key is their content — so writes must copy-on-write first
  (:meth:`BlockPool.cow`).

Invariants (checked by :meth:`BlockPool.check_invariants`, enforced by the
tier-1 property tests):

* block 0 is the TRASH block: never allocated, never freed, never cached.
* free ∪ cached ∪ live partition the non-trash blocks (no orphans).
* ``refcount[b]`` equals the number of sequences whose table contains b.
* refcount > 1 implies published; writable means refcount == 1 AND
  unpublished.
* releasing an unknown sequence (double free) raises — it never corrupts.
* a block's scale exponent never changes while live or cached: codes are
  written once on the Eq.-1 grid chosen at alloc time and never
  requantized while resident (the paper's fewer-requant-ops thesis).
* speculative rollback (:meth:`BlockPool.retract`, DESIGN §11) only ever
  frees private, unpublished tail blocks: commit never covers rejected
  drafts, so their rows can neither publish nor be shared — retracting
  a published/shared or committed-into block raises.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from repro.serving.arena import (Arena, BlockPoolError, PoolStats,
                                 TRASH_UNIT)
from repro.serving.prefix_cache import PrefixCache

__all__ = ["BlockPool", "BlockPoolError", "PoolStats", "AllocPlan",
           "TRASH_BLOCK"]

TRASH_BLOCK = TRASH_UNIT


@dataclasses.dataclass
class AllocPlan:
    """Admission-time allocation plan: what a sequence's feed would hit in
    the prefix cache and how many fresh blocks it still needs.  Planning
    is a pure query (no pinning, no stats) so the scheduler can re-plan a
    blocked head-of-line request every step without side effects."""
    n_tokens: int
    scale_exp: int
    hit_blocks: list
    hit_keys: list
    hit_tokens: int
    n_full_lookups: int
    need_new: int
    feasible: bool


class BlockPool(Arena):
    """Fixed-capacity pool of KV blocks with per-sequence block tables,
    per-block reference counts, and an optional content-addressed prefix
    cache (``prefix_cache=True``) for cross-sequence block sharing."""

    def __init__(self, num_blocks: int, block_size: int, *,
                 scale_exp: int = 0, prefix_cache: bool = False):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        super().__init__(num_blocks, scale_exp=scale_exp)
        self.num_blocks = num_blocks
        self.block_size = block_size
        # refcount-0 published blocks, insertion order == LRU order
        self._idle: "OrderedDict[int, None]" = OrderedDict()
        self.cache: PrefixCache | None = \
            PrefixCache(block_size) if prefix_cache else None

    # -- capacity ---------------------------------------------------------

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` KV rows."""
        return -(-max(n_tokens, 0) // self.block_size)

    def _n_reclaimable(self) -> int:
        return len(self._idle)

    # -- planning ---------------------------------------------------------

    def plan_seq(self, n_tokens: int, *, token_ids=None,
                 scale_exp: int | None = None) -> AllocPlan:
        """Plan an allocation: cache-hit chain + fresh blocks needed.

        A fully-cached feed reserves ONE extra block: the engine must
        re-feed the last token to get logits to sample from, and that
        write lands in the last (shared, immutable) hit block — which
        copy-on-writes into a fresh private block.
        """
        exp = self.default_scale_exp if scale_exp is None else scale_exp
        hits: list[int] = []
        keys: list[int] = []
        n_full = 0
        if self.cache is not None and token_ids is not None:
            n_full = len(token_ids) // self.block_size
            hits, keys = self.cache.lookup(token_ids, exp)
        hit_tokens = len(hits) * self.block_size
        need = self.blocks_for(n_tokens) - len(hits)
        if hits and hit_tokens >= n_tokens:
            need += 1                       # COW of the last hit block
        # hit blocks get pinned before any fresh block is taken, so idle
        # hits are NOT available for LRU reclaim by this allocation
        avail = len(self._free) + len(self._idle) \
            - sum(1 for b in hits if self.refcount[b] == 0)
        return AllocPlan(n_tokens=n_tokens, scale_exp=exp, hit_blocks=hits,
                         hit_keys=keys, hit_tokens=hit_tokens,
                         n_full_lookups=n_full, need_new=need,
                         feasible=need <= avail)

    # -- alloc / extend / free -------------------------------------------

    def alloc_seq(self, seq_id: int, n_tokens: int, *, token_ids=None,
                  scale_exp: int | None = None,
                  plan: AllocPlan | None = None) -> list[int]:
        """Allocate the blocks for a new sequence of ``n_tokens`` rows.

        With the prefix cache enabled and ``token_ids`` (or a ``plan``)
        given, the longest cached full-block chain is ATTACHED (refcount
        bumped, zero quantization ops) and only the uncached tail is
        allocated fresh; ``plan.hit_tokens`` tells the scheduler where
        prefill may start.
        """
        if seq_id in self._seqs:
            raise BlockPoolError(f"sequence {seq_id} already allocated")
        if plan is None:
            plan = self.plan_seq(n_tokens, token_ids=token_ids,
                                 scale_exp=scale_exp)
        if not plan.feasible:
            self.stats.alloc_failures += 1
            raise BlockPoolError(
                f"pool exhausted: need {plan.need_new} blocks, "
                f"{self.n_free} allocatable")
        # pin hits FIRST so the fresh-block takes below cannot LRU-reclaim
        # the very blocks this sequence is attaching to
        for blk, key in zip(plan.hit_blocks, plan.hit_keys):
            if self.cache is None or self.cache.key_of(blk) != key:
                raise BlockPoolError(
                    f"stale plan: block {blk} no longer holds key {key:x}")
            self._acquire(blk)
        fresh_goal = self.blocks_for(n_tokens) - len(plan.hit_blocks)
        new = [self._take(plan.scale_exp) for _ in range(max(fresh_goal, 0))]
        blocks = list(plan.hit_blocks) + new
        self._seqs[seq_id] = blocks
        if self.cache is not None:
            self.cache.on_alloc(seq_id, plan.hit_keys, plan.n_full_lookups,
                                plan.scale_exp)
        self._emit("pool.alloc", {
            "seq": seq_id, "hit_blocks": len(plan.hit_blocks),
            "new_blocks": len(new), "free": self.n_free})
        return list(blocks)  # copy: callers must not mutate the pool's map

    def extend(self, seq_id: int, n_tokens_total: int) -> list[int]:
        """Grow ``seq_id`` to cover ``n_tokens_total`` rows; returns the
        newly allocated blocks ([] when already covered)."""
        if seq_id not in self._seqs:
            raise BlockPoolError(f"unknown sequence {seq_id}")
        blocks = self._seqs[seq_id]
        need = self.blocks_for(n_tokens_total) - len(blocks)
        if need <= 0:
            return []
        if not self.can_alloc(need):
            self.stats.alloc_failures += 1
            raise BlockPoolError(
                f"pool exhausted: extend needs {need}, {self.n_free} free")
        exp = int(self.scale_exp[blocks[0]]) if blocks \
            else self.default_scale_exp
        new = [self._take(exp) for _ in range(need)]
        blocks.extend(new)
        self._emit("pool.extend", {
            "seq": seq_id, "new_blocks": need, "free": self.n_free})
        return new

    def retract(self, seq_id: int, n_tokens_keep: int) -> int:
        """Speculative rollback (DESIGN §11): shrink ``seq_id``'s table to
        the blocks covering its first ``n_tokens_keep`` rows, freeing the
        tail blocks that held only retracted (rejected-draft) rows.
        Returns the number of blocks freed.

        The freed tail is by construction private and unpublished:
        ``commit`` never covers speculative rows, publishing happens only
        through commit, and sharing only through published keys — so a
        rollback can never pull a block out from under another reader.  A
        published or shared tail block means the caller committed rows it
        is now trying to retract, and raises instead of corrupting; with
        the prefix cache on, the sequence's committed chain position is
        cross-checked too (:meth:`PrefixCache.assert_retractable`).
        """
        blocks = self.seq_blocks(seq_id)
        keep = self.blocks_for(n_tokens_keep)
        if keep > len(blocks):
            raise BlockPoolError(
                f"retract of seq {seq_id} to {n_tokens_keep} rows needs "
                f"{keep} blocks but it holds {len(blocks)}")
        tail = blocks[keep:]
        if not tail:
            return 0
        for blk in tail:
            if self.refcount[blk] != 1 or (
                    self.cache is not None
                    and self.cache.is_published(blk)):
                raise BlockPoolError(
                    f"retract would free shared/published block {blk} "
                    f"(seq {seq_id}) — committed rows cannot be rolled "
                    f"back")
        if self.cache is not None:
            self.cache.assert_retractable(seq_id, n_tokens_keep)
        del blocks[keep:]
        for blk in tail:
            self._release(blk)
        self.stats.frees += len(tail)
        self.stats.retracts += 1
        self.stats.retracted_blocks += len(tail)
        self._emit("pool.retract", {
            "seq": seq_id, "freed_blocks": len(tail),
            "keep_tokens": n_tokens_keep})
        return len(tail)

    def _release_seq(self, seq_id: int) -> int:
        n = super()._release_seq(seq_id)
        if self.cache is not None:
            self.cache.release(seq_id)
        return n

    def _on_release_zero(self, blk: int) -> None:
        if self.cache is not None and self.cache.is_published(blk):
            self._idle[blk] = None          # most-recently released
        else:
            self._free.append(blk)

    def _acquire(self, blk: int) -> None:
        """Attach to a published block (cache hit)."""
        self.refcount[blk] += 1
        if self.refcount[blk] == 1:
            del self._idle[blk]                 # was idle-cached
        self.stats.peak_live = max(self.stats.peak_live, self.n_live)

    def _reclaim(self) -> int:
        """Reclaim the LRU idle cached block when the free stack is
        empty."""
        if self._idle:
            blk, _ = self._idle.popitem(last=False)     # oldest first
            self.cache.forget(blk)
            self.stats.cache_evictions += 1
            return blk
        return super()._reclaim()

    # -- copy-on-write ----------------------------------------------------

    def block_writable(self, seq_id: int, logical_idx: int) -> bool:
        """May ``seq_id`` write KV rows into its ``logical_idx``-th block?
        Only private, never-published blocks are writable: a published
        block's key IS its content, and refcount > 1 means another
        sequence is reading it."""
        blk = self.seq_blocks(seq_id)[logical_idx]
        if self.refcount[blk] != 1:
            return False
        return self.cache is None or not self.cache.is_published(blk)

    def cow(self, seq_id: int, logical_idx: int) -> tuple[int, int]:
        """Copy-on-write: replace the (shared/published) block at
        ``logical_idx`` in ``seq_id``'s table with a fresh private block.
        Returns (src, dst); the CALLER must copy the device rows src->dst
        (the pool only moves the map).  Raises BlockPoolError under
        allocation pressure — the scheduler preempts and retries."""
        blocks = self.seq_blocks(seq_id)
        src = blocks[logical_idx]
        if self.block_writable(seq_id, logical_idx):
            raise BlockPoolError(
                f"COW of a writable block {src} (seq {seq_id} idx "
                f"{logical_idx}) — caller should write in place")
        dst = self._take(int(self.scale_exp[src]))
        blocks[logical_idx] = dst
        self._release(src)
        if self.cache is not None:
            self.cache.stats.cow_copies += 1
        self._emit("pool.cow", {
            "seq": seq_id, "idx": logical_idx, "src": src, "dst": dst})
        return src, dst

    # -- cache plumbing ---------------------------------------------------

    def commit(self, seq_id: int, start: int, token_ids) -> None:
        """Record that KV rows for ``token_ids`` at absolute positions
        ``start..`` are now device-resident; full blocks this completes
        become content-addressable.  No-op without the prefix cache."""
        if self.cache is not None:
            self.cache.commit(self, seq_id, start, token_ids)

    def flush_cache(self) -> int:
        """Drop all cached (idle) blocks back to the free stack and every
        content key.  Requires no live sequences."""
        if self.cache is None:
            return 0
        assert not self._seqs, "flush_cache with live sequences"
        n = self.cache.flush()
        while self._idle:
            blk, _ = self._idle.popitem(last=True)
            self._free.append(blk)
        return n

    # -- views ------------------------------------------------------------

    def table_row(self, seq_id: int, width: int):
        """(width,) int32 block table for the engine: the sequence's blocks
        in logical order, tail-padded with the trash block (those entries
        are only ever touched by masked positions).  Unknown sequences
        raise — decoding a freed sequence against trash garbage must fail
        fast, never corrupt silently; INACTIVE slots get their all-trash
        rows from the engine's ``np.full(TRASH_BLOCK)`` default, not from
        here."""
        blocks = self.seq_blocks(seq_id)
        if len(blocks) > width:
            raise BlockPoolError(
                f"sequence {seq_id} has {len(blocks)} blocks > table "
                f"width {width}")
        row = np.full((width,), TRASH_BLOCK, np.int32)
        row[:len(blocks)] = blocks
        return row

    def seq_scale_exp(self, seq_id: int) -> int:
        """The (uniform) Eq.-1 exponent of a live sequence's blocks.
        Shared blocks necessarily share exponents — the exponent is part
        of the content key (and a per-shard kernel constant, DESIGN §8)."""
        blocks = self._seqs.get(seq_id)
        if not blocks:
            raise BlockPoolError(f"unknown sequence {seq_id}")
        exps = {int(self.scale_exp[b]) for b in blocks}
        if len(exps) != 1:
            raise BlockPoolError(
                f"sequence {seq_id} spans blocks with mixed scale "
                f"exponents {sorted(exps)} — a block was requantized")
        return exps.pop()

    # -- invariants -------------------------------------------------------

    def check_invariants(self) -> None:
        """Raises AssertionError on any broken pool invariant."""
        free = set(self._free)
        idle = set(self._idle)
        assert len(free) == len(self._free), "duplicate blocks on free list"
        assert TRASH_BLOCK not in free and TRASH_BLOCK not in idle, \
            "trash block free or cached"
        assert not (free & idle), "block both free and idle-cached"
        # refcount == number of owning sequences, per block
        counts = np.zeros_like(self.refcount)
        live: set[int] = set()
        for sid, blocks in self._seqs.items():
            bset = set(blocks)
            assert len(bset) == len(blocks), f"seq {sid} repeats a block"
            assert TRASH_BLOCK not in bset, f"seq {sid} owns the trash block"
            for blk in blocks:
                counts[blk] += 1
            live |= bset
        assert (counts == self.refcount).all(), \
            "refcount out of sync with sequence ownership"
        assert not (live & free) and not (live & idle), \
            "live block also free or idle-cached"
        assert live | free | idle == set(range(1, self.num_blocks)), \
            "orphan blocks (neither free, cached, nor live)"
        if self.cache is not None:
            self.cache.check_invariants(self)
            for blk in idle:
                assert self.cache.is_published(blk), \
                    f"idle block {blk} has no content key"
            for blk in free:
                assert not self.cache.is_published(blk), \
                    f"free block {blk} still published"
            shared = np.flatnonzero(self.refcount > 1)
            for blk in shared:
                assert self.cache.is_published(int(blk)), \
                    f"block {blk} shared (refcount {self.refcount[blk]}) " \
                    f"but never published"
        else:
            assert not idle, "idle-cached blocks without a prefix cache"
            assert (self.refcount <= 1).all(), \
                "shared block without a prefix cache"
        assert self.stats.peak_live <= self.num_blocks - 1
