"""Host-side allocator for the paged KV block pool (DESIGN §9).

The device arrays live in ``models.model.init_paged_cache`` (one
(L, NB, BS, KVH, D) arena per K and V); this module owns the *map*: which
pool block belongs to which sequence, in which logical order, at which
power-of-two scale exponent.  Everything here is plain Python/numpy — no
jax — so the scheduler property tests run without a model.

Invariants (checked by :meth:`BlockPool.check_invariants`, enforced by the
tier-1 property tests):

* block 0 is the TRASH block: never allocated, never freed — inactive
  engine slots point their whole block table at it so their masked writes
  land somewhere harmless.
* every non-trash block is either on the free stack or owned by exactly
  one sequence (no orphans, no double ownership).
* freeing an unknown sequence (double free) raises — it never corrupts.
* a live block's scale exponent never changes: codes are written once on
  the Eq.-1 grid chosen at alloc time and never requantized while resident
  (the paper's fewer-requant-ops thesis applied to serving).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["BlockPool", "BlockPoolError", "PoolStats"]

TRASH_BLOCK = 0


class BlockPoolError(RuntimeError):
    """Allocator misuse (double free, unknown sequence, exhausted pool)."""


@dataclasses.dataclass
class PoolStats:
    allocs: int = 0            # blocks handed out
    frees: int = 0             # blocks returned
    evictions: int = 0         # sequences evicted (preemption)
    peak_live: int = 0         # max simultaneously-owned blocks
    alloc_failures: int = 0    # alloc/extend requests refused


class BlockPool:
    """Fixed-capacity pool of KV blocks with per-sequence block tables."""

    def __init__(self, num_blocks: int, block_size: int, *,
                 scale_exp: int = 0):
        if num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (block 0 is trash)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.default_scale_exp = scale_exp
        # LIFO free stack — recently freed blocks are re-used first (their
        # pool rows are hot).  Block 0 (trash) is never on it.
        self._free: list[int] = list(range(num_blocks - 1, 0, -1))
        self._seqs: dict[int, list[int]] = {}       # seq id -> blocks, order
        self._owner: dict[int, int] = {}            # block -> seq id
        # per-block po2 scale exponent (Eq.-1 fractional bit) — written at
        # alloc, immutable while live.  One int8 per block of metadata.
        self.scale_exp = np.full((num_blocks,), scale_exp, np.int32)
        self.stats = PoolStats()

    # -- capacity ---------------------------------------------------------

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` KV rows."""
        return -(-max(n_tokens, 0) // self.block_size)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    @property
    def utilization(self) -> float:
        return self.n_live / max(self.num_blocks - 1, 1)

    def can_alloc(self, n_blocks: int) -> bool:
        return n_blocks <= len(self._free)

    def live_seqs(self) -> list[int]:
        return list(self._seqs)

    def n_blocks_of(self, seq_id: int) -> int:
        return len(self._seqs.get(seq_id, ()))

    # -- alloc / extend / free -------------------------------------------

    def alloc_seq(self, seq_id: int, n_tokens: int, *,
                  scale_exp: int | None = None) -> list[int]:
        """Allocate the blocks for a new sequence of ``n_tokens`` rows."""
        if seq_id in self._seqs:
            raise BlockPoolError(f"sequence {seq_id} already allocated")
        need = self.blocks_for(n_tokens)
        if not self.can_alloc(need):
            self.stats.alloc_failures += 1
            raise BlockPoolError(
                f"pool exhausted: need {need} blocks, {self.n_free} free")
        exp = self.default_scale_exp if scale_exp is None else scale_exp
        blocks = [self._take(exp) for _ in range(need)]
        self._seqs[seq_id] = blocks
        for blk in blocks:
            self._owner[blk] = seq_id
        return list(blocks)  # copy: callers must not mutate the pool's map

    def extend(self, seq_id: int, n_tokens_total: int) -> list[int]:
        """Grow ``seq_id`` to cover ``n_tokens_total`` rows; returns the
        newly allocated blocks ([] when already covered)."""
        if seq_id not in self._seqs:
            raise BlockPoolError(f"unknown sequence {seq_id}")
        blocks = self._seqs[seq_id]
        need = self.blocks_for(n_tokens_total) - len(blocks)
        if need <= 0:
            return []
        if not self.can_alloc(need):
            self.stats.alloc_failures += 1
            raise BlockPoolError(
                f"pool exhausted: extend needs {need}, {self.n_free} free")
        exp = int(self.scale_exp[blocks[0]]) if blocks \
            else self.default_scale_exp
        new = [self._take(exp) for _ in range(need)]
        blocks.extend(new)
        for blk in new:
            self._owner[blk] = seq_id
        return new

    def free_seq(self, seq_id: int) -> int:
        """Return all of ``seq_id``'s blocks; raises on double free."""
        if seq_id not in self._seqs:
            raise BlockPoolError(f"double free: unknown sequence {seq_id}")
        blocks = self._seqs.pop(seq_id)
        for blk in blocks:
            del self._owner[blk]
            self._free.append(blk)
        self.stats.frees += len(blocks)
        return len(blocks)

    def evict(self, seq_id: int) -> int:
        """Preemption path: free + count the eviction."""
        n = self.free_seq(seq_id)
        self.stats.evictions += 1
        return n

    def _take(self, scale_exp: int) -> int:
        blk = self._free.pop()
        self.scale_exp[blk] = scale_exp
        self.stats.allocs += 1
        self.stats.peak_live = max(self.stats.peak_live, self.n_live)
        return blk

    # -- views ------------------------------------------------------------

    def table_row(self, seq_id: int, width: int) -> np.ndarray:
        """(width,) int32 block table for the engine: the sequence's blocks
        in logical order, tail-padded with the trash block (those entries
        are only ever touched by masked positions).  Unknown sequences
        raise — decoding a freed sequence against trash garbage must fail
        fast, never corrupt silently; INACTIVE slots get their all-trash
        rows from the engine's ``np.full(TRASH_BLOCK)`` default, not from
        here."""
        if seq_id not in self._seqs:
            raise BlockPoolError(f"unknown sequence {seq_id}")
        blocks = self._seqs[seq_id]
        if len(blocks) > width:
            raise BlockPoolError(
                f"sequence {seq_id} has {len(blocks)} blocks > table "
                f"width {width}")
        row = np.full((width,), TRASH_BLOCK, np.int32)
        row[:len(blocks)] = blocks
        return row

    def seq_scale_exp(self, seq_id: int) -> int:
        """The (uniform) Eq.-1 exponent of a live sequence's blocks."""
        blocks = self._seqs.get(seq_id)
        if not blocks:
            raise BlockPoolError(f"unknown sequence {seq_id}")
        exps = {int(self.scale_exp[b]) for b in blocks}
        if len(exps) != 1:
            raise BlockPoolError(
                f"sequence {seq_id} spans blocks with mixed scale "
                f"exponents {sorted(exps)} — a block was requantized")
        return exps.pop()

    # -- invariants -------------------------------------------------------

    def check_invariants(self) -> None:
        """Raises AssertionError on any broken pool invariant."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate blocks on free list"
        assert TRASH_BLOCK not in free, "trash block on the free list"
        assert TRASH_BLOCK not in self._owner, "trash block owned"
        owned: set[int] = set()
        for sid, blocks in self._seqs.items():
            bset = set(blocks)
            assert len(bset) == len(blocks), f"seq {sid} repeats a block"
            assert not (bset & owned), f"seq {sid} shares blocks"
            for blk in blocks:
                assert self._owner.get(blk) == sid, \
                    f"owner map out of sync for block {blk}"
            owned |= bset
        assert not (owned & free), "block both free and owned"
        assert owned | free == set(range(1, self.num_blocks)), \
            "orphan blocks (neither free nor owned)"
        assert self.stats.peak_live <= self.num_blocks - 1
