"""Continuous-batching step loop over the paged int8-KV block pool.

The engine owns the device state (params + the block-pool cache from
``models.model.init_paged_cache``) and, by default (``ragged=True``,
DESIGN §12), drives ONE jitted unified step
(``launch.steps.build_ragged_step``): every engine step flattens the
whole mixed work-list — prefill chunks, decode rows, speculative tails —
into a single (T,) token stream with per-sequence descriptors and serves
it in ONE dispatch.  jit specializes per padded stream length only
(pow2 buckets up to ``prefill_token_budget + n_slots * (spec_k + 1)``),
so the executable set is O(few) regardless of traffic mix, and the
descriptor arrays make padding waste a measured quantity
(``padded_tokens`` / ``padding_frac`` in the report).

``ragged=False`` keeps the retired per-shape dispatch trio for A/B:

* decode: (n_slots, 1) — every engine step decodes ALL live slots at
  their own positions; finished slots are backfilled by newly admitted
  requests, so the batch never drains (continuous batching).
* chunked prefill: (1, C) for C in the scheduler's bucket set — prompts
  are fed ``chunk`` tokens at a time under a per-step token budget.
* speculative verify: (n_slots, K+1) when drafting is on (DESIGN §11) —
  each live slot's last token plus up to K drafted tokens are scored in
  ONE step, with Leviathan/Chen rejection sampling fused into the jit;
  only accepted tokens commit to the pool, the rejected tail retracts.

There jit compiles 1 (decode) + |buckets| (prefill) + 1 (verify)
executables and serializes the phases the ragged path fuses.

KV codes are written once on the Eq.-1 power-of-two grid and stay
int8-resident in the pool until the request leaves; attention consumes
them in place (fused paged kernel on MXU-aligned shapes, gather reference
otherwise).  The report quantifies what that buys with the paper's Table 5
constants (``core.hwcost``): the requant ops actually executed vs the ops
a dequantize-the-cache-every-step dataflow would have executed.
"""
from __future__ import annotations

import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import hwcost
from repro.core.qmodel import QuantContext
from repro.launch import steps as S
from repro.models import model as M
from repro.models.attention import RaggedBatch
from repro.obs.metrics import MetricsRegistry
from repro.obs.profile import EnergyAccount, Profiler
from repro.obs.slo import SLOMonitor, default_slos
from repro.obs.trace import Tracer
from repro.serving.kv_pool import TRASH_BLOCK, BlockPool, BlockPoolError
from repro.serving.scheduler import (Request, RequestState, Scheduler,
                                     chunk_bucket)
from repro.serving.spec import apply_top_k, resolve_drafter, verify_tokens
from repro.serving.state_pool import TRASH_SLAB, StateSlabPool
from repro.serving.substrate import substrate_for

__all__ = ["ServingEngine", "sample_tokens", "summarize_step_times"]


def sample_tokens(logits: jax.Array, key: jax.Array,
                  temperatures: jax.Array,
                  top_k: Optional[jax.Array] = None,
                  k_cap: Optional[int] = None) -> jax.Array:
    """Greedy + temperature/top-k sampling hook.

    logits (B, V); temperatures (B,) — 0 selects greedy for that row;
    top_k (B,) int32 — 0 keeps the full vocabulary for that row.  Both
    are PER-ROW traced values, so one fixed-shape call serves a batch
    mixing greedy, full-vocab and top-k requests (continuous batching
    cannot afford a recompile per sampling config).  ``k_cap`` is a
    STATIC bound on the batch's largest top-k (the engine passes the
    host-known max): the cutoff comes from an O(V log k_cap)
    ``lax.top_k`` instead of a full-vocab sort in the decode hot loop,
    and ties at the threshold break by index so the candidate set is
    EXACTLY k (the old ``logits < kth`` kept every tied token)."""
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1)
    if top_k is not None:
        logits = apply_top_k(logits, top_k, k_cap)
    scaled = logits / jnp.maximum(temperatures, 1e-6)[:, None]
    sampled = jax.random.categorical(key, scaled, axis=-1)
    return jnp.where(temperatures > 0, sampled, greedy).astype(jnp.int32)


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else None


def summarize_step_times(step_times: dict) -> dict:
    """Per-shape compile-vs-steady split: the first call of a jitted shape
    pays tracing+compilation, the median of the rest is steady state.

    Keyed by the shape that was ACTUALLY dispatched: ragged work-list
    entries ``("ragged", T_pad, S_pad)`` become ``ragged_{T}xS{S}`` at
    the top level (these are the unified engine's only executables), and
    the retired per-shape tuples ``(B, C)`` are kept — verbatim ``BxC``
    keys — under a ``legacy_shapes`` section so older BENCH_serving.json
    entries stay comparable.  Preformatted string keys (the static
    baseline bench's) pass through at the top level.

    Edge cases are well-defined, never an IndexError (obs satellite): an
    EMPTY sample list reports ``calls 0`` with every latency field None;
    one call has a ``first_s`` but no steady state; ``p99_s`` — the
    steady-state tail over the post-compile samples — needs at least two
    steady samples, otherwise it is None rather than parroting a single
    observation back as a "percentile" (a p99 of one sample is just that
    sample, and reporting it as a tail bound is how 1-sample noise ends
    up gating a bench)."""
    shapes: dict = {}
    legacy: dict = {}
    for shape, ts in sorted(step_times.items(), key=lambda kv: str(kv[0])):
        steady_ts = ts[1:]
        steady = float(np.median(steady_ts)) if steady_ts else None
        p99 = _pct(steady_ts, 99) if len(steady_ts) >= 2 else None
        entry = {"calls": len(ts),
                 "first_s": round(ts[0], 4) if ts else None,
                 "steady_s": round(steady, 4) if steady is not None else None,
                 "p99_s": round(p99, 4) if p99 is not None else None}
        if isinstance(shape, tuple) and shape and shape[0] == "ragged":
            shapes[f"ragged_{shape[1]}xS{shape[2]}"] = entry
        elif isinstance(shape, tuple) and shape and shape[0] == "recurrent":
            # fixed-shape recurrent dispatch (DESIGN §16): one executable
            # per (n_slots, chunk), named at the top level like ragged
            shapes[f"recurrent_{shape[1]}xC{shape[2]}"] = entry
        elif isinstance(shape, tuple):
            legacy["x".join(map(str, shape))] = entry
        else:
            shapes[str(shape)] = entry
    if legacy:
        shapes["legacy_shapes"] = legacy
    return shapes


class ServingEngine:
    """Continuous-batching serving engine (DESIGN §9)."""

    def __init__(self, cfg: ModelConfig, params: Any, ctx: QuantContext, *,
                 n_slots: int = 4, block_size: int = 16,
                 max_model_len: int = 128,
                 num_blocks: Optional[int] = None, chunk: int = 16,
                 prefill_token_budget: Optional[int] = None,
                 num_slabs: Optional[int] = None,
                 top_k: int = 0, mesh=None, seed: int = 0,
                 prefix_cache: Optional[bool] = None, spec_k: int = 0,
                 drafter="ngram", ragged: bool = True,
                 trace: bool = False, trace_capacity: int = 65536,
                 profile_dir: Optional[str] = None,
                 profile_cost: bool = False,
                 record: bool = False, virtual_dt: float = 1e-3,
                 slo=None):
        self.cfg = cfg
        from repro.core.qmodel import QuantizedParams
        if isinstance(params, QuantizedParams):
            # W8A8 deploy container: the engine only ever runs the code
            # tree; the exponents already live in ctx.table
            params = params.tree
        self.params = params
        self.ctx = ctx
        self.n_slots = n_slots
        self.max_model_len = max_model_len
        # substrate routing (DESIGN §16): the config's layer mix decides
        # which pools back this engine's sequences.  Attention sequences
        # grow block tables from the BlockPool; recurrent (RWKV6 / Mamba2)
        # sequences keep ONE fixed-size quantized state slab from the
        # StateSlabPool; a hybrid (zamba2) holds both at once.
        self.substrate = sub = substrate_for(cfg)
        if spec_k > 0 and not sub.supports_spec:
            raise ValueError(
                f"spec_k={spec_k} is unsupported on the {sub.kind} "
                "substrate: speculative decoding must retract rejected "
                "draft tokens, but fixed-size recurrent state cannot be "
                "rolled back (use spec_k=0 for recurrent/hybrid models)")
        if prefix_cache and not sub.supports_prefix_cache:
            raise ValueError(
                f"prefix_cache=True is unsupported on the {sub.kind} "
                "substrate: recurrent state is a running summary, not an "
                "addressable token range, so there is no prefix to share "
                "(leave prefix_cache unset for auto, or pass False)")
        if prefix_cache is None:
            prefix_cache = sub.supports_prefix_cache
        ragged = ragged and sub.supports_ragged
        nbmax = -(-max_model_len // block_size)
        if sub.grows:
            if num_blocks is None:
                # full residency: every slot can reach max_model_len
                # (+ trash).  Callers undersize this deliberately to
                # exercise preemption.
                num_blocks = 1 + n_slots * nbmax
            scale_exp = cfg.kv_cache_frac_bits if cfg.kv_cache_bits == 8 \
                else 0
            self.pool: Optional[BlockPool] = BlockPool(
                num_blocks, block_size, scale_exp=scale_exp,
                prefix_cache=prefix_cache)
        else:
            self.pool = None
        if sub.fixed_state:
            if num_slabs is None:
                num_slabs = 1 + n_slots      # one per slot + trash
            st_exp = cfg.state_frac_bits if cfg.state_bits == 8 else 0
            self.state_pool: Optional[StateSlabPool] = StateSlabPool(
                num_slabs, scale_exp=st_exp)
        else:
            self.state_pool = None
        self.sched = Scheduler(self.pool, n_slots=n_slots, chunk=chunk,
                               max_model_len=max_model_len,
                               prefill_token_budget=prefill_token_budget,
                               state_pool=self.state_pool, substrate=sub)
        # observability (DESIGN §14): one tracer threaded through every
        # serving-path module.  Ring events are off unless ``trace=True``;
        # per-request timelines (a few floats each) are always on — they
        # are the source of the report's trace-derived latency section.
        self.tracer = Tracer(capacity=trace_capacity, clock=self._now,
                             enabled=trace)
        if self.pool is not None:
            self.pool.tracer = self.tracer
            if self.pool.cache is not None:
                self.pool.cache.tracer = self.tracer
        if self.state_pool is not None:
            self.state_pool.tracer = self.tracer
        self.sched.tracer = self.tracer
        if sub.snapshot_preempt:
            # pure-recurrent preemption snapshots the sequence's whole
            # state slab to the host (no token range to recompute from the
            # pool) — the scheduler calls this hook, admit restores it
            self.sched.snapshot_fn = self._snapshot_slab
        # flight recorder (DESIGN §15): record mode switches run() onto a
        # deterministic VIRTUAL clock (virtual_dt seconds per step, idle
        # gaps jump to the next arrival) and tees the scheduler-decision
        # events into an unbounded sink — the capture run is then exactly
        # reproducible, which is the whole replay contract.  Tracing is
        # forced on (the decision event call sites are ring-gated).
        if virtual_dt <= 0.0:
            raise ValueError(f"virtual_dt must be > 0, got {virtual_dt}")
        self.record = record
        self.virtual_dt = virtual_dt
        self._virtual_time: Optional[float] = 0.0 if record else None
        if record:
            self.tracer.enabled = True
            self.tracer.decision_sink = []
        # SLO burn-rate monitor (DESIGN §15): evaluated once per step on
        # the engine clock (virtual under record mode, so SLO evaluation
        # replays deterministically too).  ``slo=True`` takes the stock
        # objective set; a list of SLObjective customizes it.
        if slo is None:
            self.slo: Optional[SLOMonitor] = None
        else:
            objectives = default_slos() if slo is True else slo
            self.slo = SLOMonitor(objectives, tracer=self.tracer,
                                  value_fn=self._metric_value)
        self.profiler = Profiler(profile_dir=profile_dir, cost=profile_cost)
        # live Table-5 energy proxy, split prefill / decode / spec_wasted;
        # reconciles exactly with the requant counters below (tested)
        self.energy = EnergyAccount("bit_shifting")
        if sub.kind == "attention":
            self.cache = M.init_paged_cache(cfg, num_blocks, block_size)
        elif sub.kind == "recurrent":
            self.cache = M.init_paged_state(cfg, self.state_pool.num_slabs)
        else:                                # hybrid: slabs + block tables
            self.cache = M.init_paged_state(cfg, self.state_pool.num_slabs,
                                            num_blocks=num_blocks,
                                            block_size=block_size)
        # sampling is FUSED into the jitted step: one dispatch + one host
        # sync per engine step, and only the (B,) sampled tokens ever leave
        # the device — logits never cross to the host.  The rng key derives
        # from a per-call counter via fold_in inside the jit, so the host
        # does zero PRNG work per step and runs stay seed-reproducible.
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        self.spec_k = spec_k
        self.drafter = resolve_drafter(drafter)
        self.ragged = ragged
        self.seed = seed
        base_key = jax.random.PRNGKey(seed)
        kp1 = spec_k + 1
        self._step_fn = self._spec_fn = self._ragged_fn = None
        self._rec_fn = None
        if sub.kind == "attention":
            base_step = S.build_paged_step(cfg, ctx, mesh=mesh)

            def sampled_step(params, tokens, cache, positions, bt, temps,
                             topks, last_idx, step_idx, k_cap):
                logits, cache = base_step(params, tokens, cache, positions,
                                          bt)
                row = jax.lax.dynamic_index_in_dim(logits, last_idx, axis=1,
                                                   keepdims=False)  # (B, V)
                key = jax.random.fold_in(base_key, step_idx)
                return sample_tokens(row, key, temps, topks,
                                     k_cap=k_cap), cache

            # donate the pool: the per-token scatter then updates the
            # arena in place — without donation XLA copies the whole
            # multi-MB pool every step, which is exactly the
            # write-amplification the paged design exists to avoid.
            # k_cap is static (the host-known max top-k of the batch):
            # one extra executable per distinct cap, and the sampler's
            # cutoff stays an O(V log k) partial sort.
            self._step_fn = jax.jit(sampled_step, donate_argnums=(2,),
                                    static_argnums=(9,))

            # speculative verify step (DESIGN §11): score the (B, K+1)
            # chunk and resolve draft acceptance in ONE dispatch —
            # rejection sampling is fused into the jit, and only
            # (out tokens, accepted counts) ever cross to the host
            def spec_verify_step(params, tokens, cache, positions, bt,
                                 temps, topks, n_drafts, step_idx, k_cap):
                logits, cache = base_step(params, tokens, cache, positions,
                                          bt)
                key = jax.random.fold_in(base_key, step_idx)
                out, n_acc = verify_tokens(logits, tokens, n_drafts, key,
                                           temps, topks, k_cap=k_cap)
                return out, n_acc, cache

            self._spec_fn = jax.jit(spec_verify_step, donate_argnums=(2,),
                                    static_argnums=(9,))

            # UNIFIED ragged step (DESIGN §12): the whole mixed work-list
            # — prefill chunks, decode rows, speculative tails —
            # flattened to one (T,) stream with per-sequence descriptors,
            # served by ONE dispatch.  Sampling and draft verification
            # share one fused sampler: every sequence gathers K+1 logit
            # rows starting at its ``sample_start`` and runs
            # Leviathan/Chen verification — a prefill/decode row rides
            # with n_drafts=0, which reduces verify_tokens to plain
            # sampling of row 0, so one executable covers every traffic
            # class.
            base_ragged = S.build_ragged_step(cfg, ctx, mesh=mesh)

            def ragged_sampled_step(params, tokens, cache, positions, rb,
                                    temps, topks, sample_start, n_drafts,
                                    step_idx, k_cap):
                logits, cache = base_ragged(params, tokens, cache,
                                            positions, rb)
                t = logits.shape[0]
                idx = jnp.clip(sample_start[:, None]
                               + jnp.arange(kp1, dtype=jnp.int32)[None, :],
                               0, t - 1)
                rows = jnp.take(logits, idx, axis=0)    # (S, K+1, V)
                toks = jnp.take(tokens, idx, axis=0)    # (S, K+1)
                key = jax.random.fold_in(base_key, step_idx)
                out, n_acc = verify_tokens(rows, toks, n_drafts, key,
                                           temps, topks, k_cap=k_cap)
                return out, n_acc, cache

            self._ragged_fn = jax.jit(ragged_sampled_step,
                                      donate_argnums=(2,),
                                      static_argnums=(10,))
        else:
            # batched recurrent step (DESIGN §16): ONE fixed-shape
            # executable per (n_slots, chunk) serves the whole mixed
            # work-list — prefill chunks feed q_len=c tokens, decode rows
            # q_len=1, idle lanes q_len=0 against the trash slab — so the
            # recurrent substrate needs no ragged flattening at all.  The
            # step gathers each row's slab, dequantizes to the compute
            # dtype, runs every layer, and re-quantizes the WHOLE state
            # back to its slab exactly once (the context-free requant the
            # report's ops/token gauge quantifies).  Sampling is fused
            # like the attention paths; logits are already (B, V).
            base_rec = S.build_recurrent_step(cfg, ctx, mesh=mesh)

            def recurrent_sampled_step(params, tokens, cache, slab_ids,
                                       q_len, positions, bt, temps, topks,
                                       step_idx, k_cap):
                logits, cache = base_rec(params, tokens, cache, slab_ids,
                                         q_len, positions, bt)
                key = jax.random.fold_in(base_key, step_idx)
                return sample_tokens(logits, key, temps, topks,
                                     k_cap=k_cap), cache

            self._rec_fn = jax.jit(recurrent_sampled_step,
                                   donate_argnums=(2,),
                                   static_argnums=(10,))
        # padded-stream buckets: pow2 from 8 up to the step's worst case
        # (full prefill budget + every slot verifying a K-token tail), so
        # jit sees O(log) distinct ragged executables
        budget = self.sched.prefill_token_budget
        self._t_max = max(8, -(-(budget + n_slots * kp1) // 8) * 8)

        # COW device copy (DESIGN §10): duplicate one pool block's rows
        # (all layers, K and V) into a fresh private block before a write
        # would land in a shared/published block.  Donated for the same
        # reason as the step: copy block_size rows, not the whole arena.
        def cow_copy(cache, src, dst):
            return jax.tree.map(
                lambda a: a.at[:, dst].set(a[:, src]), cache)

        self._cow_fn = jax.jit(cow_copy, donate_argnums=(0,))
        self._step_counter = 0
        # engine-level default top-k, applied to requests that don't set
        # their own (Request.top_k > 0 wins per slot)
        self.default_top_k = top_k
        # one requant op per KV element (paper's unit of Table 5).  Only
        # layers that WRITE per-token KV count: every layer on attention,
        # the shared attention blocks (one per attn_every stride) on
        # hybrid, none on pure recurrent.
        if sub.kind == "hybrid":
            n_kv_layers = cfg.n_layers // cfg.hybrid.attn_every
        elif sub.kind == "recurrent":
            n_kv_layers = 0
        else:
            n_kv_layers = cfg.n_layers
        self._elems_per_token = (n_kv_layers * cfg.n_kv_heads
                                 * cfg.resolved_head_dim * 2)
        # fixed-slab counterpart: ops to requantize one sequence's WHOLE
        # recurrent state, paid once per step regardless of context
        # (DESIGN §16) — 'performed' when slabs are int8, the
        # counterfactual 'avoided' bucket when they stay fp32
        self._state_elems_per_step = hwcost.state_quant_ops_per_step(cfg) \
            if sub.fixed_state else 0
        # running total of the state ops above — kept SEPARATE from the
        # merged performed/avoided buckets so a hybrid run can report the
        # recurrent substrate's share of the per-token gauge on its own
        self.requant_ops_state = 0
        self.requant_ops_performed = 0
        self.requant_ops_avoided = 0
        # quant ops the PREFIX CACHE deleted outright: cached-prefix tokens
        # are never quantized at all for the hitting request (Table 5)
        self.requant_ops_avoided_cache = 0
        # quant ops SPENT on rejected drafts: performed, then rolled back —
        # exactly the waste the paper's write-once scheme minimizes
        # elsewhere, reported honestly instead of hidden (Table 5)
        self.requant_ops_wasted_spec = 0
        # true-W8A8 forward accounting (DESIGN §13): per-token dynamic
        # quant ops of the projection/MLP/head dataflow — activation quant
        # at every module boundary + the fused output requant.  Zero on the
        # dense path, so the forward counters below only move under
        # matmul_kernel='int8'.  Kept SEPARATE from the KV counters above
        # so the KV-only Table-5 accounting stays comparable across runs.
        self._fwd_elems_per_token = (
            hwcost.forward_quant_ops_per_token(cfg)
            if cfg.matmul_kernel == "int8" else 0)
        self.requant_ops_forward = 0
        self.requant_ops_forward_avoided_cache = 0
        self.requant_ops_forward_wasted_spec = 0
        self.cache_hit_prefill_tokens = 0
        self.decode_steps = 0
        self.prefill_chunks = 0
        self.spec_steps = 0
        self.spec_slot_steps = 0    # (live slot, verify step) pairs
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        self.ragged_steps = 0
        self.recurrent_steps = 0
        # padding honesty (satellite): every dispatched token that carried
        # no real work — pow2 bucket rounding, empty decode slots, unused
        # draft columns — counted at dispatch time on BOTH paths
        self.dispatched_tokens = 0
        self.padded_tokens = 0
        self._step_times: dict[tuple, list] = {}    # shape key -> wall s
        self._t0 = time.perf_counter()
        self._skip = 0.0
        self._wall_s = 0.0
        # the registry is the single source of report naming/typing:
        # report() is a nested view of it (DESIGN §14)
        self.metrics = MetricsRegistry()
        self._register_metrics()

    # -- clock ------------------------------------------------------------

    def _now(self) -> float:
        """Engine clock, seconds.  Real (monotonic minus fast-forwarded
        idle gaps) normally; the deterministic VIRTUAL clock under
        ``record=True`` — every timeline mark, trace timestamp and SLO
        window then replays bit-identically (DESIGN §15)."""
        if self._virtual_time is not None:
            return self._virtual_time
        return time.perf_counter() - self._t0 + self._skip

    def _metric_value(self, name: str):
        """Registry read for the SLO monitor's gauge objectives."""
        return self.metrics.get_value(name)

    # -- public API -------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.tracer.req_submit(req.rid, req.arrival)
        self.sched.submit(req)

    def workload_record(self, requests: list[Request]):
        """Freeze the last ``record=True`` run into a portable
        :class:`repro.obs.replay.WorkloadRecord` (DESIGN §15)."""
        from repro.obs.replay import capture_workload
        return capture_workload(self, requests)

    def reset_metrics(self, *, flush_cache: bool = True) -> None:
        """Clear accounting between runs (e.g. after a warm-up workload
        that populated the jit caches) — engine must be drained first.
        The sampling step counter resets too, so a reused engine replays
        the same rng stream (seed-reproducible across passes); note that
        post-reset ``first_s`` per shape reflects a WARM first call, not
        compilation.  By default the PREFIX CACHE is flushed too, so every
        pass starts cold — inter-pass hits would make pass N incomparable
        to pass 1; pass ``flush_cache=False`` to measure the warm-cache
        steady state (e.g. after priming a shared system prompt)."""
        assert self.sched.idle \
            and (self.pool is None or self.pool.n_live == 0) \
            and (self.state_pool is None or self.state_pool.n_live == 0), \
            "reset_metrics on a non-drained engine"
        from repro.serving.kv_pool import PoolStats
        from repro.serving.prefix_cache import CacheStats
        self._step_counter = 0
        self.sched.done.clear()
        self.sched.admission_log.clear()
        if self.pool is not None:
            if flush_cache:
                self.pool.flush_cache()
            self.pool.reset_free_order()
            self.pool.stats = PoolStats()
            if self.pool.cache is not None:
                self.pool.cache.stats = CacheStats()
        if self.state_pool is not None:
            self.state_pool.reset_free_order()
            self.state_pool.stats = PoolStats()
        self.requant_ops_performed = 0
        self.requant_ops_avoided = 0
        self.requant_ops_avoided_cache = 0
        self.requant_ops_wasted_spec = 0
        self.requant_ops_forward = 0
        self.requant_ops_forward_avoided_cache = 0
        self.requant_ops_forward_wasted_spec = 0
        self.cache_hit_prefill_tokens = 0
        self.decode_steps = 0
        self.prefill_chunks = 0
        self.spec_steps = 0
        self.spec_slot_steps = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        self.ragged_steps = 0
        self.recurrent_steps = 0
        self.dispatched_tokens = 0
        self.padded_tokens = 0
        self._step_times.clear()
        self._wall_s = 0.0
        if self.record:
            self._virtual_time = 0.0
        self.energy.reset()
        self.tracer.reset()          # clears the decision sink too
        if self.slo is not None:
            self.slo.reset()
        self.profiler.reset()
        self.metrics.reset()        # owned metrics only; bound ones follow
        stats = getattr(self.drafter, "stats", None)
        if stats is not None:
            stats.reset()

    def run(self, requests: list[Request]) -> dict:
        """Serve ``requests`` (arrival-stamped) to completion; idle gaps
        between arrivals are fast-forwarded on the engine clock, so the
        report's latencies are arrival-relative without real sleeps.
        Under ``record=True`` the loop runs on the virtual clock instead
        (``virtual_dt`` per step): arrival→admission composition then
        depends only on the workload, never the host, so the run is
        exactly replayable (obs/replay.py)."""
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        if self.record:
            return self._run_virtual(pending)
        self._t0, self._skip = time.perf_counter(), 0.0
        while pending or not self.sched.idle:
            now = self._now()
            if self.sched.idle and pending and pending[0].arrival > now:
                self._skip += pending[0].arrival - now
                now = self._now()
            while pending and pending[0].arrival <= now:
                self.submit(pending.pop(0))
            self.step()
        self._wall_s = self._now()
        return self.report()

    def _run_virtual(self, pending: list[Request]) -> dict:
        """The record-mode run loop: same structure as ``run`` but the
        clock advances ``virtual_dt`` per step and jumps straight to the
        next arrival when idle."""
        self._virtual_time = 0.0
        while pending or not self.sched.idle:
            now = self._virtual_time
            if self.sched.idle and pending and pending[0].arrival > now:
                self._virtual_time = now = pending[0].arrival
            while pending and pending[0].arrival <= now:
                self.submit(pending.pop(0))
            self.step()
            self._virtual_time += self.virtual_dt
        self._wall_s = self._virtual_time
        return self.report()

    def step(self) -> None:
        """One engine iteration.  Ragged (default): admit → ONE unified
        dispatch over the mixed work-list.  Legacy (``ragged=False``):
        admit → chunked prefill → decode (a speculative verify step when
        drafting is on and produced drafts, the plain (B, 1) decode
        otherwise)."""
        for req in self.sched.admit(self._now()):
            if self.substrate.fixed_state:
                if req.snapshot is not None:
                    # preemption snapshot resume: the saved state codes
                    # drop back into the fresh slab — these tokens were
                    # PAID for before eviction, not prefix-cache hits
                    self._restore_snapshot(req)
                else:
                    # slabs are recycled LIFO: a fresh sequence must not
                    # inherit the previous owner's final state
                    self._reset_slab(req)
                continue
            # cached-prefix hit: those tokens' KV is already resident, so
            # their quantization ops simply never happen for this request
            self.cache_hit_prefill_tokens += req.n_prefilled
            self.requant_ops_avoided_cache += \
                req.n_prefilled * self._elems_per_token
            # under W8A8 the hit also skips the whole forward for those
            # tokens — none of their matmul-boundary quant ops ever run
            self.requant_ops_forward_avoided_cache += \
                req.n_prefilled * self._fwd_elems_per_token
        if self.substrate.fixed_state:
            self._run_recurrent_step()
        elif self.ragged:
            self._run_ragged_step()
        else:
            self._run_prefills()
            if not (self.spec_k and self._run_spec_decode()):
                self._run_decode()
        if self.slo is not None:
            self.slo.evaluate(self._now())

    # -- unified ragged step (DESIGN §12) ---------------------------------

    def _t_bucket(self, n: int) -> int:
        """Padded stream length for ``n`` real tokens: smallest power of
        two >= n (floored at the sublane size 8), capped at the step's
        static worst case — O(log) distinct jitted stream lengths."""
        b = 8
        while b < n:
            b <<= 1
        return min(b, self._t_max)

    def _run_ragged_step(self) -> None:
        """Plan the mixed work-list, then serve it in ONE dispatch.

        Planning mirrors the legacy phases: every PREFILL job contributes
        one chunk under the shared token budget (CoW-protected), every
        DECODE request contributes its fed token plus a speculative tail
        when drafting is on (pool growth degrades the tail before
        preempting peers, exactly like the per-shape verify step).
        Growth/CoW for a later request may preempt an earlier one, so
        planned items are re-validated against slot residency before the
        arrays are built — a preempted request's chunk simply drops out
        of this step, the same outcome the phase-ordered path reaches by
        dispatching before planning the next phase."""
        now = self._now()
        budget = self.sched.prefill_token_budget
        prefill_items = []                  # (req, start, c_real)
        for req in self.sched.prefill_jobs():
            if budget <= 0:
                break
            start = req.n_prefilled
            c_real = min(self.sched.chunk, len(req.feed) - start, budget)
            # copy-on-write (DESIGN §10): any block this chunk writes
            # into must be private (returns False iff req was preempted)
            if not self._cow_for_range(req, start, start + c_real):
                continue
            budget -= c_real
            prefill_items.append((req, start, c_real))

        proposals = {}
        if self.spec_k:
            for req in self.sched.decode_reqs():
                b = self._spec_budget(req)
                if b > 0:
                    d = np.asarray(self.drafter.draft(
                        np.concatenate([req.prompt, np.asarray(
                            req.generated, np.int32)]), b), np.int32)
                    proposals[req.rid] = d[:b]
        has_spec = any(len(d) for d in proposals.values())
        plans: dict[int, np.ndarray] = {}
        for req in list(self.sched.decode_reqs()):
            if req.slot is None or req.state is not RequestState.DECODE:
                continue
            drafts = proposals.get(req.rid, np.empty(0, np.int32))
            if has_spec:
                granted = self.sched.grow_for_spec(req, now, len(drafts))
                if granted is None:
                    continue                # req itself was preempted
                drafts = drafts[:granted]
                # the speculative tail must only write private blocks
                if not self._cow_for_range(req, req.n_ctx,
                                           req.n_ctx + 1 + len(drafts)):
                    continue                # req itself was preempted
            elif not self.sched.grow_for_decode(req, now):
                continue                    # req itself was preempted
            plans[req.rid] = drafts

        # re-validate: growth/CoW above may have preempted planned items
        prefill_items = [
            (r, s, c) for (r, s, c) in prefill_items
            if r.slot is not None and r.state is RequestState.PREFILL
            and r.n_prefilled == s]
        decode_items = [(r, plans[r.rid]) for r in self.sched.decode_reqs()
                        if r.rid in plans]
        if not prefill_items and not decode_items:
            return

        # -- build the flattened stream + descriptors ---------------------
        bs = self.pool.block_size
        nbmax = self.sched.nbmax
        s_pad = self.n_slots
        q_lens = [c for (_, _, c) in prefill_items] \
            + [1 + len(d) for (_, d) in decode_items]
        t_real = sum(q_lens)
        t_pad = self._t_bucket(t_real)
        tokens = np.zeros(t_pad, np.int32)
        positions = np.zeros(t_pad, np.int32)
        dest = np.zeros(t_pad, np.int32)    # padding rows scatter to trash
        q_start = np.full(s_pad, t_pad, np.int32)
        q_len = np.zeros(s_pad, np.int32)
        kv_len = np.zeros(s_pad, np.int32)
        bt = np.full((s_pad, nbmax), TRASH_BLOCK, np.int32)
        temps = np.zeros(s_pad, np.float32)
        topks = np.zeros(s_pad, np.int32)
        sample_start = np.zeros(s_pad, np.int32)
        n_drafts = np.zeros(s_pad, np.int32)
        fed: list[np.ndarray] = []
        off = 0
        for i, (req, item) in enumerate(
                [(r, (s, c)) for (r, s, c) in prefill_items]
                + [(r, d) for (r, d) in decode_items]):
            if i < len(prefill_items):
                start, c_real = item
                toks_i = np.asarray(req.feed[start:start + c_real], np.int32)
                pos_i = start + np.arange(c_real, dtype=np.int32)
                sample_start[i] = off + c_real - 1     # last real row
            else:
                d = item
                toks_i = np.concatenate(
                    [[req.generated[-1]], d]).astype(np.int32)
                pos_i = req.n_ctx + np.arange(1 + len(d), dtype=np.int32)
                sample_start[i] = off                  # fed-token row
                n_drafts[i] = len(d)
            n = len(toks_i)
            row = self.pool.table_row(req.rid, nbmax)
            tokens[off:off + n] = toks_i
            positions[off:off + n] = pos_i
            dest[off:off + n] = row[pos_i // bs] * bs + pos_i % bs
            q_start[i] = off
            q_len[i] = n
            kv_len[i] = int(pos_i[-1]) + 1
            bt[i] = row
            temps[i] = req.temperature
            topks[i] = self._req_top_k(req)
            fed.append(toks_i)
            off += n
        out, n_acc = self._dispatch_ragged(tokens, positions, dest, bt,
                                           q_start, q_len, kv_len, temps,
                                           topks, sample_start, n_drafts,
                                           t_real=t_real)
        self.ragged_steps += 1
        self.dispatched_tokens += t_pad
        self.padded_tokens += t_pad - t_real
        now = self._now()
        tr = self.tracer
        ept = self._elems_per_token + self._fwd_elems_per_token

        # -- post-process: prefill items (mirrors _prefill_chunk) ---------
        for i, (req, start, c_real) in enumerate(prefill_items):
            req.n_prefilled += c_real
            req.n_ctx = req.n_prefilled
            self.pool.commit(req.rid, start,
                             req.feed[start:start + c_real])
            self.prefill_chunks += 1
            self.requant_ops_performed += c_real * self._elems_per_token
            self.requant_ops_forward += c_real * self._fwd_elems_per_token
            self.energy.charge("prefill", c_real * ept, c_real)
            if tr.enabled:
                # chunk boundary: part of the scheduler-decision stream
                # the flight recorder diffs between runs (DESIGN §15)
                tr.event("sched.prefill_chunk", "sched", ts=now, args={
                    "rid": req.rid, "start": start, "tokens": c_real})
            tr.req_mark(req.rid, "first_chunk", now)
            if req.n_prefilled == len(req.feed):
                tok = int(out[i, 0])
                if req.t_first is None:
                    req.t_first = now
                tr.req_mark(req.rid, "first_token", now)
                tr.req_token(req.rid, now)
                done = req.finished_by(tok, self.max_model_len)
                req.generated.append(tok)
                if done:
                    self.sched.finish(req, now)
                else:
                    req.state = RequestState.DECODE

        # -- post-process: decode items (mirrors _run_decode / spec) ------
        if decode_items:
            if has_spec:
                self.spec_steps += 1
                self.spec_slot_steps += len(decode_items)
            else:
                self.decode_steps += 1
        for j, (req, d) in enumerate(decode_items):
            i = len(prefill_items) + j
            fed_tok = int(fed[i][0])
            if has_spec:
                acc = int(n_acc[i])
                emitted = out[i, :acc + 1].tolist()
                kept_drafts = 0
                n_out = 0
                done = False
                for k, tok in enumerate(emitted):
                    done = req.finished_by(int(tok), self.max_model_len)
                    req.generated.append(int(tok))
                    tr.req_token(req.rid, now)
                    self.spec_emitted += 1
                    n_out += 1
                    if k < acc:
                        kept_drafts += 1   # this draft's KV row is resident
                    if done:
                        break
                self.pool.commit(req.rid, req.n_ctx,
                                 [fed_tok] + d[:kept_drafts].tolist())
                self.requant_ops_performed += \
                    (1 + len(d)) * self._elems_per_token
                self.requant_ops_wasted_spec += \
                    (len(d) - kept_drafts) * self._elems_per_token
                # every fed row (real token + all drafts) ran the W8A8
                # forward; rejected drafts' forward ops are pure waste
                self.requant_ops_forward += \
                    (1 + len(d)) * self._fwd_elems_per_token
                self.requant_ops_forward_wasted_spec += \
                    (len(d) - kept_drafts) * self._fwd_elems_per_token
                self.energy.charge("decode", (1 + kept_drafts) * ept, n_out)
                self.energy.charge("spec_wasted",
                                   (len(d) - kept_drafts) * ept,
                                   len(d) - kept_drafts)
                self.spec_drafted += len(d)
                self.spec_accepted += acc
                req.n_ctx += 1 + kept_drafts
                if done:
                    self.sched.finish(req, now)
                else:
                    self.pool.retract(req.rid, req.n_ctx)
                self.requant_ops_avoided += \
                    req.n_ctx * self._elems_per_token
            else:
                self.pool.commit(req.rid, req.n_ctx, [fed_tok])
                self.requant_ops_performed += self._elems_per_token
                self.requant_ops_forward += self._fwd_elems_per_token
                self.energy.charge("decode", ept, 1)
                req.n_ctx += 1
                self.requant_ops_avoided += \
                    req.n_ctx * self._elems_per_token
                tok = int(out[i, 0])
                done = req.finished_by(tok, self.max_model_len)
                req.generated.append(tok)
                tr.req_token(req.rid, now)
                if done:
                    self.sched.finish(req, now)

    def _dispatch_ragged(self, tokens, positions, dest, bt, q_start, q_len,
                         kv_len, temps, topks, sample_start, n_drafts,
                         t_real: int = 0):
        """One unified dispatch + host sync; timed under the work-list
        shape key ``("ragged", T_pad, S_pad)`` so compile-vs-steady is
        attributed to what actually ran (satellite: summarize_step_times
        keyed by dispatched shape).  Emits one ``dispatch`` span per call
        when tracing is on (stream shape, real vs padded tokens,
        compile-vs-steady flag) and — with cost analysis enabled — runs
        the AOT ``cost_analysis`` once per new shape BEFORE the donating
        call consumes the cache buffer."""
        t_start = self._now()
        t0 = time.perf_counter()
        self._step_counter += 1
        topks = np.asarray(topks)
        cap = int(topks.max()) if topks.any() else None
        topks_arg = jnp.asarray(topks) if topks.any() else None
        rb = RaggedBatch(
            dest=jnp.asarray(dest), block_tables=jnp.asarray(bt),
            q_start=jnp.asarray(q_start), q_len=jnp.asarray(q_len),
            kv_len=jnp.asarray(kv_len))
        shape_key = ("ragged", len(tokens), len(temps))
        first_call = shape_key not in self._step_times
        args = (self.params, jnp.asarray(tokens), self.cache,
                jnp.asarray(positions), rb, jnp.asarray(temps), topks_arg,
                jnp.asarray(sample_start), jnp.asarray(n_drafts),
                jnp.asarray(self._step_counter, jnp.uint32), cap)
        if self.profiler.cost:
            self.profiler.cost_for(shape_key, self._ragged_fn, *args)
        if self.profiler.profile_dir is not None:
            with self.profiler.step_annotation("ragged_step",
                                               self._step_counter):
                out, n_acc, self.cache = self._ragged_fn(*args)
        else:
            out, n_acc, self.cache = self._ragged_fn(*args)
        out, n_acc = np.asarray(out), np.asarray(n_acc)   # host sync
        dt = time.perf_counter() - t0
        self._step_times.setdefault(shape_key, []).append(dt)
        tr = self.tracer
        if tr.enabled:
            tr.span("ragged_step", "dispatch", t_start, dt, {
                "shape": f"T{len(tokens)}xS{len(temps)}",
                "real_tokens": t_real,
                "padded_tokens": len(tokens) - t_real,
                "compile": first_call})
        return out, n_acc

    # -- prefill ----------------------------------------------------------

    def _run_prefills(self) -> None:
        # one shared token budget per engine step: admitting a long prompt
        # costs the decode batch at most `budget` tokens of extra latency
        budget = self.sched.prefill_token_budget
        for req in self.sched.prefill_jobs():
            zero_streak = 0
            while budget > 0 and req.state is RequestState.PREFILL:
                fed = self._prefill_chunk(req, budget)
                budget -= fed
                # progress guard: the only legitimate zero-token return is
                # the CoW-failure path, whose preemption side-effect flips
                # req.state and exits this loop.  If the state is STILL
                # PREFILL after two consecutive zero-token iterations,
                # something broke that contract — fail fast instead of
                # spinning the engine forever.
                zero_streak = zero_streak + 1 if fed == 0 else 0
                if zero_streak >= 2:
                    raise RuntimeError(
                        f"prefill of request {req.rid} made no progress "
                        f"twice in a row (state {req.state}, "
                        f"{req.n_prefilled}/{len(req.feed)} fed, budget "
                        f"{budget}) — zero-progress CoW retry without "
                        f"preemption")

    def _prefill_chunk(self, req: Request, budget: int) -> int:
        start = req.n_prefilled
        c_real = min(self.sched.chunk, len(req.feed) - start, budget)
        # copy-on-write (DESIGN §10): any block this chunk writes into
        # must be private.  Only the fully-cached-feed re-feed ever lands
        # in a shared block (partial hits start at a block boundary), but
        # the check is general: preemption retry mirrors decode growth.
        if not self._cow_for_range(req, start, start + c_real):
            return 0                        # req itself was preempted
        c_pad = chunk_bucket(c_real, self.sched.chunk)
        cap = self.max_model_len - start
        if c_pad > cap:
            # near the end of the table the padded tail could land past
            # max_model_len (clamped block-table lookups would then alias
            # LIVE rows of the last block).  Shrink to the largest power
            # of two that fits — still pow2, so at most 2 widths below
            # the bucket floor (1 and 2) join the executable set; at
            # worst the boundary chunk feeds fewer real tokens.
            c_pad = 1 << (cap.bit_length() - 1)
            c_real = min(c_real, c_pad)
        tokens = np.zeros((1, c_pad), np.int32)
        tokens[0, :c_real] = req.feed[start:start + c_real]
        positions = (start + np.arange(c_pad, dtype=np.int32))[None]
        bt = self.pool.table_row(req.rid, self.sched.nbmax)[None]
        toks = self._timed_step(tokens, positions, bt,
                                np.asarray([req.temperature], np.float32),
                                np.asarray([self._req_top_k(req)], np.int32),
                                c_real - 1, name="prefill", n_real=c_real)
        self.dispatched_tokens += c_pad
        self.padded_tokens += c_pad - c_real
        req.n_prefilled += c_real
        req.n_ctx = req.n_prefilled
        # the chunk's KV rows are device-resident now: full blocks this
        # completes become content-addressable (publish is a no-op when
        # the prefix cache is off)
        self.pool.commit(req.rid, start, req.feed[start:start + c_real])
        self.prefill_chunks += 1
        self.requant_ops_performed += c_real * self._elems_per_token
        self.requant_ops_forward += c_real * self._fwd_elems_per_token
        self.energy.charge(
            "prefill",
            c_real * (self._elems_per_token + self._fwd_elems_per_token),
            c_real)
        tr = self.tracer
        if tr.enabled:
            # chunk boundary: part of the scheduler-decision stream the
            # flight recorder diffs between runs (DESIGN §15)
            tr.event("sched.prefill_chunk", "sched", ts=self._now(),
                     args={"rid": req.rid, "start": start,
                           "tokens": c_real})
        tr.req_mark(req.rid, "first_chunk", self._now())
        if req.n_prefilled == len(req.feed):
            # prompt fully resident: the token sampled from the last real
            # row IS the first generated token (for preemption resumes it
            # just continues the sequence)
            tok = int(toks[0])
            now = self._now()
            if req.t_first is None:
                req.t_first = now
            tr.req_mark(req.rid, "first_token", now)
            tr.req_token(req.rid, now)
            done = req.finished_by(tok, self.max_model_len)
            req.generated.append(tok)
            if done:
                self.sched.finish(req, now)
            else:
                req.state = RequestState.DECODE
        return c_real

    # -- decode -----------------------------------------------------------

    def _run_decode(self) -> None:
        now = self._now()
        for req in list(self.sched.decode_reqs()):
            if req.slot is not None and req.state is RequestState.DECODE:
                self.sched.grow_for_decode(req, now)
        reqs = self.sched.decode_reqs()
        if not reqs:
            return
        tokens = np.zeros((self.n_slots, 1), np.int32)
        positions = np.zeros((self.n_slots, 1), np.int32)
        bt = np.full((self.n_slots, self.sched.nbmax), TRASH_BLOCK, np.int32)
        temps = np.zeros((self.n_slots,), np.float32)
        topks = np.zeros((self.n_slots,), np.int32)
        for req in reqs:
            s = req.slot
            tokens[s, 0] = req.generated[-1]
            positions[s, 0] = req.n_ctx
            bt[s] = self.pool.table_row(req.rid, self.sched.nbmax)
            temps[s] = req.temperature
            topks[s] = self._req_top_k(req)
        toks = self._timed_step(tokens, positions, bt, temps, topks, 0,
                                name="decode", n_real=len(reqs))
        self.dispatched_tokens += self.n_slots
        self.padded_tokens += self.n_slots - len(reqs)
        self.decode_steps += 1
        self.requant_ops_performed += len(reqs) * self._elems_per_token
        self.requant_ops_forward += len(reqs) * self._fwd_elems_per_token
        self.energy.charge(
            "decode",
            len(reqs) * (self._elems_per_token + self._fwd_elems_per_token),
            len(reqs))
        now = self._now()
        tr = self.tracer
        for req in reqs:
            # the fed token's KV row is resident: blocks that fill during
            # decode publish too, so a preempted resume (or a later request
            # sharing prompt+generation) can re-attach them
            self.pool.commit(req.rid, req.n_ctx, [req.generated[-1]])
            req.n_ctx += 1
            # the dataflow the int8-resident pool deletes: dequantizing the
            # slot's whole live cache before attending, EVERY step
            self.requant_ops_avoided += req.n_ctx * self._elems_per_token
            tok = int(toks[req.slot])
            done = req.finished_by(tok, self.max_model_len)
            req.generated.append(tok)
            tr.req_token(req.rid, now)
            if done:
                self.sched.finish(req, now)

    # -- speculative decode (DESIGN §11) ---------------------------------

    def _spec_budget(self, req: Request) -> int:
        """How many tokens are worth drafting for ``req`` this step: each
        verify step emits at least one token, so drafting past the
        request's remaining generation (or the model length) only burns
        quantization ops on rows that can never be kept."""
        return max(0, min(self.spec_k,
                          req.max_new_tokens - req.n_generated - 1,
                          self.max_model_len - 1 - req.n_ctx))

    def _run_spec_decode(self) -> bool:
        """One speculative verify step at (n_slots, K+1): draft, grow the
        pool for the speculative tail (degrading the tail under pressure
        before preempting peers), COW any shared block the tail would
        land in, verify all slots in one fused dispatch, then COMMIT only
        accepted tokens and RETRACT the rejected tail's blocks.  Returns
        False when no slot produced a draft — the caller then runs the
        plain (B, 1) decode step instead of paying for a K+1-wide one."""
        now = self._now()
        proposals = {}
        for req in self.sched.decode_reqs():
            budget = self._spec_budget(req)
            if budget > 0:
                d = np.asarray(self.drafter.draft(
                    np.concatenate([req.prompt, np.asarray(
                        req.generated, np.int32)]), budget), np.int32)
                proposals[req.rid] = d[:budget]
        if not any(len(d) for d in proposals.values()):
            return False

        plans: dict[int, np.ndarray] = {}
        for req in list(self.sched.decode_reqs()):
            if req.slot is None or req.state is not RequestState.DECODE:
                continue
            drafts = proposals.get(req.rid, np.empty(0, np.int32))
            granted = self.sched.grow_for_spec(req, now, len(drafts))
            if granted is None:
                continue                    # req itself was preempted
            drafts = drafts[:granted]
            # the speculative tail must only write private blocks: COW
            # any shared/published block overlapping [n_ctx, n_ctx + k]
            if not self._cow_for_range(req, req.n_ctx,
                                       req.n_ctx + 1 + len(drafts)):
                continue                    # req itself was preempted
            plans[req.rid] = drafts
        # growth/COW for a later slot may have preempted an earlier one —
        # only requests still resident in a slot join the verify batch
        reqs = [r for r in self.sched.decode_reqs() if r.rid in plans]
        if not reqs:
            return bool(plans)

        kp1 = self.spec_k + 1
        bs = self.pool.block_size
        # one guaranteed-TRASH table column past nbmax: padded draft
        # positions point there, so their scatter lands in the trash
        # block even for a full-length sequence (a clamped lookup would
        # alias its last LIVE block)
        width = self.sched.nbmax + 1
        pad_pos = self.sched.nbmax * bs
        tokens = np.zeros((self.n_slots, kp1), np.int32)
        positions = np.full((self.n_slots, kp1), pad_pos, np.int32)
        bt = np.full((self.n_slots, width), TRASH_BLOCK, np.int32)
        temps = np.zeros((self.n_slots,), np.float32)
        topks = np.zeros((self.n_slots,), np.int32)
        n_drafts = np.zeros((self.n_slots,), np.int32)
        for req in reqs:
            s, d = req.slot, plans[req.rid]
            tokens[s, 0] = req.generated[-1]
            tokens[s, 1:1 + len(d)] = d
            positions[s, :1 + len(d)] = req.n_ctx + np.arange(
                1 + len(d), dtype=np.int32)
            bt[s, :self.sched.nbmax] = self.pool.table_row(
                req.rid, self.sched.nbmax)
            temps[s] = req.temperature
            topks[s] = self._req_top_k(req)
            n_drafts[s] = len(d)
        n_real = sum(1 + len(plans[r.rid]) for r in reqs)
        out, n_acc = self._timed_spec_step(tokens, positions, bt, temps,
                                           topks, n_drafts, n_real=n_real)
        self.dispatched_tokens += self.n_slots * kp1
        self.padded_tokens += self.n_slots * kp1 - n_real
        self.spec_steps += 1
        self.spec_slot_steps += len(reqs)
        now = self._now()
        tr = self.tracer
        for req in reqs:
            d = plans[req.rid]
            acc = int(n_acc[req.slot])
            emitted = out[req.slot, :acc + 1].tolist()
            kept_drafts = 0
            n_out = 0
            done = False
            for i, tok in enumerate(emitted):
                done = req.finished_by(int(tok), self.max_model_len)
                req.generated.append(int(tok))
                tr.req_token(req.rid, now)
                self.spec_emitted += 1
                n_out += 1
                if i < acc:
                    kept_drafts += 1    # this draft's KV row is resident
                if done:
                    break
            # publish ONLY accepted tokens (the fed token + the kept
            # draft prefix); the rejected tail's rows never reach the
            # prefix cache, and retract frees any block they alone held
            self.pool.commit(req.rid, req.n_ctx,
                             [int(tokens[req.slot, 0])]
                             + d[:kept_drafts].tolist())
            self.requant_ops_performed += \
                (1 + len(d)) * self._elems_per_token
            self.requant_ops_wasted_spec += \
                (len(d) - kept_drafts) * self._elems_per_token
            self.requant_ops_forward += \
                (1 + len(d)) * self._fwd_elems_per_token
            self.requant_ops_forward_wasted_spec += \
                (len(d) - kept_drafts) * self._fwd_elems_per_token
            ept = self._elems_per_token + self._fwd_elems_per_token
            self.energy.charge("decode", (1 + kept_drafts) * ept, n_out)
            self.energy.charge("spec_wasted", (len(d) - kept_drafts) * ept,
                               len(d) - kept_drafts)
            self.spec_drafted += len(d)
            self.spec_accepted += acc
            req.n_ctx += 1 + kept_drafts
            if done:
                self.sched.finish(req, now)
            else:
                self.pool.retract(req.rid, req.n_ctx)
            # the counterfactual a dequantize-per-step dataflow pays: the
            # slot's whole live cache re-requantized once per VERIFY step
            # (speculation amortizes it over up to K+1 emitted tokens)
            self.requant_ops_avoided += req.n_ctx * self._elems_per_token
        return True

    # -- fixed-slab recurrent step (DESIGN §16) ---------------------------

    def _snapshot_slab(self, req: Request) -> dict:
        """Scheduler preemption hook (pure-recurrent substrate): copy the
        sequence's whole state slab to the host.  O(state) bytes instead
        of the attention substrate's recompute-the-prefix, because the
        slab IS the entire sequence state.  Codes are copied as codes
        (int8 mode) or raw fp32, so the resume is bit-exact."""
        slab = self.state_pool.slab_of(req.rid)
        state = {k: np.asarray(v[:, slab])
                 for k, v in self.cache["state"].items()}
        return {"n_ctx": req.n_ctx, "state": state}

    def _restore_snapshot(self, req: Request) -> None:
        """Drop a preemption snapshot back into the freshly allocated
        slab.  Admission already resumed the token bookkeeping from
        ``snapshot['n_ctx']``; the slab's scale exponent is the engine's
        fixed per-run grid, so the codes reinterpret identically."""
        slab = self.state_pool.slab_of(req.rid)
        st = self.cache["state"]
        for k, v in req.snapshot["state"].items():
            st[k] = st[k].at[:, slab].set(jnp.asarray(v))
        req.snapshot = None

    def _reset_slab(self, req: Request) -> None:
        """Zero a freshly allocated slab.  Slabs recycle LIFO off the free
        stack still holding their previous owner's FINAL state — a new
        sequence must integrate from zero (the stale-state bug shows up
        as token divergence only several tokens in, after the decay has
        had time to amplify the inherited state's contribution)."""
        slab = self.state_pool.slab_of(req.rid)
        if "state" in self.cache:               # pure recurrent
            st = self.cache["state"]
            for k, v in st.items():
                st[k] = v.at[:, slab].set(0)
        else:                                   # hybrid Mamba slabs
            self.cache["ssm"] = jax.tree.map(
                lambda a: a.at[:, :, slab].set(0), self.cache["ssm"])

    def _charge_recurrent(self, phase: str, n_tok: int,
                          int8_state: bool) -> None:
        """Table-5 accounting for one sequence's share of a recurrent
        step: ``n_tok`` per-token KV appends (the hybrid's shared
        attention blocks; zero on pure recurrent) plus ONE whole-slab
        state requant — context-free, the §16 headline.  int8 slabs
        PERFORM the state ops; fp32 slabs book the identical count as
        the dequantize-per-step counterfactual ``avoided``, so the
        ops/token gauge compares across storage modes."""
        kv = n_tok * self._elems_per_token
        st = self._state_elems_per_step
        fwd = n_tok * self._fwd_elems_per_token
        self.requant_ops_state += st
        if int8_state:
            self.requant_ops_performed += kv + st
            self.energy.charge(phase, kv + st + fwd, n_tok)
        else:
            self.requant_ops_performed += kv
            self.requant_ops_avoided += st
            self.energy.charge(phase, kv + fwd, n_tok)
        self.requant_ops_forward += fwd

    def _run_recurrent_step(self) -> None:
        """Serve the whole mixed work-list in ONE fixed-shape dispatch
        (n_slots, chunk): prefill rows feed their next chunk (q_len = c),
        decode rows feed their last sampled token (q_len = 1), idle lanes
        ride along inert (q_len = 0 against the trash slab).  There is no
        ragged flattening and no per-shape phase trio — the recurrent
        batch is already shape-stable, so jit sees exactly one
        executable.  On the hybrid substrate the same dispatch carries
        per-row positions and block tables: Mamba layers consume the
        slabs while the shared attention blocks scatter/gather the paged
        KV pool, in the same jitted step."""
        sub = self.substrate
        now = self._now()
        if sub.grows:
            # hybrid KV half: decode rows append one KV row per step, so
            # block tables may need to grow — growth can preempt a peer,
            # exactly like the attention decode path
            for req in list(self.sched.decode_reqs()):
                if req.slot is not None \
                        and req.state is RequestState.DECODE:
                    self.sched.grow_for_decode(req, now)
        prefills = []
        for req in self.sched.prefill_jobs():
            start = req.n_prefilled
            c_real = min(self.sched.chunk, len(req.feed) - start)
            prefills.append((req, start, c_real))
        decodes = self.sched.decode_reqs()
        if not prefills and not decodes:
            return
        b, c = self.n_slots, self.sched.chunk
        tokens = np.zeros((b, c), np.int32)
        q_len = np.zeros((b,), np.int32)
        slab_ids = np.full((b,), TRASH_SLAB, np.int32)   # idle lanes
        temps = np.zeros((b,), np.float32)
        topks = np.zeros((b,), np.int32)
        if sub.grows:
            # one guaranteed-TRASH table column past nbmax: idle/padded
            # positions point there, so their KV scatter lands in the
            # trash block even for a full-length sequence
            width = self.sched.nbmax + 1
            pad_pos = self.sched.nbmax * self.pool.block_size
            positions = np.full((b, c), pad_pos, np.int32)
            bt = np.full((b, width), TRASH_BLOCK, np.int32)
        else:
            positions = bt = None
        for req, start, c_real in prefills:
            s = req.slot
            tokens[s, :c_real] = req.feed[start:start + c_real]
            q_len[s] = c_real
            slab_ids[s] = self.state_pool.slab_of(req.rid)
            temps[s] = req.temperature
            topks[s] = self._req_top_k(req)
            if sub.grows:
                positions[s, :c_real] = start + np.arange(c_real,
                                                          dtype=np.int32)
                bt[s, :self.sched.nbmax] = self.pool.table_row(
                    req.rid, self.sched.nbmax)
        for req in decodes:
            s = req.slot
            tokens[s, 0] = req.generated[-1]
            q_len[s] = 1
            slab_ids[s] = self.state_pool.slab_of(req.rid)
            temps[s] = req.temperature
            topks[s] = self._req_top_k(req)
            if sub.grows:
                positions[s, 0] = req.n_ctx
                bt[s, :self.sched.nbmax] = self.pool.table_row(
                    req.rid, self.sched.nbmax)
        n_real = int(q_len.sum())
        toks = self._dispatch_recurrent(tokens, slab_ids, q_len,
                                        positions, bt, temps, topks,
                                        n_real)
        self.recurrent_steps += 1
        self.dispatched_tokens += b * c
        self.padded_tokens += b * c - n_real
        int8_state = self.cfg.state_bits == 8
        now = self._now()
        tr = self.tracer

        # -- post-process: prefill rows (mirrors _prefill_chunk) ----------
        for req, start, c_real in prefills:
            req.n_prefilled += c_real
            req.n_ctx = req.n_prefilled
            if sub.grows:
                self.pool.commit(req.rid, start,
                                 req.feed[start:start + c_real])
            self.prefill_chunks += 1
            self._charge_recurrent("prefill", c_real, int8_state)
            if tr.enabled:
                # chunk boundary: part of the scheduler-decision stream
                # the flight recorder diffs between runs (DESIGN §15)
                tr.event("sched.prefill_chunk", "sched", ts=now, args={
                    "rid": req.rid, "start": start, "tokens": c_real})
            tr.req_mark(req.rid, "first_chunk", now)
            if req.n_prefilled == len(req.feed):
                tok = int(toks[req.slot])
                if req.t_first is None:
                    req.t_first = now
                tr.req_mark(req.rid, "first_token", now)
                tr.req_token(req.rid, now)
                done = req.finished_by(tok, self.max_model_len)
                req.generated.append(tok)
                if done:
                    self.sched.finish(req, now)
                else:
                    req.state = RequestState.DECODE

        # -- post-process: decode rows (mirrors _run_decode) --------------
        for req in decodes:
            if sub.grows:
                self.pool.commit(req.rid, req.n_ctx, [req.generated[-1]])
            req.n_ctx += 1
            if sub.grows:
                # the hybrid's KV half still avoids the dequantize-the-
                # whole-cache-per-step counterfactual, same as attention
                self.requant_ops_avoided += \
                    req.n_ctx * self._elems_per_token
            self._charge_recurrent("decode", 1, int8_state)
            tok = int(toks[req.slot])
            done = req.finished_by(tok, self.max_model_len)
            req.generated.append(tok)
            tr.req_token(req.rid, now)
            if done:
                self.sched.finish(req, now)

    def _dispatch_recurrent(self, tokens, slab_ids, q_len, positions, bt,
                            temps, topks, n_real):
        """Recurrent counterpart of ``_dispatch``: same step counter,
        top-k fast path, timing and host sync, but the descriptor set is
        (slab_ids, q_len) plus the hybrid's (positions, block tables) —
        ``None`` on the pure-recurrent substrate, where jit simply sees
        an empty pytree leaf."""
        t_start = self._now()
        t0 = time.perf_counter()
        self._step_counter += 1
        topks = np.asarray(topks)
        cap = int(topks.max()) if topks.any() else None
        topks_arg = jnp.asarray(topks) if topks.any() else None
        shape_key = ("recurrent",) + tuple(tokens.shape)
        first_call = shape_key not in self._step_times
        args = (self.params, jnp.asarray(tokens), self.cache,
                jnp.asarray(slab_ids), jnp.asarray(q_len),
                None if positions is None else jnp.asarray(positions),
                None if bt is None else jnp.asarray(bt),
                jnp.asarray(temps), topks_arg,
                jnp.asarray(self._step_counter, jnp.uint32), cap)
        if self.profiler.cost:
            self.profiler.cost_for(shape_key, self._rec_fn, *args)
        if self.profiler.profile_dir is not None:
            with self.profiler.step_annotation("recurrent",
                                               self._step_counter):
                toks, self.cache = self._rec_fn(*args)
        else:
            toks, self.cache = self._rec_fn(*args)
        toks = np.asarray(toks)                      # host sync
        dt = time.perf_counter() - t0
        self._step_times.setdefault(shape_key, []).append(dt)
        tr = self.tracer
        if tr.enabled:
            n_disp = int(np.prod(tokens.shape))
            tr.span("recurrent", "dispatch", t_start, dt, {
                "shape": "x".join(map(str, tokens.shape)),
                "real_tokens": n_real,
                "padded_tokens": n_disp - n_real,
                "compile": first_call})
        return toks

    # -- shared step plumbing --------------------------------------------

    def _cow_for_range(self, req: Request, start: int, end: int) -> bool:
        """Copy-on-write every SHARED block overlapping feed positions
        [start, end) so the chunk's KV scatter only touches private
        blocks.  The pool moves the map; the device rows are duplicated
        here (one jitted block copy, donated — block_size rows per layer,
        never the whole arena).  Returns False iff ``req`` itself was
        preempted while finding a block for the copy."""
        if self.substrate.fixed_state:
            raise BlockPoolError(
                f"copy-on-write on the {self.substrate.kind} substrate: "
                f"sequence {req.rid} keeps fixed-size recurrent state and "
                f"never shares a block (no prefix cache to COW from)")
        bs = self.pool.block_size
        for idx in range(start // bs, -(-end // bs)):
            if idx >= self.pool.n_blocks_of(req.rid):
                break                       # rows beyond the table: extend
            if self.pool.block_writable(req.rid, idx):
                continue
            pair = self.sched.cow_for_prefill(req, idx, self._now())
            if pair is None:
                return False
            src, dst = pair
            self.cache = self._cow_fn(self.cache, jnp.asarray(src),
                                      jnp.asarray(dst))
        return True

    def _req_top_k(self, req: Request) -> int:
        return req.top_k if req.top_k > 0 else self.default_top_k

    def _dispatch(self, step_fn, tokens, positions, bt, temps, topks,
                  mode_arg, name: str = "step",
                  n_real: Optional[int] = None):
        """Shared plumbing for the jitted decode/prefill and verify
        steps: step counter, the top-k fast path, timing, host sync.

        all-zero top-k (the greedy/full-vocab default) drops to the
        sampler's None fast path: no top-k cutoff ever enters the hot
        executable.  Otherwise the batch's max top-k rides along as the
        STATIC k_cap (an O(V log k) lax.top_k, one extra jit variant per
        distinct cap — bounded by the workload's top-k settings).
        ``mode_arg`` is the per-step int payload: the last real row index
        for sampled steps, the per-slot draft counts for verify steps.
        ``name``/``n_real`` feed the trace span (dispatch kind + padded
        vs real token count) when tracing is on.
        """
        t_start = self._now()
        t0 = time.perf_counter()
        self._step_counter += 1
        topks = np.asarray(topks)
        cap = int(topks.max()) if topks.any() else None
        topks_arg = jnp.asarray(topks) if topks.any() else None
        shape_key = tuple(tokens.shape)
        first_call = shape_key not in self._step_times
        args = (self.params, jnp.asarray(tokens), self.cache,
                jnp.asarray(positions), jnp.asarray(bt), jnp.asarray(temps),
                topks_arg, jnp.asarray(mode_arg, jnp.int32),
                jnp.asarray(self._step_counter, jnp.uint32), cap)
        if self.profiler.cost:
            self.profiler.cost_for(shape_key, step_fn, *args)
        if self.profiler.profile_dir is not None:
            with self.profiler.step_annotation(name, self._step_counter):
                out = step_fn(*args)
        else:
            out = step_fn(*args)
        *out, self.cache = out
        out = [np.asarray(o) for o in out]       # host sync
        dt = time.perf_counter() - t0
        self._step_times.setdefault(shape_key, []).append(dt)
        tr = self.tracer
        if tr.enabled:
            n_disp = int(np.prod(tokens.shape))
            tr.span(name, "dispatch", t_start, dt, {
                "shape": "x".join(map(str, tokens.shape)),
                "real_tokens": n_disp if n_real is None else n_real,
                "padded_tokens": 0 if n_real is None else n_disp - n_real,
                "compile": first_call})
        return out

    def _timed_step(self, tokens, positions, bt, temps, topks, last_idx,
                    name: str = "step", n_real: Optional[int] = None):
        toks, = self._dispatch(self._step_fn, tokens, positions, bt,
                               temps, topks, last_idx, name=name,
                               n_real=n_real)
        return toks

    def _timed_spec_step(self, tokens, positions, bt, temps, topks,
                         n_drafts, n_real: Optional[int] = None):
        out, n_acc = self._dispatch(self._spec_fn, tokens, positions, bt,
                                    temps, topks, n_drafts,
                                    name="spec_verify", n_real=n_real)
        return out, n_acc

    # -- report -----------------------------------------------------------

    def outputs(self) -> dict[int, np.ndarray]:
        return {r.rid: np.asarray(r.generated, np.int32)
                for r in self.sched.done}

    def _wall(self) -> float:
        return self._wall_s or self._now()

    def _latency_samples(self) -> dict[str, list]:
        """Legacy latency sample lists from the finished requests'
        timestamps (the pre-§14 source; the trace timelines must
        reproduce these exactly — cross-checked in tests/test_obs.py)."""
        done = self.sched.done
        return {
            "ttft": [r.t_first - r.arrival for r in done
                     if r.t_first is not None],
            "e2e": [r.t_done - r.arrival for r in done
                    if r.t_done is not None],
            "tpot": [(r.t_done - r.t_first) / (r.n_generated - 1)
                     for r in done if r.n_generated > 1],
        }

    def _register_metrics(self) -> None:
        """Declare every report field on the metrics registry, in report
        order (DESIGN §14).  All metrics are BOUND (FuncMetric): the
        engine's plain counters, ``PoolStats``/``CacheStats`` and the
        request lists stay the single source of truth (the property
        tests drive them directly); the registry owns naming, typing,
        help text and exposition.  ``engine.report()`` is
        ``metrics.nested()`` — a renamed or undocumented field now fails
        the golden-schema test instead of silently breaking a downstream
        bench gate."""
        m = self.metrics
        sched, pool = self.sched, self.pool
        f = m.func

        def n_requests():
            return len(sched.done) + len(sched.waiting) \
                + len(sched.active())

        def tokens_per_s():
            wall = self._wall()
            gen = sum(r.n_generated for r in sched.done)
            return round(gen / wall, 2) if wall else None

        f("n_requests", "requests seen (done + waiting + active)",
          n_requests, kind="counter", typ=int)
        f("completed", "requests served to completion",
          lambda: len(sched.done), kind="counter", typ=int)
        f("preemptions", "recompute preemptions among completed requests",
          lambda: sum(r.preemptions for r in sched.done),
          kind="counter", typ=int)
        f("gen_tokens", "tokens generated across completed requests",
          lambda: sum(r.n_generated for r in sched.done),
          kind="counter", typ=int)
        f("prompt_tokens", "prompt tokens across completed requests",
          lambda: sum(len(r.prompt) for r in sched.done),
          kind="counter", typ=int)
        f("wall_s", "run wall-clock on the engine clock (fast-forwarded "
          "arrival gaps excluded from real time)",
          lambda: round(self._wall(), 4), unit="s", typ=float)
        f("tokens_per_s", "generated-token throughput over wall_s",
          tokens_per_s, typ=float, optional=True)
        f("decode_steps", "plain (non-speculative) decode dispatches",
          lambda: self.decode_steps, kind="counter", typ=int)
        f("spec_steps", "speculative verify dispatches",
          lambda: self.spec_steps, kind="counter", typ=int)
        f("prefill_chunks", "chunked-prefill pieces dispatched",
          lambda: self.prefill_chunks, kind="counter", typ=int)
        f("ragged", "unified ragged dispatch path enabled (DESIGN §12)",
          lambda: self.ragged, typ=bool)
        f("ragged_steps", "unified ragged dispatches",
          lambda: self.ragged_steps, kind="counter", typ=int)
        f("substrate", "sequence-state substrate serving this model — "
          "attention block tables, recurrent state slabs, or the hybrid "
          "of both (DESIGN §16)",
          lambda: self.substrate.kind, typ=str)
        f("recurrent_steps", "fixed-shape recurrent dispatches "
          "(DESIGN §16)",
          lambda: self.recurrent_steps, kind="counter", typ=int)
        # padding honesty: tokens dispatched vs tokens that carried real
        # work — pow2 bucket rounding, empty decode slots, unused draft
        # columns — invisible in the Table-5 accounting before PR 6
        f("dispatched_tokens", "token rows dispatched incl. padding",
          lambda: self.dispatched_tokens, kind="counter", typ=int)
        f("padded_tokens", "dispatched token rows that carried no work",
          lambda: self.padded_tokens, kind="counter", typ=int)
        f("padding_frac", "padded_tokens / dispatched_tokens",
          lambda: round(self.padded_tokens / self.dispatched_tokens, 4)
          if self.dispatched_tokens else None, typ=float, optional=True)
        if self.spec_k:
            self._register_spec_metrics()
        for name, q in (("ttft_s", "time to first token"),
                        ("tpot_s", "per-output-token time"),
                        ("e2e_s", "request end-to-end latency")):
            key = name.split("_")[0] if name != "e2e_s" else "e2e"
            for p in (50, 99):
                f(f"{name}.p{p}", f"{q} p{p} (legacy request-timestamp "
                  f"source), seconds",
                  (lambda key=key, p=p:
                   _pct(self._latency_samples()[key], p)),
                  unit="s", typ=float, optional=True)
        f("step_shapes", "per-dispatched-shape compile-vs-steady step-time"
          " summary (dynamic keys: one per jitted shape)",
          lambda: summarize_step_times(self._step_times), typ=dict)
        if pool is not None:
            self._register_pool_metrics()
        if self.state_pool is not None:
            self._register_state_pool_metrics()
        if pool is not None and pool.cache is not None:
            self._register_cache_metrics()
        self._register_hwcost_metrics()
        self._register_energy_metrics()
        self._register_timeline_metrics()
        f("obs.trace_enabled", "ring-event tracing active",
          lambda: self.tracer.enabled, typ=bool)
        f("obs.trace_events", "events currently held in the trace ring",
          lambda: len(self.tracer.events), typ=int)
        f("obs.trace_emitted", "events emitted since start/reset",
          lambda: self.tracer.n_emitted, kind="counter", typ=int)
        f("obs.trace_dropped", "events evicted from the bounded ring",
          lambda: self.tracer.dropped, kind="counter", typ=int)
        f("obs.trace_capacity", "trace ring capacity (hard bound)",
          lambda: self.tracer.capacity, typ=int)
        # silent-span-loss visibility (DESIGN §15): the prometheus-
        # conventional _total alias of the drop counter plus the ring
        # occupancy fraction — a scrape can alert on drops BEFORE a
        # truncated trace surprises someone in Perfetto
        f("obs.trace_dropped_total",
          "events evicted from the bounded ring (prometheus-"
          "conventional view of obs.trace_dropped)",
          lambda: self.tracer.dropped, kind="counter", typ=int,
          alias_of="obs.trace_dropped")
        f("obs.trace_ring_used",
          "trace ring occupancy fraction (held / capacity); 1.0 means "
          "the next event evicts the oldest",
          lambda: round(len(self.tracer.events) / self.tracer.capacity,
                        6), typ=float)
        if self.slo is not None:
            self._register_slo_metrics()
        if self.profiler.enabled:
            f("profile", "jax-profiler/cost-analysis attribution "
              "(dynamic keys; present only when profiling is on)",
              lambda: self.profiler.report(), typ=dict, optional=True)
        m.check_aliases()

    def _register_slo_metrics(self) -> None:
        f = self.metrics.func
        f("slo.objectives", "number of configured SLO objectives",
          lambda: len(self.slo.objectives), typ=int)
        f("slo.evaluations", "monitoring ticks since start/reset "
          "(one per engine step)",
          lambda: self.slo.evaluations, kind="counter", typ=int)
        f("slo.alerts_fired", "burn-rate alert firings since "
          "start/reset",
          lambda: self.slo.alerts_fired, kind="counter", typ=int)
        f("slo.alerts_active", "objectives currently in alert",
          lambda: self.slo.alerts_active, typ=int)
        f("slo.worst_burn_rate", "max burn rate across objectives at "
          "the last evaluation (1.0 = violations exactly exhaust the "
          "error budget)",
          lambda: self.slo.worst_burn_rate(), typ=float, optional=True)
        f("slo.status", "per-objective window/burn/firing state "
          "(dynamic keys: one per objective)",
          lambda: self.slo.status(), typ=dict)

    def _register_spec_metrics(self) -> None:
        f = self.metrics.func
        f("speculative.spec_k", "max draft tokens per verify step",
          lambda: self.spec_k, typ=int)
        f("speculative.drafter", "drafter implementation",
          lambda: type(self.drafter).__name__, typ=str)
        f("speculative.verify_steps", "speculative verify dispatches",
          lambda: self.spec_steps, kind="counter", typ=int)
        f("speculative.fallback_decode_steps",
          "plain decode dispatches (no slot produced a draft)",
          lambda: self.decode_steps, kind="counter", typ=int)
        f("speculative.drafted_tokens", "tokens proposed by the drafter",
          lambda: self.spec_drafted, kind="counter", typ=int)
        f("speculative.accepted_tokens", "drafted tokens that verified",
          lambda: self.spec_accepted, kind="counter", typ=int)
        f("speculative.acceptance_rate", "accepted / drafted",
          lambda: round(self.spec_accepted / self.spec_drafted, 4)
          if self.spec_drafted else None, typ=float, optional=True)
        f("speculative.emitted_tokens",
          "tokens emitted by verify steps (accepted + correction/bonus)",
          lambda: self.spec_emitted, kind="counter", typ=int)
        # emitted per (slot, verify step) pair — the amortization
        # speculation buys a sequence (1.0 == plain decode; K+1 == every
        # draft accepted).  Normalized per SLOT so batching can't
        # inflate it past K+1.
        f("speculative.tokens_per_step",
          "emitted tokens per (slot, verify step) pair",
          lambda: round(self.spec_emitted / self.spec_slot_steps, 4)
          if self.spec_slot_steps else None, typ=float, optional=True)
        f("speculative.retracts", "speculative rollbacks that freed "
          "blocks (view of pool.retracts — single source of truth)",
          lambda: self.pool.stats.retracts, kind="counter", typ=int,
          alias_of="pool.retracts")
        f("speculative.retracted_blocks", "blocks freed by rollback "
          "(view of pool.retracted_blocks)",
          lambda: self.pool.stats.retracted_blocks, kind="counter",
          typ=int, alias_of="pool.retracted_blocks")
        f("speculative.requant_ops_wasted",
          "KV quant ops spent on rejected drafts (performed, rolled back)",
          lambda: self.requant_ops_wasted_spec, kind="counter", typ=int)
        f("speculative.drafter_calls", "draft() invocations",
          lambda: getattr(self.drafter, "stats").calls
          if hasattr(self.drafter, "stats") else 0,
          kind="counter", typ=int)
        f("speculative.drafter_proposed", "tokens the drafter proposed "
          "(before the engine's per-request budget truncation)",
          lambda: getattr(self.drafter, "stats").proposed
          if hasattr(self.drafter, "stats") else 0,
          kind="counter", typ=int)
        f("speculative.drafter_empty", "draft() calls that proposed "
          "nothing (request decodes at the plain per-token rate)",
          lambda: getattr(self.drafter, "stats").empty
          if hasattr(self.drafter, "stats") else 0,
          kind="counter", typ=int)

    def _register_pool_metrics(self) -> None:
        f, pool = self.metrics.func, self.pool
        f("pool.num_blocks", "pool capacity in blocks (incl. trash)",
          lambda: pool.num_blocks, typ=int)
        f("pool.block_size", "tokens per KV block",
          lambda: pool.block_size, typ=int)
        f("pool.peak_live_blocks", "max simultaneously-live blocks",
          lambda: pool.stats.peak_live, typ=int)
        f("pool.peak_utilization", "peak_live / allocatable blocks",
          lambda: round(pool.stats.peak_live
                        / max(pool.num_blocks - 1, 1), 3), typ=float)
        f("pool.utilization", "live blocks / allocatable blocks now",
          lambda: round(pool.utilization, 3), typ=float)
        f("pool.residency", "(live + cached) / allocatable blocks now",
          lambda: round(pool.residency, 3), typ=float)
        f("pool.allocs", "blocks handed out fresh (not cache hits)",
          lambda: pool.stats.allocs, kind="counter", typ=int)
        f("pool.frees", "block references released",
          lambda: pool.stats.frees, kind="counter", typ=int)
        f("pool.evictions", "blocks released by preemption",
          lambda: pool.stats.evictions, kind="counter", typ=int)
        f("pool.seq_evictions", "sequences preempted",
          lambda: pool.stats.seq_evictions, kind="counter", typ=int)
        f("pool.cache_evictions", "idle cached blocks reclaimed (LRU)",
          lambda: pool.stats.cache_evictions, kind="counter", typ=int)
        f("pool.retracts", "speculative rollbacks that freed blocks "
          "(canonical; speculative.retracts is a view of this)",
          lambda: pool.stats.retracts, kind="counter", typ=int)
        f("pool.retracted_blocks", "blocks freed by rollback (canonical)",
          lambda: pool.stats.retracted_blocks, kind="counter", typ=int)
        f("pool.alloc_failures", "alloc/extend requests refused",
          lambda: pool.stats.alloc_failures, kind="counter", typ=int)

    def _register_state_pool_metrics(self) -> None:
        """Fixed-slab substrate accounting (DESIGN §16) — the recurrent
        counterpart of the ``pool.*`` section."""
        f, sp = self.metrics.func, self.state_pool
        f("state_pool.num_slabs", "slab capacity (incl. trash slab 0)",
          lambda: sp.num_slabs, typ=int)
        f("state_pool.scale_exp", "fixed Eq.-1 scale exponent slabs are "
          "allocated with (0 when slabs store fp32 state)",
          lambda: sp.default_scale_exp, typ=int)
        f("state_pool.state_quant_ops_per_step", "ops to requantize one "
          "sequence's WHOLE state once — paid per step, context-free",
          lambda: self._state_elems_per_step, typ=int)
        f("state_pool.requant_ops_state", "whole-slab state requant ops "
          "booked so far (performed when slabs are int8, counterfactual "
          "otherwise) — the recurrent share of hwcost totals",
          lambda: self.requant_ops_state, kind="counter", typ=int)

        def state_ops_per_token():
            tok = self.energy.tokens["prefill"] + self.energy.tokens[
                "decode"]
            return round(self.requant_ops_state / tok, 2) if tok else None

        f("state_pool.state_ops_per_token", "recurrent-substrate share "
          "of hwcost.requant_ops_per_token — context-free by "
          "construction, the number the §16 bench gate compares against "
          "the attention baseline",
          state_ops_per_token, typ=float, optional=True)
        f("state_pool.peak_live_slabs", "max simultaneously-live slabs",
          lambda: sp.stats.peak_live, typ=int)
        f("state_pool.utilization", "live slabs / allocatable slabs now",
          lambda: round(sp.utilization, 3), typ=float)
        f("state_pool.allocs", "slabs handed out",
          lambda: sp.stats.allocs, kind="counter", typ=int)
        f("state_pool.frees", "slab references released",
          lambda: sp.stats.frees, kind="counter", typ=int)
        f("state_pool.seq_evictions", "sequences preempted off slabs",
          lambda: sp.stats.seq_evictions, kind="counter", typ=int)
        f("state_pool.alloc_failures", "slab allocations refused",
          lambda: sp.stats.alloc_failures, kind="counter", typ=int)

    def _register_cache_metrics(self) -> None:
        f, pool = self.metrics.func, self.pool
        f("prefix_cache.hits", "full-block lookups served from cache",
          lambda: pool.cache.stats.hits, kind="counter", typ=int)
        f("prefix_cache.misses", "full-block lookups that missed",
          lambda: pool.cache.stats.misses, kind="counter", typ=int)
        f("prefix_cache.hit_rate", "hits / (hits + misses)",
          lambda: round(pool.cache.stats.hit_rate, 4), typ=float)
        f("prefix_cache.hit_tokens", "tokens covered by block hits",
          lambda: pool.cache.stats.hit_tokens, kind="counter", typ=int)
        f("prefix_cache.lookup_tokens", "tokens covered by lookups",
          lambda: pool.cache.stats.lookup_tokens, kind="counter", typ=int)
        f("prefix_cache.token_hit_rate", "hit_tokens / lookup_tokens",
          lambda: round(pool.cache.stats.token_hit_rate, 4), typ=float)
        f("prefix_cache.cached_prefill_tokens",
          "prefill tokens served from resident KV (never re-quantized)",
          lambda: self.cache_hit_prefill_tokens, kind="counter", typ=int)
        f("prefix_cache.cow_copies", "shared blocks copied before a write",
          lambda: pool.cache.stats.cow_copies, kind="counter", typ=int)
        f("prefix_cache.published_blocks",
          "blocks registered under a content key",
          lambda: pool.cache.stats.published, kind="counter", typ=int)
        f("prefix_cache.cache_evictions",
          "idle cached blocks reclaimed (LRU)",
          lambda: pool.cache.stats.evictions, kind="counter", typ=int)
        f("prefix_cache.resident_cached_blocks",
          "idle cached blocks resident now",
          lambda: pool.n_cached, typ=int)
        f("prefix_cache.quant_ops_avoided",
          "KV quant ops deleted outright by cache hits",
          lambda: self.requant_ops_avoided_cache, kind="counter", typ=int)

    def _register_hwcost_metrics(self) -> None:
        f = self.metrics.func
        f("hwcost.requant_ops_performed",
          "KV requant ops executed (paper Table 5 unit)",
          lambda: self.requant_ops_performed, kind="counter", typ=int)
        f("hwcost.requant_ops_avoided", "ops a dequantize-the-cache-every-"
          "step dataflow would have executed on top",
          lambda: self.requant_ops_avoided, kind="counter", typ=int)
        # ops a cache-less engine would have PERFORMED for the tokens the
        # prefix cache served from resident blocks (Table 5's strongest
        # case: quantized zero times instead of once)
        f("hwcost.requant_ops_avoided_prefix_cache",
          "ops deleted outright by prefix-cache hits",
          lambda: self.requant_ops_avoided_cache, kind="counter", typ=int)
        # ops spent quantizing speculative rows that were REJECTED —
        # performed (inside requant_ops_performed), then rolled back
        # before they could publish: the price of per-step amortization,
        # reported instead of hidden
        f("hwcost.requant_ops_wasted_speculation",
          "ops spent on rejected speculative rows",
          lambda: self.requant_ops_wasted_spec, kind="counter", typ=int)

        # substrate-comparable headline gauge (DESIGN §16): what a
        # requant-per-step dataflow pays per useful token — performed +
        # the avoided counterfactual, over prefill + decode tokens.  On
        # attention this GROWS with context (the avoided bucket is
        # n_ctx * elems per step); on the fixed-slab substrate it is
        # CONTEXT-FREE (one whole-slab requant per step), which is the
        # paper's dataflow thesis at its strongest — the recurrent bench
        # gate asserts this number sits strictly below the equal-length
        # attention baseline.
        def requant_ops_per_token():
            tok = self.energy.tokens["prefill"] + self.energy.tokens[
                "decode"]
            ops = self.requant_ops_performed + self.requant_ops_avoided
            return round(ops / tok, 2) if tok else None

        f("hwcost.requant_ops_per_token",
          "KV+state requant ops (performed + avoided counterfactual) "
          "per useful token",
          requant_ops_per_token, typ=float, optional=True)
        f("hwcost.energy_uj_bit_shift",
          "Table-5 bit-shift energy of the ops performed",
          lambda: hwcost.estimate(
              "bit_shifting", self.requant_ops_performed).energy_uj,
          unit="uJ", typ=float)
        f("hwcost.energy_uj_if_requant_per_step",
          "counterfactual energy of a requant-per-step dataflow",
          lambda: hwcost.estimate(
              "bit_shifting", self.requant_ops_performed
              + self.requant_ops_avoided).energy_uj, unit="uJ", typ=float)
        f("hwcost.energy_uj_if_no_prefix_cache",
          "counterfactual energy without the prefix cache",
          lambda: hwcost.estimate(
              "bit_shifting", self.requant_ops_performed
              + self.requant_ops_avoided_cache).energy_uj,
          unit="uJ", typ=float)
        f("hwcost.energy_uj_if_scaling_factor",
          "counterfactual energy with a scaling-factor requant unit",
          lambda: hwcost.estimate(
              "scaling_factor", self.requant_ops_performed
              + self.requant_ops_avoided).energy_uj, unit="uJ", typ=float)
        # full-forward W8A8 accounting (DESIGN §13): separate keys so the
        # KV-only Table-5 numbers stay comparable across W8A8-on/off runs
        # (forward keys are all zero on the dense path)
        f("hwcost.w8a8", "int8 weight+activation matmul path active",
          lambda: self.cfg.matmul_kernel == "int8", typ=bool)
        f("hwcost.forward_quant_ops_per_token",
          "per-token dynamic quant ops of the W8A8 forward dataflow",
          lambda: self._fwd_elems_per_token, typ=int)
        f("hwcost.requant_ops_forward",
          "W8A8 forward boundary quant ops executed",
          lambda: self.requant_ops_forward, kind="counter", typ=int)
        f("hwcost.requant_ops_forward_avoided_prefix_cache",
          "forward ops skipped for cache-hit prefill tokens",
          lambda: self.requant_ops_forward_avoided_cache,
          kind="counter", typ=int)
        f("hwcost.requant_ops_forward_wasted_speculation",
          "forward ops spent on rejected speculative rows",
          lambda: self.requant_ops_forward_wasted_spec,
          kind="counter", typ=int)
        f("hwcost.energy_uj_forward_bit_shift",
          "Table-5 bit-shift energy of the forward ops",
          lambda: hwcost.estimate(
              "bit_shifting", self.requant_ops_forward).energy_uj,
          unit="uJ", typ=float)
        f("hwcost.energy_uj_forward_if_scaling_factor",
          "counterfactual forward energy with a scaling-factor unit",
          lambda: hwcost.estimate(
              "scaling_factor", self.requant_ops_forward).energy_uj,
          unit="uJ", typ=float)

    def _register_energy_metrics(self) -> None:
        """Live Table-5 energy proxy split by phase (DESIGN §14): the
        requant ops (KV + W8A8 forward) attributed to prefill / decode /
        spec_wasted at each commit point, priced at the Table-5
        bit-shifting unit.  ``sum(phase quant_ops) ==
        requant_ops_performed + requant_ops_forward`` ALWAYS (the
        reconciliation test + bench gate assert it)."""
        f, en = self.metrics.func, self.energy
        f("energy.unit", "Table-5 requant unit pricing the proxy",
          lambda: en.kind, typ=str)
        for p in ("prefill", "decode", "spec_wasted"):
            f(f"energy.{p}.quant_ops",
              f"requant ops (KV + forward) attributed to {p}",
              lambda p=p: en.quant_ops[p], kind="counter", typ=int)
            f(f"energy.{p}.tokens",
              "rejected draft rows" if p == "spec_wasted" else
              f"useful tokens processed in {p}",
              lambda p=p: en.tokens[p], kind="counter", typ=int)
            f(f"energy.{p}.energy_uj",
              f"Table-5 energy of the {p} ops",
              lambda p=p: round(en.energy_uj(p), 6), unit="uJ", typ=float)
            f(f"energy.{p}.uj_per_token",
              "wasted energy amortized over EMITTED decode tokens"
              if p == "spec_wasted" else
              f"energy per useful {p} token",
              lambda p=p: (lambda v: None if v is None else round(v, 9))(
                  en.uj_per_token(p)),
              unit="uJ", typ=float, optional=True)
        f("energy.total_quant_ops", "sum of phase quant ops (== "
          "hwcost.requant_ops_performed + hwcost.requant_ops_forward)",
          lambda: en.total_quant_ops, kind="counter", typ=int)
        f("energy.total_energy_uj", "Table-5 energy of all requant ops",
          lambda: round(hwcost.estimate(
              en.kind, en.total_quant_ops).energy_uj, 6),
          unit="uJ", typ=float)
        f("energy.proxy_uj_per_token", "LIVE headline gauge: total requant"
          " energy over useful (prefill + decode) tokens",
          lambda: (lambda v: None if v is None else round(v, 9))(
              en.proxy_uj_per_token()),
          unit="uJ", typ=float, optional=True)

    def _register_timeline_metrics(self) -> None:
        """Latency percentiles DERIVED FROM THE TRACE (per-request
        timelines), the §14 source of truth going forward; the legacy
        ttft_s/tpot_s/e2e_s sections stay as the cross-check."""
        f, tr = self.metrics.func, self.tracer
        f("timeline.source", "where these latencies come from",
          lambda: "trace", typ=str)
        f("timeline.requests", "requests with a timeline",
          lambda: len(tr.timelines), typ=int)
        f("timeline.completed", "timelines with a done mark",
          lambda: sum(1 for t in tr.timelines.values()
                      if t.done is not None), typ=int)
        for name, key in (("ttft_s", "ttft"), ("tpot_s", "tpot"),
                          ("e2e_s", "e2e")):
            for p in (50, 99):
                f(f"timeline.{name}.p{p}",
                  f"trace-derived {key} p{p}, seconds",
                  (lambda key=key, p=p:
                   _pct(tr.derive_latencies()[key], p)),
                  unit="s", typ=float, optional=True)

    # -- report -----------------------------------------------------------

    def report(self) -> dict:
        """Schema-stable snapshot of the metrics registry, nested into
        the report shape the benches consume (DESIGN §14).  Disabled
        sections surface as explicit ``None`` (their metrics are never
        registered), preserving the pre-§14 contract."""
        rep = self.metrics.nested()
        rep.setdefault("speculative", None)
        rep.setdefault("prefix_cache", None)
        rep.setdefault("pool", None)
        rep.setdefault("state_pool", None)
        return rep
