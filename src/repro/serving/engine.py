"""Continuous-batching step loop over the paged int8-KV block pool.

The engine owns the device state (params + the block-pool cache from
``models.model.init_paged_cache``) and drives ONE jitted step builder
(``launch.steps.build_paged_step``) at two shapes:

* decode: (n_slots, 1) — every engine step decodes ALL live slots at
  their own positions; finished slots are backfilled by newly admitted
  requests, so the batch never drains (continuous batching).
* chunked prefill: (1, C) for C in the scheduler's bucket set — prompts
  are fed ``chunk`` tokens at a time under a per-step token budget.

jit therefore compiles a BOUNDED set of executables:
1 (decode) + |buckets| (prefill) — bucketing is what keeps that true.

KV codes are written once on the Eq.-1 power-of-two grid and stay
int8-resident in the pool until the request leaves; attention consumes
them in place (fused paged kernel on MXU-aligned shapes, gather reference
otherwise).  The report quantifies what that buys with the paper's Table 5
constants (``core.hwcost``): the requant ops actually executed vs the ops
a dequantize-the-cache-every-step dataflow would have executed.
"""
from __future__ import annotations

import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import hwcost
from repro.core.qmodel import QuantContext
from repro.launch import steps as S
from repro.models import model as M
from repro.serving.kv_pool import TRASH_BLOCK, BlockPool
from repro.serving.scheduler import (Request, RequestState, Scheduler,
                                     chunk_bucket)

__all__ = ["ServingEngine", "sample_tokens", "summarize_step_times"]


def sample_tokens(logits: jax.Array, key: jax.Array,
                  temperatures: jax.Array,
                  top_k: Optional[jax.Array] = None) -> jax.Array:
    """Greedy + temperature/top-k sampling hook.

    logits (B, V); temperatures (B,) — 0 selects greedy for that row;
    top_k (B,) int32 — 0 keeps the full vocabulary for that row.  Both
    are PER-ROW traced values, so one fixed-shape call serves a batch
    mixing greedy, full-vocab and top-k requests (continuous batching
    cannot afford a recompile per sampling config)."""
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1)
    if top_k is not None:
        v = logits.shape[-1]
        srt = jnp.sort(logits, axis=-1)                    # ascending
        kth_idx = jnp.clip(v - jnp.maximum(top_k, 1), 0, v - 1)
        kth = jnp.take_along_axis(srt, kth_idx[:, None], axis=-1)
        logits = jnp.where((top_k[:, None] > 0) & (logits < kth),
                           -jnp.inf, logits)
    scaled = logits / jnp.maximum(temperatures, 1e-6)[:, None]
    sampled = jax.random.categorical(key, scaled, axis=-1)
    return jnp.where(temperatures > 0, sampled, greedy).astype(jnp.int32)


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else None


def summarize_step_times(step_times: dict) -> dict:
    """Per-shape compile-vs-steady split: the first call of a jitted shape
    pays tracing+compilation, the median of the rest is steady state.
    Keys may be shape tuples (the engine's) or preformatted strings (the
    static-baseline bench's)."""
    shapes = {}
    for shape, ts in sorted(step_times.items()):
        key = "x".join(map(str, shape)) if isinstance(shape, tuple) \
            else str(shape)
        steady = float(np.median(ts[1:])) if len(ts) > 1 else None
        shapes[key] = {
            "calls": len(ts), "first_s": round(ts[0], 4),
            "steady_s": round(steady, 4) if steady is not None else None}
    return shapes


class ServingEngine:
    """Continuous-batching serving engine (DESIGN §9)."""

    def __init__(self, cfg: ModelConfig, params: Any, ctx: QuantContext, *,
                 n_slots: int = 4, block_size: int = 16,
                 max_model_len: int = 128,
                 num_blocks: Optional[int] = None, chunk: int = 16,
                 prefill_token_budget: Optional[int] = None,
                 top_k: int = 0, mesh=None, seed: int = 0,
                 prefix_cache: bool = True):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx
        self.n_slots = n_slots
        self.max_model_len = max_model_len
        nbmax = -(-max_model_len // block_size)
        if num_blocks is None:
            # full residency: every slot can reach max_model_len (+ trash).
            # Callers undersize this deliberately to exercise preemption.
            num_blocks = 1 + n_slots * nbmax
        scale_exp = cfg.kv_cache_frac_bits if cfg.kv_cache_bits == 8 else 0
        self.pool = BlockPool(num_blocks, block_size, scale_exp=scale_exp,
                              prefix_cache=prefix_cache)
        self.sched = Scheduler(self.pool, n_slots=n_slots, chunk=chunk,
                               max_model_len=max_model_len,
                               prefill_token_budget=prefill_token_budget)
        self.cache = M.init_paged_cache(cfg, num_blocks, block_size)
        # sampling is FUSED into the jitted step: one dispatch + one host
        # sync per engine step, and only the (B,) sampled tokens ever leave
        # the device — logits never cross to the host.  The rng key derives
        # from a per-call counter via fold_in inside the jit, so the host
        # does zero PRNG work per step and runs stay seed-reproducible.
        base_step = S.build_paged_step(cfg, ctx, mesh=mesh)
        base_key = jax.random.PRNGKey(seed)

        def sampled_step(params, tokens, cache, positions, bt, temps, topks,
                         last_idx, step_idx):
            logits, cache = base_step(params, tokens, cache, positions, bt)
            row = jax.lax.dynamic_index_in_dim(logits, last_idx, axis=1,
                                               keepdims=False)     # (B, V)
            key = jax.random.fold_in(base_key, step_idx)
            return sample_tokens(row, key, temps, topks), cache

        # donate the pool: the per-token scatter then updates the arena in
        # place — without donation XLA copies the whole multi-MB pool
        # every step, which is exactly the write-amplification the paged
        # design exists to avoid
        self._step_fn = jax.jit(sampled_step, donate_argnums=(2,))

        # COW device copy (DESIGN §10): duplicate one pool block's rows
        # (all layers, K and V) into a fresh private block before a write
        # would land in a shared/published block.  Donated for the same
        # reason as the step: copy block_size rows, not the whole arena.
        def cow_copy(cache, src, dst):
            return jax.tree.map(
                lambda a: a.at[:, dst].set(a[:, src]), cache)

        self._cow_fn = jax.jit(cow_copy, donate_argnums=(0,))
        self._step_counter = 0
        # engine-level default top-k, applied to requests that don't set
        # their own (Request.top_k > 0 wins per slot)
        self.default_top_k = top_k
        # one requant op per KV element (paper's unit of Table 5)
        self._elems_per_token = (cfg.n_layers * cfg.n_kv_heads
                                 * cfg.resolved_head_dim * 2)
        self.requant_ops_performed = 0
        self.requant_ops_avoided = 0
        # quant ops the PREFIX CACHE deleted outright: cached-prefix tokens
        # are never quantized at all for the hitting request (Table 5)
        self.requant_ops_avoided_cache = 0
        self.cache_hit_prefill_tokens = 0
        self.decode_steps = 0
        self.prefill_chunks = 0
        self._step_times: dict[tuple, list] = {}    # (B, C) -> wall seconds
        self._t0 = time.perf_counter()
        self._skip = 0.0
        self._wall_s = 0.0

    # -- clock ------------------------------------------------------------

    def _now(self) -> float:
        return time.perf_counter() - self._t0 + self._skip

    # -- public API -------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.sched.submit(req)

    def reset_metrics(self, *, flush_cache: bool = True) -> None:
        """Clear accounting between runs (e.g. after a warm-up workload
        that populated the jit caches) — engine must be drained first.
        The sampling step counter resets too, so a reused engine replays
        the same rng stream (seed-reproducible across passes); note that
        post-reset ``first_s`` per shape reflects a WARM first call, not
        compilation.  By default the PREFIX CACHE is flushed too, so every
        pass starts cold — inter-pass hits would make pass N incomparable
        to pass 1; pass ``flush_cache=False`` to measure the warm-cache
        steady state (e.g. after priming a shared system prompt)."""
        assert self.sched.idle and self.pool.n_live == 0, \
            "reset_metrics on a non-drained engine"
        from repro.serving.kv_pool import PoolStats
        from repro.serving.prefix_cache import CacheStats
        self._step_counter = 0
        self.sched.done.clear()
        self.sched.admission_log.clear()
        if flush_cache:
            self.pool.flush_cache()
        self.pool.stats = PoolStats()
        if self.pool.cache is not None:
            self.pool.cache.stats = CacheStats()
        self.requant_ops_performed = 0
        self.requant_ops_avoided = 0
        self.requant_ops_avoided_cache = 0
        self.cache_hit_prefill_tokens = 0
        self.decode_steps = 0
        self.prefill_chunks = 0
        self._step_times.clear()
        self._wall_s = 0.0

    def run(self, requests: list[Request]) -> dict:
        """Serve ``requests`` (arrival-stamped) to completion; idle gaps
        between arrivals are fast-forwarded on the engine clock, so the
        report's latencies are arrival-relative without real sleeps."""
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        self._t0, self._skip = time.perf_counter(), 0.0
        while pending or not self.sched.idle:
            now = self._now()
            if self.sched.idle and pending and pending[0].arrival > now:
                self._skip += pending[0].arrival - now
                now = self._now()
            while pending and pending[0].arrival <= now:
                self.submit(pending.pop(0))
            self.step()
        self._wall_s = self._now()
        return self.report()

    def step(self) -> None:
        """One engine iteration: admit → chunked prefill → decode."""
        for req in self.sched.admit(self._now()):
            # cached-prefix hit: those tokens' KV is already resident, so
            # their quantization ops simply never happen for this request
            self.cache_hit_prefill_tokens += req.n_prefilled
            self.requant_ops_avoided_cache += \
                req.n_prefilled * self._elems_per_token
        self._run_prefills()
        self._run_decode()

    # -- prefill ----------------------------------------------------------

    def _run_prefills(self) -> None:
        # one shared token budget per engine step: admitting a long prompt
        # costs the decode batch at most `budget` tokens of extra latency
        budget = self.sched.prefill_token_budget
        for req in self.sched.prefill_jobs():
            while budget > 0 and req.state is RequestState.PREFILL:
                budget -= self._prefill_chunk(req, budget)

    def _prefill_chunk(self, req: Request, budget: int) -> int:
        start = req.n_prefilled
        c_real = min(self.sched.chunk, len(req.feed) - start, budget)
        # copy-on-write (DESIGN §10): any block this chunk writes into
        # must be private.  Only the fully-cached-feed re-feed ever lands
        # in a shared block (partial hits start at a block boundary), but
        # the check is general: preemption retry mirrors decode growth.
        if not self._cow_for_range(req, start, start + c_real):
            return 0                        # req itself was preempted
        c_pad = chunk_bucket(c_real, self.sched.chunk)
        cap = self.max_model_len - start
        if c_pad > cap:
            # near the end of the table the padded tail could land past
            # max_model_len (clamped block-table lookups would then alias
            # LIVE rows of the last block).  Shrink to the largest power
            # of two that fits — still pow2, so at most 2 widths below
            # the bucket floor (1 and 2) join the executable set; at
            # worst the boundary chunk feeds fewer real tokens.
            c_pad = 1 << (cap.bit_length() - 1)
            c_real = min(c_real, c_pad)
        tokens = np.zeros((1, c_pad), np.int32)
        tokens[0, :c_real] = req.feed[start:start + c_real]
        positions = (start + np.arange(c_pad, dtype=np.int32))[None]
        bt = self.pool.table_row(req.rid, self.sched.nbmax)[None]
        toks = self._timed_step(tokens, positions, bt,
                                np.asarray([req.temperature], np.float32),
                                np.asarray([self._req_top_k(req)], np.int32),
                                c_real - 1)
        req.n_prefilled += c_real
        req.n_ctx = req.n_prefilled
        # the chunk's KV rows are device-resident now: full blocks this
        # completes become content-addressable (publish is a no-op when
        # the prefix cache is off)
        self.pool.commit(req.rid, start, req.feed[start:start + c_real])
        self.prefill_chunks += 1
        self.requant_ops_performed += c_real * self._elems_per_token
        if req.n_prefilled == len(req.feed):
            # prompt fully resident: the token sampled from the last real
            # row IS the first generated token (for preemption resumes it
            # just continues the sequence)
            tok = int(toks[0])
            now = self._now()
            if req.t_first is None:
                req.t_first = now
            done = req.finished_by(tok, self.max_model_len)
            req.generated.append(tok)
            if done:
                self.sched.finish(req, now)
            else:
                req.state = RequestState.DECODE
        return c_real

    # -- decode -----------------------------------------------------------

    def _run_decode(self) -> None:
        now = self._now()
        for req in list(self.sched.decode_reqs()):
            if req.slot is not None and req.state is RequestState.DECODE:
                self.sched.grow_for_decode(req, now)
        reqs = self.sched.decode_reqs()
        if not reqs:
            return
        tokens = np.zeros((self.n_slots, 1), np.int32)
        positions = np.zeros((self.n_slots, 1), np.int32)
        bt = np.full((self.n_slots, self.sched.nbmax), TRASH_BLOCK, np.int32)
        temps = np.zeros((self.n_slots,), np.float32)
        topks = np.zeros((self.n_slots,), np.int32)
        for req in reqs:
            s = req.slot
            tokens[s, 0] = req.generated[-1]
            positions[s, 0] = req.n_ctx
            bt[s] = self.pool.table_row(req.rid, self.sched.nbmax)
            temps[s] = req.temperature
            topks[s] = self._req_top_k(req)
        toks = self._timed_step(tokens, positions, bt, temps, topks, 0)
        self.decode_steps += 1
        self.requant_ops_performed += len(reqs) * self._elems_per_token
        now = self._now()
        for req in reqs:
            # the fed token's KV row is resident: blocks that fill during
            # decode publish too, so a preempted resume (or a later request
            # sharing prompt+generation) can re-attach them
            self.pool.commit(req.rid, req.n_ctx, [req.generated[-1]])
            req.n_ctx += 1
            # the dataflow the int8-resident pool deletes: dequantizing the
            # slot's whole live cache before attending, EVERY step
            self.requant_ops_avoided += req.n_ctx * self._elems_per_token
            tok = int(toks[req.slot])
            done = req.finished_by(tok, self.max_model_len)
            req.generated.append(tok)
            if done:
                self.sched.finish(req, now)

    # -- shared step plumbing --------------------------------------------

    def _cow_for_range(self, req: Request, start: int, end: int) -> bool:
        """Copy-on-write every SHARED block overlapping feed positions
        [start, end) so the chunk's KV scatter only touches private
        blocks.  The pool moves the map; the device rows are duplicated
        here (one jitted block copy, donated — block_size rows per layer,
        never the whole arena).  Returns False iff ``req`` itself was
        preempted while finding a block for the copy."""
        bs = self.pool.block_size
        for idx in range(start // bs, -(-end // bs)):
            if idx >= self.pool.n_blocks_of(req.rid):
                break                       # rows beyond the table: extend
            if self.pool.block_writable(req.rid, idx):
                continue
            pair = self.sched.cow_for_prefill(req, idx, self._now())
            if pair is None:
                return False
            src, dst = pair
            self.cache = self._cow_fn(self.cache, jnp.asarray(src),
                                      jnp.asarray(dst))
        return True

    def _req_top_k(self, req: Request) -> int:
        return req.top_k if req.top_k > 0 else self.default_top_k

    def _timed_step(self, tokens, positions, bt, temps, topks, last_idx):
        t0 = time.perf_counter()
        self._step_counter += 1
        # all-zero top-k (the greedy/full-vocab default) drops to the
        # sampler's None fast path: the per-step full-vocab jnp.sort never
        # enters the hot executable.  Costs at most one extra jit variant
        # per shape.
        topks = np.asarray(topks)
        topks_arg = jnp.asarray(topks) if topks.any() else None
        toks, self.cache = self._step_fn(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(positions), jnp.asarray(bt), jnp.asarray(temps),
            topks_arg, jnp.asarray(last_idx, jnp.int32),
            jnp.asarray(self._step_counter, jnp.uint32))
        toks = np.asarray(toks)                  # host sync
        self._step_times.setdefault(tuple(tokens.shape), []).append(
            time.perf_counter() - t0)
        return toks

    # -- report -----------------------------------------------------------

    def outputs(self) -> dict[int, np.ndarray]:
        return {r.rid: np.asarray(r.generated, np.int32)
                for r in self.sched.done}

    def report(self) -> dict:
        done = self.sched.done
        ttft = [r.t_first - r.arrival for r in done if r.t_first is not None]
        e2e = [r.t_done - r.arrival for r in done if r.t_done is not None]
        tpot = [(r.t_done - r.t_first) / (r.n_generated - 1)
                for r in done if r.n_generated > 1]
        gen_tokens = sum(r.n_generated for r in done)
        prompt_tokens = sum(len(r.prompt) for r in done)
        wall = self._wall_s or self._now()
        shapes = summarize_step_times(self._step_times)
        perf = self.requant_ops_performed
        avoid = self.requant_ops_avoided
        cache_avoid = self.requant_ops_avoided_cache
        hw = {
            "requant_ops_performed": perf,
            "requant_ops_avoided": avoid,
            # ops a cache-less engine would have PERFORMED for the tokens
            # the prefix cache served from resident blocks (Table 5's
            # strongest case: quantized zero times instead of once)
            "requant_ops_avoided_prefix_cache": cache_avoid,
            "energy_uj_bit_shift": hwcost.estimate(
                "bit_shifting", perf).energy_uj,
            "energy_uj_if_requant_per_step": hwcost.estimate(
                "bit_shifting", perf + avoid).energy_uj,
            "energy_uj_if_no_prefix_cache": hwcost.estimate(
                "bit_shifting", perf + cache_avoid).energy_uj,
            "energy_uj_if_scaling_factor": hwcost.estimate(
                "scaling_factor", perf + avoid).energy_uj,
        }
        cache = None
        if self.pool.cache is not None:
            cs = self.pool.cache.stats
            cache = {
                "hits": cs.hits,
                "misses": cs.misses,
                "hit_rate": round(cs.hit_rate, 4),
                "hit_tokens": cs.hit_tokens,
                "lookup_tokens": cs.lookup_tokens,
                "token_hit_rate": round(cs.token_hit_rate, 4),
                "cached_prefill_tokens": self.cache_hit_prefill_tokens,
                "cow_copies": cs.cow_copies,
                "published_blocks": cs.published,
                "cache_evictions": cs.evictions,
                "resident_cached_blocks": self.pool.n_cached,
                "quant_ops_avoided": cache_avoid,
            }
        return {
            "n_requests": len(done) + len(self.sched.waiting)
            + len(self.sched.active()),
            "completed": len(done),
            "preemptions": sum(r.preemptions for r in done),
            "gen_tokens": gen_tokens,
            "prompt_tokens": prompt_tokens,
            "wall_s": round(wall, 4),
            "tokens_per_s": round(gen_tokens / wall, 2) if wall else None,
            "decode_steps": self.decode_steps,
            "prefill_chunks": self.prefill_chunks,
            "ttft_s": {"p50": _pct(ttft, 50), "p99": _pct(ttft, 99)},
            "tpot_s": {"p50": _pct(tpot, 50), "p99": _pct(tpot, 99)},
            "e2e_s": {"p50": _pct(e2e, 50), "p99": _pct(e2e, 99)},
            "step_shapes": shapes,
            "pool": {
                "num_blocks": self.pool.num_blocks,
                "block_size": self.pool.block_size,
                "peak_live_blocks": self.pool.stats.peak_live,
                "peak_utilization": round(
                    self.pool.stats.peak_live
                    / max(self.pool.num_blocks - 1, 1), 3),
                "utilization": round(self.pool.utilization, 3),
                "residency": round(self.pool.residency, 3),
                "allocs": self.pool.stats.allocs,
                "frees": self.pool.stats.frees,
                "evictions": self.pool.stats.evictions,
                "seq_evictions": self.pool.stats.seq_evictions,
                "cache_evictions": self.pool.stats.cache_evictions,
                "alloc_failures": self.pool.stats.alloc_failures,
            },
            "prefix_cache": cache,
            "hwcost": hw,
        }
