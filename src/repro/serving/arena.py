"""Shared arena allocator underneath the serving substrates (DESIGN §16).

PR 10 lifts the "sequence state = growing KV blocks" assumption out of the
serving stack: :class:`Arena` owns the machinery common to every substrate
— a fixed set of numbered units with a LIFO free stack, per-unit reference
counts, per-unit power-of-two scale exponents (Eq. 1), lifecycle stats,
and the optional obs tracer hook — while the two substrates specialize it:

* :class:`repro.serving.kv_pool.BlockPool` — the growing block-table
  substrate for attention KV (units are KV blocks; adds the
  content-addressed prefix cache, idle-LRU reclaim, COW, retract);
* :class:`repro.serving.state_pool.StateSlabPool` — the fixed-size
  state-slab substrate for recurrent models (units are whole-state slabs;
  exactly one per sequence, never extended, never shared).

Everything here is plain Python/numpy — no jax — so the substrate property
tests run without a model.  Unit 0 is the TRASH unit in every arena:
never allocated, never freed; masked lanes read/write it harmlessly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Arena", "BlockPoolError", "PoolStats", "TRASH_UNIT"]

TRASH_UNIT = 0


class BlockPoolError(RuntimeError):
    """Allocator misuse (double free, unknown sequence, exhausted pool)."""


@dataclasses.dataclass
class PoolStats:
    allocs: int = 0            # units handed out fresh (not cache hits)
    frees: int = 0             # unit references released
    evictions: int = 0         # UNITS released by preemption
    seq_evictions: int = 0     # sequences preempted
    cache_evictions: int = 0   # idle cached blocks reclaimed (LRU)
    retracts: int = 0          # speculative rollbacks that freed blocks
    retracted_blocks: int = 0  # blocks freed by rollback (rejected rows)
    peak_live: int = 0         # max simultaneously-live units
    alloc_failures: int = 0    # alloc/extend requests refused


class Arena:
    """Fixed-capacity pool of numbered units with per-sequence unit lists,
    per-unit reference counts, and per-unit po2 scale exponents.

    Subclass hooks keep the substrate semantics out of the shared core:

    * ``_on_release_zero(unit)`` — where a unit goes when its refcount
      drops to 0 (default: back on the LIFO free stack; the KV substrate
      parks published blocks on its idle-LRU instead);
    * ``_reclaim()`` — called by :meth:`_take` when the free stack is
      empty (default: raise; the KV substrate evicts its LRU idle cached
      block).
    """

    # noun used in error messages and the tracer event names
    unit_noun = "block"
    EVT_FREE = "pool.free"
    EVT_EVICT = "pool.evict"

    def __init__(self, num_units: int, *, scale_exp: int = 0):
        if num_units < 2:
            raise ValueError(
                f"pool needs >= 2 {self.unit_noun}s "
                f"({self.unit_noun} 0 is trash)")
        self.num_units = num_units
        self.default_scale_exp = scale_exp
        # LIFO free stack — recently freed units are re-used first (their
        # arena rows are hot).  Unit 0 (trash) is never on it.
        self._free: list[int] = list(range(num_units - 1, 0, -1))
        self._seqs: dict[int, list[int]] = {}       # seq id -> units, order
        # per-unit owner count; sharing happens only via cache hits
        self.refcount = np.zeros((num_units,), np.int32)
        # per-unit po2 scale exponent (Eq.-1 fractional bit) — written at
        # alloc.  One int per unit of metadata.
        self.scale_exp = np.full((num_units,), scale_exp, np.int32)
        self.stats = PoolStats()
        # optional obs hook (DESIGN §14): the engine attaches its Tracer
        # here; every emission is guarded on ``tracer is not None and
        # tracer.enabled`` so the standalone pool (property tests, no
        # engine) pays one attribute read per lifecycle transition.
        self.tracer = None

    # -- capacity ---------------------------------------------------------

    @property
    def n_free(self) -> int:
        """Allocatable units: truly free + reclaimable (substrate hook)."""
        return len(self._free) + self._n_reclaimable()

    @property
    def n_cached(self) -> int:
        """Resident refcount-0 units reclaimable under pressure."""
        return self._n_reclaimable()

    @property
    def n_live(self) -> int:
        """Units referenced by at least one sequence."""
        return (self.num_units - 1) - len(self._free) \
            - self._n_reclaimable()

    @property
    def utilization(self) -> float:
        return self.n_live / max(self.num_units - 1, 1)

    @property
    def residency(self) -> float:
        """Fraction of the arena holding useful codes (live + cached)."""
        return (self.n_live + self.n_cached) / max(self.num_units - 1, 1)

    def can_alloc(self, n_units: int) -> bool:
        return n_units <= self.n_free

    def _n_reclaimable(self) -> int:
        return 0

    def live_seqs(self) -> list[int]:
        return list(self._seqs)

    def seq_ids(self):
        return self._seqs.keys()

    def seq_blocks(self, seq_id: int) -> list[int]:
        """The sequence's units in logical order (read-only view)."""
        if seq_id not in self._seqs:
            raise BlockPoolError(f"unknown sequence {seq_id}")
        return self._seqs[seq_id]

    def n_blocks_of(self, seq_id: int) -> int:
        return len(self._seqs.get(seq_id, ()))

    # -- free / evict -----------------------------------------------------

    def free_seq(self, seq_id: int) -> int:
        """Release all of ``seq_id``'s unit references; raises on double
        free."""
        if seq_id not in self._seqs:
            raise BlockPoolError(f"double free: unknown sequence {seq_id}")
        n = self._release_seq(seq_id)
        self._emit(self.EVT_FREE, {
            "seq": seq_id, "blocks": n, "free": self.n_free})
        return n

    def evict(self, seq_id: int) -> int:
        """Preemption path: release references + count the eviction
        (unit-granular: ``stats.evictions`` counts units, the preempted
        sequence itself counts once in ``stats.seq_evictions``)."""
        if seq_id not in self._seqs:
            raise BlockPoolError(f"double free: unknown sequence {seq_id}")
        n = self._release_seq(seq_id)
        self.stats.evictions += n
        self.stats.seq_evictions += 1
        self._emit(self.EVT_EVICT, {
            "seq": seq_id, "blocks": n, "free": self.n_free})
        return n

    def _release_seq(self, seq_id: int) -> int:
        units = self._seqs.pop(seq_id)
        for u in units:
            self._release(u)
        self.stats.frees += len(units)
        return len(units)

    def _release(self, unit: int) -> None:
        self.refcount[unit] -= 1
        assert self.refcount[unit] >= 0, \
            f"refcount underflow on {self.unit_noun} {unit}"
        if self.refcount[unit] == 0:
            self._on_release_zero(unit)

    def _on_release_zero(self, unit: int) -> None:
        self._free.append(unit)

    def _take(self, scale_exp: int) -> int:
        """Hand out a fresh private unit, falling back to the substrate's
        reclaim hook when the free stack is empty."""
        if self._free:
            unit = self._free.pop()
        else:
            unit = self._reclaim()
        self.scale_exp[unit] = scale_exp
        self.refcount[unit] = 1
        self.stats.allocs += 1
        self.stats.peak_live = max(self.stats.peak_live, self.n_live)
        return unit

    def _reclaim(self) -> int:
        raise BlockPoolError(
            f"pool exhausted: no free or cached {self.unit_noun}s")

    # -- obs --------------------------------------------------------------

    def _emit(self, name: str, args: dict) -> None:
        tr = self.tracer
        if tr is not None and tr.enabled:
            tr.event(name, "pool", args=args)

    # -- replay determinism ----------------------------------------------

    def reset_free_order(self) -> None:
        """Restore the free stack to its pristine allocation order
        (lowest unit id pops first).  Free-list order is run history —
        an identical logical workload replayed after a reset would
        otherwise land on different PHYSICAL units, which the flight
        recorder's decision stream would flag as a spurious divergence.
        Requires no live sequences."""
        assert not self._seqs, "reset_free_order with live sequences"
        self._free.sort(reverse=True)
