"""Roofline extraction from compiled SPMD artifacts.

Methodology (EXPERIMENTS.md §Roofline):

* ``cost_analysis()`` reports PER-DEVICE flops/bytes of the partitioned
  program, and counts each while-loop body ONCE (verified empirically, see
  EXPERIMENTS.md §Dry-run caveats).  Rolled production compiles therefore
  undercount scanned structure.
* The fit path (benchmarks/roofline.py) re-lowers reduced-DEPTH variants
  under ``scan_lib.analysis_unroll()`` (every scan fully unrolled => exact
  counting) and extrapolates linearly in depth, which is exact because cost
  is affine in layer count.
* Collective traffic is parsed from the compiled HLO text with ring-model
  multipliers per collective kind and replica-group size.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (3D-torus usable per chip ~3 links; we report per-link seconds, i.e.
the most conservative single-link serialization).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "s64": 8, "pred": 1, "s16": 2, "u16": 2,
                "f64": 8, "c64": 8}

# one HLO instruction line:  %name = RESULT-TYPE op-name(...), attrs.
# Operands print WITHOUT inline types inside a computation, so bytes come
# from the RESULT type (always printed at the definition).
_LINE_RE = re.compile(
    r"=\s*(?P<result>\(?\s*[a-z0-9]+\[[0-9,]*\][^=]*?)\s"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)"
    r"(?:-start)?\(")
_TYPE_RE = re.compile(r"([a-z][0-9]+|pred)\[([0-9,]*)\]")
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _bytes_of(types_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(types_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_BRACKET_RE.search(line)
    if m:
        return max(int(m.group(2)), 2)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 2)
    return 2


def collective_traffic(hlo_text: str) -> dict:
    """Per-device ring-model traffic (bytes) by collective kind, derived
    from RESULT sizes R and group size P:

    all-gather:      R x (P-1)/P  (result = gathered tensor)
    all-reduce:      2 x R x (P-1)/P  (ring reduce-scatter + all-gather)
    reduce-scatter:  R x (P-1)   (result = shard; input = R x P)
    all-to-all:      R x (P-1)/P
    collective-permute: R
    """
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        r = _bytes_of(m.group("result"))
        p = _group_size(line)
        if op == "all-gather":
            traffic = r * (p - 1) / p
        elif op == "all-reduce":
            traffic = 2.0 * r * (p - 1) / p
        elif op == "reduce-scatter":
            traffic = r * (p - 1)
        elif op == "all-to-all":
            traffic = r * (p - 1) / p
        else:  # collective-permute
            traffic = r
        out[op] = out.get(op, 0.0) + traffic
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclasses.dataclass
class CostSample:
    """Per-device costs from one compiled artifact."""

    flops: float
    bytes_hbm: float
    collectives: dict

    def scaled(self, w: float) -> "CostSample":
        return CostSample(self.flops * w, self.bytes_hbm * w,
                          {k: v * w for k, v in self.collectives.items()})

    def __add__(self, other: "CostSample") -> "CostSample":
        keys = set(self.collectives) | set(other.collectives)
        return CostSample(
            self.flops + other.flops, self.bytes_hbm + other.bytes_hbm,
            {k: self.collectives.get(k, 0) + other.collectives.get(k, 0)
             for k in keys})


def sample_of(compiled) -> CostSample:
    cost = compiled.cost_analysis()
    return CostSample(
        flops=float(cost.get("flops", 0.0)),
        bytes_hbm=float(cost.get("bytes accessed", 0.0)),
        collectives=collective_traffic(compiled.as_text()))


def roofline_terms(sample: CostSample) -> dict:
    """Three per-device roofline terms in seconds + the dominant one."""
    t_compute = sample.flops / PEAK_FLOPS
    t_memory = sample.bytes_hbm / HBM_BW
    t_coll = sample.collectives.get("total", 0.0) / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant}
