"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run pins the device
count via XLA_FLAGS before any jax call, while tests/benches must keep the
default single device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod topology: 16x16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ``data`` (DP / FSDP), ``model`` (TP / EP); ``pod`` is the DCI-
    connected data-parallel axis added in the multi-pod configuration.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Degenerate mesh over the locally visible devices (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
