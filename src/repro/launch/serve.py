"""Serving driver: integer-deploy path with batched prefill + decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b \
        --batch 4 --prompt-len 32 --gen 16 [--mode int] [--calibrate]

Pipeline (DESIGN §3): optional Algorithm-1 calibration on one batch ->
int8 weight conversion -> jit'd prefill + decode steps in the requested
quantization mode.  The decode loop is greedy (framework demo; sampling
plugs into serve_step).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.lm_calibrate import calibrate_lm
from repro.core.qmodel import QuantContext, QuantMode
from repro.data import SyntheticLMStream
from repro.launch import steps as S
from repro.models import model as M


def serve(arch: str, *, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          mode: str = "int", calibrate: bool = True, smoke: bool = True,
          seed: int = 0, params=None, attn_kernel: str | None = None,
          mesh_shape: tuple[int, int] | None = None,
          cfg_overrides: dict | None = None) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if attn_kernel is not None:
        # 'flash' routes prefill/decode through the fused Pallas attention
        # (DESIGN §2); int8 KV codes then skip the dequantized HBM copy.
        cfg = dataclasses.replace(cfg, attn_kernel=attn_kernel)
    if cfg_overrides:
        # e.g. head_dim=128 so the fused decode kernel genuinely launches
        # on smoke configs (it refuses non-lane-multiple head dims)
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    mesh = None
    if mesh_shape is not None:
        # (data, model) mesh: flash runs per-shard via shard_map — KV heads
        # over 'model', batch over 'data' (DESIGN §8).  The builders raise
        # NotImplementedError if 'model' doesn't divide n_kv_heads.
        mesh = jax.make_mesh(tuple(mesh_shape), ("data", "model"))
    if params is None:
        params = M.init_params(cfg, jax.random.PRNGKey(seed))
    stream = SyntheticLMStream(
        cfg.vocab_size, prompt_len, batch, seed=seed,
        encoder_seq=cfg.encdec.encoder_seq if cfg.family == "audio" else None,
        d_model=cfg.d_model if cfg.family == "audio" else None)
    b0 = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
    prompt = {k: v for k, v in b0.items() if k in ("tokens",
                                                   "encoder_features")}

    ctx = QuantContext(mode=QuantMode(mode))
    report = None
    if calibrate and mode in ("fake", "int"):
        t0 = time.time()
        ctx_cal, report = calibrate_lm(
            lambda p, b, c: M.forward(p, b, cfg, c), params, prompt)
        ctx = dataclasses.replace(ctx_cal, mode=QuantMode(mode))
        print(f"calibrated {len(report.results)} modules "
              f"in {time.time()-t0:.1f}s")

    max_seq = prompt_len + gen
    prefill_fn = jax.jit(S.build_prefill_step(cfg, ctx, mesh=mesh,
                                              max_seq=max_seq))
    serve_fn = jax.jit(S.build_serve_step(cfg, ctx, mesh=mesh))

    t0 = time.time()
    logits, cache = prefill_fn(params, prompt)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    for i in range(gen - 1):
        tok, cache = serve_fn(params, tok, cache,
                              jnp.asarray(prompt_len + i, jnp.int32))
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen_tokens = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    return {"tokens": gen_tokens, "prefill_s": t_prefill,
            "decode_s_per_tok": t_decode / max(gen - 1, 1),
            "report": report, "ctx": ctx}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mode", default="int",
                    choices=["fp", "fake", "fake_sf", "int"])
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--attn-kernel", default=None,
                    choices=["chunked", "flash"],
                    help="attention path (DESIGN §2); default: cfg's")
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="serve on a (data, model) device mesh, e.g. '1x2';"
                         " with --attn-kernel flash the fused kernels run"
                         " per-shard via shard_map (DESIGN §8)")
    args = ap.parse_args(argv)
    mesh_shape = None
    if args.mesh is not None:
        d, m = (int(x) for x in args.mesh.lower().split("x"))
        mesh_shape = (d, m)
    out = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen=args.gen, mode=args.mode,
                calibrate=not args.no_calibrate, smoke=not args.full,
                attn_kernel=args.attn_kernel, mesh_shape=mesh_shape)
    print(f"generated {out['tokens'].shape} tokens | "
          f"prefill {out['prefill_s']:.2f}s | "
          f"decode {1e3*out['decode_s_per_tok']:.1f} ms/tok")
    print("sample:", out["tokens"][0][:16])


if __name__ == "__main__":
    main()
