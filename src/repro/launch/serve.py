"""Serving driver: integer-deploy path with batched prefill + decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b \
        --batch 4 --prompt-len 32 --gen 16 [--mode int] [--calibrate]

Pipeline (DESIGN §3): optional Algorithm-1 calibration on one batch ->
int8 weight conversion -> jit'd prefill + decode steps in the requested
quantization mode.  The decode loop is greedy (framework demo; sampling
plugs into serve_step).  Steps are AOT-compiled first, so the reported
``prefill_s`` / ``decode_s_per_tok`` are STEADY-STATE; compile time is
reported separately (``compile_prefill_s`` / ``compile_decode_s``).

``--engine`` switches to the continuous-batching serving engine
(DESIGN §9): a synthetic Poisson workload of mixed prompt/gen lengths is
served from the paged int8-KV block pool with slot-based continuous
batching, chunked prefill, and per-request sampling/stop handling; the
report adds throughput, latency percentiles, pool utilization, and the
paper-Table-5 requant-energy accounting.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.lm_calibrate import calibrate_lm
from repro.core.qmodel import QuantContext, QuantMode
from repro.data import SyntheticLMStream
from repro.launch import steps as S
from repro.models import model as M


def _resolve_cfg_mesh(arch: str, *, smoke: bool,
                      attn_kernel: str | None = None,
                      cfg_overrides: dict | None = None,
                      mesh_shape: tuple[int, int] | None = None):
    """Shared config/mesh setup for the classic and engine drivers.

    ``attn_kernel='flash'`` routes prefill/decode through the fused Pallas
    attention (DESIGN §2); int8 KV codes then skip the dequantized HBM
    copy.  ``cfg_overrides`` patches arbitrary config fields (e.g.
    head_dim=128 so the fused decode kernel genuinely launches on smoke
    configs — it refuses non-lane-multiple head dims).  ``mesh_shape``
    builds a (data, model) mesh: the fused kernels run per-shard via
    shard_map — KV heads over 'model', batch over 'data' (DESIGN §8/§9);
    the step builders raise NotImplementedError if 'model' doesn't divide
    n_kv_heads."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if attn_kernel is not None:
        cfg = dataclasses.replace(cfg, attn_kernel=attn_kernel)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    mesh = None
    if mesh_shape is not None:
        mesh = jax.make_mesh(tuple(mesh_shape), ("data", "model"))
    return cfg, mesh


def serve(arch: str, *, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          mode: str = "int", calibrate: bool = True, smoke: bool = True,
          seed: int = 0, params=None, attn_kernel: str | None = None,
          mesh_shape: tuple[int, int] | None = None,
          cfg_overrides: dict | None = None) -> dict:
    cfg, mesh = _resolve_cfg_mesh(arch, smoke=smoke, attn_kernel=attn_kernel,
                                  cfg_overrides=cfg_overrides,
                                  mesh_shape=mesh_shape)
    if params is None:
        params = M.init_params(cfg, jax.random.PRNGKey(seed))
    stream = SyntheticLMStream(
        cfg.vocab_size, prompt_len, batch, seed=seed,
        encoder_seq=cfg.encdec.encoder_seq if cfg.family == "audio" else None,
        d_model=cfg.d_model if cfg.family == "audio" else None)
    b0 = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
    prompt = {k: v for k, v in b0.items() if k in ("tokens",
                                                   "encoder_features")}

    ctx = QuantContext(mode=QuantMode(mode))
    report = None
    if calibrate and mode in ("fake", "int"):
        t0 = time.time()
        ctx_cal, report = calibrate_lm(
            lambda p, b, c: M.forward(p, b, cfg, c), params, prompt)
        ctx = dataclasses.replace(ctx_cal, mode=QuantMode(mode))
        print(f"calibrated {len(report.results)} modules "
              f"in {time.time()-t0:.1f}s")

    max_seq = prompt_len + gen
    prefill_fn = jax.jit(S.build_prefill_step(cfg, ctx, mesh=mesh,
                                              max_seq=max_seq))
    serve_fn = jax.jit(S.build_serve_step(cfg, ctx, mesh=mesh))

    # AOT-compile both steps so the timings below are steady-state: the
    # old code folded jit tracing+compilation into prefill_s and the first
    # decode step, which dwarfed the actual compute at smoke scale.
    t0 = time.time()
    prefill_c = prefill_fn.lower(params, prompt).compile()
    compile_prefill_s = time.time() - t0

    t0 = time.time()
    logits, cache = prefill_c(params, prompt)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    t0 = time.time()
    serve_c = serve_fn.lower(params, tok, cache,
                             jnp.asarray(prompt_len, jnp.int32)).compile()
    compile_decode_s = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    for i in range(gen - 1):
        tok, cache = serve_c(params, tok, cache,
                             jnp.asarray(prompt_len + i, jnp.int32))
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen_tokens = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    return {"tokens": gen_tokens, "prefill_s": t_prefill,
            "decode_s_per_tok": t_decode / max(gen - 1, 1),
            "compile_prefill_s": compile_prefill_s,
            "compile_decode_s": compile_decode_s,
            "report": report, "ctx": ctx}


def poisson_workload(vocab_size: int, *, n_requests: int, rate: float,
                     prompt_lens=(8, 16, 24, 32), gen_lens=(4, 8, 16, 24),
                     temperature: float = 0.0, seed: int = 0,
                     shared_prefix: int = 0) -> list:
    """Synthetic open-loop workload: Poisson arrivals (exponential
    inter-arrival at ``rate`` req/s on the engine clock) with mixed
    prompt/generation lengths — the shape continuous batching exists for
    (a static batch pads every request to the longest member).

    ``shared_prefix`` prepends the SAME ``shared_prefix`` random tokens (a
    synthetic system prompt) to every request's prompt — the shape the
    content-addressed prefix cache exists for (DESIGN §10): real fleets
    are dominated by shared prefixes, and the cache quantizes them once.
    ``prompt_lens`` then sizes the per-request unique tail."""
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab_size, size=shared_prefix
                          ).astype(np.int32)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        tail = rng.integers(0, vocab_size,
                            size=int(rng.choice(prompt_lens))
                            ).astype(np.int32)
        reqs.append(Request(
            rid=i,
            prompt=np.concatenate([prefix, tail]) if shared_prefix else tail,
            max_new_tokens=int(rng.choice(gen_lens)),
            temperature=temperature,
            arrival=t))
    return reqs


def serve_engine(arch: str, *, n_requests: int = 16, rate: float = 50.0,
                 n_slots: int = 4, block_size: int = 16, chunk: int = 16,
                 max_model_len: int | None = None,
                 num_blocks: int | None = None, mode: str = "fp",
                 calibrate: bool = False, smoke: bool = True, seed: int = 0,
                 attn_kernel: str | None = None, kv_bits: int | None = 8,
                 temperature: float = 0.0, top_k: int = 0,
                 mesh_shape: tuple[int, int] | None = None,
                 prompt_lens=(8, 16, 24, 32), gen_lens=(4, 8, 16, 24),
                 requests=None, cfg_overrides: dict | None = None,
                 shared_prefix: int = 0, prefix_cache: bool | None = None,
                 num_slabs: int | None = None,
                 state_bits: int | None = None,
                 spec_k: int = 0, drafter="ngram",
                 ragged: bool = True, w8a8: bool = False,
                 trace: str | bool = False, trace_capacity: int = 65536,
                 metrics_path: str | None = None,
                 profile_dir: str | None = None,
                 profile_cost: bool = False,
                 record: str | bool = False, virtual_dt: float = 1e-3,
                 slo=None) -> dict:
    """Continuous-batching serving on the paged int8-KV block pool
    (DESIGN §9/§10).  Returns {"report", "outputs", "requests", "engine"}.

    ``shared_prefix`` prepends an N-token system prompt to every request
    (see :func:`poisson_workload`); ``prefix_cache=False`` disables the
    content-addressed cache for A/B comparison at equal pool size, and
    the default ``None`` lets the substrate decide (on for attention,
    off — and an error if forced on — for recurrent/hybrid models,
    whose state is a running summary with no addressable prefix).

    Recurrent / hybrid archs (``rwkv6_3b``, ``zamba2_2_7b``) serve from
    the fixed-slab substrate (DESIGN §16): ``num_slabs`` sizes the state
    pool (default 1 trash + one slab per slot) and ``state_bits=8``
    stores slabs as int8 Eq.-1 codes requantized once per engine step
    (``None`` = fp32 slabs, the parity-oracle mode).
    ``spec_k > 0`` turns on speculative decoding (DESIGN §11): up to K
    tokens per slot are drafted (``drafter``: 'ngram' prompt-lookup
    self-drafting, or any object with draft(history, k)) and verified in
    one paged step, with rollback-safe publishing — rejected drafts
    never reach the prefix cache.  ``ragged=False`` falls back to the
    legacy per-shape step trio (bucketed prefill / decode / spec-verify
    dispatches) instead of the unified ragged work-list (DESIGN §12) —
    kept for A/B padding and throughput comparison.  ``w8a8=True`` is the
    true-W8A8 deploy path (DESIGN §13): forces mode='int' with
    Algorithm-1 calibration (threaded along the dataflow), sets
    ``cfg.matmul_kernel='int8'`` and pre-quantizes the matmul weights to
    int8 codes, so every projection/MLP/head matmul in the engine runs
    int8 x int8 -> int32 with the fused bit-shift requant.

    Observability (DESIGN §14): ``trace`` turns on the ring-buffered
    event tracer — pass a path string to also export the Chrome
    trace-event JSON there (load it in Perfetto / ``chrome://tracing``).
    ``metrics_path`` writes the prometheus text exposition of the
    metrics registry after the run.  ``profile_dir`` wraps each jitted
    dispatch in a ``jax.profiler`` step annotation and captures the run
    into that directory; ``profile_cost`` additionally records XLA
    FLOPs/bytes per compiled shape via AOT ``cost_analysis()``.

    Flight recorder (DESIGN §15): ``record`` runs the engine on the
    deterministic virtual clock and freezes the run into a portable
    :class:`repro.obs.replay.WorkloadRecord` (returned under
    ``"record"``; pass a path string to also save it as JSON) — replay
    it with ``repro.obs.replay.replay_workload`` or ``--replay``.
    ``slo`` attaches an SLO burn-rate monitor (``True`` for the stock
    objectives or a list of ``SLObjective``); alerts land in the
    tracer and the report's ``slo`` section."""
    from repro.serving import ServingEngine
    overrides = dict(cfg_overrides or {})
    if kv_bits is not None:
        overrides.setdefault("kv_cache_bits", kv_bits)
    if state_bits is not None:
        overrides.setdefault("state_bits", state_bits)
    if w8a8:
        mode, calibrate = "int", True
        overrides["matmul_kernel"] = "int8"
    cfg, mesh = _resolve_cfg_mesh(arch, smoke=smoke, attn_kernel=attn_kernel,
                                  cfg_overrides=overrides,
                                  mesh_shape=mesh_shape)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))

    ctx = QuantContext(mode=QuantMode(mode))
    if calibrate and mode in ("fake", "int"):
        stream = SyntheticLMStream(cfg.vocab_size, max(prompt_lens), 4,
                                   seed=seed)
        b0 = {k: jnp.asarray(v) for k, v in stream.batch(0).items()
              if k == "tokens"}
        ctx_cal, _ = calibrate_lm(
            lambda p, b, c: M.forward(p, b, cfg, c), params, b0)
        ctx = dataclasses.replace(ctx_cal, mode=QuantMode(mode))

    quantized = None
    if cfg.matmul_kernel == "int8":
        # W8A8 deploy: one-time weight-code conversion on the calibrated
        # grids; the engine forward then passes int8 codes straight through
        # qlinear (bit-identical to on-the-fly quantization).  The codes
        # shard exactly like their float counterparts under §8 meshes.
        from repro.core.qmodel import quantize_params
        if ctx.mode is not QuantMode.INT:
            raise ValueError("matmul_kernel='int8' requires mode='int' "
                             "(pass w8a8=True or mode='int')")
        quantized = quantize_params(params, ctx)
        params = quantized.tree

    if requests is None:
        requests = poisson_workload(
            cfg.vocab_size, n_requests=n_requests, rate=rate,
            prompt_lens=prompt_lens, gen_lens=gen_lens,
            temperature=temperature, seed=seed,
            shared_prefix=shared_prefix)
    if max_model_len is None:
        need = max(len(r.prompt) + r.max_new_tokens for r in requests)
        max_model_len = -(-need // block_size) * block_size
    engine = ServingEngine(cfg, params, ctx, n_slots=n_slots,
                           block_size=block_size, chunk=chunk,
                           max_model_len=max_model_len,
                           num_blocks=num_blocks, num_slabs=num_slabs,
                           top_k=top_k, mesh=mesh,
                           seed=seed, prefix_cache=prefix_cache,
                           spec_k=spec_k, drafter=drafter, ragged=ragged,
                           trace=bool(trace), trace_capacity=trace_capacity,
                           profile_dir=profile_dir,
                           profile_cost=profile_cost,
                           record=bool(record), virtual_dt=virtual_dt,
                           slo=slo)
    if profile_dir is not None:
        with engine.profiler.capture():
            report = engine.run(requests)
    else:
        report = engine.run(requests)
    if isinstance(trace, str) and trace:
        engine.tracer.export(trace)
    if metrics_path is not None:
        with open(metrics_path, "w") as fh:
            fh.write(engine.metrics.to_prometheus())
    rec = None
    if record:
        rec = engine.workload_record(requests)
        if isinstance(record, str):
            rec.save(record)
    return {"report": report, "outputs": engine.outputs(),
            "requests": requests, "engine": engine,
            "quantized": quantized, "ctx": ctx, "record": rec}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", "--model", dest="arch", required=True,
                    help="architecture name (alias: --model) — includes "
                         "the recurrent/hybrid archs rwkv6_3b and "
                         "zamba2_2_7b, served from the fixed-slab "
                         "substrate (DESIGN §16)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mode", default="int",
                    choices=["fp", "fake", "fake_sf", "int"])
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--attn-kernel", default=None,
                    choices=["chunked", "flash"],
                    help="attention path (DESIGN §2); default: cfg's")
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="serve on a (data, model) device mesh, e.g. '1x2';"
                         " with --attn-kernel flash the fused kernels run"
                         " per-shard via shard_map (DESIGN §8)")
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine on the paged int8-KV "
                         "block pool (DESIGN §9) against a synthetic "
                         "Poisson workload of mixed prompt/gen lengths")
    ap.add_argument("--requests", type=int, default=16,
                    help="[--engine] workload size")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="[--engine] Poisson arrival rate, req/s")
    ap.add_argument("--slots", type=int, default=4,
                    help="[--engine] continuous-batch width")
    ap.add_argument("--block-size", type=int, default=16,
                    help="[--engine] KV pool block size, tokens")
    ap.add_argument("--chunk", type=int, default=16,
                    help="[--engine] prefill chunk / per-step token budget")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="[--engine] sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="[--engine] top-k sampling cutoff (0 = full)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="[--engine] prepend the SAME N-token system "
                         "prompt to every request — the workload the "
                         "content-addressed prefix cache serves with one "
                         "quantization pass (DESIGN §10)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="[--engine] force the content-addressed prefix "
                         "cache ON (default: substrate decides — on for "
                         "attention archs, unavailable on recurrent/"
                         "hybrid ones)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="[--engine] disable the prefix cache (baseline "
                         "for A/B at equal pool size)")
    ap.add_argument("--slabs", type=int, default=None, metavar="N",
                    help="[--engine] recurrent-state pool size in slabs "
                         "(DESIGN §16; default 1 trash + one per slot); "
                         "ignored on pure-attention archs")
    ap.add_argument("--state-bits", type=int, default=None, choices=[8],
                    help="[--engine] store recurrent state slabs as int8 "
                         "Eq.-1 codes, requantized once per engine step "
                         "(default: fp32 slabs, the parity-oracle mode); "
                         "ignored on pure-attention archs")
    ap.add_argument("--spec-k", type=int, default=0, metavar="K",
                    help="[--engine] speculative decoding (DESIGN §11): "
                         "draft up to K tokens per slot and verify them "
                         "in ONE paged step; accepted tokens commit, the "
                         "rejected tail's blocks retract so they never "
                         "publish to the prefix cache (0 = off)")
    ap.add_argument("--drafter", default="ngram", choices=["ngram"],
                    help="[--engine --spec-k] draft proposer: 'ngram' is "
                         "the model-free prompt-lookup self-drafter "
                         "(small-draft-model hooks plug in via the "
                         "serve_engine(drafter=...) API)")
    ap.add_argument("--w8a8", action="store_true",
                    help="[--engine] true W8A8 serving (DESIGN §13): "
                         "calibrate with Algorithm 1 threaded along the "
                         "dataflow, pre-quantize weights to int8 codes and "
                         "run every projection/MLP/head matmul through the "
                         "fused int8 shift-requant path (implies "
                         "--mode int)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="[--engine] enable structured event tracing "
                         "(DESIGN §14) and export the run as Chrome "
                         "trace-event JSON — open it in Perfetto "
                         "(ui.perfetto.dev) or chrome://tracing")
    ap.add_argument("--trace-capacity", type=int, default=65536,
                    help="[--engine --trace] trace ring-buffer size; the "
                         "ring is a hard memory bound — oldest events "
                         "drop first and the export reports the count")
    ap.add_argument("--metrics", default=None, metavar="OUT.prom",
                    help="[--engine] write the metrics registry as "
                         "prometheus text exposition after the run")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="[--engine] capture a jax.profiler trace of the "
                         "run into DIR (one StepTraceAnnotation per "
                         "jitted dispatch)")
    ap.add_argument("--profile-cost", action="store_true",
                    help="[--engine] record XLA FLOPs/bytes per compiled "
                         "shape via AOT cost_analysis() in the report's "
                         "profile section")
    ap.add_argument("--record", default=None, metavar="OUT.json",
                    help="[--engine] flight recorder (DESIGN §15): run "
                         "on the deterministic virtual clock and save a "
                         "portable workload record (arrivals, prompts, "
                         "sampling params, seeds, config fingerprint, "
                         "emitted tokens, scheduler-decision stream) "
                         "for later --replay")
    ap.add_argument("--replay", default=None, metavar="IN.json",
                    help="(implies --engine) replay a recorded workload: "
                         "re-inject the captured arrival process on the "
                         "virtual clock (engine knobs from the record) "
                         "and report token parity + the scheduler-"
                         "decision diff; exits nonzero on divergence")
    ap.add_argument("--slo", action="store_true",
                    help="[--engine] attach the stock SLO objectives "
                         "(TTFT/e2e percentile targets, pool-pressure "
                         "ceiling) with rolling-window burn-rate "
                         "alerting; alerts print after the run and land "
                         "in the trace on the 'slo' lane")
    ap.add_argument("--no-ragged", action="store_true",
                    help="[--engine] use the legacy per-shape step trio "
                         "(bucketed prefill / decode / spec-verify) "
                         "instead of the unified ragged work-list "
                         "dispatch (DESIGN §12) — A/B baseline for "
                         "padding waste and compile count")
    args = ap.parse_args(argv)
    mesh_shape = None
    if args.mesh is not None:
        d, m = (int(x) for x in args.mesh.lower().split("x"))
        mesh_shape = (d, m)
    if args.prefix_cache and args.no_prefix_cache:
        ap.error("--prefix-cache and --no-prefix-cache are mutually "
                 "exclusive")
    # tri-state: None lets the substrate decide (engine errors with a
    # clear message if --prefix-cache is forced on a recurrent arch)
    prefix_cache = (True if args.prefix_cache
                    else False if args.no_prefix_cache else None)

    if args.replay:                   # implies --engine
        from repro.obs.replay import (WorkloadRecord, build_requests,
                                      replay_workload)
        rec = WorkloadRecord.load(args.replay)
        es = rec.engine
        # block_size/num_blocks are None in recurrent-substrate records
        # (no KV pool existed); serve_engine's defaults only matter for
        # archs that grow, where the record always carries real values.
        out = serve_engine(args.arch, requests=build_requests(rec),
                           n_slots=es["n_slots"],
                           block_size=es["block_size"] or 16,
                           chunk=es["chunk"],
                           max_model_len=es["max_model_len"],
                           num_blocks=es["num_blocks"], mode=args.mode,
                           calibrate=not args.no_calibrate,
                           smoke=not args.full,
                           attn_kernel=args.attn_kernel,
                           top_k=es["default_top_k"], seed=es["seed"],
                           mesh_shape=mesh_shape,
                           prefix_cache=es["prefix_cache"],
                           num_slabs=es.get("num_slabs"),
                           spec_k=es["spec_k"], drafter=args.drafter,
                           ragged=es["ragged"], w8a8=args.w8a8,
                           record=True, virtual_dt=es["virtual_dt"])
        res = replay_workload(rec, out["engine"])
        print(f"replay {args.replay}: fingerprint "
              f"{'match' if res.fingerprint_match else 'MISMATCH'} "
              f"(record {res.record_fingerprint}, engine "
              f"{res.engine_fingerprint})")
        print("tokens: " + ("identical" if res.token_identical else
                            f"MISMATCH rids={res.mismatched_rids}"))
        print(f"scheduler-decision diff: {len(res.decision_diff)} lines"
              + ("" if res.decision_diff else " (empty — identical)"))
        for line in res.decision_diff[:40]:
            print("  " + line)
        raise SystemExit(0 if res.ok else 1)

    if args.engine:
        import json
        try:
            out = serve_engine(
                args.arch, n_requests=args.requests,
                rate=args.rate, n_slots=args.slots,
                block_size=args.block_size, chunk=args.chunk,
                mode=args.mode, calibrate=not args.no_calibrate,
                smoke=not args.full,
                attn_kernel=args.attn_kernel,
                temperature=args.temperature, top_k=args.top_k,
                mesh_shape=mesh_shape,
                shared_prefix=args.shared_prefix,
                prefix_cache=prefix_cache,
                num_slabs=args.slabs, state_bits=args.state_bits,
                spec_k=args.spec_k, drafter=args.drafter,
                ragged=not args.no_ragged, w8a8=args.w8a8,
                trace=args.trace if args.trace else False,
                trace_capacity=args.trace_capacity,
                metrics_path=args.metrics,
                profile_dir=args.profile_dir,
                profile_cost=args.profile_cost,
                record=args.record if args.record else False,
                slo=True if args.slo else None)
        except ValueError as e:
            # substrate incompatibilities (e.g. --spec-k / --prefix-cache
            # on a recurrent arch) surface as one actionable line, not a
            # traceback
            ap.exit(2, f"error: {e}\n")
        print(json.dumps(out["report"], indent=2))
        if args.record:
            rec = out["record"]
            print(f"record: {len(rec.requests)} requests, "
                  f"{len(rec.decisions)} scheduler decisions, "
                  f"fingerprint {rec.fingerprint} -> {args.record} "
                  f"(replay with --replay {args.record})")
        if args.slo:
            mon = out["engine"].slo
            state = "ALERT" if mon.alerts_active else "ok"
            print(f"slo: {state} — {mon.alerts_fired} alert(s) fired "
                  f"over {mon.evaluations} evaluations; worst burn "
                  f"rate {mon.worst_burn_rate()}")
            for a in mon.alerts:
                print(f"  alert {a['objective']}: burn {a['burn_rate']} "
                      f"({a['window_bad']}/{a['window_total']} over "
                      f"window) at t={a['t']:.3f}s")
        if args.trace:
            obs = out["report"]["obs"]
            print(f"trace: {obs['trace_events']} events "
                  f"({obs['trace_dropped']} dropped, ring "
                  f"{obs['trace_capacity']}) -> {args.trace} "
                  f"(open in ui.perfetto.dev)")
        if args.metrics:
            print(f"metrics: prometheus exposition -> {args.metrics}")
        en = out["report"]["energy"]
        print(f"energy proxy ({en['unit']}): "
              f"{en['proxy_uj_per_token']} uJ/token live "
              f"[prefill {en['prefill']['uj_per_token']}, "
              f"decode {en['decode']['uj_per_token']}, "
              f"spec-wasted {en['spec_wasted']['uj_per_token']}]")
        sl = out["report"].get("state_pool")
        if sl is not None:
            hw = out["report"].get("hwcost", {})
            print(f"state slabs ({out['report']['substrate']}): "
                  f"{sl['peak_live_slabs']}/{sl['num_slabs']} peak live "
                  f"({sl['allocs']} allocs, {sl['seq_evictions']} "
                  f"evictions), {sl['state_quant_ops_per_step']} state "
                  f"requant ops/step/seq (scale exp {sl['scale_exp']}); "
                  f"requant ops/token {hw.get('requant_ops_per_token')}")
        hw = out["report"].get("hwcost", {})
        if hw.get("w8a8"):
            print(f"w8a8 forward: {hw['requant_ops_forward']} requant ops "
                  f"-> {hw['energy_uj_forward_bit_shift']:.3f} uJ "
                  f"(bit-shift) vs "
                  f"{hw['energy_uj_forward_if_scaling_factor']:.3f} uJ "
                  f"(scaling-factor unit); "
                  f"{len(out['quantized'].converted)} weight tensors "
                  f"pre-quantized to int8 codes")
        pc = out["report"].get("prefix_cache")
        if pc is not None:
            print(f"prefix cache: hit-rate {pc['hit_rate']:.1%} "
                  f"({pc['hits']}/{pc['hits'] + pc['misses']} block "
                  f"lookups), {pc['cached_prefill_tokens']} prefill "
                  f"tokens served from cache, {pc['cow_copies']} COW "
                  f"copies, {pc['cache_evictions']} LRU evictions")
        sp = out["report"].get("speculative")
        if sp is not None:
            print(f"speculative (K={sp['spec_k']}, {sp['drafter']}): "
                  f"acceptance {sp['acceptance_rate']}, "
                  f"{sp['tokens_per_step']} tokens/step over "
                  f"{sp['verify_steps']} verify steps, "
                  f"{sp['retracted_blocks']} blocks retracted "
                  f"({sp['requant_ops_wasted']} quant ops spent on "
                  f"rejected drafts)")
        return
    out = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen=args.gen, mode=args.mode,
                calibrate=not args.no_calibrate, smoke=not args.full,
                attn_kernel=args.attn_kernel, mesh_shape=mesh_shape)
    print(f"generated {out['tokens'].shape} tokens | "
          f"compile {out['compile_prefill_s']:.2f}s+"
          f"{out['compile_decode_s']:.2f}s | "
          f"prefill {out['prefill_s']:.2f}s | "
          f"decode {1e3*out['decode_s_per_tok']:.1f} ms/tok (steady)")
    print("sample:", out["tokens"][0][:16])


if __name__ == "__main__":
    main()
