"""pjit-able train / prefill / serve steps + ShapeDtypeStruct input specs.

These are the functions the dry-run lowers for every (arch x shape x mesh)
cell and the train/serve drivers execute for real.  Everything here is a
pure function of (abstract) arrays with static (cfg, ctx) — no globals.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from repro.models.scan_lib import scan as _scan
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.core.qmodel import QuantContext, QuantMode
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.optim import adamw, adafactor, clip_by_global_norm
from repro.optim.optimizers import Optimizer, OptState

__all__ = ["pick_optimizer", "build_train_step", "build_prefill_step",
           "build_serve_step", "build_paged_step", "build_ragged_step",
           "input_specs", "abstract_params", "abstract_opt_state",
           "abstract_cache", "abstract_paged_cache", "param_count"]

ADAFACTOR_THRESHOLD = 30e9  # params; above this AdamW state cannot fit v5e


def abstract_params(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))


def param_count(cfg: ModelConfig) -> int:
    leaves = jax.tree_util.tree_leaves(abstract_params(cfg))
    return sum(int(functools.reduce(lambda a, b: a * b, l.shape, 1))
               for l in leaves)


def pick_optimizer(cfg: ModelConfig) -> Optimizer:
    return adafactor() if param_count(cfg) > ADAFACTOR_THRESHOLD else adamw()


def abstract_opt_state(cfg: ModelConfig, opt: Optimizer) -> Any:
    return jax.eval_shape(lambda: opt.init(abstract_params(cfg)))


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Any:
    return jax.eval_shape(lambda: M.init_cache(cfg, batch, max_seq))


def abstract_paged_cache(cfg: ModelConfig, num_blocks: int,
                         block_size: int) -> Any:
    return jax.eval_shape(
        lambda: M.init_paged_cache(cfg, num_blocks, block_size))


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, shardable, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract model inputs for one assigned shape cell.

    train/prefill: token batch (+ stub encoder features for [audio]);
    decode: one new token + the KV/state cache at seq_len + position scalar.
    """
    b, s = shape.global_batch, shape.seq_len
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)

    if shape.kind == "train":
        batch = {"tokens": tok(b, s), "labels": tok(b, s),
                 "mask": jax.ShapeDtypeStruct((b, s), jnp.float32)}
        if cfg.family == "audio":
            batch["encoder_features"] = jax.ShapeDtypeStruct(
                (b, cfg.encdec.encoder_seq, cfg.d_model), jnp.bfloat16)
        return {"batch": batch}

    if shape.kind == "prefill":
        batch = {"tokens": tok(b, s)}
        if cfg.family == "audio":
            batch["encoder_features"] = jax.ShapeDtypeStruct(
                (b, cfg.encdec.encoder_seq, cfg.d_model), jnp.bfloat16)
        return {"batch": batch}

    # decode: one token against a cache of length seq_len
    return {"tokens": tok(b, 1),
            "cache": abstract_cache(cfg, b, s),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def batch_specs_tree(batch: dict, mesh: Mesh) -> dict:
    spec = {}
    for k, v in batch.items():
        spec[k] = shd.batch_sharding(mesh, v.ndim)
    return spec


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, ctx: QuantContext, opt: Optimizer,
                     lr_fn, *, remat: bool = True, clip_norm: float = 1.0,
                     accum_steps: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    accum_steps > 1 runs gradient accumulation over microbatches (batch dim
    split A x B/A, fp32 grad accumulator sharded like the params).  Large
    configs need it: the per-layer saved-activation stack scales with the
    per-step batch, so e.g. deepseek-67b train_4k at global batch 256 on
    256 chips saves 95 x 16 x 4096 x 8192 x 2B = 102 GB/device without
    accumulation vs 6.4 GB at accum=16.
    """

    def grads_of(params, batch):
        def loss_of(p):
            return M.loss_fn(p, batch, cfg, ctx, remat=remat)

        return jax.value_and_grad(loss_of, has_aux=True)(params)

    def train_step(params, opt_state: OptState, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)

            def acc_step(carry, mb):
                g_acc, loss_acc = carry
                (loss, _), g = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), None

            (g_sum, loss_sum), _ = _scan(
                acc_step, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            loss = loss_sum / accum_steps
            metrics = {"nll": loss}
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(opt_state.step)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return train_step


def _resolve_attn_kernel(cfg: ModelConfig, attn_kernel: Optional[str],
                         mesh: Optional[Mesh] = None) -> ModelConfig:
    """Serving-path attention selector (DESIGN §2): ``attn_kernel`` overrides
    ``cfg.attn_kernel`` for this step builder only — 'flash' routes prefill
    and decode through the fused Pallas kernel (int8 KV codes dequantized
    in-register), 'chunked' keeps the pure-JAX reference.

    On a >1-device mesh the flash kernels run per-shard under shard_map
    (DESIGN §8): KV heads — whole GQA groups, scales resident — partition
    across ``cfg.attn_shard_axis``, so the axis size must divide
    ``n_kv_heads``.  Mesh shapes that would split a GQA group raise an
    explicit NotImplementedError here, at build time, instead of silently
    demoting to the chunked path (or worse, gathering the cache)."""
    if attn_kernel is not None and attn_kernel != cfg.attn_kernel:
        cfg = dataclasses.replace(cfg, attn_kernel=attn_kernel)
    if cfg.attn_kernel == "flash" and mesh is not None and mesh.size > 1:
        if cfg.attn_shard_axis != "model":
            # the cache rules and the logical 'heads' activation pins are
            # wired to 'model'; a different kernel shard axis would make
            # GSPMD reshard the cache at the shard_map boundary every
            # step — refuse rather than silently regress (DESIGN §8)
            raise NotImplementedError(
                f"attn_shard_axis='{cfg.attn_shard_axis}' is not wired "
                f"through the cache/activation sharding rules yet; only "
                f"'model' is supported on multi-device meshes")
        from repro.kernels.ops import attn_shard_size
        tp = attn_shard_size(mesh, cfg.attn_shard_axis)
        # the head count the kernel actually shards: MLA's flash prefill
        # runs with kvh == n_heads (n_kv_heads is nominal there)
        kvh = cfg.n_heads if cfg.mla is not None else cfg.n_kv_heads
        if kvh % tp:
            raise NotImplementedError(
                f"attn_kernel='flash' shards KV heads over mesh axis "
                f"'{cfg.attn_shard_axis}' (size {tp}), which must divide "
                f"the KV head count ({kvh}"
                + (", = n_heads for MLA" if cfg.mla is not None else
                   " = n_kv_heads")
                + f"); pick a mesh whose '{cfg.attn_shard_axis}' axis "
                f"divides it or use attn_kernel='chunked'")
    return cfg


def _check_matmul_kernel(cfg: ModelConfig, ctx: QuantContext) -> None:
    """Build-time validation of the W8A8 matmul path (DESIGN §13).

    ``matmul_kernel='int8'`` means the params tree carries pre-quantized
    int8 weight codes whose values only make sense on the calibrated po2
    grids — running them through the fp/fake float paths would silently
    produce garbage logits, so refuse at build time rather than at the
    first decoded token."""
    if cfg.matmul_kernel not in ("dense", "int8"):
        raise ValueError(
            f"unknown matmul_kernel={cfg.matmul_kernel!r}; expected "
            "'dense' or 'int8'")
    if cfg.matmul_kernel == "int8" and ctx.mode is not QuantMode.INT:
        raise NotImplementedError(
            "matmul_kernel='int8' is the W8A8 deploy path: it requires a "
            "calibrated QuantContext in INT mode (serve --engine --w8a8 "
            f"builds one); got mode={ctx.mode.value!r}")


def _mesh_scope(mesh: Optional[Mesh]):
    """Activation-sharding scope for a step body: makes ``constrain`` and
    ``current_mesh()`` (the shard_map'd flash kernels, DESIGN §8) see the
    mesh while the step is TRACED, wherever the jit call happens."""
    import contextlib
    return (shd.activation_sharding(mesh) if mesh is not None
            else contextlib.nullcontext())


def build_prefill_step(cfg: ModelConfig, ctx: QuantContext,
                       attn_kernel: Optional[str] = None,
                       mesh: Optional[Mesh] = None,
                       max_seq: Optional[int] = None):
    cfg = _resolve_attn_kernel(cfg, attn_kernel, mesh)
    _check_matmul_kernel(cfg, ctx)

    def prefill_step(params, batch):
        with _mesh_scope(mesh):
            return M.prefill(params, batch, cfg, ctx, max_seq=max_seq)

    return prefill_step


def build_serve_step(cfg: ModelConfig, ctx: QuantContext,
                     attn_kernel: Optional[str] = None,
                     mesh: Optional[Mesh] = None):
    """One batched decode step (greedy sampling of the next token)."""
    cfg = _resolve_attn_kernel(cfg, attn_kernel, mesh)
    _check_matmul_kernel(cfg, ctx)

    def serve_step(params, tokens, cache, pos):
        with _mesh_scope(mesh):
            logits, cache = M.decode_step(params, tokens, cache, pos, cfg,
                                          ctx)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok[:, None], cache

    return serve_step


def build_paged_step(cfg: ModelConfig, ctx: QuantContext,
                     attn_kernel: Optional[str] = None,
                     mesh: Optional[Mesh] = None):
    """One serving-engine step over the paged KV block pool (DESIGN §9):
    (params, tokens (B,C), cache, positions (B,C), block_tables (B,NBmax))
    -> (logits (B,C,V), cache).  The SAME builder serves continuous-
    batching decode (B=n_slots, C=1) and chunked prefill (B=1, C=chunk);
    jit specializes per distinct (B, C) — the engine's bucketing keeps
    that set bounded."""
    cfg = _resolve_attn_kernel(cfg, attn_kernel, mesh)
    _check_matmul_kernel(cfg, ctx)

    def paged_step(params, tokens, cache, positions, block_tables):
        with _mesh_scope(mesh):
            return M.paged_step(params, tokens, cache, positions,
                                block_tables, cfg, ctx)

    return paged_step


def build_recurrent_step(cfg: ModelConfig, ctx: QuantContext,
                         attn_kernel: Optional[str] = None,
                         mesh: Optional[Mesh] = None):
    """One serving step on the fixed-slab recurrent substrate (DESIGN §16):
    (params, tokens (B,C), cache, slab_ids (B,), q_len (B,), positions,
    block_tables) -> (logits (B,V), cache).  ONE fixed shape
    (B = n_slots, C = chunk) covers prefill chunks, decode rows, and idle
    lanes at once — per-row ``q_len`` does the bucketing work, so jit
    specializes exactly once.  ``positions``/``block_tables`` are None for
    pure recurrent families; the hybrid family threads them into the
    shared attention block's KV pool."""
    cfg = _resolve_attn_kernel(cfg, attn_kernel, mesh)
    _check_matmul_kernel(cfg, ctx)

    def recurrent_step(params, tokens, cache, slab_ids, q_len,
                       positions=None, block_tables=None):
        with _mesh_scope(mesh):
            return M.paged_recurrent_step(params, tokens, cache, slab_ids,
                                          q_len, positions, block_tables,
                                          cfg, ctx)

    return recurrent_step


def build_ragged_step(cfg: ModelConfig, ctx: QuantContext,
                      attn_kernel: Optional[str] = None,
                      mesh: Optional[Mesh] = None):
    """One UNIFIED serving step over the flattened mixed stream (DESIGN
    §12): (params, tokens (T,), cache, positions (T,), ragged
    RaggedBatch) -> (logits (T,V), cache).  Replaces the per-shape
    paged_step dispatch trio — jit specializes per (T_pad, S_pad) only,
    and the engine's T bucketing keeps that set O(few)."""
    cfg = _resolve_attn_kernel(cfg, attn_kernel, mesh)
    _check_matmul_kernel(cfg, ctx)

    def ragged_step(params, tokens, cache, positions, ragged):
        with _mesh_scope(mesh):
            return M.ragged_step(params, tokens, cache, positions, ragged,
                                 cfg, ctx)

    return ragged_step


# ---------------------------------------------------------------------------
# jit wiring with shardings for a given mesh
# ---------------------------------------------------------------------------

SERVE_FSDP_BYTES = 12e9  # replicate serve weights across data below this


def serve_needs_fsdp(cfg: ModelConfig, mesh: Mesh, bytes_per_param=2) -> bool:
    """Serving re-gathers FSDP weights EVERY decode step (measured: 128 GB
    per token on qwen3-32b decode_32k — §Perf iteration D).  Below
    ``SERVE_FSDP_BYTES``/device the weights are replicated across the data
    axis instead.  MoE expert stacks are excluded: serve mode shards them
    2-D (expert x data, never gathered — §Perf V4), so only the NON-expert
    params need to fit replicated-over-data."""
    n = param_count(cfg)
    if cfg.moe is not None:
        m = cfg.moe
        n -= (cfg.n_layers - m.n_dense_layers) * m.e_padded * 3             * cfg.d_model * m.d_expert
    return n * bytes_per_param / mesh.shape["model"] > SERVE_FSDP_BYTES


def default_accum_steps(cfg: ModelConfig, shape: ShapeConfig,
                        mesh: Mesh) -> int:
    """Microbatch so each data shard sees ~1 sequence per micro-step on
    >10B-param configs; small models run the whole batch in one step."""
    if param_count(cfg) < 10e9:
        return 1
    ds = _data_size(mesh)
    return max(1, shape.global_batch // ds)


def jit_train_step(cfg: ModelConfig, ctx: QuantContext, mesh: Mesh,
                   opt: Optimizer, lr_fn, *, remat: bool = True,
                   fsdp: bool = True, accum_steps: int = 1):
    params_abs = abstract_params(cfg)
    p_spec = shd.param_sharding_rules(params_abs, mesh, fsdp=fsdp)
    opt_abs = abstract_opt_state(cfg, opt)
    o_spec = _opt_spec_like(opt_abs, p_spec)
    step = build_train_step(cfg, ctx, opt, lr_fn, remat=remat,
                            accum_steps=accum_steps)
    bspec = shd.batch_sharding(mesh, 2)

    def batch_spec_of(abs_batch):
        return {k: shd.batch_sharding(mesh, v.ndim)
                for k, v in abs_batch.items()}

    def wire(abs_batch):
        in_shardings = (
            jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec,
                         is_leaf=_is_pspec),
            jax.tree.map(lambda s: NamedSharding(mesh, s), o_spec,
                         is_leaf=_is_pspec),
            jax.tree.map(lambda s: NamedSharding(mesh, s),
                         batch_spec_of(abs_batch), is_leaf=_is_pspec),
        )
        out_shardings = (in_shardings[0], in_shardings[1], None)
        return jax.jit(step, in_shardings=in_shardings,
                       out_shardings=out_shardings)

    return step, wire, (params_abs, opt_abs, p_spec, o_spec)


def _is_pspec(x):
    return isinstance(x, P)


def _opt_spec_like(opt_abs: Any, p_spec: Any) -> Any:
    """Moments inherit their param's spec (ZeRO-1); factored adafactor rows/
    cols inherit the matching prefix; scalars replicate."""
    p_flat, p_tree = jax.tree_util.tree_flatten(p_spec,
                                                is_leaf=_is_pspec)

    def like(sub, spec):
        if sub is None:
            return None
        if isinstance(sub, tuple):          # adafactor (row, col)
            row_spec = P(*spec[:-1]) if len(spec) else P()
            col_spec = P(*(list(spec[:-2]) + [spec[-1]])) if len(spec) >= 2 \
                else P()
            return (row_spec, col_spec)
        return spec

    def map_state(field):
        if field is None:
            return None
        leaves = p_tree.flatten_up_to(field)
        return p_tree.unflatten([like(l, s) for l, s in zip(leaves, p_flat)])

    return OptState(step=P(), m=map_state(opt_abs.m), v=map_state(opt_abs.v))


def jit_serve_step(cfg: ModelConfig, ctx: QuantContext, mesh: Mesh,
                   shape: ShapeConfig, *, fsdp: bool = True,
                   attn_kernel: Optional[str] = None):
    """jit'd decode step with full sharding wiring for one decode cell."""
    params_abs = abstract_params(cfg)
    p_spec = shd.param_sharding_rules(params_abs, mesh, fsdp=fsdp,
                                      serve=True)
    cache_abs = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    # flash keeps the cache HEAD-sharded (shard_map residency, DESIGN §8);
    # chunked keeps it sequence-sharded (context-parallel decode, §5)
    c_spec = shd.cache_sharding_rules(
        cache_abs, mesh, attn_kernel=attn_kernel or cfg.attn_kernel,
        attn_shard_axis=cfg.attn_shard_axis)
    step = build_serve_step(cfg, ctx, attn_kernel, mesh)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=_is_pspec)
    tok_spec = NamedSharding(mesh, shd.batch_sharding(mesh, 2)
                             if shape.global_batch % _data_size(mesh) == 0
                             else P(None, None))
    jitted = jax.jit(step, in_shardings=(ns(p_spec), tok_spec, ns(c_spec),
                                         NamedSharding(mesh, P())),
                     out_shardings=(tok_spec, ns(c_spec)))
    return jitted, (params_abs, cache_abs, p_spec, c_spec)


def jit_prefill_step(cfg: ModelConfig, ctx: QuantContext, mesh: Mesh,
                     shape: ShapeConfig, *, fsdp: bool = True,
                     attn_kernel: Optional[str] = None):
    params_abs = abstract_params(cfg)
    p_spec = shd.param_sharding_rules(params_abs, mesh, fsdp=fsdp,
                                      serve=True)
    step = build_prefill_step(cfg, ctx, attn_kernel, mesh)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=_is_pspec)
    specs = input_specs(cfg, shape)
    b_spec = {k: NamedSharding(mesh, shd.batch_sharding(mesh, v.ndim))
              for k, v in specs["batch"].items()}
    jitted = jax.jit(step, in_shardings=(ns(p_spec), b_spec))
    return jitted, (params_abs, specs["batch"], p_spec)


def _data_size(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
