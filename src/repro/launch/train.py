"""Production training driver: data pipeline + pjit train step + async
checkpointing + fault-tolerant supervision, wired per DESIGN §5.

    PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1 [--smoke] \
        [--mode fake] [--accum 2] [--compress-grads]

On a real cluster this process runs per host under ``jax.distributed``;
here it drives the locally visible devices.  The RunSupervisor restart loop
(restore latest commit -> re-mesh -> continue) is exercised end-to-end by
tests/test_fault_tolerance.py and examples/fault_tolerant_train.py.

XLA runtime flags for straggler mitigation at scale (documented, applied by
the launcher environment, not here):
    --xla_tpu_enable_megascale_barrier=true
    MEGASCALE_TIMEOUT_SECONDS / slow-collective watchdogs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config, get_smoke_config
from repro.core.qmodel import QuantContext, QuantMode
from repro.data import ShardedLoader, SyntheticLMStream
from repro.distributed import sharding as shd
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.launch import steps as S
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.optim.schedule import warmup_cosine


def train(arch: str, steps: int, *, batch: int = 8, seq: int = 128,
          ckpt_dir: str | None = None, smoke: bool = True,
          mode: str = "fp", lr: float = 3e-3, accum: int = 1,
          ckpt_every: int = 50, log_every: int = 10, seed: int = 0,
          compress_grads: bool = False) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    ctx = QuantContext(mode=QuantMode(mode))
    mesh = make_local_mesh()
    opt = S.pick_optimizer(cfg)
    lr_fn = warmup_cosine(lr, max(steps // 10, 1), steps)
    monitor = HeartbeatMonitor(n_hosts=jax.process_count())

    with mesh, shd.activation_sharding(mesh):
        step_fn, wire, (params_abs, opt_abs, p_spec, o_spec) = \
            S.jit_train_step(cfg, ctx, mesh, opt, lr_fn, remat=False,
                             fsdp=False, accum_steps=accum)

        params = M.init_params(cfg, jax.random.PRNGKey(seed))
        opt_state = opt.init(params)
        start_step = 0
        ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        if ckpt and ckpt.latest_step() is not None:
            state, extra = ckpt.restore(
                jax.eval_shape(lambda: {"params": params, "opt": opt_state}))
            params, opt_state = state["params"], state["opt"]
            start_step = extra.get("step", ckpt.latest_step())
            print(f"resumed from step {start_step}")

        stream = SyntheticLMStream(
            cfg.vocab_size, seq, batch, seed=seed,
            encoder_seq=cfg.encdec.encoder_seq if cfg.family == "audio"
            else None,
            d_model=cfg.d_model if cfg.family == "audio" else None)
        loader = ShardedLoader(stream, shardings={}, start_step=start_step)

        jitted = jax.jit(step_fn)
        losses = []
        t_start = time.time()
        try:
            for _ in range(start_step, steps):
                step_i, b = next(loader)
                t0 = time.time()
                params, opt_state, metrics = jitted(params, opt_state, b)
                monitor.beat(jax.process_index(), time.time() - t0)
                loss = float(metrics["loss"])
                losses.append(loss)
                if step_i % log_every == 0:
                    print(f"step {step_i:5d} loss {loss:.4f} "
                          f"lr {float(metrics['lr']):.2e} "
                          f"gnorm {float(metrics['grad_norm']):.2f} "
                          f"({time.time()-t0:.2f}s)")
                if ckpt and step_i and step_i % ckpt_every == 0:
                    ckpt.save(step_i, {"params": params, "opt": opt_state},
                              extra={"step": step_i,
                                     "data_state": loader.state()})
        finally:
            loader.close()
            if ckpt:
                ckpt.save(steps, {"params": params, "opt": opt_state},
                          extra={"step": steps}, blocking=True)

    return {"params": params, "losses": losses,
            "wall_s": time.time() - t_start}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full", action="store_true",
                    help="full config (default: smoke-scale)")
    ap.add_argument("--mode", default="fp",
                    choices=["fp", "fake", "fake_sf", "int"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args(argv)
    out = train(args.arch, args.steps, batch=args.batch, seq=args.seq,
                ckpt_dir=args.ckpt_dir, smoke=not args.full, mode=args.mode,
                lr=args.lr, accum=args.accum,
                compress_grads=args.compress_grads)
    print(f"final loss {out['losses'][-1]:.4f} "
          f"(first {out['losses'][0]:.4f}) in {out['wall_s']:.0f}s")


if __name__ == "__main__":
    main()
