"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell against the production mesh and extract roofline inputs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k [--multi-pod] [--mode int] [--json out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

The FIRST two lines below must run before ANY other import (jax locks the
device count on first initialization).
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (get_config, supported_shapes, ARCH_IDS)  # noqa: E402
from repro.configs.base import SHAPES, ModelConfig  # noqa: E402
from repro.core.qmodel import QuantContext, QuantMode  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch import analysis as A  # noqa: E402
from repro.launch import steps as S  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.optim.schedule import warmup_cosine  # noqa: E402


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), D = tokens;
    N_active for MoE.  Decode: D = batch (one token each)."""
    n = S.param_count(cfg)
    if cfg.moe is not None:
        m = cfg.moe
        active_expert = (m.top_k + m.n_shared) * 3 * cfg.d_model * m.d_expert
        all_expert = (m.n_experts + m.n_shared) * 3 * cfg.d_model * m.d_expert
        n_moe_layers = cfg.n_layers - m.n_dense_layers
        n = n - (all_expert - active_expert) * n_moe_layers
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n * tokens


def lower_cell(arch: str, shape_name: str, mesh, mode: str = "fp",
               fsdp: bool | None = None, remat: bool = True,
               accum_steps: int | None = None,
               cfg: ModelConfig | None = None):
    """Lower + compile one cell; returns (lowered, compiled, meta).

    ``cfg`` overrides the registry config (used by the roofline fit to
    lower reduced-depth variants).  ``fsdp=None`` = auto: always on for
    train; for serve only when the weights cannot replicate across the
    data axis (steps.serve_needs_fsdp)."""
    cfg = cfg if cfg is not None else get_config(arch)
    shape = SHAPES[shape_name]
    ctx = QuantContext(mode=QuantMode(mode))
    if fsdp is None:
        fsdp = True if shape.kind == "train" else \
            S.serve_needs_fsdp(cfg, mesh,
                               bytes_per_param=1 if mode == "int" else 2)
    t0 = time.time()

    with mesh, shd.activation_sharding(mesh):
        if shape.kind == "train":
            opt = S.pick_optimizer(cfg)
            if accum_steps is None:
                accum_steps = S.default_accum_steps(cfg, shape, mesh)
            step, wire, (params_abs, opt_abs, p_spec, o_spec) = \
                S.jit_train_step(cfg, ctx, mesh, opt,
                                 warmup_cosine(3e-4, 100, 10_000),
                                 remat=remat, fsdp=fsdp,
                                 accum_steps=accum_steps)
            specs = S.input_specs(cfg, shape)
            jitted = wire(specs["batch"])
            lowered = jitted.lower(params_abs,
                                   S.abstract_opt_state(cfg, opt),
                                   specs["batch"])
        elif shape.kind == "prefill":
            jitted, (params_abs, batch_abs, p_spec) = \
                S.jit_prefill_step(cfg, ctx, mesh, shape, fsdp=fsdp)
            lowered = jitted.lower(params_abs, batch_abs)
        else:
            jitted, (params_abs, cache_abs, p_spec, c_spec) = \
                S.jit_serve_step(cfg, ctx, mesh, shape, fsdp=fsdp)
            lowered = jitted.lower(
                params_abs,
                jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
                cache_abs, jax.ShapeDtypeStruct((), jnp.int32))

        compiled = lowered.compile()
    return lowered, compiled, {"lower_compile_s": time.time() - t0,
                               "cfg": cfg, "shape": shape,
                               "accum_steps": accum_steps}


def analyze(compiled, cfg, shape, mesh) -> dict:
    sample = A.sample_of(compiled)
    terms = A.roofline_terms(sample)
    mem = compiled.memory_analysis()
    mf = model_flops(cfg, shape)
    n_dev = mesh.devices.size
    return {
        "arch": cfg.name, "shape": shape.name, "devices": n_dev,
        "hlo_flops_per_device": sample.flops,
        "hlo_bytes_per_device": sample.bytes_hbm,
        "collective_bytes_per_device": sample.collectives,
        **terms,
        "model_flops_global": mf,
        "useful_flops_ratio": mf / (sample.flops * n_dev)
        if sample.flops else 0.0,
        "temp_bytes_per_device": mem.temp_size_in_bytes,
        "arg_bytes_per_device": mem.argument_size_in_bytes,
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, mode: str,
             fsdp: bool | None = None, remat: bool = True,
             verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered, compiled, meta = lower_cell(arch, shape_name, mesh, mode=mode,
                                         fsdp=fsdp, remat=remat)
    rec = analyze(compiled, meta["cfg"], meta["shape"], mesh)
    rec.update(multi_pod=multi_pod, mode=mode,
               lower_compile_s=meta["lower_compile_s"],
               accum_steps=meta["accum_steps"])
    if verbose:
        print(f"== {arch} x {shape_name} "
              f"({'2x16x16' if multi_pod else '16x16'}, mode={mode}, "
              f"accum={meta['accum_steps']}) ==")
        print(compiled.memory_analysis())
        print(f"  temp {rec['temp_bytes_per_device']/1e9:.2f} GB/dev | "
              f"args {rec['arg_bytes_per_device']/1e9:.2f} GB/dev")
        print(f"  collectives/dev "
              f"{ {k: f'{v/1e9:.2f}GB' for k, v in rec['collective_bytes_per_device'].items()} }")
        print(f"  roofline(rolled): compute {rec['t_compute_s']*1e3:.2f} ms"
              f" | memory {rec['t_memory_s']*1e3:.2f} ms"
              f" | collective {rec['t_collective_s']*1e3:.2f} ms"
              f" -> {rec['dominant']}  [NOTE: rolled-loop counts; "
              f"see benchmarks/roofline.py for exact fitted terms]")
        print(f"  compile {rec['lower_compile_s']:.0f}s")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="fp", choices=["fp", "fake", "int"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            if arch == "resnet_paper":
                continue
            cfg = get_config(arch)
            for shp in supported_shapes(cfg):
                cells.append((arch, shp))
    else:
        cells.append((args.arch, args.shape))

    records, failures = [], []
    for arch, shp in cells:
        try:
            records.append(run_cell(arch, shp, multi_pod=args.multi_pod,
                                    mode=args.mode,
                                    fsdp=False if args.no_fsdp else None,
                                    remat=not args.no_remat))
        except Exception as e:  # noqa: BLE001 — report every cell
            failures.append({"arch": arch, "shape": shp, "error": repr(e)})
            print(f"FAILED {arch} x {shp}: {e!r}", file=sys.stderr)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"records": records, "failures": failures}, f,
                      indent=1, default=str)
    print(f"\n{len(records)} cells compiled, {len(failures)} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
