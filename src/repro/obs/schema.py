"""Golden report schema (DESIGN §14): the committed contract for every
metric the serving engine reports.

``GOLDEN_SCHEMA`` is the full-featured engine's registry (speculation ON,
prefix cache ON) projected down to the stable identity fields — kind,
python type, unit, optionality, aliasing.  Help strings are deliberately
NOT part of the contract (they may be reworded freely; the golden test
only asserts they are non-empty).  Regenerate after an intentional
schema change by pasting ``schema_of(engine.metrics)`` — the golden test
(``tests/test_obs.py``) and the CI schema diff both fail loudly on any
undeclared drift, which is the whole point: a renamed or retyped report
field must be a reviewed schema change, not a silent bench breakage.

Conditional sections: ``speculative.*`` metrics exist only when
``spec_k > 0``, ``prefix_cache.*`` only when the cache is on (the report
surfaces the disabled sections as literal ``None``); ``profile`` exists
only when profiling is enabled and is dynamic; ``slo.*`` only when an
SLO monitor is configured (DESIGN §15).  ``diff_schema`` takes the
engine's feature flags into account so a cache-off engine isn't
reported as "missing" the cache section.
"""
from __future__ import annotations

__all__ = ["GOLDEN_SCHEMA", "DYNAMIC_KEYS", "SECTION_FLAGS",
           "schema_of", "diff_schema"]

# report keys whose VALUE shape is dynamic (per-jitted-shape /
# per-profiled-shape / per-objective subdicts) — typed as dict,
# contents not golden
DYNAMIC_KEYS = ("step_shapes", "profile", "slo.status")

# prefix -> engine feature that must be on for the section to register
SECTION_FLAGS = {"speculative.": "spec", "prefix_cache.": "cache",
                 "profile": "profile", "slo.": "slo",
                 "pool.": "kv", "state_pool.": "slab"}

GOLDEN_SCHEMA = {
    "n_requests": {"kind": "counter", "type": "int"},
    "completed": {"kind": "counter", "type": "int"},
    "preemptions": {"kind": "counter", "type": "int"},
    "gen_tokens": {"kind": "counter", "type": "int"},
    "prompt_tokens": {"kind": "counter", "type": "int"},
    "wall_s": {"kind": "gauge", "type": "float", "unit": "s"},
    "tokens_per_s": {"kind": "gauge", "type": "float", "optional": True},
    "decode_steps": {"kind": "counter", "type": "int"},
    "spec_steps": {"kind": "counter", "type": "int"},
    "prefill_chunks": {"kind": "counter", "type": "int"},
    "ragged": {"kind": "gauge", "type": "bool"},
    "ragged_steps": {"kind": "counter", "type": "int"},
    "substrate": {"kind": "gauge", "type": "str"},
    "recurrent_steps": {"kind": "counter", "type": "int"},
    "dispatched_tokens": {"kind": "counter", "type": "int"},
    "padded_tokens": {"kind": "counter", "type": "int"},
    "padding_frac": {"kind": "gauge", "type": "float", "optional": True},
    "speculative.spec_k": {"kind": "gauge", "type": "int"},
    "speculative.drafter": {"kind": "gauge", "type": "str"},
    "speculative.verify_steps": {"kind": "counter", "type": "int"},
    "speculative.fallback_decode_steps": {"kind": "counter", "type": "int"},
    "speculative.drafted_tokens": {"kind": "counter", "type": "int"},
    "speculative.accepted_tokens": {"kind": "counter", "type": "int"},
    "speculative.acceptance_rate":
        {"kind": "gauge", "type": "float", "optional": True},
    "speculative.emitted_tokens": {"kind": "counter", "type": "int"},
    "speculative.tokens_per_step":
        {"kind": "gauge", "type": "float", "optional": True},
    "speculative.retracts":
        {"kind": "counter", "type": "int", "alias_of": "pool.retracts"},
    "speculative.retracted_blocks":
        {"kind": "counter", "type": "int",
         "alias_of": "pool.retracted_blocks"},
    "speculative.requant_ops_wasted": {"kind": "counter", "type": "int"},
    "speculative.drafter_calls": {"kind": "counter", "type": "int"},
    "speculative.drafter_proposed": {"kind": "counter", "type": "int"},
    "speculative.drafter_empty": {"kind": "counter", "type": "int"},
    "ttft_s.p50":
        {"kind": "gauge", "type": "float", "unit": "s", "optional": True},
    "ttft_s.p99":
        {"kind": "gauge", "type": "float", "unit": "s", "optional": True},
    "tpot_s.p50":
        {"kind": "gauge", "type": "float", "unit": "s", "optional": True},
    "tpot_s.p99":
        {"kind": "gauge", "type": "float", "unit": "s", "optional": True},
    "e2e_s.p50":
        {"kind": "gauge", "type": "float", "unit": "s", "optional": True},
    "e2e_s.p99":
        {"kind": "gauge", "type": "float", "unit": "s", "optional": True},
    "step_shapes": {"kind": "gauge", "type": "dict"},
    "pool.num_blocks": {"kind": "gauge", "type": "int"},
    "pool.block_size": {"kind": "gauge", "type": "int"},
    "pool.peak_live_blocks": {"kind": "gauge", "type": "int"},
    "pool.peak_utilization": {"kind": "gauge", "type": "float"},
    "pool.utilization": {"kind": "gauge", "type": "float"},
    "pool.residency": {"kind": "gauge", "type": "float"},
    "pool.allocs": {"kind": "counter", "type": "int"},
    "pool.frees": {"kind": "counter", "type": "int"},
    "pool.evictions": {"kind": "counter", "type": "int"},
    "pool.seq_evictions": {"kind": "counter", "type": "int"},
    "pool.cache_evictions": {"kind": "counter", "type": "int"},
    "pool.retracts": {"kind": "counter", "type": "int"},
    "pool.retracted_blocks": {"kind": "counter", "type": "int"},
    "pool.alloc_failures": {"kind": "counter", "type": "int"},
    "state_pool.num_slabs": {"kind": "gauge", "type": "int"},
    "state_pool.scale_exp": {"kind": "gauge", "type": "int"},
    "state_pool.state_quant_ops_per_step": {"kind": "gauge", "type": "int"},
    "state_pool.requant_ops_state": {"kind": "counter", "type": "int"},
    "state_pool.state_ops_per_token":
        {"kind": "gauge", "type": "float", "optional": True},
    "state_pool.peak_live_slabs": {"kind": "gauge", "type": "int"},
    "state_pool.utilization": {"kind": "gauge", "type": "float"},
    "state_pool.allocs": {"kind": "counter", "type": "int"},
    "state_pool.frees": {"kind": "counter", "type": "int"},
    "state_pool.seq_evictions": {"kind": "counter", "type": "int"},
    "state_pool.alloc_failures": {"kind": "counter", "type": "int"},
    "prefix_cache.hits": {"kind": "counter", "type": "int"},
    "prefix_cache.misses": {"kind": "counter", "type": "int"},
    "prefix_cache.hit_rate": {"kind": "gauge", "type": "float"},
    "prefix_cache.hit_tokens": {"kind": "counter", "type": "int"},
    "prefix_cache.lookup_tokens": {"kind": "counter", "type": "int"},
    "prefix_cache.token_hit_rate": {"kind": "gauge", "type": "float"},
    "prefix_cache.cached_prefill_tokens": {"kind": "counter", "type": "int"},
    "prefix_cache.cow_copies": {"kind": "counter", "type": "int"},
    "prefix_cache.published_blocks": {"kind": "counter", "type": "int"},
    "prefix_cache.cache_evictions": {"kind": "counter", "type": "int"},
    "prefix_cache.resident_cached_blocks": {"kind": "gauge", "type": "int"},
    "prefix_cache.quant_ops_avoided": {"kind": "counter", "type": "int"},
    "hwcost.requant_ops_performed": {"kind": "counter", "type": "int"},
    "hwcost.requant_ops_avoided": {"kind": "counter", "type": "int"},
    "hwcost.requant_ops_avoided_prefix_cache":
        {"kind": "counter", "type": "int"},
    "hwcost.requant_ops_wasted_speculation":
        {"kind": "counter", "type": "int"},
    "hwcost.requant_ops_per_token":
        {"kind": "gauge", "type": "float", "optional": True},
    "hwcost.energy_uj_bit_shift":
        {"kind": "gauge", "type": "float", "unit": "uJ"},
    "hwcost.energy_uj_if_requant_per_step":
        {"kind": "gauge", "type": "float", "unit": "uJ"},
    "hwcost.energy_uj_if_no_prefix_cache":
        {"kind": "gauge", "type": "float", "unit": "uJ"},
    "hwcost.energy_uj_if_scaling_factor":
        {"kind": "gauge", "type": "float", "unit": "uJ"},
    "hwcost.w8a8": {"kind": "gauge", "type": "bool"},
    "hwcost.forward_quant_ops_per_token": {"kind": "gauge", "type": "int"},
    "hwcost.requant_ops_forward": {"kind": "counter", "type": "int"},
    "hwcost.requant_ops_forward_avoided_prefix_cache":
        {"kind": "counter", "type": "int"},
    "hwcost.requant_ops_forward_wasted_speculation":
        {"kind": "counter", "type": "int"},
    "hwcost.energy_uj_forward_bit_shift":
        {"kind": "gauge", "type": "float", "unit": "uJ"},
    "hwcost.energy_uj_forward_if_scaling_factor":
        {"kind": "gauge", "type": "float", "unit": "uJ"},
    "energy.unit": {"kind": "gauge", "type": "str"},
    "energy.prefill.quant_ops": {"kind": "counter", "type": "int"},
    "energy.prefill.tokens": {"kind": "counter", "type": "int"},
    "energy.prefill.energy_uj":
        {"kind": "gauge", "type": "float", "unit": "uJ"},
    "energy.prefill.uj_per_token":
        {"kind": "gauge", "type": "float", "unit": "uJ", "optional": True},
    "energy.decode.quant_ops": {"kind": "counter", "type": "int"},
    "energy.decode.tokens": {"kind": "counter", "type": "int"},
    "energy.decode.energy_uj":
        {"kind": "gauge", "type": "float", "unit": "uJ"},
    "energy.decode.uj_per_token":
        {"kind": "gauge", "type": "float", "unit": "uJ", "optional": True},
    "energy.spec_wasted.quant_ops": {"kind": "counter", "type": "int"},
    "energy.spec_wasted.tokens": {"kind": "counter", "type": "int"},
    "energy.spec_wasted.energy_uj":
        {"kind": "gauge", "type": "float", "unit": "uJ"},
    "energy.spec_wasted.uj_per_token":
        {"kind": "gauge", "type": "float", "unit": "uJ", "optional": True},
    "energy.total_quant_ops": {"kind": "counter", "type": "int"},
    "energy.total_energy_uj":
        {"kind": "gauge", "type": "float", "unit": "uJ"},
    "energy.proxy_uj_per_token":
        {"kind": "gauge", "type": "float", "unit": "uJ", "optional": True},
    "timeline.source": {"kind": "gauge", "type": "str"},
    "timeline.requests": {"kind": "gauge", "type": "int"},
    "timeline.completed": {"kind": "gauge", "type": "int"},
    "timeline.ttft_s.p50":
        {"kind": "gauge", "type": "float", "unit": "s", "optional": True},
    "timeline.ttft_s.p99":
        {"kind": "gauge", "type": "float", "unit": "s", "optional": True},
    "timeline.tpot_s.p50":
        {"kind": "gauge", "type": "float", "unit": "s", "optional": True},
    "timeline.tpot_s.p99":
        {"kind": "gauge", "type": "float", "unit": "s", "optional": True},
    "timeline.e2e_s.p50":
        {"kind": "gauge", "type": "float", "unit": "s", "optional": True},
    "timeline.e2e_s.p99":
        {"kind": "gauge", "type": "float", "unit": "s", "optional": True},
    "obs.trace_enabled": {"kind": "gauge", "type": "bool"},
    "obs.trace_events": {"kind": "gauge", "type": "int"},
    "obs.trace_emitted": {"kind": "counter", "type": "int"},
    "obs.trace_dropped": {"kind": "counter", "type": "int"},
    "obs.trace_capacity": {"kind": "gauge", "type": "int"},
    "obs.trace_dropped_total":
        {"kind": "counter", "type": "int",
         "alias_of": "obs.trace_dropped"},
    "obs.trace_ring_used": {"kind": "gauge", "type": "float"},
    "slo.objectives": {"kind": "gauge", "type": "int"},
    "slo.evaluations": {"kind": "counter", "type": "int"},
    "slo.alerts_fired": {"kind": "counter", "type": "int"},
    "slo.alerts_active": {"kind": "gauge", "type": "int"},
    "slo.worst_burn_rate":
        {"kind": "gauge", "type": "float", "optional": True},
    "slo.status": {"kind": "gauge", "type": "dict"},
    "profile":
        {"kind": "gauge", "type": "dict", "optional": True},
}


def schema_of(registry) -> dict[str, dict]:
    """Project a registry's :meth:`describe` down to the golden identity
    fields (paste the output here to regenerate after a reviewed
    change)."""
    out = {}
    for name, d in registry.describe().items():
        e = {"kind": d["kind"], "type": d["type"]}
        if d.get("unit"):
            e["unit"] = d["unit"]
        if d.get("optional"):
            e["optional"] = True
        if d.get("alias_of"):
            e["alias_of"] = d["alias_of"]
        out[name] = e
    return out


def _section_on(name: str, features: dict) -> bool:
    for prefix, flag in SECTION_FLAGS.items():
        if name == prefix or name.startswith(prefix):
            return bool(features.get(flag, False))
    return True


def diff_schema(got: dict, golden: dict = None, *,
                spec: bool = True, cache: bool = True,
                profile: bool = False, slo: bool = False,
                kv: bool = True, slab: bool = False) -> list[str]:
    """Human-readable differences between an engine's projected schema
    and the golden one, respecting which conditional sections the
    engine's feature flags enable.  Empty list == schema-clean.
    ``kv``/``slab`` mirror the substrate (DESIGN §16): ``pool.*`` exists
    on the growing substrates (attention/hybrid), ``state_pool.*`` on the
    fixed-state ones (recurrent/hybrid)."""
    golden = GOLDEN_SCHEMA if golden is None else golden
    feats = {"spec": spec, "cache": cache, "profile": profile,
             "slo": slo, "kv": kv, "slab": slab}
    errs = []
    for name, want in golden.items():
        if not _section_on(name, feats):
            if name in got:
                errs.append(f"{name}: registered but its section is off")
            continue
        have = got.get(name)
        if have is None:
            errs.append(f"{name}: missing from registry")
        elif have != want:
            errs.append(f"{name}: {have} != golden {want}")
    for name in got:
        if name not in golden:
            errs.append(f"{name}: registered but not in GOLDEN_SCHEMA — "
                        f"document it (kind/type/unit) or remove it")
    return errs
