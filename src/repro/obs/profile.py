"""Profiling + energy hooks for the serving engine (DESIGN §14).

Three optional, independently-gated capabilities:

* **`jax.profiler` capture windows** — :meth:`Profiler.capture` wraps a
  run of the engine in ``jax.profiler.trace(profile_dir)`` so the XLA
  trace (TensorBoard / Perfetto loadable) lines up with the host-side
  obs trace; :meth:`Profiler.step_annotation` puts a
  ``StepTraceAnnotation`` around each ``ragged_step`` dispatch so steps
  are delimited inside the device trace.
* **Per-compiled-shape cost analysis** — :meth:`Profiler.cost_for`
  runs AOT ``lower(...).cost_analysis()`` once per compiled stream
  shape (FLOPs + bytes accessed per dispatch), memoized by the same
  shape keys as the engine's compile cache.  This is the attribution
  table: padded FLOPs per shape × dispatch counts = where compute went.
* **Energy accounting** — :class:`EnergyAccount` turns the engine's
  Table-5 requant counters into a live joules-proxy per token, split by
  phase (prefill / decode / spec-wasted).  The proxy is DEFINED as the
  Table-5 bit-shifting energy of the requant ops attributed to each
  phase (KV-path ops + forward W8A8 boundary ops; the paper's Table 5
  measures the requant unit, so that is what the proxy covers — see
  DESIGN §14 for the formula).  It reconciles *exactly* with the
  engine's hwcost counters: sum over phases of ``quant_ops`` equals
  ``requant_ops_performed + requant_ops_forward`` (asserted in
  tests/test_obs.py and gated in ``serving_bench --check``).

`jax` is imported lazily inside methods: constructing a disabled
Profiler (the default) never touches jax, keeping host-only imports of
`repro.obs` jax-free.
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional

from ..core import hwcost

__all__ = ["Profiler", "EnergyAccount", "ENERGY_PHASES"]

ENERGY_PHASES = ("prefill", "decode", "spec_wasted")


class EnergyAccount:
    """Per-phase requant-op ledger → Table-5 energy proxy.

    The engine calls :meth:`charge` at each commit point with the phase,
    the number of requant ops the step executed, and the number of
    useful tokens it produced (0 for ``spec_wasted`` — wasted draft work
    has energy but no tokens, which is exactly why it gets its own
    bucket)."""

    def __init__(self, kind: str = "bit_shifting"):
        if kind not in hwcost.TABLE5:
            raise ValueError(f"unknown Table-5 unit kind {kind!r}")
        self.kind = kind
        self.quant_ops = {p: 0 for p in ENERGY_PHASES}
        self.tokens = {p: 0 for p in ENERGY_PHASES}

    def charge(self, phase: str, quant_ops: int, tokens: int) -> None:
        self.quant_ops[phase] += quant_ops
        self.tokens[phase] += tokens

    def reset(self) -> None:
        for p in ENERGY_PHASES:
            self.quant_ops[p] = 0
            self.tokens[p] = 0

    @property
    def total_quant_ops(self) -> int:
        return sum(self.quant_ops.values())

    def energy_uj(self, phase: str) -> float:
        return hwcost.energy_uj(self.kind, self.quant_ops[phase])

    def uj_per_token(self, phase: str) -> Optional[float]:
        """Energy proxy per USEFUL token of the phase.  ``spec_wasted``
        divides by the *emitted* decode tokens instead — its meaning is
        'wasted joules amortized over what we actually kept'."""
        ops = self.quant_ops[phase]
        toks = self.tokens["decode"] if phase == "spec_wasted" \
            else self.tokens[phase]
        if toks == 0:
            return None if ops == 0 else float("inf")
        return hwcost.energy_uj(self.kind, ops) / toks

    def proxy_uj_per_token(self) -> Optional[float]:
        """The headline live gauge: total requant energy over total
        useful (prefill-fed + decode-emitted) tokens."""
        toks = self.tokens["prefill"] + self.tokens["decode"]
        if toks == 0:
            return None
        return hwcost.energy_uj(self.kind, self.total_quant_ops) / toks

    def report(self) -> dict:
        out: dict = {"unit": self.kind}
        for p in ENERGY_PHASES:
            uj = self.energy_uj(p)
            upt = self.uj_per_token(p)
            out[p] = {
                "quant_ops": self.quant_ops[p],
                "tokens": self.tokens[p],
                "energy_uj": round(uj, 6),
                "uj_per_token": None if upt is None
                else round(upt, 9),
            }
        total = self.proxy_uj_per_token()
        out["total_quant_ops"] = self.total_quant_ops
        out["total_energy_uj"] = round(
            hwcost.energy_uj(self.kind, self.total_quant_ops), 6)
        out["proxy_uj_per_token"] = None if total is None \
            else round(total, 9)
        return out


class Profiler:
    """Optional jax-profiler + AOT-cost-analysis wrapper.

    ``profile_dir=None`` and ``cost=False`` (the defaults) make every
    method a no-op; the engine constructs one unconditionally so call
    sites stay unconditional too."""

    def __init__(self, *, profile_dir: Optional[str] = None,
                 cost: bool = False):
        self.profile_dir = profile_dir
        self.cost = cost
        self.shape_costs: dict[Any, dict] = {}

    @property
    def enabled(self) -> bool:
        return self.profile_dir is not None or self.cost

    # -- capture windows --------------------------------------------------

    @contextlib.contextmanager
    def capture(self):
        """Wrap a whole engine run in a profiler trace window."""
        if self.profile_dir is None:
            yield
            return
        import jax
        with jax.profiler.trace(self.profile_dir):
            yield

    @contextlib.contextmanager
    def step_annotation(self, name: str, step: int):
        """Delimit one jitted dispatch inside the device trace."""
        if self.profile_dir is None:
            yield
            return
        import jax
        with jax.profiler.StepTraceAnnotation(name, step_num=step):
            yield

    # -- per-shape cost analysis ------------------------------------------

    def cost_for(self, shape_key, jitfn, *args) -> Optional[dict]:
        """FLOPs/bytes of one compiled stream shape, memoized.

        Uses AOT ``lower(...).cost_analysis()`` (no compile, no
        execute — safe to call before the real dispatch donates its
        buffers); falls back to ``.compile().cost_analysis()`` on older
        jax.  Returns {flops, bytes_accessed} (floats, -1.0 when the
        backend reports nothing) or None when cost analysis is off."""
        if not self.cost:
            return None
        hit = self.shape_costs.get(shape_key)
        if hit is not None:
            return hit
        entry = {"flops": -1.0, "bytes_accessed": -1.0}
        try:
            lowered = jitfn.lower(*args)
            try:
                ca = lowered.cost_analysis()
            except Exception:
                ca = lowered.compile().cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0] if ca else {}
            if isinstance(ca, dict):
                if "flops" in ca:
                    entry["flops"] = float(ca["flops"])
                if "bytes accessed" in ca:
                    entry["bytes_accessed"] = float(ca["bytes accessed"])
        except Exception:
            pass        # cost analysis is best-effort attribution only
        self.shape_costs[shape_key] = entry
        return entry

    def report(self) -> Optional[dict]:
        """Per-shape attribution table (None when fully disabled)."""
        if not self.enabled:
            return None
        return {
            "profile_dir": self.profile_dir,
            "cost_analysis": {
                str(k): v for k, v in sorted(self.shape_costs.items(),
                                             key=lambda kv: str(kv[0]))
            },
        }

    def reset(self) -> None:
        self.shape_costs.clear()
