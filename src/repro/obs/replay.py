"""Workload flight recorder: deterministic capture + replay
(DESIGN §15).

**Capture.**  An engine built with ``record=True`` runs under a
deterministic VIRTUAL clock (``engine.virtual_dt`` seconds per step,
idle gaps jump straight to the next arrival) and tees every
scheduler-decision event — admission order, prefill chunk boundaries,
preemptions, spec degradation, pool alloc/CoW/retract, prefix-cache
hit/publish (``trace.DECISION_CATS``) — into an unbounded decision
sink next to the bounded trace ring.  :func:`capture_workload` then
freezes the run into a portable :class:`WorkloadRecord`: arrival
process, prompt token ids, sampling params, seeds, spec-k, an engine
config fingerprint, the emitted tokens, the decision stream and the
per-request timelines.  Because arrival→admission composition depends
only on the virtual clock, the capture run is itself exactly
reproducible — which is what makes the replay contract below testable
at all (a wall-clock capture's admissions would race the scheduler).

**Replay.**  :func:`replay_workload` re-injects the recorded arrival
process into a fresh ``record=True`` engine (same virtual clock, same
seeds via the engine's ``fold_in(step_counter)`` rng) and checks two
things: the emitted tokens are IDENTICAL per request, and the
scheduler-decision diff (:func:`diff_decisions`, a unified diff over
canonicalized ``(name, args)`` lines — timestamps excluded by
construction) is EMPTY.  Replaying against a *different* engine config
(ragged vs legacy, W8A8 on/off, spec on/off) turns the same record
into an A/B harness: the token parity check still holds at greedy fp32
while the decision diff localizes exactly where the two schedulers
diverged.

Pure Python (stdlib only): the record is plain JSON, and this module
imports nothing from jax — only ``repro.serving.scheduler.Request``
(host-side) to rebuild the workload.
"""
from __future__ import annotations

import dataclasses
import difflib
import hashlib
import json
import numbers
from typing import Any, Optional

__all__ = ["WorkloadRecord", "ReplayResult", "RECORD_VERSION",
           "engine_settings", "engine_fingerprint", "capture_workload",
           "build_requests", "decision_lines", "diff_decisions",
           "replay_workload"]

RECORD_VERSION = 1

# immutable Request fields that define the workload
_REQUEST_FIELDS = ("rid", "prompt", "max_new_tokens", "temperature",
                   "top_k", "stop_token", "arrival")
_TIMELINE_MARKS = ("arrival", "admit", "first_chunk", "first_token",
                   "done", "n_generated", "preemptions")


def _canon(v: Any) -> Any:
    """JSON-stable canonical form: numpy scalars become python
    ints/floats (a loaded record must compare equal to a live one),
    floats round to 9 places, containers recurse."""
    if v is None or isinstance(v, (bool, str)):
        return v
    if isinstance(v, numbers.Integral):
        return int(v)
    if isinstance(v, numbers.Real):
        return round(float(v), 9)
    if isinstance(v, dict):
        return {str(k): _canon(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_canon(x) for x in v]
    return str(v)


# -- engine identity -------------------------------------------------------

def engine_settings(engine) -> dict:
    """The portable engine/scheduler configuration a replay needs to
    reconstruct an equivalent engine (and the fingerprint input)."""
    pool = engine.pool
    s = {
        "model": dataclasses.asdict(engine.cfg),
        "n_slots": engine.n_slots,
        "block_size": pool.block_size if pool is not None else None,
        "num_blocks": pool.num_blocks if pool is not None else None,
        "max_model_len": engine.max_model_len,
        "chunk": engine.sched.chunk,
        "prefill_token_budget": engine.sched.prefill_token_budget,
        "default_top_k": engine.default_top_k,
        "seed": engine.seed,
        "prefix_cache": pool is not None and pool.cache is not None,
        "spec_k": engine.spec_k,
        "drafter": type(engine.drafter).__name__,
        "ragged": engine.ragged,
        "virtual_dt": engine.virtual_dt,
    }
    # substrate keys ride along ONLY off the attention substrate, so
    # every pre-§16 transformer fingerprint stays byte-identical
    sub = getattr(engine, "substrate", None)
    if sub is not None and sub.kind != "attention":
        s["substrate"] = sub.kind
        s["num_slabs"] = engine.state_pool.num_slabs
        s["state_scale_exp"] = engine.state_pool.default_scale_exp
    return _canon(s)


def engine_fingerprint(engine) -> str:
    """Short stable hash of :func:`engine_settings` — two engines with
    the same fingerprint must schedule a recorded workload
    identically."""
    blob = json.dumps(engine_settings(engine), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


# -- the record ------------------------------------------------------------

@dataclasses.dataclass
class WorkloadRecord:
    """One captured serving run, JSON-portable."""
    version: int
    fingerprint: str
    engine: dict                 # engine_settings() of the capture engine
    requests: list               # [{rid, prompt, ..., arrival}, ...]
    outputs: dict                # rid -> [token, ...]
    decisions: list              # [[name, args], ...] in emission order
    timelines: dict              # rid -> lifecycle marks (virtual clock)
    meta: dict                   # run-level scalars (steps, tokens, ...)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["outputs"] = {str(k): v for k, v in d["outputs"].items()}
        d["timelines"] = {str(k): v for k, v in d["timelines"].items()}
        return d

    @classmethod
    def from_json(cls, obj: dict) -> "WorkloadRecord":
        if obj.get("version") != RECORD_VERSION:
            raise ValueError(
                f"workload record version {obj.get('version')!r} != "
                f"supported {RECORD_VERSION}")
        return cls(
            version=obj["version"], fingerprint=obj["fingerprint"],
            engine=obj["engine"], requests=obj["requests"],
            outputs={int(k): list(v)
                     for k, v in obj["outputs"].items()},
            decisions=[[n, a] for n, a in obj["decisions"]],
            timelines={int(k): v for k, v in obj["timelines"].items()},
            meta=obj.get("meta", {}))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)

    @classmethod
    def load(cls, path: str) -> "WorkloadRecord":
        with open(path) as f:
            return cls.from_json(json.load(f))


def _serial_requests(requests) -> list:
    out = []
    for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
        d = {f: getattr(r, f) for f in _REQUEST_FIELDS}
        d["prompt"] = [int(t) for t in r.prompt]
        out.append(_canon(d))
    return out


def _serial_outputs(outputs) -> dict:
    return {int(rid): [int(t) for t in toks]
            for rid, toks in outputs.items()}


def capture_workload(engine, requests) -> WorkloadRecord:
    """Freeze a finished ``record=True`` run into a
    :class:`WorkloadRecord`.  Call after ``engine.run(requests)`` and
    before any ``reset_metrics`` (which clears the decision sink)."""
    if not getattr(engine, "record", False):
        raise ValueError("capture needs ServingEngine(record=True) — a "
                         "wall-clock run is not deterministically "
                         "replayable")
    sink = engine.tracer.decision_sink
    if sink is None:
        raise ValueError("engine has no decision sink — was the tracer "
                         "replaced after construction?")
    timelines = {
        int(rid): _canon({m: getattr(tl, m) for m in _TIMELINE_MARKS})
        for rid, tl in engine.tracer.timelines.items()}
    return WorkloadRecord(
        version=RECORD_VERSION,
        fingerprint=engine_fingerprint(engine),
        engine=engine_settings(engine),
        requests=_serial_requests(requests),
        outputs=_serial_outputs(engine.outputs()),
        decisions=[[name, _canon(args) if args else {}]
                   for name, args in sink],
        timelines=timelines,
        meta=_canon({
            "n_requests": len(requests),
            "n_decisions": len(sink),
            "decode_steps": engine.decode_steps,
            "ragged_steps": engine.ragged_steps,
            "recurrent_steps": engine.recurrent_steps,
            "prefill_chunks": engine.prefill_chunks,
            "wall_s_virtual": engine._wall_s,
        }))


def build_requests(record: WorkloadRecord) -> list:
    """Materialize the recorded arrival process as fresh Request
    objects (imported lazily: keeps ``repro.obs`` importable without
    the serving package on the path)."""
    from repro.serving.scheduler import Request
    return [Request(rid=d["rid"], prompt=list(d["prompt"]),
                    max_new_tokens=d["max_new_tokens"],
                    temperature=d["temperature"], top_k=d["top_k"],
                    stop_token=d["stop_token"], arrival=d["arrival"])
            for d in record.requests]


# -- decision diff ---------------------------------------------------------

def decision_lines(decisions) -> list[str]:
    """Canonical one-line form of each decision: ``name k=v k=v`` with
    sorted keys and JSON-canonical values.  No timestamps — replay
    equivalence is about order and content, not wall clock."""
    out = []
    for name, args in decisions:
        if args:
            kv = " ".join(
                f"{k}={json.dumps(_canon(v), sort_keys=True)}"
                for k, v in sorted(args.items()))
            out.append(f"{name} {kv}")
        else:
            out.append(str(name))
    return out


def diff_decisions(a, b, *, label_a: str = "recorded",
                   label_b: str = "replayed") -> list[str]:
    """Unified diff between two decision streams; ``[]`` means the two
    runs made IDENTICAL scheduling decisions in the same order."""
    return list(difflib.unified_diff(
        decision_lines(a), decision_lines(b),
        fromfile=label_a, tofile=label_b, lineterm=""))


# -- replay ----------------------------------------------------------------

@dataclasses.dataclass
class ReplayResult:
    """Outcome of one :func:`replay_workload` call."""
    report: dict
    outputs: dict                  # rid -> [token, ...] from the replay
    token_identical: bool
    mismatched_rids: list
    decision_diff: list            # unified-diff lines; [] == identical
    fingerprint_match: bool
    record_fingerprint: str
    engine_fingerprint: str

    @property
    def ok(self) -> bool:
        """Token-identical AND decision-identical."""
        return self.token_identical and not self.decision_diff


def replay_workload(record: WorkloadRecord, engine, *,
                    strict_fingerprint: bool = False) -> ReplayResult:
    """Re-inject ``record``'s arrival process into ``engine`` and
    compare outcomes.

    The engine must be ``record=True`` (virtual clock + decision sink)
    and drained; its metrics/tracer/prefix-cache are reset so the
    replay starts from the same cold state as the capture.  With
    ``strict_fingerprint`` a config mismatch raises instead of being
    reported — use the default (False) for deliberate A/B replays
    across engine configs."""
    if not getattr(engine, "record", False):
        raise ValueError("replay needs ServingEngine(record=True)")
    fp = engine_fingerprint(engine)
    match = fp == record.fingerprint
    if strict_fingerprint and not match:
        raise ValueError(
            f"engine fingerprint {fp} != record {record.fingerprint} "
            f"(pass strict_fingerprint=False for A/B replays)")
    engine.reset_metrics(flush_cache=True)
    report = engine.run(build_requests(record))
    outputs = _serial_outputs(engine.outputs())
    mism = sorted(
        (set(record.outputs) ^ set(outputs))
        | {rid for rid in set(record.outputs) & set(outputs)
           if record.outputs[rid] != outputs[rid]})
    diff = diff_decisions(record.decisions,
                          engine.tracer.decision_sink)
    return ReplayResult(
        report=report, outputs=outputs,
        token_identical=not mism, mismatched_rids=mism,
        decision_diff=diff, fingerprint_match=match,
        record_fingerprint=record.fingerprint,
        engine_fingerprint=fp)
