"""Serving observability layer (DESIGN §14/§15): metrics registry,
structured event tracing, profiling + energy hooks, workload flight
recorder (capture/replay) and SLO burn-rate monitoring.

``metrics``/``trace``/``schema``/``slo``/``replay`` are stdlib-only
(importable from the jax-free host modules); ``profile`` imports jax
lazily inside methods.
"""
from repro.obs.metrics import (Counter, FuncMetric, Gauge, Histogram,
                               MetricsRegistry, prom_name)
from repro.obs.profile import ENERGY_PHASES, EnergyAccount, Profiler
from repro.obs.replay import (ReplayResult, WorkloadRecord,
                              capture_workload, diff_decisions,
                              engine_fingerprint, replay_workload)
from repro.obs.schema import GOLDEN_SCHEMA, diff_schema, schema_of
from repro.obs.slo import SLObjective, SLOMonitor, default_slos
from repro.obs.trace import Timeline, Tracer, validate_chrome_trace

__all__ = [
    "Counter", "Gauge", "Histogram", "FuncMetric", "MetricsRegistry",
    "prom_name",
    "Tracer", "Timeline", "validate_chrome_trace",
    "Profiler", "EnergyAccount", "ENERGY_PHASES",
    "GOLDEN_SCHEMA", "schema_of", "diff_schema",
    "WorkloadRecord", "ReplayResult", "capture_workload",
    "replay_workload", "diff_decisions", "engine_fingerprint",
    "SLObjective", "SLOMonitor", "default_slos",
]
