"""Unified metrics registry for the serving stack (DESIGN §14).

One process-local registry of TYPED, DOCUMENTED metrics replaces the
ad-hoc counter attributes and hand-rolled report dicts that grew across
PRs 3–7: every scalar the engine reports is declared exactly once, with
a kind (counter / gauge / histogram), a python type and a help string,
and ``engine.report()`` becomes a *view* of the registry
(:meth:`MetricsRegistry.nested`) instead of a dict assembled by hand —
so renames break the golden-schema test (``tests/test_obs.py``), not a
downstream bench gate three PRs later.

Two metric flavors:

* **Owned** (:class:`Counter`, :class:`Gauge`, :class:`Histogram`) hold
  their own value; hot-path increments are one dict-free attribute add.
* **Bound** (:class:`FuncMetric`) read an EXISTING source at snapshot
  time through a zero-argument callable.  This is how ``PoolStats``,
  ``CacheStats``, the spec-decode acceptance counters and the hwcost
  requant accounting migrate onto the registry without perturbing the
  jax-free host structs the property tests drive directly: the structs
  stay the single source of truth, the registry is the single source of
  *naming, typing and exposition*.  A bound metric may declare
  ``alias_of`` — e.g. ``speculative.retracts`` aliases
  ``pool.retracts`` — so duplicated report fields are documented as
  views of one canonical counter and can never silently diverge.

Exposition: :meth:`MetricsRegistry.snapshot` (flat JSON-able dict),
:meth:`MetricsRegistry.nested` (report-shaped, split on ``.``) and
:meth:`MetricsRegistry.to_prometheus` (text format 0.0.4: ``# HELP`` /
``# TYPE`` pairs, dots mapped to underscores, labeled series as
``name{label="value"}``).

Pure Python (stdlib only) — importable from the jax-free host modules
(`kv_pool`, `scheduler`, `prefix_cache`) and cheap enough to leave on.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional

__all__ = ["Counter", "Gauge", "Histogram", "FuncMetric",
           "MetricsRegistry", "prom_name"]

_KINDS = ("counter", "gauge", "histogram")


def prom_name(name: str) -> str:
    """Prometheus-legal metric name: dots (the registry's nesting
    separator) become underscores; anything else non-alphanumeric too."""
    return "".join(c if c.isalnum() or c == "_" else "_" for c in name)


def _escape_help(s: str) -> str:
    """HELP-line escaping per exposition format 0.0.4: backslash and
    newline only (double quotes are legal in help text)."""
    return s.replace("\\", "\\\\").replace("\n", "\\n")


def _escape_label_value(s: str) -> str:
    """Label-value escaping per exposition format 0.0.4: backslash,
    double quote and newline."""
    return (s.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


class _Metric:
    """Common shape: identity + documentation.  ``typ`` is the python
    type of the snapshot value (int/float/bool/str); ``optional`` marks
    metrics whose value may legitimately be None (e.g. a percentile of
    an empty sample set)."""

    kind = "gauge"

    def __init__(self, name: str, help: str, *, typ: type = float,
                 unit: str = "", optional: bool = False,
                 alias_of: Optional[str] = None):
        if not name:
            raise ValueError("metric needs a name")
        if not help:
            raise ValueError(f"metric {name!r} needs a help string — "
                             f"undocumented metrics are what this "
                             f"registry exists to prevent")
        self.name = name
        self.help = help
        self.typ = typ
        self.unit = unit
        self.optional = optional
        self.alias_of = alias_of

    def value(self) -> Any:                      # pragma: no cover
        raise NotImplementedError

    def reset(self) -> None:
        pass

    def describe(self) -> dict:
        d = {"kind": self.kind, "type": self.typ.__name__,
             "help": self.help}
        if self.unit:
            d["unit"] = self.unit
        if self.optional:
            d["optional"] = True
        if self.alias_of:
            d["alias_of"] = self.alias_of
        return d


class Counter(_Metric):
    """Monotonic counter, optionally labeled.

    Unlabeled: ``c.inc(3)``; labeled (``label_names=("phase",)``):
    ``c.inc(3, phase="prefill")``.  ``value()`` returns the int total
    for unlabeled counters and a {label-string: int} dict otherwise
    (label series also expose individually in the prometheus text)."""

    kind = "counter"

    def __init__(self, name: str, help: str, *, label_names=(), typ=int,
                 **kw):
        super().__init__(name, help, typ=typ, **kw)
        self.label_names = tuple(label_names)
        self._total = 0
        self._series: dict[tuple, int] = {}

    def inc(self, n: int = 1, **labels) -> None:
        self._total += n
        if self.label_names:
            key = tuple(labels[k] for k in self.label_names)
            self._series[key] = self._series.get(key, 0) + n

    def get(self, **labels) -> int:
        if not labels:
            return self._total
        return self._series.get(
            tuple(labels[k] for k in self.label_names), 0)

    def value(self):
        if not self.label_names:
            return self._total
        return {",".join(f"{k}={v}" for k, v in zip(self.label_names,
                                                    key)): n
                for key, n in sorted(self._series.items())}

    def reset(self) -> None:
        self._total = 0
        self._series.clear()


class Gauge(_Metric):
    """Set-to-current-value metric (``g.set(v)``, ``g.add(dv)``)."""

    kind = "gauge"

    def __init__(self, name: str, help: str, **kw):
        super().__init__(name, help, **kw)
        self._v: Any = 0

    def set(self, v) -> None:
        self._v = v

    def add(self, dv) -> None:
        self._v += dv

    def value(self):
        return self._v

    def reset(self) -> None:
        self._v = 0


class Histogram(_Metric):
    """Fixed-bucket histogram (prometheus-style cumulative ``le``
    buckets) that ALSO keeps exact percentiles cheap: observations are
    O(1) (bucket increment + sum), and ``percentile`` answers from the
    bucket upper bounds — good enough for step-time monitoring, while
    the trace timelines (obs/trace.py) keep the exact values for the
    report's latency percentiles."""

    kind = "histogram"

    def __init__(self, name: str, help: str, *, buckets, **kw):
        super().__init__(name, help, typ=dict, **kw)
        bs = sorted(float(b) for b in buckets)
        if not bs:
            raise ValueError(f"histogram {name!r} needs buckets")
        self.buckets = bs + [math.inf]
        self.counts = [0] * len(self.buckets)
        self.sum = 0.0
        self.n = 0

    def observe(self, v: float) -> None:
        self.sum += v
        self.n += 1
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.counts[i] += 1
                return

    def percentile(self, q: float):
        """Upper bound of the bucket holding the q-th percentile sample
        (None when empty).  An UPPER bound, never an interpolation —
        monitoring must not under-report tails.

        Contract (vs ``obs.trace.Tracer.derive_latencies``): a
        histogram forgets the samples, so this is bucket-bound — the
        error vs the exact rank statistic is non-negative and at most
        the width of the bucket the sample landed in.  The trace
        timelines keep exact samples and the report's ``timeline``
        percentiles use THOSE; the two must not be conflated (pinned by
        ``tests/test_obs.py::
        test_histogram_percentile_vs_exact_error_bound``)."""
        if self.n == 0:
            return None
        rank = max(1, math.ceil(q / 100.0 * self.n))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return self.buckets[i]
        return self.buckets[-1]               # pragma: no cover

    def value(self):
        return {"count": self.n, "sum": round(self.sum, 6),
                "buckets": {("+Inf" if math.isinf(ub) else repr(ub)): c
                            for ub, c in zip(self.buckets, self.counts)}}

    def reset(self) -> None:
        self.counts = [0] * len(self.buckets)
        self.sum = 0.0
        self.n = 0


class FuncMetric(_Metric):
    """Registry-bound view of an external source: ``fn`` is evaluated at
    snapshot time.  ``kind`` says how the value behaves over time
    (counter vs gauge) for the prometheus exposition."""

    def __init__(self, name: str, help: str, fn: Callable[[], Any], *,
                 kind: str = "gauge", **kw):
        super().__init__(name, help, **kw)
        if kind not in _KINDS:
            raise ValueError(f"unknown metric kind {kind!r}")
        self.kind = kind
        self.fn = fn

    def value(self):
        return self.fn()


class MetricsRegistry:
    """Ordered registry of uniquely named metrics.

    Registration order is report order: :meth:`nested` builds the
    report dict by splitting names on ``.`` in insertion order, so the
    engine registers metrics in the exact section layout its report has
    always had."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    # -- registration -----------------------------------------------------

    def register(self, metric: _Metric):
        if metric.name in self._metrics:
            raise ValueError(f"metric {metric.name!r} already registered")
        self._metrics[metric.name] = metric
        return metric

    def check_aliases(self) -> None:
        """Every ``alias_of`` must name a registered canonical metric.
        Deferred from :meth:`register` so sections can alias across the
        report's insertion order; the engine calls this once after its
        full registration (and the golden-schema test again)."""
        for m in self._metrics.values():
            if m.alias_of is not None and m.alias_of not in self._metrics:
                raise ValueError(
                    f"{m.name!r} aliases unknown metric {m.alias_of!r}")

    def counter(self, name, help, **kw) -> Counter:
        return self.register(Counter(name, help, **kw))

    def gauge(self, name, help, **kw) -> Gauge:
        return self.register(Gauge(name, help, **kw))

    def histogram(self, name, help, *, buckets, **kw) -> Histogram:
        return self.register(Histogram(name, help, buckets=buckets, **kw))

    def func(self, name, help, fn, **kw) -> FuncMetric:
        return self.register(FuncMetric(name, help, fn, **kw))

    # -- access -----------------------------------------------------------

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __len__(self) -> int:
        return len(self._metrics)

    def get(self, name: str) -> _Metric:
        return self._metrics[name]

    def names(self) -> list[str]:
        return list(self._metrics)

    def reset(self) -> None:
        """Zero every OWNED metric (bound metrics follow their source)."""
        for m in self._metrics.values():
            m.reset()

    # -- exposition -------------------------------------------------------

    def describe(self) -> dict[str, dict]:
        """{name: {kind, type, help, ...}} — the machine-readable schema
        the golden test and the CI schema diff consume."""
        return {name: m.describe() for name, m in self._metrics.items()}

    def get_value(self, name: str) -> Any:
        """Current value of one metric by dotted name (KeyError when
        not registered) — the SLO monitor's gauge-objective read."""
        return self._metrics[name].value()

    def snapshot(self) -> dict[str, Any]:
        """Flat {dotted-name: value} snapshot, JSON-serializable."""
        return {name: m.value() for name, m in self._metrics.items()}

    def nested(self) -> dict:
        """Snapshot nested by the ``.`` separator, insertion-ordered —
        the engine report's exact shape."""
        out: dict = {}
        for name, m in self._metrics.items():
            parts = name.split(".")
            node = out
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = m.value()
        return out

    def to_prometheus(self) -> str:
        """Text exposition (format 0.0.4).  Non-numeric metrics (strings,
        booleans-as-config) surface as ``name_info{value="..."} 1`` so
        the scrape keeps the full schema without type abuse.  HELP text
        and label values are escaped per the format spec (``\\`` and
        newline in help; ``\\``, ``"`` and newline in label values) —
        round-trip pinned by ``tests/test_obs.py``."""
        lines: list[str] = []
        for name, m in self._metrics.items():
            pn = prom_name(name)
            help_ = _escape_help(m.help)
            if isinstance(m, Histogram):
                lines.append(f"# HELP {pn} {help_}")
                lines.append(f"# TYPE {pn} histogram")
                cum = 0
                for ub, c in zip(m.buckets, m.counts):
                    cum += c
                    le = "+Inf" if math.isinf(ub) else repr(ub)
                    lines.append(f'{pn}_bucket{{le="{le}"}} {cum}')
                lines.append(f"{pn}_sum {m.sum}")
                lines.append(f"{pn}_count {m.n}")
                continue
            v = m.value()
            if isinstance(m, Counter) and m.label_names:
                lines.append(f"# HELP {pn} {help_}")
                lines.append(f"# TYPE {pn} {m.kind}")
                for key, n in sorted(m._series.items()):
                    lbl = ",".join(
                        f'{k}="{_escape_label_value(str(val))}"'
                        for k, val in zip(m.label_names, key))
                    lines.append(f"{pn}{{{lbl}}} {n}")
                lines.append(f"{pn}_total {m._total}")
                continue
            if isinstance(v, bool):
                v = int(v)
            if v is None or isinstance(v, str) or isinstance(v, dict):
                lines.append(f"# HELP {pn} {help_}")
                lines.append(f"# TYPE {pn} gauge")
                sval = "none" if v is None else str(v)
                lines.append(f'{pn}_info{{value='
                             f'"{_escape_label_value(sval)}"}} 1')
                continue
            lines.append(f"# HELP {pn} {help_}")
            lines.append(f"# TYPE {pn} {m.kind}")
            lines.append(f"{pn} {v}")
        return "\n".join(lines) + "\n"
