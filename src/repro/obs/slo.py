"""SLO objectives + rolling-window burn-rate monitoring (DESIGN §15).

An :class:`SLObjective` is a percentile-style target over one signal:
"``metric`` must stay <= ``target`` for all but ``budget_frac`` of the
observations in the last ``window_s`` seconds".  Two signal families:

* **request** objectives (``metric`` in ``ttft``/``tpot``/``e2e``) are
  fed from the tracer's per-request timelines: each COMPLETED request
  contributes one observation per objective, stamped at its ``done``
  time.  ``target=0.5, budget_frac=0.05`` therefore reads as
  "p95(TTFT) <= 500 ms over the window".
* **gauge** objectives name a registry metric (e.g.
  ``energy.proxy_uj_per_token`` for the Table-5 energy-per-token
  budget, ``pool.utilization`` for a pool-pressure ceiling) and sample
  it once per :meth:`SLOMonitor.evaluate` tick (one tick per engine
  step).  ``None`` samples (metric not yet defined, e.g. no tokens
  emitted) are skipped, not counted against the budget.

**Burn rate** is the standard error-budget derivative:
``burn = (bad / total) / budget_frac`` over the rolling window — 1.0
means violations arrive exactly at the rate that exhausts the budget,
2.0 means twice that.  An alert FIRES when ``burn >=
burn_threshold`` with at least ``min_samples`` observations in the
window, and CLEARS when it drops back below; both transitions append a
structured record to :attr:`SLOMonitor.alerts` and emit a tracer event
(``slo.alert`` / ``slo.recover``, lane ``slo``) so alerts line up with
the dispatch spans that caused them in the Perfetto view.

The monitor is deliberately passive about time: the engine passes
``now`` (its own ``_now()``, real or virtual clock) into
:meth:`evaluate`, so SLO evaluation is deterministic under the flight
recorder's virtual clock (obs/replay.py).

Pure Python (stdlib only) — jax-free like the rest of ``repro.obs``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

__all__ = ["SLObjective", "SLOMonitor", "REQUEST_METRICS",
           "default_slos"]

# timeline-derived per-request latency signals (Timeline property names)
REQUEST_METRICS = ("ttft", "tpot", "e2e")


@dataclasses.dataclass(frozen=True)
class SLObjective:
    """One objective: ``metric <= target`` for all but ``budget_frac``
    of the observations in a ``window_s`` rolling window."""
    name: str
    metric: str                  # REQUEST_METRICS member or registry name
    target: float
    budget_frac: float = 0.05
    window_s: float = 60.0
    burn_threshold: float = 1.0
    min_samples: int = 1

    def __post_init__(self):
        if not self.name:
            raise ValueError("objective needs a name")
        if not 0.0 < self.budget_frac <= 1.0:
            raise ValueError(f"{self.name}: budget_frac must be in "
                             f"(0, 1], got {self.budget_frac}")
        if self.window_s <= 0.0:
            raise ValueError(f"{self.name}: window_s must be > 0")
        if self.min_samples < 1:
            raise ValueError(f"{self.name}: min_samples must be >= 1")

    @property
    def kind(self) -> str:
        return "request" if self.metric in REQUEST_METRICS else "gauge"


def default_slos(*, ttft_s: Optional[float] = 0.5,
                 e2e_s: Optional[float] = 5.0,
                 tpot_s: Optional[float] = None,
                 energy_uj_per_token: Optional[float] = None,
                 pool_utilization: Optional[float] = 0.98,
                 budget_frac: float = 0.05, window_s: float = 60.0,
                 burn_threshold: float = 1.0,
                 min_samples: int = 1) -> list[SLObjective]:
    """The stock objective set; pass ``None`` to drop one."""
    mk = lambda name, metric, target: SLObjective(  # noqa: E731
        name, metric, target, budget_frac=budget_frac,
        window_s=window_s, burn_threshold=burn_threshold,
        min_samples=min_samples)
    objs = []
    if ttft_s is not None:
        objs.append(mk("ttft", "ttft", ttft_s))
    if tpot_s is not None:
        objs.append(mk("tpot", "tpot", tpot_s))
    if e2e_s is not None:
        objs.append(mk("e2e", "e2e", e2e_s))
    if energy_uj_per_token is not None:
        objs.append(mk("energy_per_token", "energy.proxy_uj_per_token",
                       energy_uj_per_token))
    if pool_utilization is not None:
        objs.append(mk("pool_pressure", "pool.utilization",
                       pool_utilization))
    return objs


class SLOMonitor:
    """Rolling-window burn-rate evaluator over a set of objectives.

    ``tracer`` feeds the request objectives (and receives the alert
    events); ``value_fn(name) -> value`` feeds the gauge objectives
    (the engine binds it to its metrics registry).  Either may be None
    — the corresponding objective family just never observes."""

    def __init__(self, objectives, *, tracer=None,
                 value_fn: Optional[Callable[[str], object]] = None):
        objectives = list(objectives)
        names = [o.name for o in objectives]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate objective names in {names}")
        self.objectives: dict[str, SLObjective] = \
            {o.name: o for o in objectives}
        self.tracer = tracer
        self.value_fn = value_fn
        self._windows: dict[str, deque] = \
            {n: deque() for n in self.objectives}
        self._seen_done: set = set()
        self._active: dict[str, dict] = {}
        self._last_burn: dict[str, Optional[float]] = \
            {n: None for n in self.objectives}
        self.alerts: list[dict] = []
        self.alerts_fired = 0
        self.evaluations = 0

    # -- observation --------------------------------------------------------

    def observe(self, name: str, t: float, value: float) -> None:
        """Record one observation for objective ``name`` at time ``t``
        (monotone per objective) and trim the window."""
        obj = self.objectives[name]
        win = self._windows[name]
        win.append((t, value <= obj.target))
        cutoff = t - obj.window_s
        while win and win[0][0] < cutoff:
            win.popleft()

    def _ingest(self, now: float) -> None:
        req_objs = [o for o in self.objectives.values()
                    if o.kind == "request"]
        if req_objs and self.tracer is not None:
            for rid, tl in self.tracer.timelines.items():
                if tl.done is None or rid in self._seen_done:
                    continue
                self._seen_done.add(rid)
                for obj in req_objs:
                    v = getattr(tl, obj.metric)
                    if v is not None:
                        self.observe(obj.name, tl.done, v)
        if self.value_fn is not None:
            for obj in self.objectives.values():
                if obj.kind != "gauge":
                    continue
                try:
                    v = self.value_fn(obj.metric)
                except KeyError:
                    continue
                if v is None:
                    continue
                self.observe(obj.name, now, float(v))

    # -- burn rate + alerting ----------------------------------------------

    def burn_rate(self, name: str, now: float):
        """(burn, total, bad) over the window ending at ``now``; burn is
        None when the window is empty."""
        obj = self.objectives[name]
        win = self._windows[name]
        cutoff = now - obj.window_s
        while win and win[0][0] < cutoff:
            win.popleft()
        total = len(win)
        if total == 0:
            return None, 0, 0
        bad = sum(1 for _, ok in win if not ok)
        return (bad / total) / obj.budget_frac, total, bad

    def evaluate(self, now: float) -> None:
        """One monitoring tick: ingest new observations, recompute burn
        rates, fire/clear alerts.  The engine calls this once per step."""
        self.evaluations += 1
        self._ingest(now)
        for obj in self.objectives.values():
            burn, total, bad = self.burn_rate(obj.name, now)
            self._last_burn[obj.name] = burn
            firing = (burn is not None and total >= obj.min_samples
                      and burn >= obj.burn_threshold)
            was = obj.name in self._active
            if firing and not was:
                alert = {"objective": obj.name, "metric": obj.metric,
                         "target": obj.target,
                         "burn_rate": round(burn, 4),
                         "window_total": total, "window_bad": bad,
                         "t": now}
                self._active[obj.name] = alert
                self.alerts.append(alert)
                self.alerts_fired += 1
                if self.tracer is not None:
                    self.tracer.event("slo.alert", "slo", ts=now,
                                      args=dict(alert))
            elif was and not firing:
                del self._active[obj.name]
                if self.tracer is not None:
                    self.tracer.event(
                        "slo.recover", "slo", ts=now,
                        args={"objective": obj.name,
                              "burn_rate": None if burn is None
                              else round(burn, 4)})

    # -- views --------------------------------------------------------------

    @property
    def alerts_active(self) -> int:
        return len(self._active)

    def worst_burn_rate(self) -> Optional[float]:
        burns = [b for b in self._last_burn.values() if b is not None]
        return max(burns) if burns else None

    def status(self) -> dict:
        """Per-objective state (the registry's dynamic ``slo.status``)."""
        out = {}
        for name, obj in self.objectives.items():
            burn = self._last_burn[name]
            win = self._windows[name]
            out[name] = {
                "metric": obj.metric, "target": obj.target,
                "budget_frac": obj.budget_frac,
                "window_s": obj.window_s,
                "window_total": len(win),
                "window_bad": sum(1 for _, ok in win if not ok),
                "burn_rate": None if burn is None else round(burn, 4),
                "firing": name in self._active,
            }
        return out

    def reset(self) -> None:
        for win in self._windows.values():
            win.clear()
        self._seen_done.clear()
        self._active.clear()
        self._last_burn = {n: None for n in self.objectives}
        self.alerts.clear()
        self.alerts_fired = 0
        self.evaluations = 0
