"""Structured event tracing for the serving engine (DESIGN §14).

Two complementary stores, one object:

* **Event ring** — a bounded ``collections.deque`` of span/instant
  events covering the span taxonomy in DESIGN §14: scheduler admission,
  chunked-prefill planning, preemption, CoW retries, ``grow_for_spec``
  degradation, pool alloc/free/evict/retract, prefix-cache
  hit/miss/publish, and every jitted dispatch (stream shape, real vs
  padded token counts, compile-vs-steady flag).  The ring NEVER grows
  past ``capacity``: old events drop (counted in ``dropped``) instead
  of growing the host heap on a long-lived server.  With
  ``enabled=False`` every recording call is one attribute test — the
  overhead gate in ``serving_bench --check`` holds the whole disabled
  layer under 1% of a steady engine step.
* **Per-request timelines** — arrival → admission → first prefill
  chunk → first token (TTFT) → per-token (ring-gated) → done.  These
  are a handful of floats per request, always on, and are the SOURCE
  for the report's ``timeline`` latency section: TTFT/TPOT/e2e
  percentiles are *derived from the trace* and cross-checked against
  the legacy request-timestamp lists (``tests/test_obs.py``,
  ``serving_bench --check``).

Export is Chrome trace-event JSON (the Perfetto-loadable subset:
``X``/``i``/``M`` phases, microsecond timestamps), see
:meth:`Tracer.to_chrome` and ``examples/inspect_trace.py``.

Pure Python (stdlib only) — safe to import from the jax-free host
modules (kv_pool / scheduler / prefix_cache carry an optional tracer).
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import deque
from typing import Any, Callable, Optional

__all__ = ["Tracer", "Timeline", "validate_chrome_trace",
           "CHROME_REQUIRED_KEYS", "DECISION_CATS"]

# Perfetto lanes (tids) per subsystem: stable small ints so a trace of
# one engine renders as a fixed set of named tracks.
LANES = {"engine": 0, "dispatch": 1, "sched": 2, "pool": 3, "cache": 4,
         "requests": 5, "profile": 6, "slo": 7}

# Event categories that constitute the scheduler-decision stream: what
# the flight recorder (obs/replay.py) captures losslessly and diffs
# between a recorded run and its replay.  Admission order, chunk
# boundaries, preemptions, spec degradation, pool alloc/CoW/retract and
# prefix-cache hit/publish all live here.
DECISION_CATS = ("sched", "pool", "cache")

CHROME_REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")


@dataclasses.dataclass
class Timeline:
    """One request's lifecycle marks on the engine clock (seconds).
    ``None`` marks simply never happened (e.g. an unfinished request at
    report time)."""
    rid: int
    arrival: float
    admit: Optional[float] = None
    first_chunk: Optional[float] = None
    first_token: Optional[float] = None
    done: Optional[float] = None
    n_generated: int = 0
    preemptions: int = 0
    tokens: list = dataclasses.field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        return None if self.first_token is None \
            else self.first_token - self.arrival

    @property
    def e2e(self) -> Optional[float]:
        return None if self.done is None else self.done - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        """Per-output-token time, same definition as the legacy report:
        (done - first_token) / (n_generated - 1)."""
        if self.done is None or self.first_token is None \
                or self.n_generated < 2:
            return None
        return (self.done - self.first_token) / (self.n_generated - 1)


class Tracer:
    """Ring-buffered structured events + always-on request timelines."""

    def __init__(self, *, capacity: int = 65536,
                 clock: Optional[Callable[[], float]] = None,
                 enabled: bool = False):
        if capacity < 1:
            raise ValueError("trace ring needs capacity >= 1")
        self.capacity = capacity
        self.clock = clock or time.perf_counter
        self.enabled = enabled
        self.events: deque = deque(maxlen=capacity)
        self.n_emitted = 0
        self.timelines: dict[int, Timeline] = {}
        # Optional UNBOUNDED side-channel for the flight recorder: when
        # set (a list), every event whose category is in DECISION_CATS
        # is also appended as (name, args) — no timestamp, so two runs
        # of the same workload compare by decision order and content,
        # not wall clock.  The ring may drop events under load; the
        # decision sink never does (record mode only, bounded by the
        # workload's own decision count).
        self.decision_sink: Optional[list] = None

    # -- ring events ------------------------------------------------------

    @property
    def dropped(self) -> int:
        """Events evicted from the ring (bounded-memory guarantee)."""
        return self.n_emitted - len(self.events)

    def event(self, name: str, cat: str, *, ts: Optional[float] = None,
              args: Optional[dict] = None) -> None:
        """Instant event (phase ``i``).  ``ts`` on the tracer clock,
        seconds; defaults to now."""
        if not self.enabled:
            return
        if self.decision_sink is not None and cat in DECISION_CATS:
            self.decision_sink.append((name, args))
        self.n_emitted += 1
        self.events.append(
            ("i", name, cat, self.clock() if ts is None else ts, 0.0,
             args))

    def span(self, name: str, cat: str, ts: float, dur: float,
             args: Optional[dict] = None) -> None:
        """Complete span (phase ``X``): started at ``ts``, lasted
        ``dur`` seconds.  Recorded after the fact — the engine times its
        dispatches anyway, so spans cost one append, not two."""
        if not self.enabled:
            return
        self.n_emitted += 1
        self.events.append(("X", name, cat, ts, dur, args))

    # -- request timelines (always on) ------------------------------------

    def req_submit(self, rid: int, arrival: float) -> None:
        """First submission creates the timeline; a re-queue after
        preemption keeps the original marks."""
        if rid not in self.timelines:
            self.timelines[rid] = Timeline(rid=rid, arrival=arrival)

    def req_mark(self, rid: int, mark: str, t: float) -> None:
        """Set a lifecycle mark once (first occurrence wins — a resumed
        request's re-admission is not its admission latency)."""
        tl = self.timelines.get(rid)
        if tl is not None and getattr(tl, mark) is None:
            setattr(tl, mark, t)

    def req_preempt(self, rid: int) -> None:
        tl = self.timelines.get(rid)
        if tl is not None:
            tl.preemptions += 1

    def req_token(self, rid: int, t: float) -> None:
        """Per-token mark — ring-gated (full inter-token detail only
        when tracing is on; TTFT/TPOT need only the lifecycle marks)."""
        if self.enabled:
            tl = self.timelines.get(rid)
            if tl is not None:
                tl.tokens.append(t)

    def req_done(self, rid: int, t: float, n_generated: int) -> None:
        tl = self.timelines.get(rid)
        if tl is not None and tl.done is None:
            tl.done = t
            tl.n_generated = n_generated

    # -- derivation -------------------------------------------------------

    def derive_latencies(self) -> dict[str, list]:
        """TTFT / TPOT / e2e sample lists derived from the COMPLETED
        request timelines — the trace-derived counterpart of the legacy
        ``report()`` percentile inputs.

        Contract (vs ``obs.metrics.Histogram.percentile``): these are
        EXACT raw samples — percentiles computed from them (the
        engine's ``timeline`` report section) interpolate between true
        observations.  A ``Histogram`` only retains bucket counts, so
        its ``percentile`` returns the UPPER BOUND of the bucket
        holding the rank — biased high by at most one bucket width.
        Reports must never swap one for the other silently; the
        pinning test is ``tests/test_obs.py::
        test_histogram_percentile_vs_exact_error_bound``."""
        ttft = [tl.ttft for tl in self.timelines.values()
                if tl.ttft is not None]
        tpot = [tl.tpot for tl in self.timelines.values()
                if tl.tpot is not None]
        e2e = [tl.e2e for tl in self.timelines.values()
               if tl.e2e is not None]
        return {"ttft": ttft, "tpot": tpot, "e2e": e2e}

    def reset(self) -> None:
        self.events.clear()
        self.n_emitted = 0
        self.timelines.clear()
        if self.decision_sink is not None:
            self.decision_sink.clear()

    # -- chrome trace export ----------------------------------------------

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object (Perfetto: open ui.perfetto.dev
        and drop the file).  Ring events become ``i``/``X`` events on
        per-subsystem lanes; request timelines render as one span per
        request on the ``requests`` lane with TTFT marked as an instant
        event, so queueing, prefill and decode phases line up against
        the dispatch spans that served them."""
        us = 1e6
        ev: list[dict] = []
        ev.append({"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                   "ts": 0, "args": {"name": "repro-serving-engine"}})
        for lane, tid in LANES.items():
            ev.append({"name": "thread_name", "ph": "M", "pid": 0,
                       "tid": tid, "ts": 0, "args": {"name": lane}})
        for ph, name, cat, ts, dur, args in self.events:
            e = {"name": name, "cat": cat, "ph": ph, "pid": 0,
                 "tid": LANES.get(cat, LANES["engine"]),
                 "ts": round(ts * us, 3)}
            if ph == "X":
                e["dur"] = round(dur * us, 3)
            if ph == "i":
                e["s"] = "t"                 # thread-scoped instant
            if args:
                e["args"] = args
            ev.append(e)
        for tl in self.timelines.values():
            start = tl.admit if tl.admit is not None else tl.arrival
            end = tl.done if tl.done is not None else \
                (tl.tokens[-1] if tl.tokens else start)
            args = {"rid": tl.rid, "arrival_s": tl.arrival,
                    "n_generated": tl.n_generated,
                    "preemptions": tl.preemptions}
            if tl.ttft is not None:
                args["ttft_s"] = round(tl.ttft, 6)
            if tl.tpot is not None:
                args["tpot_s"] = round(tl.tpot, 6)
            ev.append({"name": f"req {tl.rid}", "cat": "request",
                       "ph": "X", "pid": 0, "tid": LANES["requests"],
                       "ts": round(start * us, 3),
                       "dur": round(max(end - start, 0.0) * us, 3),
                       "args": args})
            if tl.first_token is not None:
                ev.append({"name": f"first_token rid={tl.rid}",
                           "cat": "request", "ph": "i", "s": "t",
                           "pid": 0, "tid": LANES["requests"],
                           "ts": round(tl.first_token * us, 3)})
        return {"traceEvents": ev, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped,
                              "ring_capacity": self.capacity}}

    def export(self, path: str) -> dict:
        """Write the Chrome trace to ``path``; returns the object."""
        obj = self.to_chrome()
        with open(path, "w") as f:
            json.dump(obj, f)
        return obj


def validate_chrome_trace(obj: Any) -> list[str]:
    """Schema check against the Chrome trace-event format (the subset
    Perfetto's JSON importer requires).  Returns a list of problems —
    empty means loadable.  Used by ``tests/test_obs.py`` and the bench
    gate, so a malformed exporter fails CI instead of Perfetto.

    Deliberately order-agnostic: the format does not require sorted
    timestamps (Perfetto sorts on import), so out-of-order ``ts`` is
    valid.  An empty ``traceEvents`` list and an events-only trace
    (instants, no ``X`` spans) are both valid too."""
    problems: list[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' list"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    try:
        json.dumps(obj)
    except (TypeError, ValueError) as e:
        problems.append(f"not JSON-serializable: {e}")
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            problems.append(f"event {i} is not an object")
            continue
        for k in CHROME_REQUIRED_KEYS:
            if k not in e:
                problems.append(f"event {i} ({e.get('name')!r}) "
                                f"missing required key {k!r}")
        ph = e.get("ph")
        if ph not in ("X", "i", "B", "E", "M", "C"):
            problems.append(f"event {i} has unknown phase {ph!r}")
        if ph == "X" and "dur" not in e:
            problems.append(f"event {i} ({e.get('name')!r}) is a "
                            f"complete span without 'dur'")
        ts = e.get("ts")
        if ts is not None and not isinstance(ts, (int, float)):
            problems.append(f"event {i} ts is not numeric")
    return problems
