from repro.optim.optimizers import (adamw, adafactor, OptState,  # noqa: F401
                                    clip_by_global_norm)
from repro.optim.schedule import warmup_cosine, warmup_linear  # noqa: F401
from repro.optim.compression import (quantize_grads_po2,  # noqa: F401
                                     dequantize_grads_po2)
