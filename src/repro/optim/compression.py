"""Gradient compression for cross-pod all-reduce — the paper's quantization
scheme reused as a COLLECTIVE CODEC (beyond-paper extension, DESIGN §5).

Cross-pod (DCI) bandwidth is the scarcest link at 1000+ nodes.  Gradients
are quantized per-leaf to int8 on a power-of-two grid (Eq. 1 with N chosen
from the max-heuristic Eq. 6), all-reduced in int32 (sums of int8 codes on
a SHARED grid are exact — no codebooks, no per-shard rescale), and
dequantized by a single bit-shift: 4x less DCI traffic, and the decode cost
is the paper's cheapest unit (Table 5).

Usage inside a shard_map'd train step:
    codes, n = quantize_grads_po2(g)
    codes = jax.lax.psum(codes_int32, axis_name)      # exact integer sum
    g = dequantize_grads_po2(codes, n, count)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.qscheme import max_frac_bits, round_half_away

__all__ = ["quantize_grads_po2", "dequantize_grads_po2", "compressed_psum"]


def _leaf_n(g: jax.Array, bits: int) -> jax.Array:
    """Eq. 6 max-heuristic, computed on-device (traced): the finest
    power-of-two grid whose range covers max|g|."""
    int_bits = jnp.ceil(jnp.log2(jnp.max(jnp.abs(g.astype(jnp.float32)))
                                 + 1e-12) + 1.0)
    return (bits - 1) - jnp.clip(int_bits, -20.0, 20.0)


def quantize_grads_po2(grads: Any, bits: int = 8) -> tuple[Any, Any]:
    """Per-leaf power-of-two quantization -> (int32 codes, fractional bits).

    Codes are int32 so the subsequent psum cannot overflow for <= 2^23
    participants; the WIRE format stays 8-bit (codes are in [-128, 127]) —
    collective implementations pack accordingly.
    """
    def q(g):
        n = _leaf_n(g, bits)
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        scaled = g.astype(jnp.float32) * jnp.exp2(n)
        return jnp.clip(round_half_away(scaled), lo, hi).astype(jnp.int32), n

    flat, treedef = jax.tree_util.tree_flatten(grads)
    out = [q(g) for g in flat]
    codes = treedef.unflatten([o[0] for o in out])
    ns = treedef.unflatten([o[1] for o in out])
    return codes, ns


def dequantize_grads_po2(codes: Any, ns: Any, count: int = 1) -> Any:
    """codes * 2^-n / count — the mean gradient after an integer psum."""
    return jax.tree.map(
        lambda c, n: (c.astype(jnp.float32) * jnp.exp2(-n) / count),
        codes, ns)


def compressed_psum(grads: Any, axis_name: str, bits: int = 8) -> Any:
    """All-reduce-mean with po2-compressed payload (call under shard_map).

    The grid (n) must agree across participants: we psum-MAX the per-leaf
    int-bit requirement first (tiny scalar traffic), then quantize on the
    shared grid, integer-psum, and shift back.
    """
    def shared_n(g):
        n = _leaf_n(g, bits)
        return -jax.lax.pmax(-n, axis_name)    # min n == coarsest grid wins

    ns = jax.tree.map(shared_n, grads)

    def q(g, n):
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        return jnp.clip(round_half_away(g.astype(jnp.float32) * jnp.exp2(n)),
                        lo, hi).astype(jnp.int32)

    codes = jax.tree.map(q, grads, ns)
    codes = jax.lax.psum(codes, axis_name)
    count = jax.lax.psum(1, axis_name)
    return jax.tree.map(
        lambda c, n, g: (c.astype(jnp.float32) * jnp.exp2(-n) / count
                         ).astype(g.dtype), codes, ns, grads)
