"""Optimizers as pure (init, update) pairs over pytrees — no optax
dependency, so state sharding is fully ours to control (ZeRO-1: the
distributed layer shards these states over (data, model)).

  adamw     — fp32 moments, bf16 params; decoupled weight decay.
  adafactor — factored second moment (row/col) for the 100B+ configs where
              full AdamW state (12 bytes/param) would not fit 16 GB HBM
              even sharded; falls back to full v for small/1-D leaves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["OptState", "adamw", "adafactor", "clip_by_global_norm"]


class OptState(NamedTuple):
    step: jax.Array
    m: Any            # first moment (None for adafactor)
    v: Any            # second moment (full array, or (row, col) tuple)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any, jax.Array], tuple[Any, OptState]]


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def _zip_map(fn, ref_tree, *trees):
    """Map fn over leaves of ref_tree, flattening other trees up-to ref's
    structure (their leaves may themselves be small tuples, e.g. factored v).
    Returns one unflattened tree per element of fn's output tuple."""
    leaves, treedef = jax.tree_util.tree_flatten(ref_tree)
    others = [treedef.flatten_up_to(t) for t in trees]
    results = [fn(l, *per) for l, *per in zip(leaves, *others)]
    n_out = len(results[0])
    return tuple(treedef.unflatten([r[i] for r in results])
                 for i in range(n_out))


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(zeros, params),
                        v=jax.tree.map(zeros, params))

    def update(grads, state, params, lr):
        step = state.step + 1
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        new_p, new_m, new_v = _zip_map(upd, grads, state.m, state.v, params)
        return new_p, OptState(step=step, m=new_m, v=new_v)

    return Optimizer(init=init, update=update)


def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0, min_dim_factored: int = 128
              ) -> Optimizer:
    """Adafactor (Shazeer & Stern 2018) without momentum: O(rows+cols)
    second-moment state for matrices — the only fit for 671B on v5e."""

    def _factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored \
            and p.shape[-2] >= min_dim_factored

    def init(params):
        def v_init(p):
            if _factored(p):
                return (jnp.zeros(p.shape[:-1], jnp.float32),
                        jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
            return jnp.zeros(p.shape, jnp.float32)

        return OptState(step=jnp.zeros((), jnp.int32), m=None,
                        v=jax.tree.map(v_init, params))

    def update(grads, state, params, lr):
        step = state.step + 1
        beta2 = 1.0 - step.astype(jnp.float32) ** -0.8

        def upd(g, v, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if isinstance(v, tuple):
                vr, vc = v
                vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                new_v = (vr, vc)
            else:
                vhat = beta2 * v + (1 - beta2) * g2
                new_v = vhat
            u = g32 / jnp.sqrt(vhat + eps)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_v

        new_p, new_v = _zip_map(upd, grads, state.v, params)
        return new_p, OptState(step=step, m=None, v=new_v)

    return Optimizer(init=init, update=update)
