"""Pure-jnp oracles for every Pallas kernel — the allclose reference.

Kept independent of the kernels (no shared helper with the kernel bodies)
so a bug cannot cancel itself out; semantics mirror
``repro.core.qscheme.shift_requant`` / ``repro.core.integer_ops``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["int8_matmul_ref", "quantize_ref", "residual_requant_ref"]


def _requant(acc: jax.Array, shift: int, lo: int, hi: int) -> jax.Array:
    acc = acc.astype(jnp.int32)
    if shift > 0:
        half = 1 << (shift - 1)
        acc = jnp.where(acc >= 0, (acc + half) >> shift,
                        -(((-acc) + half) >> shift))
    elif shift < 0:
        acc = acc << (-shift)
    return jnp.clip(acc, lo, hi)


def int8_matmul_ref(x_int: jax.Array, w_int: jax.Array,
                    b_int: Optional[jax.Array], *, shift: int,
                    bias_shift: int = 0, relu: bool = False,
                    lo: int = -128, hi: int = 127,
                    out_dtype=jnp.int8) -> jax.Array:
    acc = x_int.astype(jnp.int32) @ w_int.astype(jnp.int32)
    if b_int is not None:
        b = b_int.astype(jnp.int32)
        b = (b << bias_shift) if bias_shift >= 0 else _requant(
            b, -bias_shift, -(2**31), 2**31 - 1).astype(jnp.int32)
        acc = acc + b
    if relu:
        acc = jnp.maximum(acc, 0)
    return _requant(acc, shift, lo, hi).astype(out_dtype)


def quantize_ref(x: jax.Array, *, n: int, bits: int = 8,
                 unsigned: bool = False) -> jax.Array:
    lo, hi = (0, (1 << bits) - 1) if unsigned else (-(1 << (bits - 1)),
                                                    (1 << (bits - 1)) - 1)
    s = x.astype(jnp.float32) * (2.0 ** n)
    r = jnp.trunc(s + jnp.where(s >= 0, 0.5, -0.5))
    out_dtype = (jnp.uint8 if unsigned else jnp.int8) if bits <= 8 else jnp.int32
    return jnp.clip(r, lo, hi).astype(out_dtype)


def residual_requant_ref(a_int: jax.Array, b_int: jax.Array, *, n_a: int,
                         n_b: int, n_o: int, bits: int = 8,
                         relu: bool = False) -> jax.Array:
    n_hi = max(n_a, n_b)
    acc = (a_int.astype(jnp.int32) << (n_hi - n_a)) + \
          (b_int.astype(jnp.int32) << (n_hi - n_b))
    if relu:
        acc = jnp.maximum(acc, 0)
    unsigned = relu
    lo, hi = (0, (1 << bits) - 1) if unsigned else (-(1 << (bits - 1)),
                                                    (1 << (bits - 1)) - 1)
    out_dtype = jnp.uint8 if unsigned else jnp.int8
    return _requant(acc, n_hi - n_o, lo, hi).astype(out_dtype)
