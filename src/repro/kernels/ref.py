"""Pure-jnp oracles for every Pallas kernel — the allclose reference.

Kept independent of the kernels (no shared helper with the kernel bodies)
so a bug cannot cancel itself out; semantics mirror
``repro.core.qscheme.shift_requant`` / ``repro.core.integer_ops``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["int8_matmul_ref", "quantize_ref", "residual_requant_ref",
           "ragged_attention_ref"]


def _requant(acc: jax.Array, shift: int, lo: int, hi: int) -> jax.Array:
    acc = acc.astype(jnp.int32)
    if shift > 0:
        half = 1 << (shift - 1)
        acc = jnp.where(acc >= 0, (acc + half) >> shift,
                        -(((-acc) + half) >> shift))
    elif shift < 0:
        acc = acc << (-shift)
    return jnp.clip(acc, lo, hi)


def int8_matmul_ref(x_int: jax.Array, w_int: jax.Array,
                    b_int: Optional[jax.Array], *, shift: int,
                    bias_shift: int = 0, relu: bool = False,
                    lo: int = -128, hi: int = 127,
                    out_dtype=jnp.int8) -> jax.Array:
    acc = x_int.astype(jnp.int32) @ w_int.astype(jnp.int32)
    if b_int is not None:
        b = b_int.astype(jnp.int32)
        b = (b << bias_shift) if bias_shift >= 0 else _requant(
            b, -bias_shift, -(2**31), 2**31 - 1).astype(jnp.int32)
        acc = acc + b
    if relu:
        acc = jnp.maximum(acc, 0)
    return _requant(acc, shift, lo, hi).astype(out_dtype)


def quantize_ref(x: jax.Array, *, n: int, bits: int = 8,
                 unsigned: bool = False) -> jax.Array:
    lo, hi = (0, (1 << bits) - 1) if unsigned else (-(1 << (bits - 1)),
                                                    (1 << (bits - 1)) - 1)
    s = x.astype(jnp.float32) * (2.0 ** n)
    r = jnp.trunc(s + jnp.where(s >= 0, 0.5, -0.5))
    out_dtype = (jnp.uint8 if unsigned else jnp.int8) if bits <= 8 else jnp.int32
    return jnp.clip(r, lo, hi).astype(out_dtype)


def residual_requant_ref(a_int: jax.Array, b_int: jax.Array, *, n_a: int,
                         n_b: int, n_o: int, bits: int = 8,
                         relu: bool = False) -> jax.Array:
    n_hi = max(n_a, n_b)
    acc = (a_int.astype(jnp.int32) << (n_hi - n_a)) + \
          (b_int.astype(jnp.int32) << (n_hi - n_b))
    if relu:
        acc = jnp.maximum(acc, 0)
    unsigned = relu
    lo, hi = (0, (1 << bits) - 1) if unsigned else (-(1 << (bits - 1)),
                                                    (1 << (bits - 1)) - 1)
    out_dtype = jnp.uint8 if unsigned else jnp.int8
    return _requant(acc, n_hi - n_o, lo, hi).astype(out_dtype)


def ragged_token_meta(q_start: jax.Array, q_len: jax.Array,
                      kv_len: jax.Array, t: int):
    """Per-TOKEN view of the ragged descriptors: (sid, valid, pos) for
    each of the ``t`` stream rows.  ``q_start`` must be nondecreasing
    (padding descriptors carry ``q_start >= t`` and capture nothing);
    rows between one sequence's end and the next one's start are padding
    (``valid`` False, ``pos`` -1 so every KV position is masked)."""
    s = q_start.shape[0]
    tok = jnp.arange(t, dtype=jnp.int32)
    sid = jnp.clip(jnp.searchsorted(q_start, tok, side="right") - 1, 0, s - 1)
    local = tok - q_start[sid]
    valid = jnp.logical_and(local >= 0, local < q_len[sid])
    pos = jnp.where(valid, kv_len[sid] - q_len[sid] + local, -1)
    return sid, valid, pos


def ragged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                         block_tables: jax.Array, q_start: jax.Array,
                         q_len: jax.Array, kv_len: jax.Array, *,
                         kv_frac_bits: Optional[int] = None,
                         scale: Optional[float] = None) -> jax.Array:
    """Gather-based oracle for the unified ragged paged kernel.

    q (T, H, Dk) is the flattened mixed step stream; descriptors as in
    ``kernels.ragged_flash``.  Every token gathers its OWN sequence's
    table from the pool, dequantizes (the dataflow the kernel deletes),
    and attends under the descriptor-derived causal mask
    ``kv_pos <= kv_len - q_len + local``.  Rows covered by no descriptor
    return exactly zero.  The math is laid out token-batched with C == 1
    — the same contraction order as the per-shape paged reference, so
    the ragged engine's logits match the per-shape engine's bit for bit
    on the reference path.
    """
    from repro.core.qscheme import dequant
    t, h, dk = q.shape
    bs, kvh = k_pool.shape[1], k_pool.shape[2]
    dv = v_pool.shape[-1]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    if k_pool.dtype == jnp.int8 and kv_frac_bits is None:
        raise ValueError("int8 KV codes require kv_frac_bits (the "
                         "cache's static Eq.-1 fractional bit)")
    sid, valid, pos = ragged_token_meta(q_start, q_len, kv_len, t)
    bt_tok = block_tables[sid]                         # (T, NBmax)
    s_len = block_tables.shape[1] * bs
    k = k_pool[bt_tok].reshape(t, s_len, kvh, dk)
    v = v_pool[bt_tok].reshape(t, s_len, kvh, dv)
    if k.dtype == jnp.int8:
        k = dequant(k, int(kv_frac_bits), out_dtype=q.dtype)
        v = dequant(v, int(kv_frac_bits), out_dtype=q.dtype)
    else:
        k, v = k.astype(q.dtype), v.astype(q.dtype)
    qg = q.reshape(t, 1, kvh, g, dk)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    kv_pos = jnp.arange(s_len)
    mask = kv_pos[None, None, :] <= pos[:, None, None]   # (T, 1, S)
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(t, h, dv).astype(q.dtype)
    # fully-masked padding rows came out of the softmax as NaN — they are
    # no sequence's output, pin them to zero
    return jnp.where(valid[:, None, None], out, 0)
