"""Pallas API compatibility across jax versions.

jax <= 0.4.x names the TPU compiler-params dataclass ``TPUCompilerParams``;
newer releases renamed it ``CompilerParams``.  Resolve once here so every
kernel module stays version-agnostic.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
