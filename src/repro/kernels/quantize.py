"""Pallas TPU kernel: elementwise quantization (float -> int8 codes), Eq. 1.

Used at unified-module *entry* boundaries (activation -> int8 before the MXU)
and for offline weight conversion.  Blocked over rows so arbitrarily large
activations stream through VMEM; the scale 2^{N} is a static constant folded
into the kernel (no scalar operand).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quantize_kernel", "make_quantize"]


def quantize_kernel(x_ref, o_ref, *, n: int, lo: int, hi: int, out_dtype):
    x = x_ref[...].astype(jnp.float32) * (2.0 ** n)
    # round-half-away (hardware rounding, see qscheme.round_half_away)
    r = jnp.trunc(x + jnp.where(x >= 0, 0.5, -0.5))
    o_ref[...] = jnp.clip(r, lo, hi).astype(out_dtype)


def make_quantize(rows: int, cols: int, *, br: int, bc: int, n: int,
                  bits: int = 8, unsigned: bool = False,
                  interpret: bool = False):
    lo, hi = (0, (1 << bits) - 1) if unsigned else (-(1 << (bits - 1)),
                                                    (1 << (bits - 1)) - 1)
    out_dtype = (jnp.uint8 if unsigned else jnp.int8) if bits <= 8 else jnp.int32
    kernel = functools.partial(quantize_kernel, n=n, lo=lo, hi=hi,
                               out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(rows // br, cols // bc),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), out_dtype),
        interpret=interpret,
    )
