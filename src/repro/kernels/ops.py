"""jit'd public wrappers around the Pallas kernels.

Responsibilities: flatten batch dims, pad to tile multiples, pick MXU-aligned
block shapes that fit VMEM, dispatch to the Pallas kernel (interpret mode on
CPU), and fall back to the jnp reference for shapes where a kernel launch is
not worthwhile.

VMEM budget reasoning (v5e: ~128 MiB VMEM/core, we target < 8 MiB per call
to leave room for double-buffering):
  int8 x tile  bm*bk      (1 B)     128*512  = 64 KiB
  int8 w tile  bk*bn      (1 B)     512*512  = 256 KiB
  int32 acc    bm*bn      (4 B)     128*512  = 256 KiB
so default (bm, bk, bn) = (128, 512, 512) uses < 1 MiB with K-streaming,
and every dim is a multiple of the 128-lane MXU tiling.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.integer_ops import LinearQuantSpec, int_linear
from repro.kernels import ref
from repro.kernels.int8_matmul import make_int8_matmul
from repro.kernels.quantize import make_quantize
from repro.kernels.residual_requant import make_residual_requant

__all__ = ["int8_matmul", "quantize_act", "residual_requant",
           "use_interpret", "DEFAULT_BLOCKS"]

DEFAULT_BLOCKS = (128, 512, 512)  # (bm, bk, bn)


def use_interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    r = x.shape[axis] % mult
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - r)
    return jnp.pad(x, pad)


def _pick_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    bm, bk, bn = DEFAULT_BLOCKS
    return min(bm, m), min(bk, k), min(bn, n)


def int8_matmul(x_int: jax.Array, w_int: jax.Array,
                b_int: Optional[jax.Array], spec: LinearQuantSpec,
                *, relu: bool = False) -> jax.Array:
    """(..., K) int8 @ (K, N) int8 -> (..., N) int8 with fused requant.

    Static shift constants come from ``spec`` (deploy artifacts).  Shapes
    not worth a kernel launch (tiny K or M) use the jnp reference — same
    bit-exact contract.
    """
    *batch, k = x_int.shape
    n = w_int.shape[-1]
    m = 1
    for d in batch:
        m *= d
    unsigned = relu and spec.out_unsigned
    lo, hi = ((0, (1 << spec.bits) - 1) if unsigned
              else (-(1 << (spec.bits - 1)), (1 << (spec.bits - 1)) - 1))

    if m < 16 or k < 128 or n < 128:
        out = int_linear(x_int, w_int, b_int, spec, apply_relu=relu)
        return out

    x2 = x_int.reshape(m, k)
    bm, bk, bn = _pick_blocks(m, k, n)
    x2 = _pad_to(_pad_to(x2, bm, 0), bk, 1)
    w2 = _pad_to(_pad_to(w_int, bk, 0), bn, 1)
    mp, kp = x2.shape
    np_ = w2.shape[1]
    has_bias = b_int is not None
    call = make_int8_matmul(
        mp, kp, np_, bm=bm, bk=bk, bn=bn,
        shift=spec.requant_shift, bias_shift=spec.bias_shift, relu=relu,
        lo=lo, hi=hi, has_bias=has_bias,
        out_dtype=jnp.uint8 if unsigned else jnp.int8,
        interpret=use_interpret())
    if has_bias:
        b2 = _pad_to(b_int.reshape(1, -1), bn, 1)
        out = call(x2, w2, b2)
    else:
        out = call(x2, w2)
    return out[:m, :n].reshape(*batch, n)


def quantize_act(x: jax.Array, n: int, bits: int = 8,
                 unsigned: bool = False) -> jax.Array:
    """Elementwise Eq.-1 quantization of an activation tensor."""
    *batch, c = x.shape
    rows = 1
    for d in batch:
        rows *= d
    if rows < 8 or c < 128:
        return ref.quantize_ref(x, n=n, bits=bits, unsigned=unsigned)
    x2 = x.reshape(rows, c)
    br, bc = min(256, rows), min(512, c)
    x2 = _pad_to(_pad_to(x2, br, 0), bc, 1)
    call = make_quantize(x2.shape[0], x2.shape[1], br=br, bc=bc, n=n,
                         bits=bits, unsigned=unsigned,
                         interpret=use_interpret())
    return call(x2)[:rows, :c].reshape(*batch, c)


def residual_requant(a_int: jax.Array, b_int: jax.Array, *, n_a: int,
                     n_b: int, n_o: int, bits: int = 8,
                     relu: bool = False) -> jax.Array:
    """Fused Fig. 1(c)/(d) residual add on int8 codes."""
    assert a_int.shape == b_int.shape
    *batch, c = a_int.shape
    rows = 1
    for d in batch:
        rows *= d
    if rows < 8 or c < 128:
        return ref.residual_requant_ref(a_int, b_int, n_a=n_a, n_b=n_b,
                                        n_o=n_o, bits=bits, relu=relu)
    a2 = a_int.reshape(rows, c)
    b2 = b_int.reshape(rows, c)
    br, bc = min(256, rows), min(512, c)
    a2 = _pad_to(_pad_to(a2, br, 0), bc, 1)
    b2 = _pad_to(_pad_to(b2, br, 0), bc, 1)
    call = make_residual_requant(a2.shape[0], a2.shape[1], br=br, bc=bc,
                                 n_a=n_a, n_b=n_b, n_o=n_o, bits=bits,
                                 relu=relu, interpret=use_interpret())
    return call(a2, b2)[:rows, :c].reshape(*batch, c)
