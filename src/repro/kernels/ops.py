"""jit'd public wrappers around the Pallas kernels.

Responsibilities: flatten batch dims, pad to tile multiples, pick MXU-aligned
block shapes that fit VMEM, dispatch to the Pallas kernel (interpret mode on
CPU), and fall back to the jnp reference for shapes where a kernel launch is
not worthwhile.

VMEM budget reasoning (v5e: ~128 MiB VMEM/core, we target < 8 MiB per call
to leave room for double-buffering):
  int8 x tile  bm*bk      (1 B)     128*512  = 64 KiB
  int8 w tile  bk*bn      (1 B)     512*512  = 256 KiB
  int32 acc    bm*bn      (4 B)     128*512  = 256 KiB
so default (bm, bk, bn) = (128, 512, 512) uses < 1 MiB with K-streaming,
and every dim is a multiple of the 128-lane MXU tiling.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.integer_ops import LinearQuantSpec, int_linear
from repro.kernels import ref
from repro.kernels.flash_attention import (make_flash_decode,
                                           make_flash_prefill,
                                           make_paged_flash_decode)
from repro.kernels.int8_matmul import make_int8_matmul
from repro.kernels.quantize import make_quantize
from repro.kernels.ragged_flash import make_ragged_paged_flash
from repro.kernels.residual_requant import make_residual_requant

__all__ = ["int8_matmul", "quantize_act", "residual_requant",
           "flash_attention", "flash_decode", "paged_attention",
           "ragged_attention", "attention_kv_bytes", "attn_shard_size",
           "use_interpret", "DEFAULT_BLOCKS", "FLASH_BLOCKS"]

DEFAULT_BLOCKS = (128, 512, 512)  # (bm, bk, bn)
FLASH_BLOCKS = (256, 512)         # (bq, bk) — q tile x kv tile


def use_interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    r = x.shape[axis] % mult
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - r)
    return jnp.pad(x, pad)


def _pick_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    bm, bk, bn = DEFAULT_BLOCKS
    return min(bm, m), min(bk, k), min(bn, n)


def int8_matmul(x_int: jax.Array, w_int: jax.Array,
                b_int: Optional[jax.Array], spec: LinearQuantSpec,
                *, relu: bool = False, force_kernel: bool = False) -> jax.Array:
    """(..., K) int8 @ (K, N) int8 -> (..., N) int8 with fused requant.

    The batched/ragged entry point for the W8A8 forward (DESIGN §13):
    leading dims — a (B, S) batch or a packed ragged (T,) token stream —
    are flattened into the M axis.  Static shift constants come from
    ``spec`` (deploy artifacts).  Shapes not worth a kernel launch (tiny
    K, N or M — e.g. non-MXU-aligned head/model dims) use the jnp
    reference ``int_linear`` — same bit-exact contract, so the fallback
    is invisible to the parity rig.  On CPU the reference also serves
    MXU-aligned shapes by default: interpret-mode Pallas simulates the
    grid serially and would dominate the serving step for zero fidelity
    gain.  ``force_kernel=True`` overrides that policy so kernel parity
    tests exercise the fused epilogue itself (in interpret mode on CPU).
    """
    *batch, k = x_int.shape
    n = w_int.shape[-1]
    m = 1
    for d in batch:
        m *= d
    unsigned = relu and spec.out_unsigned
    lo, hi = ((0, (1 << spec.bits) - 1) if unsigned
              else (-(1 << (spec.bits - 1)), (1 << (spec.bits - 1)) - 1))

    if m < 16 or k < 128 or n < 128 or (use_interpret() and not force_kernel):
        out = int_linear(x_int, w_int, b_int, spec, apply_relu=relu)
        return out

    x2 = x_int.reshape(m, k)
    bm, bk, bn = _pick_blocks(m, k, n)
    x2 = _pad_to(_pad_to(x2, bm, 0), bk, 1)
    w2 = _pad_to(_pad_to(w_int, bk, 0), bn, 1)
    mp, kp = x2.shape
    np_ = w2.shape[1]
    has_bias = b_int is not None
    call = make_int8_matmul(
        mp, kp, np_, bm=bm, bk=bk, bn=bn,
        shift=spec.requant_shift, bias_shift=spec.bias_shift, relu=relu,
        lo=lo, hi=hi, has_bias=has_bias,
        out_dtype=jnp.uint8 if unsigned else jnp.int8,
        interpret=use_interpret())
    if has_bias:
        b2 = _pad_to(b_int.reshape(1, -1), bn, 1)
        out = call(x2, w2, b2)
    else:
        out = call(x2, w2)
    return out[:m, :n].reshape(*batch, n)


# ---------------------------------------------------------------------------
# fused (int8-KV) flash attention — DESIGN.md §2
# multi-device shard_map wiring (KV heads over the tensor axis) — DESIGN.md §8
# ---------------------------------------------------------------------------

def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def attn_shard_size(mesh: Optional[Mesh], shard_axis: str) -> int:
    """Size of the tensor axis the flash kernels shard heads over (1 when
    there is no mesh or the axis is absent — the single-device path)."""
    if mesh is None or shard_axis not in mesh.axis_names:
        return 1
    return mesh.shape[shard_axis]


def _attn_batch_spec(mesh: Mesh, b: int):
    """Batch-dim spec entry: the composite data axes — the SAME selection
    ``sharding.batch_sharding`` uses, so the shard_map boundary matches
    the activations' layout — when they divide B, else replicated."""
    from repro.distributed import sharding as shd
    dp = shd._dp(mesh)
    return dp if (dp and b % shd._axis_size(mesh, dp) == 0) else None


def _check_head_divisibility(kvh: int, tp: int, shard_axis: str):
    if kvh % tp:
        raise NotImplementedError(
            f"flash attention shards KV heads over mesh axis "
            f"'{shard_axis}' (size {tp}), which must divide the operand's "
            f"KV head count ({kvh}); use attn_kernel='chunked' (sequence-"
            f"sharded) for this mesh shape")


# Why jit + a bounded cache: eager shard_map cannot evaluate the closed
# calls inside the wrapper (jax.checkpoint / custom_vjp raise
# NotImplementedError outside jit), so direct eager callers (tests, REPL)
# need the jit; under an outer jitted step it simply inlines.  The cache
# keeps eager re-calls from retracing; bounded so long-lived serving
# processes can't accumulate a closure per distinct (mesh, q_offset, ...).
@functools.lru_cache(maxsize=64)
def _make_sharded_prefill(mesh: Mesh, head_entry, bdim, causal: bool,
                          q_offset: int, kv_frac_bits, scale):
    """shard_map'd prefill: q/k/v enter head-sharded on ``head_entry``
    (whole GQA groups per shard — kvh % tp == 0 is checked by the caller;
    None when the tensor axis is trivial), batch-sharded on the data axes
    when divisible.  Each shard runs the full single-device wrapper on its
    local heads: per-shard block picking, padding, and the static
    power-of-two KV scale folded into that shard's kernel constants.  No
    collectives — softmax is over the (replicated) KV sequence, so shards
    are independent."""
    from jax.experimental.shard_map import shard_map
    spec = P(bdim, None, head_entry, None)

    def local(q, k, v):
        return flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                               kv_frac_bits=kv_frac_bits, scale=scale)

    # check_rep=False: pallas_call has no replication rule
    return jax.jit(shard_map(local, mesh=mesh, in_specs=(spec, spec, spec),
                             out_specs=spec, check_rep=False))


@functools.lru_cache(maxsize=64)
def _make_sharded_decode(mesh: Mesh, head_entry, bdim, kv_frac_bits,
                         scale):
    """shard_map'd decode: the cache stays resident head-sharded (int8
    codes + their static scale per shard), q is resharded to match (tiny),
    ``pos`` is replicated.  Grouped query heads of a KV head land on the
    same shard, so the kernel's one-DMA-per-group contract holds."""
    from jax.experimental.shard_map import shard_map
    spec = P(bdim, None, head_entry, None)

    def local(pos, q, k, v):
        return flash_decode(q, k, v, pos=pos, kv_frac_bits=kv_frac_bits,
                            scale=scale)

    return jax.jit(shard_map(local, mesh=mesh,
                             in_specs=(P(), spec, spec, spec),
                             out_specs=spec, check_rep=False))


def _resolve_kv_frac_bits(k: jax.Array, kv_frac_bits: Optional[int]) -> int:
    """int8 KV codes are meaningless without their Eq.-1 fractional bit —
    defaulting to 2^0 would be a silent temperature/scale corruption."""
    if k.dtype == jnp.int8:
        if kv_frac_bits is None:
            raise ValueError("int8 KV codes require kv_frac_bits (the "
                             "cache's static Eq.-1 fractional bit)")
        return int(kv_frac_bits)
    return 0


def _dequant_then_repeat(q, k, v, nkv):
    """Reference dataflow the kernel deletes: full dequant copy + group
    repeat, then the pure-JAX chunked attention."""
    from repro.core.qscheme import dequant
    from repro.models.attention import _repeat_kv
    if k.dtype == jnp.int8:
        k = dequant(k, nkv, out_dtype=q.dtype)
        v = dequant(v, nkv, out_dtype=q.dtype)
    groups = q.shape[2] // k.shape[2]
    return _repeat_kv(k, groups), _repeat_kv(v, groups)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_offset: int = 0,
                    kv_frac_bits: Optional[int] = None,
                    scale: Optional[float] = None,
                    mesh: Optional[Mesh] = None,
                    shard_axis: str = "model") -> jax.Array:
    """Fused flash attention: q (B,Sq,H,Dk) x KV (B,Skv,KVH,D) -> (B,Sq,H,Dv).

    K/V may be int8 Eq.-1 codes (then ``kv_frac_bits`` is their static
    fractional bit): the codes are loaded directly into VMEM and dequantized
    in-register — the bf16 KV tensor never materializes in HBM.  GQA is
    contracted via the kernel's index maps, never repeated.  Shapes not
    worth a launch fall back to the pure-JAX ``chunked_attention`` (which
    stays the reference oracle).  ``q_offset`` must be a *static* int here
    (prefill); traced decode positions go through :func:`flash_decode`.

    With a multi-device ``mesh`` the call runs under shard_map: KV heads
    (whole GQA groups) are partitioned across ``shard_axis`` and every
    shard launches the kernel on its local heads (DESIGN §8).  The axis
    size must divide the KV head count.
    """
    b, sq, h, dk = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    if mesh is not None and mesh.size > 1:
        # >1 device: ALWAYS cross a shard_map boundary — GSPMD treats the
        # pallas_call as an opaque custom call and would gather/replicate
        # its operands otherwise (the exact dataflow this kernel deletes).
        tp = attn_shard_size(mesh, shard_axis)
        _check_head_divisibility(kvh, tp, shard_axis)
        call = _make_sharded_prefill(mesh, shard_axis if tp > 1 else None,
                                     _attn_batch_spec(mesh, b),
                                     causal, q_offset, kv_frac_bits, scale)
        return call(q, k, v)
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    nkv = _resolve_kv_frac_bits(k, kv_frac_bits)
    int8_kv = k.dtype == jnp.int8
    if sq < 16 or skv < 128:
        from repro.models.attention import chunked_attention
        kr, vr = _dequant_then_repeat(q, k, v, nkv)
        return chunked_attention(q, kr, vr, causal=causal,
                                 q_offset=q_offset, scale=scale)

    bq, bk = FLASH_BLOCKS
    sq_p = _round_up(sq, 128)
    skv_p = _round_up(skv, 128)
    bq, bk = min(bq, sq_p), min(bk, skv_p)
    sq_p, skv_p = _round_up(sq_p, bq), _round_up(skv_p, bk)
    dk_p, dv_p = _round_up(dk, 128), _round_up(dv, 128)

    def kernel_call(q_, k_, v_):
        qp = _pad_to(_pad_to(q_, bq, 1), dk_p, 3)
        kp = _pad_to(_pad_to(k_, bk, 1), dk_p, 3)
        vp = _pad_to(_pad_to(v_, bk, 1), dv_p, 3)
        call = make_flash_prefill(
            b, h, kvh, sq_p, skv_p, dk_p, dv_p, bq=bq, bk=bk, causal=causal,
            q_offset=q_offset, sq=sq, skv=skv,
            score_scale=scale * 2.0 ** (-nkv), v_scale=2.0 ** (-nkv),
            k_dtype=k_.dtype, out_dtype=q_.dtype, interpret=use_interpret())
        return call(qp, kp, vp)[:, :sq, :, :dv]

    if int8_kv:
        # inference-only dataflow (codes are non-differentiable anyway)
        return kernel_call(q, k, v)

    # float KV (train / prefill-from-scratch): pallas_call has no VJP rule,
    # so pair the fused forward with a backward that recomputes through the
    # chunked reference — same exact function, flash-attention style.
    def ref_fn(q_, k_, v_):
        from repro.models.attention import chunked_attention
        kr, vr = _dequant_then_repeat(q_, k_, v_, nkv)
        return chunked_attention(q_, kr, vr, causal=causal,
                                 q_offset=q_offset, scale=scale)

    @jax.custom_vjp
    def attn(q_, k_, v_):
        return kernel_call(q_, k_, v_)

    def attn_fwd(q_, k_, v_):
        return kernel_call(q_, k_, v_), (q_, k_, v_)

    def attn_bwd(res, g):
        return jax.vjp(ref_fn, *res)[1](g)

    attn.defvjp(attn_fwd, attn_bwd)
    return attn(q, k, v)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 pos: jax.Array, kv_frac_bits: Optional[int] = None,
                 scale: Optional[float] = None,
                 mesh: Optional[Mesh] = None,
                 shard_axis: str = "model") -> jax.Array:
    """Single-token fused decode: q (B,1,H,Dk) over the full cache
    (B,S_max,KVH,D), masked at traced absolute position ``pos``.

    The cache is read IN PLACE (native layout, int8 codes straight into
    VMEM); grouped query heads share one KV tile DMA.  Falls back to the
    chunked reference when the cache length has no MXU-aligned tile divisor
    OR the head dims are not lane multiples — padding the head dim here
    would copy the ENTIRE cache every decode step, which is exactly the
    dataflow this kernel deletes.

    With a multi-device ``mesh``: shard_map over ``shard_axis`` with the
    cache resident head-sharded — int8 codes AND their static power-of-two
    scale stay with their shard; only the (B,1,H,D) query and the scalar
    position cross the boundary (DESIGN §8).
    """
    b, sq1, h, dk = q.shape
    assert sq1 == 1, "flash_decode is the q_len=1 kernel"
    s_max, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    if mesh is not None and mesh.size > 1:
        tp = attn_shard_size(mesh, shard_axis)
        _check_head_divisibility(kvh, tp, shard_axis)
        call = _make_sharded_decode(mesh, shard_axis if tp > 1 else None,
                                    _attn_batch_spec(mesh, b),
                                    kv_frac_bits, scale)
        return call(jnp.asarray(pos, jnp.int32), q, k, v)
    groups = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    nkv = _resolve_kv_frac_bits(k, kv_frac_bits)

    bk = next((c for c in (512, 256, 128) if s_max % c == 0), None)
    if bk is None or s_max < 128 or dk % 128 or dv % 128:
        from repro.models.attention import chunked_attention
        kr, vr = _dequant_then_repeat(q, k, v, nkv)
        return chunked_attention(q, kr, vr, causal=True, q_offset=pos,
                                 scale=scale)

    gp = max(8, _round_up(groups, 8))
    q4 = _pad_to(q[:, 0].reshape(b, kvh, groups, dk), gp, 2)

    call = make_flash_decode(
        b, kvh, gp, s_max, dk, dv, bk=bk,
        score_scale=scale * 2.0 ** (-nkv), v_scale=2.0 ** (-nkv),
        out_dtype=q.dtype, interpret=use_interpret())
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)
    out = call(pos_arr, q4, k, v)                      # (B, KVH, gp, dv)
    return out[:, :, :groups].reshape(b, 1, h, dv)


# ---------------------------------------------------------------------------
# paged attention over the serving engine's KV block pool — DESIGN.md §9
# ---------------------------------------------------------------------------

def _paged_ref_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                         block_tables: jax.Array, q_positions: jax.Array,
                         nkv: int, scale: float) -> jax.Array:
    """Reference paged attention: gather every table block from the pool,
    dequantize, and attend with per-(slot, query) causal masks.

    This IS the dataflow the paged kernel deletes — a dequantized gathered
    copy of each slot's cache materializes in HBM — kept as the oracle, the
    CPU path, and the fallback for shapes the kernel refuses (non-lane-
    multiple head dims, non-MXU block sizes, multi-token chunks)."""
    from repro.core.qscheme import dequant
    b, c, h, dk = q.shape
    bs, kvh = k_pool.shape[1], k_pool.shape[2]
    dv = v_pool.shape[-1]
    g = h // kvh
    s_len = block_tables.shape[1] * bs
    k = k_pool[block_tables].reshape(b, s_len, kvh, dk)
    v = v_pool[block_tables].reshape(b, s_len, kvh, dv)
    if k.dtype == jnp.int8:
        k = dequant(k, nkv, out_dtype=q.dtype)
        v = dequant(v, nkv, out_dtype=q.dtype)
    else:
        k, v = k.astype(q.dtype), v.astype(q.dtype)
    qg = q.reshape(b, c, kvh, g, dk)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    kv_pos = jnp.arange(s_len)
    mask = kv_pos[None, None, :] <= q_positions[:, :, None]   # (B, C, S)
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, c, h, dv).astype(q.dtype)


@functools.lru_cache(maxsize=64)
def _make_sharded_paged_decode(mesh: Mesh, head_entry, bdim, kv_frac_bits,
                               scale):
    """shard_map'd paged decode: the BLOCK POOL stays resident head-sharded
    on ``head_entry`` (int8 codes + static po2 scale per shard, exactly
    like the dense cache in DESIGN §8); block tables and per-slot positions
    are slot-metadata — they follow the q/batch partition (``bdim``) and
    are replicated over the tensor axis, so every head shard walks the
    same logical→physical block mapping.  No collectives."""
    from jax.experimental.shard_map import shard_map
    qspec = P(bdim, None, head_entry, None)
    pspec = P(None, None, head_entry, None)

    def local(pos, bt, q, kp, vp):
        return paged_attention(q, kp, vp, bt, pos[:, None],
                               kv_frac_bits=kv_frac_bits, scale=scale)

    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(P(bdim), P(bdim, None), qspec, pspec, pspec),
        out_specs=qspec, check_rep=False))


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: jax.Array, q_positions: jax.Array, *,
                    kv_frac_bits: Optional[int] = None,
                    scale: Optional[float] = None,
                    mesh: Optional[Mesh] = None,
                    shard_axis: str = "model") -> jax.Array:
    """Attention over the serving engine's paged KV block pool (DESIGN §9).

    q: (B, C, H, Dk) — C == 1 is the continuous-batching decode hot path,
    C > 1 a chunked-prefill chunk.  k/v_pool: (NB, BS, KVH, D) — ALL
    sequences' blocks in one pool, int8 Eq.-1 codes (``kv_frac_bits``) or
    float.  block_tables: (B, NBmax) int32 mapping each slot's logical
    block ``i`` to its pool block (unallocated tail entries point at the
    trash block and are masked).  q_positions: (B, C) int32 absolute
    positions of the query tokens; attention is causal per slot
    (``kv_pos <= q_positions[b, c]``), which is what lets a fixed-width
    slot batch serve sequences of different live lengths.

    The C == 1 case with MXU-aligned shapes launches the fused paged
    kernel: the block table is consumed by the BlockSpec index maps, so KV
    codes stream block-by-block from the pool straight into VMEM — no
    gathered copy, no dequantized copy, written-once codes are never
    requantized.  Everything else takes the reference gather path.  With a
    multi-device ``mesh`` the kernel path crosses a shard_map boundary:
    pool head-sharded over ``shard_axis``, tables/positions replicated
    across it (batch over the data axes when divisible).
    """
    b, c, h, dk = q.shape
    bs, kvh = k_pool.shape[1], k_pool.shape[2]
    dv = v_pool.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    nkv = _resolve_kv_frac_bits(k_pool, kv_frac_bits)
    kernel_ok = (c == 1 and bs % 128 == 0 and dk % 128 == 0
                 and dv % 128 == 0)
    if mesh is not None and mesh.size > 1:
        tp = attn_shard_size(mesh, shard_axis)
        _check_head_divisibility(kvh, tp, shard_axis)
        if not kernel_ok:
            # reference path is plain jnp — GSPMD partitions it directly
            return _paged_ref_attention(q, k_pool, v_pool, block_tables,
                                        q_positions, nkv, scale)
        call = _make_sharded_paged_decode(
            mesh, shard_axis if tp > 1 else None, _attn_batch_spec(mesh, b),
            kv_frac_bits, scale)
        return call(jnp.asarray(q_positions[:, 0], jnp.int32),
                    jnp.asarray(block_tables, jnp.int32), q, k_pool, v_pool)
    if not kernel_ok:
        return _paged_ref_attention(q, k_pool, v_pool, block_tables,
                                    q_positions, nkv, scale)
    groups = h // kvh
    gp = max(8, _round_up(groups, 8))
    q4 = _pad_to(q[:, 0].reshape(b, kvh, groups, dk), gp, 2)
    call = make_paged_flash_decode(
        b, kvh, gp, block_tables.shape[1], bs, dk, dv,
        score_scale=scale * 2.0 ** (-nkv), v_scale=2.0 ** (-nkv),
        out_dtype=q.dtype, interpret=use_interpret())
    pos = jnp.asarray(q_positions[:, 0], jnp.int32)
    out = call(pos, jnp.asarray(block_tables, jnp.int32), q4, k_pool, v_pool)
    return out[:, :, :groups].reshape(b, 1, h, dv)


@functools.lru_cache(maxsize=64)
def _make_sharded_ragged(mesh: Mesh, head_entry, kv_frac_bits, scale,
                         tq_max):
    """shard_map'd ragged attention: the pool stays resident head-sharded
    (like paged decode); the packed (T, H, D) stream is head-sharded on
    its head axis and the descriptors are replicated.  The token axis is
    NOT partitioned — a ragged stream has no slot-aligned batch dim for
    the data axes to split, and T_pad is a few dozen rows, so replicating
    it across data-parallel shards is the cheap and correct layout."""
    from jax.experimental.shard_map import shard_map
    qspec = P(None, head_entry, None)
    pspec = P(None, None, head_entry, None)

    def local(q, kp, vp, bt, qs, ql, kl):
        return ragged_attention(q, kp, vp, bt, qs, ql, kl,
                                kv_frac_bits=kv_frac_bits, scale=scale,
                                tq_max=tq_max)

    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(qspec, pspec, pspec, P(None, None), P(), P(), P()),
        out_specs=qspec, check_rep=False))


def ragged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     block_tables: jax.Array, q_start: jax.Array,
                     q_len: jax.Array, kv_len: jax.Array, *,
                     kv_frac_bits: Optional[int] = None,
                     scale: Optional[float] = None,
                     tq_max: Optional[int] = None,
                     mesh: Optional[Mesh] = None,
                     shard_axis: str = "model") -> jax.Array:
    """Unified ragged-batch attention over the paged KV pool (DESIGN §12).

    One call serves a MIXED serving step: q (T, H, Dk) is the flattened
    token stream — prefill chunks, decode rows, and speculative tails
    packed back to back — and the per-sequence descriptors ``q_start`` /
    ``q_len`` / ``kv_len`` (S,) + ``block_tables`` (S, NBmax) say which
    stream rows belong to which sequence and how much KV each one sees.
    Descriptor contract (host-built): ``q_start`` nondecreasing, windows
    disjoint, ``q_len <= kv_len``, padding slots all-zero with trash
    tables.  Returns (T, H, Dv) with non-descriptor rows exactly zero.

    MXU-aligned pools (bs/dk/dv lane multiples) launch the single
    ``ragged_flash`` pallas_call — descriptors ride scalar prefetch, the
    block walk happens in the DMA engine, int8 codes dequantize
    in-register.  ``tq_max`` (static) bounds the per-sequence q_len so
    the kernel's q window stays narrow; None means the whole stream
    width.  Other shapes take the gather oracle
    (``ref.ragged_attention_ref``), which is also the CPU engine path.
    With a multi-device ``mesh``, KV heads shard over ``shard_axis``
    (whole GQA groups — §8) and descriptors replicate.
    """
    t, h, dk = q.shape
    bs, kvh = k_pool.shape[1], k_pool.shape[2]
    dv = v_pool.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    nkv = _resolve_kv_frac_bits(k_pool, kv_frac_bits)
    kernel_ok = bs % 128 == 0 and dk % 128 == 0 and dv % 128 == 0
    bt = jnp.asarray(block_tables, jnp.int32)
    qs = jnp.asarray(q_start, jnp.int32)
    ql = jnp.asarray(q_len, jnp.int32)
    kl = jnp.asarray(kv_len, jnp.int32)
    if mesh is not None and mesh.size > 1:
        tp = attn_shard_size(mesh, shard_axis)
        _check_head_divisibility(kvh, tp, shard_axis)
        if not kernel_ok:
            # reference path is plain jnp — GSPMD partitions it directly
            return ref.ragged_attention_ref(
                q, k_pool, v_pool, bt, qs, ql, kl,
                kv_frac_bits=kv_frac_bits, scale=scale)
        call = _make_sharded_ragged(mesh, shard_axis if tp > 1 else None,
                                    kv_frac_bits, scale, tq_max)
        return call(q, k_pool, v_pool, bt, qs, ql, kl)
    if not kernel_ok:
        return ref.ragged_attention_ref(
            q, k_pool, v_pool, bt, qs, ql, kl,
            kv_frac_bits=kv_frac_bits, scale=scale)
    t_pad = _round_up(t, 8)
    tq = _round_up(min(tq_max, t) if tq_max else t, 8)
    tq = min(tq, t_pad)
    qp = _pad_to(q, 8, 0)
    call = make_ragged_paged_flash(
        bt.shape[0], h, kvh, bt.shape[1], bs, t_pad, tq, dk, dv,
        score_scale=scale * 2.0 ** (-nkv), v_scale=2.0 ** (-nkv),
        out_dtype=q.dtype, interpret=use_interpret())
    out = call(qs, ql, kl, bt, qp, k_pool, v_pool)     # (T_pad, H, dv)
    # rows covered by no descriptor were never written by the kernel —
    # pin them to the contract's zero
    _, valid, _ = ref.ragged_token_meta(qs, ql, kl, t)
    return jnp.where(valid[:, None, None], out[:t], 0)


def attention_kv_bytes(skv: int, kvh: int, dk: int, dv: int, *,
                       kv_bits: int = 16, fused: bool = True,
                       batch: int = 1, groups: int = 1) -> int:
    """Analytic HBM bytes touched for the KV operands of one attention call.

    ``fused``: codes are DMA'd once and dequantized in VMEM (this module).
    ``not fused``: the dequantize-then-attend pipeline, staged uniformly —
    [int8 only] dequant pass reads the codes and writes a bf16 copy;
    [groups > 1 only] the repeat reads that copy and writes it ``groups``x;
    attention then reads whatever the last stage produced.
    """
    elems = batch * skv * kvh * (dk + dv)
    code_bytes = kv_bits // 8
    if fused:
        return elems * code_bytes
    bf16 = 2
    total, cur = 0, code_bytes
    if kv_bits < 16:
        total += code_bytes + bf16     # dequant: read codes, write bf16 copy
        cur = bf16
    if groups > 1:
        total += cur + bf16 * groups   # repeat: read copy, write groups x
        cur = bf16 * groups
    return elems * (total + cur)       # + the attention read itself


def quantize_act(x: jax.Array, n: int, bits: int = 8,
                 unsigned: bool = False) -> jax.Array:
    """Elementwise Eq.-1 quantization of an activation tensor."""
    *batch, c = x.shape
    rows = 1
    for d in batch:
        rows *= d
    if rows < 8 or c < 128:
        return ref.quantize_ref(x, n=n, bits=bits, unsigned=unsigned)
    x2 = x.reshape(rows, c)
    br, bc = min(256, rows), min(512, c)
    x2 = _pad_to(_pad_to(x2, br, 0), bc, 1)
    call = make_quantize(x2.shape[0], x2.shape[1], br=br, bc=bc, n=n,
                         bits=bits, unsigned=unsigned,
                         interpret=use_interpret())
    return call(x2)[:rows, :c].reshape(*batch, c)


def residual_requant(a_int: jax.Array, b_int: jax.Array, *, n_a: int,
                     n_b: int, n_o: int, bits: int = 8,
                     relu: bool = False) -> jax.Array:
    """Fused Fig. 1(c)/(d) residual add on int8 codes."""
    assert a_int.shape == b_int.shape
    *batch, c = a_int.shape
    rows = 1
    for d in batch:
        rows *= d
    if rows < 8 or c < 128:
        return ref.residual_requant_ref(a_int, b_int, n_a=n_a, n_b=n_b,
                                        n_o=n_o, bits=bits, relu=relu)
    a2 = a_int.reshape(rows, c)
    b2 = b_int.reshape(rows, c)
    br, bc = min(256, rows), min(512, c)
    a2 = _pad_to(_pad_to(a2, br, 0), bc, 1)
    b2 = _pad_to(_pad_to(b2, br, 0), bc, 1)
    call = make_residual_requant(a2.shape[0], a2.shape[1], br=br, bc=bc,
                                 n_a=n_a, n_b=n_b, n_o=n_o, bits=bits,
                                 relu=relu, interpret=use_interpret())
    return call(a2, b2)[:rows, :c].reshape(*batch, c)
