"""Pallas TPU kernels for the paper's compute hot-spots.

Layout per scaffold convention:
  int8_matmul.py / quantize.py / residual_requant.py — pl.pallas_call bodies
  ops.py — jit'd public wrappers (padding, block choice, CPU interpret)
  ref.py — pure-jnp oracles used by the allclose tests
"""
from repro.kernels import ops, ref  # noqa: F401
