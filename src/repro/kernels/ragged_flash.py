"""Unified RAGGED paged flash attention: one pallas_call per mixed step.

The serving engine's step traffic is inherently mixed — some slots are
mid-prefill (a chunk of C tokens), some are decoding (1 token), some are
verifying a speculative tail (1 + K tokens).  Dispatching each class at
its own padded shape costs three executables per step, pow2 bucket
padding, and serialized phases (DESIGN §9/§11).  This kernel serves the
whole step in ONE dispatch over a FLATTENED token stream:

    q:   (T_pad, H, Dk)      — all live tokens of every class, packed
    out: (T_pad, H, Dv)
    per-sequence descriptors, scalar-prefetched like the paged decode
    kernel's (positions, block table):
      q_start (S,)      first stream row of sequence s
      q_len   (S,)      its token count this step (0 = padding slot)
      kv_len  (S,)      its TOTAL visible KV rows after this step
      block_tables (S, NBmax)  logical block -> pool block

Grid (H, S, NBmax): the head axis is parallel; the sequence and
block-table axes are sequential ("arbitrary") because every (s, ti)
step revisits the same (T_pad, 1, Dv) output block — Pallas keeps it
resident in VMEM for the whole sweep, and each sequence read-modify-
writes only its own disjoint row window, so the packed stream is
assembled in place.  The K/V index maps are the paged-decode gather
(``bt_ref[s, ti]`` — the block walk happens in the DMA engine), and the
int8 Eq.-1 codes dequantize in-register exactly as in
``flash_attention.py``: K's power-of-two scale folds into the softmax
scale, V's into the final normalization.

Causal masking is derived PER ROW from the descriptors instead of from
the operand shape: stream row ``q_start[s] + i`` is the token at
absolute position ``kv_len[s] - q_len[s] + i``, so

    mask[i, j] = (0 <= i < q_len[s]) and (kv_pos[j] <= position(i))

covers all three traffic classes with one formula — a decode row
(q_len=1) sees its whole context, a prefill chunk gets the staircase,
a speculative tail gets the staircase rooted at the committed context.

The q window per sequence is a STATIC ``tq`` rows wide (max per-sequence
q_len, padded to the sublane size), dynamically positioned with
``pl.ds`` and clamped to the stream end; rows of the window outside
``[q_start, q_start + q_len)`` are fully masked and their output write
is suppressed (read-modify-write keeps neighbouring sequences' rows).
Stream rows not covered by ANY descriptor are never written — the ops
wrapper zeroes them after the call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.flash_attention import _STATS_LANES, DEFAULT_MASK_VALUE

__all__ = ["make_ragged_paged_flash"]


def _ragged_paged_flash_kernel(qs_ref, ql_ref, kl_ref, bt_ref,
                               q_ref, k_ref, v_ref, o_ref,
                               m_scr, l_scr, acc_scr, *, score_scale: float,
                               v_scale: float, bs: int, nbmax: int, tq: int,
                               t_pad: int, out_dtype):
    """Grid (head, seq, ti).  Blocks: q/o (T_pad, 1, d) — the whole packed
    stream for one head, revisited across (seq, ti); k/v (1, bs, 1, d) —
    the pool block named by ``bt_ref[s, ti]``."""
    s_ = pl.program_id(1)
    ti = pl.program_id(2)
    qs = qs_ref[s_]
    ql = ql_ref[s_]
    kl = kl_ref[s_]
    # static-width q window, clamped so it never runs past the stream;
    # ``off`` is where the sequence's row 0 lands inside the window
    start = jnp.minimum(qs, t_pad - tq)
    off = qs - start

    @pl.when(ti == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, -jnp.inf, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    # skip table-tail trash blocks (ti*bs >= kv_len) and padding slots
    @pl.when(jnp.logical_and(ti * bs < kl, ql > 0))
    def _compute():
        q = q_ref[pl.ds(start, tq), 0, :]              # (tq, dk)
        k = k_ref[0, :, 0, :].astype(q.dtype)          # (bs, dk) pool block
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * score_scale

        # descriptor-derived causal mask: window row i is the sequence's
        # local token ``i - off`` at absolute position kv_len - q_len + local
        local = jax.lax.broadcasted_iota(jnp.int32, (tq, bs), 0) - off
        pos = kl - ql + local
        valid = jnp.logical_and(local >= 0, local < ql)
        kv_pos = ti * bs + jax.lax.broadcasted_iota(jnp.int32, (tq, bs), 1)
        s = jnp.where(jnp.logical_and(valid, kv_pos <= pos), s,
                      DEFAULT_MASK_VALUE)

        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_curr = jnp.max(s, axis=1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_curr)
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.exp(s - m_next)
        l_scr[...] = jnp.broadcast_to(
            l_prev * alpha + jnp.sum(p, axis=1, keepdims=True), l_scr.shape)
        m_scr[...] = jnp.broadcast_to(m_next, m_scr.shape)

        v = v_ref[0, :, 0, :].astype(q.dtype)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(q.dtype), v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ti == nbmax - 1)
    def _store():
        l = l_scr[:, :1]
        l_inv = jnp.where(l == 0.0, 1.0, 1.0 / l)
        vals = (acc_scr[...] * l_inv * v_scale).astype(out_dtype)
        local = jax.lax.broadcasted_iota(jnp.int32, (tq, 1), 0) - off
        valid = jnp.logical_and(local >= 0, local < ql)
        # masked read-modify-write: window rows of OTHER sequences (the
        # windows of adjacent short sequences overlap) keep their values
        cur = o_ref[pl.ds(start, tq), 0, :]
        o_ref[pl.ds(start, tq), 0, :] = jnp.where(valid, vals, cur)


def make_ragged_paged_flash(s: int, h: int, kvh: int, nbmax: int, bs: int,
                            t_pad: int, tq: int, dk_p: int, dv_p: int, *,
                            score_scale: float, v_scale: float, out_dtype,
                            interpret: bool = False):
    """Build the unified ragged pallas_call.

    Operands: q_start/q_len/kv_len (S,) + block_tables (S, NBmax), all
    int32 scalar-prefetch · q (T_pad, H, dk) · k/v POOL (NB, bs, KVH, d).
    Output (T_pad, H, dv) — packed like q; rows covered by no descriptor
    are left unwritten (the wrapper zeroes them).

    Contract (callers build descriptors host-side): ``q_start`` is
    nondecreasing with ``q_start + q_len <= t_pad`` per sequence, row
    windows ``[q_start, q_start + q_len)`` are pairwise disjoint, every
    ``q_len <= tq``, padding slots carry ``q_len == kv_len == 0`` with
    trash-block tables.  ``h``/``kvh`` are PER-SHARD counts under the §8
    shard_map wiring — whole GQA groups per shard, same as the other
    flash kernels.
    """
    assert kvh >= 1 and h % kvh == 0, (
        f"(per-shard) query heads ({h}) must be a positive multiple of "
        f"(per-shard) KV heads ({kvh})")
    assert 1 <= tq <= t_pad and tq % 8 == 0 and t_pad % 8 == 0, (
        f"q window {tq} must be a sublane multiple within the padded "
        f"stream {t_pad}")
    groups = h // kvh
    kernel = functools.partial(
        _ragged_paged_flash_kernel, score_scale=score_scale,
        v_scale=v_scale, bs=bs, nbmax=nbmax, tq=tq, t_pad=t_pad,
        out_dtype=out_dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(h, s, nbmax),
        in_specs=[
            pl.BlockSpec((t_pad, 1, dk_p),
                         lambda h_, s_, ti, qs, ql, kl, bt: (0, h_, 0)),
            pl.BlockSpec((1, bs, 1, dk_p),
                         lambda h_, s_, ti, qs, ql, kl, bt:
                         (bt[s_, ti], 0, h_ // groups, 0)),
            pl.BlockSpec((1, bs, 1, dv_p),
                         lambda h_, s_, ti, qs, ql, kl, bt:
                         (bt[s_, ti], 0, h_ // groups, 0)),
        ],
        out_specs=pl.BlockSpec((t_pad, 1, dv_p),
                               lambda h_, s_, ti, qs, ql, kl, bt: (0, h_, 0)),
        scratch_shapes=[
            pltpu.VMEM((tq, _STATS_LANES), jnp.float32),   # running max m
            pltpu.VMEM((tq, _STATS_LANES), jnp.float32),   # running sum l
            pltpu.VMEM((tq, dv_p), jnp.float32),           # output acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t_pad, h, dv_p), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
