"""Pallas TPU flash attention with FUSED int8-KV bit-shift dequantization.

The paper's thesis (DESIGN.md §1) is that every avoidable memory touch of a
full-precision tensor costs energy and information: its ASIC fuses the
requant unit between the MAC array and SRAM so un-requantized tensors never
reach memory.  ``int8_matmul.py`` realizes that for projections; this module
realizes it for attention — the dominant cost at long sequence and during
decode (DESIGN.md §2).

Dataflow (the whole point):

    HBM:   int8 KV codes ──DMA──▶ VMEM tile ──cast·2^-N (in-register)──▶ MXU
                                      │
           (the bf16 KV tensor never exists in HBM; previously the cache was
            dequantized to a full bf16 copy *before* attention, tripling KV
            read/write bytes, and the (B,H,qc,kc) score tiles round-tripped
            through HBM between the softmax and the PV matmul)

Dequantization of a power-of-two-grid code is ``x * 2^-N`` (Eq. 1 inverse)
with static ``N``:

  * K codes: the scalar folds into the softmax scale — the kernel computes
    ``(q @ K_codes^T) * (sm_scale * 2^-N_k)``; the cast int8→bf16 is exact
    (|code| <= 128 < 2^8) and happens on the VMEM tile.
  * V codes: the scalar folds into the final normalization —
    ``out = acc * 2^-N_v / l`` — exact because ``l`` depends only on ``p``.

Two grid variants:

  * **prefill**: grid (B, H, Sq/bq, Skv/bk), causal, online softmax with
    fp32 running (m, l, acc) in VMEM scratch, GQA via the K/V index map
    (``h // groups`` — no repeated KV is ever materialized).  KV tiles
    above the causal diagonal (and fully-padded tiles) are skipped.
  * **decode**: q_len == 1, grid (B, KVH, S/bk), the (scalar, traced)
    absolute position arrives via scalar prefetch; all ``groups`` query
    heads of one KV head ride in the sublane dimension of a single q tile,
    so a KV tile is DMA'd exactly once per group (GQA-aware).  KV tiles
    entirely in the future (``kv_start > pos``) are skipped.

Tiling follows the ``int8_matmul`` conventions: lane dim 128, fp32 scratch
persists across the innermost ("arbitrary") KV grid dimension, block shapes
are static and chosen by the ``ops.py`` wrapper which also pads inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["make_flash_prefill", "make_flash_decode",
           "make_paged_flash_decode", "DEFAULT_MASK_VALUE"]

# Finite stand-in for -inf: exp(MASK - m) underflows to exactly 0.0 in f32
# whenever any in-tile entry is live, and never produces inf - inf = NaN.
DEFAULT_MASK_VALUE = -0.7 * float(np.finfo(np.float32).max)

# m/l running statistics keep a full 128-lane register row (TPU lane width);
# only column 0 is semantically live, the rest is broadcast.
_STATS_LANES = 128


def _flash_prefill_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                          *, groups: int, score_scale: float, v_scale: float,
                          causal: bool, q_offset: int, sq: int, skv: int,
                          bq: int, bk: int, nk: int, out_dtype):
    """Grid (b, h, qi, ki), ki innermost.  Block shapes:
    q (1,bq,1,dk) · k (1,bk,1,dk) · v (1,bk,1,dv) · o (1,bq,1,dv)."""
    del groups, sq  # encoded in the index maps / wrapper slicing
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, -jnp.inf, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    # Tile-level skipping: causal tiles strictly above the diagonal and
    # fully-padded tiles contribute nothing — no DMA'd compute is wasted.
    kv_start = ki * bk
    run = kv_start < skv
    if causal:
        run = jnp.logical_and(run, kv_start <= q_offset + (qi + 1) * bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :]                          # (bq, dk)
        # int8 KV codes cast in-register; exact (|code| < 2^8 << bf16 mantissa)
        k = k_ref[0, :, 0, :].astype(q.dtype)          # (bk, dk)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * score_scale

        kv_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kv_pos < skv                            # padding mask
        if causal:
            q_pos = (q_offset + qi * bq
                     + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
            mask = jnp.logical_and(mask, kv_pos <= q_pos)
        s = jnp.where(mask, s, DEFAULT_MASK_VALUE)

        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_curr = jnp.max(s, axis=1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_curr)
        alpha = jnp.exp(m_prev - m_next)               # old-stats correction
        p = jnp.exp(s - m_next)                        # masked entries -> 0.0
        l_scr[...] = jnp.broadcast_to(
            l_prev * alpha + jnp.sum(p, axis=1, keepdims=True), l_scr.shape)
        m_scr[...] = jnp.broadcast_to(m_next, m_scr.shape)

        v = v_ref[0, :, 0, :].astype(q.dtype)          # (bk, dv)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(q.dtype), v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _store():
        l = l_scr[:, :1]
        l_inv = jnp.where(l == 0.0, 1.0, 1.0 / l)      # fully-masked rows
        o_ref[0, :, 0, :] = (acc_scr[...] * l_inv * v_scale).astype(out_dtype)


def make_flash_prefill(b: int, h: int, kvh: int, sq_p: int, skv_p: int,
                       dk_p: int, dv_p: int, *, bq: int, bk: int,
                       causal: bool, q_offset: int, sq: int, skv: int,
                       score_scale: float, v_scale: float, k_dtype,
                       out_dtype, interpret: bool = False):
    """Build the prefill pallas_call.

    Input layouts match the model's native (B, S, H, D) — the K/V index map
    contracts the GQA grouping (``h // groups``) so grouped heads read the
    same KV tile and nothing is repeated in HBM.  ``sq``/``skv`` are the
    true (unpadded) lengths; ``*_p`` the padded operand shapes.

    ``h``/``kvh`` are PER-SHARD counts: under the shard_map wiring
    (DESIGN §8) each device builds this call for its local slice of the
    head axis, so whole GQA groups must land on one shard — the wrapper
    partitions KV heads, never splits a group.
    """
    del k_dtype
    assert kvh >= 1 and h % kvh == 0, (
        f"(per-shard) query heads ({h}) must be a positive multiple of "
        f"(per-shard) KV heads ({kvh}): the shard_map wrapper may only "
        f"partition whole GQA groups across the tensor axis")
    groups = h // kvh
    nk = skv_p // bk
    kernel = functools.partial(
        _flash_prefill_kernel, groups=groups, score_scale=score_scale,
        v_scale=v_scale, causal=causal, q_offset=q_offset, sq=sq, skv=skv,
        bq=bq, bk=bk, nk=nk, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(b, h, sq_p // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, dk_p),
                         lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((1, bk, 1, dk_p),
                         lambda b_, h_, qi, ki: (b_, ki, h_ // groups, 0)),
            pl.BlockSpec((1, bk, 1, dv_p),
                         lambda b_, h_, qi, ki: (b_, ki, h_ // groups, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dv_p),
                               lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq_p, h, dv_p), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _STATS_LANES), jnp.float32),   # running max m
            pltpu.VMEM((bq, _STATS_LANES), jnp.float32),   # running sum l
            pltpu.VMEM((bq, dv_p), jnp.float32),           # output acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )


def _flash_decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, score_scale: float,
                         v_scale: float, bk: int, nk: int, out_dtype):
    """Grid (b, kv_head, ki).  One q tile carries all ``groups`` query heads
    of this KV head in its sublane dim — the KV tile is loaded once and
    shared (GQA-aware).  ``pos`` (absolute position of the new token) is a
    traced scalar delivered by scalar prefetch; KV tiles with
    ``kv_start > pos`` are skipped, so decode cost tracks the LIVE sequence
    length, not the allocated cache length."""
    ki = pl.program_id(2)
    pos = pos_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, -jnp.inf, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    @pl.when(ki * bk <= pos)
    def _compute():
        q = q_ref[0, 0]                                # (gp, dk)
        k = k_ref[0, :, 0, :].astype(q.dtype)          # (bk, dk)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * score_scale

        gp = q.shape[0]
        kv_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (gp, bk), 1)
        s = jnp.where(kv_pos <= pos, s, DEFAULT_MASK_VALUE)

        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_curr = jnp.max(s, axis=1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_curr)
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.exp(s - m_next)
        l_scr[...] = jnp.broadcast_to(
            l_prev * alpha + jnp.sum(p, axis=1, keepdims=True), l_scr.shape)
        m_scr[...] = jnp.broadcast_to(m_next, m_scr.shape)

        v = v_ref[0, :, 0, :].astype(q.dtype)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(q.dtype), v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _store():
        l = l_scr[:, :1]
        l_inv = jnp.where(l == 0.0, 1.0, 1.0 / l)
        o_ref[0, 0] = (acc_scr[...] * l_inv * v_scale).astype(out_dtype)


def make_flash_decode(b: int, kvh: int, gp: int, s_max: int, dk_p: int,
                      dv_p: int, *, bk: int, score_scale: float,
                      v_scale: float, out_dtype, interpret: bool = False):
    """Build the decode pallas_call.

    Operands: pos (1,) int32 scalar-prefetch · q (B, KVH, gp, dk) ·
    k/v (B, S_max, KVH, d) — the cache's native layout, indexed in place
    (no transpose, no dequantized copy).  ``gp`` is the GQA group count
    padded to the sublane minimum.  ``kvh`` is the PER-SHARD KV head count
    under the shard_map wiring (DESIGN §8); the group structure is
    shard-invariant, so ``gp`` needs no per-shard adjustment.
    """
    assert kvh >= 1 and gp >= 1, (
        f"(per-shard) decode needs at least one KV head and one group "
        f"(got kvh={kvh}, gp={gp}) — the shard_map wrapper must not "
        f"over-partition the head axis")
    nk = s_max // bk
    kernel = functools.partial(
        _flash_decode_kernel, score_scale=score_scale, v_scale=v_scale,
        bk=bk, nk=nk, out_dtype=out_dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, gp, dk_p),
                         lambda b_, h_, ki, pos_ref: (b_, h_, 0, 0)),
            pl.BlockSpec((1, bk, 1, dk_p),
                         lambda b_, h_, ki, pos_ref: (b_, ki, h_, 0)),
            pl.BlockSpec((1, bk, 1, dv_p),
                         lambda b_, h_, ki, pos_ref: (b_, ki, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, dv_p),
                               lambda b_, h_, ki, pos_ref: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gp, _STATS_LANES), jnp.float32),
            pltpu.VMEM((gp, _STATS_LANES), jnp.float32),
            pltpu.VMEM((gp, dv_p), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, gp, dv_p), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )


def _paged_flash_decode_kernel(pos_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                               m_scr, l_scr, acc_scr, *, score_scale: float,
                               v_scale: float, bs: int, nb: int, out_dtype):
    """Grid (slot, kv_head, ti).  Identical online softmax to the dense
    decode kernel, except (a) the KV tile for grid step ``ti`` is whatever
    POOL BLOCK the slot's block table names (the index map reads
    ``bt_ref[b, ti]`` — the gather happens in the DMA engine, no gathered
    copy ever exists in HBM), and (b) the mask position is PER-SLOT
    (``pos_ref[b]``), which is what makes continuous batching work: every
    slot in the fixed-width batch decodes at its own sequence length."""
    del bt_ref  # consumed by the index maps
    b = pl.program_id(0)
    ti = pl.program_id(2)
    pos = pos_ref[b]

    @pl.when(ti == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, -jnp.inf, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    @pl.when(ti * bs <= pos)
    def _compute():
        q = q_ref[0, 0]                                # (gp, dk)
        k = k_ref[0, :, 0, :].astype(q.dtype)          # (bs, dk) pool block
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * score_scale

        gp = q.shape[0]
        kv_pos = ti * bs + jax.lax.broadcasted_iota(jnp.int32, (gp, bs), 1)
        s = jnp.where(kv_pos <= pos, s, DEFAULT_MASK_VALUE)

        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_curr = jnp.max(s, axis=1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_curr)
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.exp(s - m_next)
        l_scr[...] = jnp.broadcast_to(
            l_prev * alpha + jnp.sum(p, axis=1, keepdims=True), l_scr.shape)
        m_scr[...] = jnp.broadcast_to(m_next, m_scr.shape)

        v = v_ref[0, :, 0, :].astype(q.dtype)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(q.dtype), v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ti == nb - 1)
    def _store():
        l = l_scr[:, :1]
        l_inv = jnp.where(l == 0.0, 1.0, 1.0 / l)
        o_ref[0, 0] = (acc_scr[...] * l_inv * v_scale).astype(out_dtype)


def make_paged_flash_decode(b: int, kvh: int, gp: int, nbmax: int, bs: int,
                            dk_p: int, dv_p: int, *, score_scale: float,
                            v_scale: float, out_dtype,
                            interpret: bool = False):
    """Build the PAGED decode pallas_call (serving engine, DESIGN §9).

    Operands: pos (B,) int32 + block_tables (B, nbmax) int32 (both
    scalar-prefetch) · q (B, KVH, gp, dk) · k/v POOL (NB, bs, KVH, d) — the
    block pool's native layout.  ``nbmax`` is the per-sequence block-table
    width (grid's KV extent), ``bs`` the pool block size; the K/V index
    maps translate grid step ``ti`` to pool block ``bt[b, ti]``, so the
    kernel walks each slot's logical sequence through physically scattered
    blocks with zero gather/copy.  Unallocated table tail entries point at
    the pool's trash block; their tiles are masked by ``pos`` exactly like
    the dense kernel masks the cache tail.  ``kvh`` is the PER-SHARD KV
    head count under shard_map (pool head-sharded, tables/positions
    replicated across the tensor axis — DESIGN §9)."""
    assert kvh >= 1 and gp >= 1, (
        f"(per-shard) paged decode needs at least one KV head and one "
        f"group (got kvh={kvh}, gp={gp})")
    kernel = functools.partial(
        _paged_flash_decode_kernel, score_scale=score_scale, v_scale=v_scale,
        bs=bs, nb=nbmax, out_dtype=out_dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, nbmax),
        in_specs=[
            pl.BlockSpec((1, 1, gp, dk_p),
                         lambda b_, h_, ti, pos_ref, bt_ref: (b_, h_, 0, 0)),
            pl.BlockSpec((1, bs, 1, dk_p),
                         lambda b_, h_, ti, pos_ref, bt_ref:
                         (bt_ref[b_, ti], 0, h_, 0)),
            pl.BlockSpec((1, bs, 1, dv_p),
                         lambda b_, h_, ti, pos_ref, bt_ref:
                         (bt_ref[b_, ti], 0, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, dv_p),
                               lambda b_, h_, ti, pos_ref, bt_ref:
                               (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gp, _STATS_LANES), jnp.float32),
            pltpu.VMEM((gp, _STATS_LANES), jnp.float32),
            pltpu.VMEM((gp, dv_p), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, gp, dv_p), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
