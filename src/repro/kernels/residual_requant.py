"""Pallas TPU kernel: fused residual add + grid alignment + requantization.

Paper Fig. 1(c)/(d): the shortcut and branch arrive as int8 codes on
different power-of-two grids (n_a, n_b).  Both are left-shifted onto the
finer common grid in int32 (exact), added, optionally ReLU'd (case c), and
requantized with ONE shift — a single fused elementwise pass instead of
three (dequant, add, quant), and the int32 sum never reaches HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["residual_requant_kernel", "make_residual_requant"]


def residual_requant_kernel(a_ref, b_ref, o_ref, *, sa: int, sb: int,
                            shift: int, relu: bool, lo: int, hi: int,
                            out_dtype):
    acc = (a_ref[...].astype(jnp.int32) << sa) + \
          (b_ref[...].astype(jnp.int32) << sb)
    if relu:
        acc = jnp.maximum(acc, 0)
    if shift > 0:
        half = 1 << (shift - 1)
        acc = jnp.where(acc >= 0, (acc + half) >> shift,
                        -(((-acc) + half) >> shift))
    elif shift < 0:
        acc = acc << (-shift)
    o_ref[...] = jnp.clip(acc, lo, hi).astype(out_dtype)


def make_residual_requant(rows: int, cols: int, *, br: int, bc: int,
                          n_a: int, n_b: int, n_o: int, bits: int = 8,
                          relu: bool = False, interpret: bool = False):
    n_hi = max(n_a, n_b)
    unsigned = relu
    lo, hi = (0, (1 << bits) - 1) if unsigned else (-(1 << (bits - 1)),
                                                    (1 << (bits - 1)) - 1)
    out_dtype = jnp.uint8 if unsigned else jnp.int8
    kernel = functools.partial(
        residual_requant_kernel, sa=n_hi - n_a, sb=n_hi - n_b,
        shift=n_hi - n_o, relu=relu, lo=lo, hi=hi, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(rows // br, cols // bc),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                  pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), out_dtype),
        interpret=interpret,
    )
