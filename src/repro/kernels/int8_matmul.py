"""Pallas TPU kernel: int8 x int8 -> int32 matmul with a FUSED bit-shift
requantization epilogue — the paper's requant unit realized in VMEM.

This is the hardware-adaptation centerpiece (DESIGN.md §2): on the paper's
ASIC, the requant unit sits between the MAC array and SRAM so the un-requantized
int32 tensor never reaches memory.  On TPU the analogue is fusing the shift /
round / clip (and the Fig. 1(b) ReLU sign-check, and the Eq. 3 bias align)
into the matmul kernel's epilogue while the accumulator tile is still in
VMEM — the int32 tensor never reaches HBM, quartering the output writeback
bytes and removing a separate elementwise kernel launch.

Tiling: grid (M/bm, N/bn, K/bk) with K innermost ("arbitrary" semantics);
the int32 accumulator tile lives in a VMEM scratch buffer across K steps.
MXU alignment: bm/bn/bk multiples of 128 when shapes allow (int8 MXU packs
32x128x128); the ops.py wrapper pads otherwise.

W8A8 serving (DESIGN §13): every qlinear module of the engine forward
routes here through ``ops.int8_matmul`` when ``cfg.matmul_kernel='int8'``
— all shift amounts come from the calibrated ``LinearQuantSpec`` and are
compile-time constants, so one specialization per module shape serves
the whole run (and shards unchanged under §8 shard_map).  On interpret-
mode backends the wrapper runs ``integer_ops.int_linear`` instead (bit-
exact, no Python-loop overhead); kernel tests force the body with
``force_kernel=True``.  Zero-padded tiles are proven leak-free through
both bias-align shift signs in ``tests/test_kernels.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["int8_matmul_kernel", "make_int8_matmul"]


def _shift_requant_i32(acc: jax.Array, shift: int, lo: int, hi: int) -> jax.Array:
    """Static-shift requant: round-half-away right shift + clip, int math only."""
    if shift > 0:
        half = 1 << (shift - 1)
        acc = jnp.where(acc >= 0, (acc + half) >> shift,
                        -(((-acc) + half) >> shift))
    elif shift < 0:
        # negative shift = LEFT shift: saturate BEFORE shifting.  int32 <<
        # wraps silently, so an accumulator past 2^31 / 2^|shift| would
        # sign-flip straight through the clip below; clamping to the
        # largest magnitude that shifts exactly keeps the result on the
        # saturating side (the clamped value already maps >= hi / <= lo
        # for any sub-int32 output window).
        bound = (2**31 - 1) >> (-shift)
        acc = jnp.clip(acc, -bound, bound) << (-shift)
    return jnp.clip(acc, lo, hi)


def int8_matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *,
                       nk: int, shift: int, bias_shift: int,
                       relu: bool, lo: int, hi: int, out_dtype):
    """Grid = (i: M tiles, j: N tiles, k: K tiles), K innermost.

    b_ref holds the int8 bias codes; the Eq. 3 left-shift alignment
    ``b << ((N_x + N_w) - N_b)`` happens here in int32, once per (i, j) tile.
    """
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        acc = acc_ref[...]
        if b_ref is not None:
            b = b_ref[...].astype(jnp.int32)
            if bias_shift >= 0:
                b = b << bias_shift
            else:
                # negative bias_shift: the bias grid is FINER than the
                # accumulator grid, so drop low bits with a rounding
                # right-shift by |bias_shift| (Eq. 3, "sacrificing smaller
                # values").
                b = _shift_requant_i32(b, -bias_shift, -(2**31), 2**31 - 1)
            acc = acc + b
        if relu:
            acc = jnp.maximum(acc, 0)  # Fig. 1(b): sign check pre-requant
        o_ref[...] = _shift_requant_i32(acc, shift, lo, hi).astype(out_dtype)


def make_int8_matmul(m: int, k: int, n: int, *, bm: int, bk: int, bn: int,
                     shift: int, bias_shift: int = 0, relu: bool = False,
                     lo: int = -128, hi: int = 127, has_bias: bool = False,
                     out_dtype=jnp.int8, interpret: bool = False):
    """Build the pallas_call for an (m, k) x (k, n) int8 matmul.

    All quantization constants are *static* (they are deploy-time shift
    amounts, per the paper's artifact split), so the epilogue compiles to
    immediate shifts — no scalar memory traffic.
    """
    nk = k // bk
    kernel = functools.partial(
        int8_matmul_kernel, nk=nk, shift=shift, bias_shift=bias_shift,
        relu=relu, lo=lo, hi=hi, out_dtype=out_dtype)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        wrapped = kernel
    else:
        def wrapped(x_ref, w_ref, o_ref, acc_ref):
            return kernel(x_ref, w_ref, None, o_ref, acc_ref)

    return pl.pallas_call(
        wrapped,
        grid=(m // bm, n // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
