"""Core contribution of the paper: dataflow-based joint quantization.

  qscheme     — Eq. 1 power-of-two quantization (+ STE variant)
  integer_ops — Eq. 2-4 integer-only linear/conv/residual ops
  dataflow    — Fig. 1 unified-module construction over a layer graph
  calibrate   — Algorithm 1 grid-search calibration (no fine-tuning)
  qmodel      — execution modes (fp / fake / int) + weight conversion
  hwcost      — Table 5 analytical hardware-cost model
"""
from repro.core.qscheme import (QuantParams, fake_quant, fake_quant_ste,
                                quant, dequant, shift_requant)  # noqa: F401
from repro.core.dataflow import (OpKind, OpNode, UnifiedModule, QuantPlan,
                                 build_plan, QuantizedTensor)  # noqa: F401
from repro.core.qmodel import QuantMode, QuantContext, ModuleBits, qlinear  # noqa: F401
