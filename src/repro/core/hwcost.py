"""Analytical hardware-cost model — reproduces the paper's Table 5 comparison.

The paper synthesized RTL units (UMC 40nm, 500 MHz, 32-bit input / 8-bit
output) for the three requantization mechanisms.  No synthesis flow exists
offline, so we *seed* the model with the paper's measured constants and
combine them with quantization-op counts extracted from our graphs/HLO.
Energy per op = power / frequency (one requant per cycle, as in the paper's
throughput-normalized comparison).

Measured constants (paper Table 5):

    op type          power(mW)   area(um^2)
    scaling factor   30.6        502.7
    codebook         228.8       1787.6
    bit-shifting     15.5        198.2

Derived: bit-shift is ~2x cheaper than scaling factor, ~14.8x power /
~9.0x area cheaper than codebook — matching the abstract's ~15x / ~9x claim.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

__all__ = [
    "QuantOpCost",
    "TABLE5",
    "CLOCK_HZ",
    "energy_per_op_pj",
    "energy_uj",
    "HardwareReport",
    "estimate",
    "memory_access_bytes",
    "forward_quant_ops_per_token",
]

CLOCK_HZ = 500e6  # paper's synthesis clock


@dataclasses.dataclass(frozen=True)
class QuantOpCost:
    name: str
    power_mw: float
    area_um2: float

    @property
    def energy_pj(self) -> float:
        """pJ per requantization op at the synthesis clock."""
        return self.power_mw * 1e-3 / CLOCK_HZ * 1e12


TABLE5: Mapping[str, QuantOpCost] = {
    "scaling_factor": QuantOpCost("scaling_factor", 30.6, 502.7),
    "codebook": QuantOpCost("codebook", 228.8, 1787.6),
    "bit_shifting": QuantOpCost("bit_shifting", 15.5, 198.2),
}


def energy_per_op_pj(kind: str) -> float:
    return TABLE5[kind].energy_pj


def energy_uj(kind: str, n_quant_ops: int) -> float:
    """Total requant energy in uJ for ``n_quant_ops`` ops of ``kind`` —
    the scalar the live obs gauges read at every snapshot (DESIGN §14),
    without building a full :class:`HardwareReport` per read."""
    return TABLE5[kind].energy_pj * n_quant_ops * 1e-6


@dataclasses.dataclass
class HardwareReport:
    kind: str
    n_quant_ops: int          # element-wise requantizations executed
    energy_uj: float          # total requant energy
    area_um2: float           # one requant unit's area (per-PE overhead)
    vs_bit_shift_energy: float

    def row(self) -> str:
        return (f"{self.kind},{self.n_quant_ops},{self.energy_uj:.3f},"
                f"{self.area_um2:.1f},{self.vs_bit_shift_energy:.2f}x")


def estimate(kind: str, n_quant_ops: int) -> HardwareReport:
    """Energy/area of executing ``n_quant_ops`` requantizations with a unit
    of the given kind."""
    c = TABLE5[kind]
    ref = TABLE5["bit_shifting"]
    return HardwareReport(
        kind=kind,
        n_quant_ops=n_quant_ops,
        energy_uj=energy_uj(kind, n_quant_ops),
        area_um2=c.area_um2,
        vs_bit_shift_energy=c.energy_pj / ref.energy_pj,
    )


def forward_quant_ops_per_token(cfg) -> int:
    """Per-token quantization ops of a W8A8 dense-transformer forward.

    Extends the Table-5 accounting from the KV path to the full forward
    (DESIGN §13).  Counts only the DYNAMIC per-token ops the requant unit
    executes at serve time: the Eq.-1 activation quantization at each
    unified-module input boundary plus the fused Eq.-5 bit-shift
    requantization of each module's int32 output.  Weight and bias codes
    are produced once at engine build (:func:`repro.core.qmodel.quantize_params`)
    and amortize to zero per token; KV-cache quantization is counted
    separately by the engine's existing KV counters.

    Per layer (GQA dims): inputs to wq/wk/wv (3*d_model, the shared
    post-norm activation is quantized once per projection — each module
    has its own N_x grid), wo (n_heads*head_dim), w1/w3 (2*d_model) and
    w2 (d_ff); outputs of wq (n_heads*head_dim), wk/wv
    (2*n_kv_heads*head_dim), wo (d_model), w1/w3 (2*d_ff) and w2
    (d_model).  Plus the lm_head boundary: d_model in, vocab_padded out.
    """
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    q_dim = cfg.n_heads * hd
    kv_dim = cfg.n_kv_heads * hd
    act_in = 3 * d + q_dim + 2 * d + cfg.d_ff
    requant_out = q_dim + 2 * kv_dim + d + 2 * cfg.d_ff + d
    head = d + cfg.vocab_padded
    return cfg.n_layers * (act_in + requant_out) + head


def state_quant_ops_per_step(cfg) -> int:
    """Eq.-1 quantization ops to re-quantize ONE sequence's ENTIRE
    recurrent state once, i.e. per engine step on the fixed-slab
    substrate (DESIGN §16).

    This is the recurrent counterpart of the per-token KV write: a
    transformer quantizes ``n_layers * n_kv_heads * head_dim * 2`` new
    elements per token and the cost of touching the cache grows with
    context; a recurrent layer re-quantizes its fixed-size state slab
    once per step, so the per-step cost is CONTEXT-FREE.  Counted
    whether the slab is stored int8 (ops performed) or fp32 (the same
    ops as the counterfactual ``avoided`` bucket), so
    ``requant_ops_per_token`` compares across storage modes.

    RWKV6 per layer: the (H, 64, 64) wkv matrix plus the two d_model
    token-shift rows.  Mamba2 per layer: the (H, P, N) SSD state plus
    the (d_conv-1, d_conv_in) rolling conv window.  Hybrid stacks count
    the Mamba slab for every layer (the shared attention block's KV is
    on the ordinary per-token accounting).  Zero on pure attention.
    """
    s = cfg.ssm
    if s is None:
        return 0
    if s.kind == "rwkv6":
        n_heads = cfg.d_model // 64            # HEAD_DIM = 64
        return cfg.n_layers * (n_heads * 64 * 64 + 2 * cfg.d_model)
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    d_conv_in = d_inner + 2 * s.n_groups * s.d_state
    per_layer = (n_heads * s.head_dim * s.d_state
                 + (s.d_conv - 1) * d_conv_in)
    return cfg.n_layers * per_layer


def memory_access_bytes(n_elements: int, bits: int) -> int:
    """Storage/traffic for one tensor — the paper's ~4x memory-access claim
    (8-bit vs fp32) falls out of bits/32."""
    return n_elements * bits // 8
