"""Competing quantization mechanisms the paper compares against.

* ``scale_quant``  — arbitrary-float per-tensor scale (TensorRT / IOA
  style): int8 codes + one fp32 multiplier per tensor.  Better range fit
  than power-of-two, but the requant unit needs a 32-bit multiplier
  (Table 5: ~2x the bit-shifter's power/area).
* ``codebook_quant`` — k-means codebook (Deep Compression style): 4-bit
  indices into a 16-entry fp table.  Best compression, but the
  encode/decode unit costs ~15x power (Table 5).

Both are implemented faithfully enough to reproduce the accuracy columns of
Tables 1/3; hwcost.py carries their measured hardware constants.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["scale_quant", "codebook_quant"]


def scale_quant(x: jax.Array, bits: int = 8) -> jax.Array:
    """Symmetric per-tensor float-scale fake quantization."""
    hi = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / hi
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -hi - 1, hi)
    return (q * scale).astype(x.dtype)


def codebook_quant(x: jax.Array, bits: int = 4, iters: int = 10,
                   seed: int = 0) -> jax.Array:
    """k-means codebook fake quantization (2^bits entries, Lloyd's)."""
    flat = np.asarray(x, np.float32).ravel()
    k = 1 << bits
    rng = np.random.default_rng(seed)
    # init centroids at quantiles (stable for heavy-tailed weights)
    centroids = np.quantile(flat, np.linspace(0.01, 0.99, k))
    for _ in range(iters):
        idx = np.argmin(np.abs(flat[:, None] - centroids[None, :]), axis=1)
        for j in range(k):
            sel = flat[idx == j]
            if sel.size:
                centroids[j] = sel.mean()
    idx = np.argmin(np.abs(flat[:, None] - centroids[None, :]), axis=1)
    out = centroids[idx].reshape(np.asarray(x).shape)
    return jnp.asarray(out, dtype=x.dtype)
