"""Quantized-execution context — how the paper's scheme enters the models.

Execution modes (selectable per run, identical module structure):

* ``fp``    — bf16/fp32 reference; quant machinery compiled out.
* ``fake``  — Eq. (1) in float arithmetic at every planned quant point.
    Used by calibration and CPU accuracy benches.  (QAT variant adds STE.)
* ``int``   — deploy path: int8 weight codes, activations quantized at
    unified-module boundaries, int8 x int8 -> int32 matmuls, single
    bit-shift requantization per module (Pallas kernel on TPU, jnp
    reference otherwise).

The context carries the calibration table (module name -> fractional bits).
Uncalibrated modules fall back to ``default_n`` bits chosen by the Eq.-6
max-heuristic at conversion time — this keeps the dry-run path static.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.core import integer_ops
from repro.core.calibrate import CalibrationReport
from repro.core.qscheme import QuantParams, fake_quant, quant, dequant

__all__ = ["QuantMode", "ModuleBits", "QuantContext", "qlinear",
           "quantize_weight_tree", "QuantizedParams", "quantize_params",
           "module_name_for_path",
           "DEFAULT_N_W", "DEFAULT_N_X", "DEFAULT_N_O"]

# Static fall-back fractional bits (paper Fig. 2b: chosen shifts cluster
# around 3 and 8 for weights/activations on ResNet-50; transformer weights
# are ~N(0, 0.02) so n_w=8 keeps |w|<0.5 in range; activations post-norm are
# O(1..10) so n_x=4).
DEFAULT_N_W = 8
DEFAULT_N_X = 4
DEFAULT_N_O = 4


class QuantMode(enum.Enum):
    FP = "fp"
    FAKE = "fake"        # paper's bit-shift scheme, float arithmetic
    FAKE_SF = "fake_sf"  # scaling-factor baseline (IOA/TensorRT-style W8A8)
    INT = "int"


@dataclasses.dataclass(frozen=True)
class ModuleBits:
    """Calibrated fractional bits for one unified module."""

    n_x: int = DEFAULT_N_X
    n_w: int = DEFAULT_N_W
    n_b: Optional[int] = None
    n_o: int = DEFAULT_N_O
    out_unsigned: bool = False


@dataclasses.dataclass(frozen=True)
class QuantContext:
    """Static quantization configuration threaded through a model's forward.

    Hashable/static so it can be a jit static argument; the table is a
    frozen mapping of module name -> ModuleBits.
    """

    mode: QuantMode = QuantMode.FP
    bits: int = 8
    table: Mapping[str, ModuleBits] = dataclasses.field(default_factory=dict)

    def __hash__(self):
        return hash((self.mode, self.bits, tuple(sorted(self.table.items(),
                                                        key=lambda kv: kv[0]))))

    def __eq__(self, other):
        return (isinstance(other, QuantContext)
                and (self.mode, self.bits) == (other.mode, other.bits)
                and dict(self.table) == dict(other.table))

    def bits_for(self, name: str) -> ModuleBits:
        return self.table.get(name, ModuleBits())

    @classmethod
    def from_report(cls, mode: QuantMode, report: CalibrationReport,
                    bits: int = 8) -> "QuantContext":
        table = {}
        for name, r in report.results.items():
            table[name] = ModuleBits(
                n_x=DEFAULT_N_X, n_w=r.n_w if r.n_w is not None else DEFAULT_N_W,
                n_b=r.n_b, n_o=r.n_o)
        return cls(mode=mode, bits=bits, table=table)


def _fp_linear(x, w, b):
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# activation capture for Algorithm-1 calibration of LM modules
# ---------------------------------------------------------------------------
# Works under scan/jit via io_callback: each qlinear call streams its
# (input, weight, bias) to the host store; the FIRST occurrence per module
# name is kept (scanned layers share a name -> layer-0 calibrates the stack,
# matching the shared-bits scan constraint, DESIGN §3).

import contextlib
import threading

_CAPTURE = threading.local()


@contextlib.contextmanager
def capture_activations():
    store: dict[str, tuple] = {}
    prev = getattr(_CAPTURE, "store", None)
    _CAPTURE.store = store
    try:
        yield store
    finally:
        _CAPTURE.store = prev


def _maybe_capture(name: str, x, w, b):
    store = getattr(_CAPTURE, "store", None)
    if store is None:
        return

    def cb(xv, wv, bv):
        if name not in store:
            store[name] = (xv, wv, bv if b is not None else None)

    import jax.experimental
    jax.experimental.io_callback(
        cb, None, x, w, b if b is not None else jnp.zeros((), x.dtype),
        ordered=True)


def qlinear(ctx: QuantContext, name: str, x: jax.Array, w: jax.Array,
            b: Optional[jax.Array] = None, *, use_kernel: bool = True) -> jax.Array:
    """One unified-module linear op under the active quantization mode.

    ``x`` is a float activation at the module boundary; the return value is a
    float activation on the output grid (``fake``/``int``) or exact (``fp``).
    In ``int`` mode, ``w`` may already be int8 codes (from
    :func:`quantize_weight_tree`); float weights are quantized on the fly
    (dry-run convenience path).
    """
    if w.dtype == jnp.int8 and ctx.mode != QuantMode.INT:
        # pre-quantized codes are meaningless as float values — a fp/fake
        # forward over a quantize_params tree is a wiring bug, not a result.
        raise ValueError(
            f"module {name!r}: int8 weight codes reached the "
            f"{ctx.mode.value!r} path — QuantizedParams trees require INT "
            "mode (cfg.matmul_kernel='int8')")
    if ctx.mode == QuantMode.FP:
        _maybe_capture(name, x, w, b)
        return _fp_linear(x, w, b)

    if ctx.mode == QuantMode.FAKE_SF:
        # competing scheme (Table 1/3 baseline): per-tensor float scales on
        # weights AND activations — accuracy reference, costly requant HW.
        from repro.core.baselines import scale_quant
        return _fp_linear(scale_quant(x, ctx.bits),
                          scale_quant(w, ctx.bits).astype(x.dtype),
                          None if b is None else
                          scale_quant(b, ctx.bits).astype(x.dtype))

    mb = ctx.bits_for(name)
    if ctx.mode == QuantMode.FAKE:
        xq = fake_quant(x, mb.n_x, ctx.bits)
        wq = fake_quant(w, mb.n_w, ctx.bits).astype(x.dtype)
        bq = None if b is None else fake_quant(
            b, mb.n_b if mb.n_b is not None else mb.n_w, ctx.bits).astype(x.dtype)
        return _fp_linear(xq, wq, bq)

    # INT mode — integer-only math between the boundary casts.
    x_int = quant(x, mb.n_x, ctx.bits)
    w_int = w if w.dtype == jnp.int8 else quant(w, mb.n_w, ctx.bits)
    n_b = mb.n_b if mb.n_b is not None else mb.n_w
    b_int = None
    if b is not None:
        b_int = b if b.dtype == jnp.int8 else quant(b, n_b, ctx.bits)
    spec = integer_ops.LinearQuantSpec(
        n_x=mb.n_x, n_w=mb.n_w, n_b=n_b, n_o=mb.n_o, bits=ctx.bits)
    if use_kernel:
        # Pallas fused kernel when shapes allow; falls back to jnp reference.
        from repro.kernels import ops as kops
        o_int = kops.int8_matmul(x_int, w_int, b_int, spec)
    else:
        o_int = integer_ops.int_linear(x_int, w_int, b_int, spec)
    return dequant(o_int, mb.n_o, out_dtype=x.dtype)


def quantize_weight_tree(params: Any, ctx: QuantContext,
                         name_fn=None) -> Any:
    """Convert a pytree of float weights to int8 codes for the deploy path.

    Leaves whose path ends in a matmul weight (2-D+, name containing 'w' by
    default) become int8 codes on the grid from ctx.table (or DEFAULT_N_W).
    Norm gains / embeddings stay float (they are folded or boundary ops).
    """
    flat = jax.tree_util.tree_flatten_with_path(params)
    leaves, treedef = flat

    def path_name(path):
        return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)

    out = []
    for path, leaf in leaves:
        nm = path_name(path)
        is_weight = (isinstance(leaf, jax.Array) and leaf.ndim >= 2
                     and ("norm" not in nm) and ("embed" not in nm))
        if name_fn is not None:
            is_weight = name_fn(nm, leaf)
        if is_weight:
            mb = ctx.bits_for(nm)
            out.append(quant(leaf, mb.n_w, ctx.bits))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# W8A8 deploy containers (DESIGN §13)
# ---------------------------------------------------------------------------

def module_name_for_path(path_name: str, table: Mapping[str, ModuleBits]
                         ) -> Optional[str]:
    """Map a params-tree path to the qlinear module name the forward uses.

    Tree paths carry structural prefixes the calibration table does not
    ('blocks/attn/wq' vs the qlinear name 'attn/wq'); the longest path
    suffix present in the table is the module whose grid the forward will
    read at this weight.  None when no calibrated module matches — such
    leaves stay float and (in INT mode) quantize on the fly at defaults.
    """
    parts = path_name.split("/")
    for i in range(len(parts)):
        cand = "/".join(parts[i:])
        if cand in table:
            return cand
    return None


@dataclasses.dataclass(frozen=True)
class QuantizedParams:
    """Deploy-time weight container for ``cfg.matmul_kernel='int8'``.

    ``tree`` is the params pytree with matmul weights replaced by int8
    codes; the po2 exponents live in ``ctx.table`` (static, hashable —
    they become compile-time shift constants in the fused kernel, which
    is also why the §8 shard_map path needs no changes: codes shard
    exactly like their float counterparts and exponents ride along as
    kernel constants).  ``converted`` records which tree paths were
    quantized, for reporting and tests.
    """

    tree: Any
    ctx: QuantContext
    converted: tuple = ()


def quantize_params(params: Any, ctx: QuantContext) -> QuantizedParams:
    """Pre-quantize calibrated matmul weights to int8 codes (DESIGN §13).

    Codes are bit-identical to qlinear's on-the-fly ``quant(w, mb.n_w)``
    — the INT branch passes int8 weights through untouched, so a forward
    over the returned tree produces exactly the tokens of the float-weight
    INT forward while skipping the per-step weight quantization.  Only
    2-D+ leaves whose path maps onto a calibrated module convert;
    embeddings, norm gains and biases stay float (a tied lm_head reads
    ``embed.T`` and therefore also stays float, quantizing on the fly to
    the same codes).  Scanned stacks quantize the whole leading layer
    axis on the one shared grid, matching the scan constraint (DESIGN §3).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)

    def path_name(path):
        return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)

    out, converted = [], []
    for path, leaf in flat:
        nm = path_name(path)
        mod = module_name_for_path(nm, ctx.table)
        if (mod is not None and isinstance(leaf, jax.Array)
                and leaf.ndim >= 2 and leaf.dtype != jnp.int8
                and "embed" not in nm):
            mb = ctx.bits_for(mod)
            out.append(quant(leaf, mb.n_w, ctx.bits))
            converted.append(nm)
        else:
            out.append(leaf)
    return QuantizedParams(
        tree=jax.tree_util.tree_unflatten(treedef, out), ctx=ctx,
        converted=tuple(converted))
