"""Power-of-two ("bit-shift") quantization scheme — Eq. (1) of the paper.

    Q(r; N_r, n_bits) = clip(round(r * 2^{N_r}),
                             -2^{n_bits-1}, 2^{n_bits-1} - 1) * 2^{-N_r}

The scale is constrained to a power of two so that every dequantization /
requantization at inference is a bit shift with round-to-nearest — no
multipliers (scaling factors) and no codebooks.  ``N_r`` (the "fractional
bit") is the only parameter per tensor; it may be negative (then only digits
before the binary point are kept).

Three representations coexist:

* ``fake_quant(r, N, bits)``   — float-in/float-out Eq. (1); used during
  calibration (Algorithm 1) and for CPU accuracy evaluation.  Bit-exactly
  ``dequant(quant(r))``.
* ``quant(r, N, bits)``        — float → integer code (int8/int16/int32).
* ``dequant(q, N)``            — integer code → float.

All functions are jit/vmap/grad-safe.  ``fake_quant_ste`` attaches a
straight-through estimator for QAT (beyond-paper extension).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "QuantParams",
    "int_bounds",
    "quant",
    "dequant",
    "fake_quant",
    "fake_quant_ste",
    "max_frac_bits",
    "search_window",
    "round_half_away",
    "shift_requant",
]


@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Quantization parameters for one tensor (one unified-module edge).

    Attributes:
      n: fractional bit N_r — scale is 2**-n.  May be negative.
      bits: total bit width including the sign bit.
      unsigned: if True the integer range is [0, 2**bits - 1] (paper Fig. 1b:
        post-ReLU activations need no sign bit).
    """

    n: int
    bits: int = 8
    unsigned: bool = False

    @property
    def scale(self) -> float:
        return 2.0 ** (-self.n)

    def bounds(self) -> tuple[int, int]:
        return int_bounds(self.bits, self.unsigned)

    def storage_dtype(self):
        if self.bits <= 8:
            return jnp.uint8 if self.unsigned else jnp.int8
        if self.bits <= 16:
            return jnp.uint16 if self.unsigned else jnp.int16
        return jnp.uint32 if self.unsigned else jnp.int32


def int_bounds(bits: int, unsigned: bool = False) -> tuple[int, int]:
    """Integer clipping range for a given bit width (sign bit included)."""
    if unsigned:
        return 0, (1 << bits) - 1
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def round_half_away(x: jax.Array) -> jax.Array:
    """Round-to-nearest, ties away from zero (hardware ``round()`` semantics).

    The paper's RTL uses conventional rounding; jnp.round is banker's rounding
    (ties-to-even) which is NOT what a shift-and-add rounding unit does.
    """
    return jnp.trunc(x + jnp.where(x >= 0, 0.5, -0.5))


def quant(r: jax.Array, n: jax.Array | int, bits: int = 8,
          unsigned: bool = False, dtype=None) -> jax.Array:
    """Float → integer code: ``clip(round(r * 2^n))`` (the r^I of Eq. 1)."""
    lo, hi = int_bounds(bits, unsigned)
    scaled = r.astype(jnp.float32) * jnp.exp2(jnp.asarray(n, jnp.float32))
    q = jnp.clip(round_half_away(scaled), lo, hi)
    if dtype is None:
        dtype = QuantParams(0, bits, unsigned).storage_dtype()
    return q.astype(dtype)


def dequant(q: jax.Array, n: jax.Array | int, out_dtype=jnp.float32) -> jax.Array:
    """Integer code → float: ``q * 2^-n``."""
    return (q.astype(jnp.float32) * jnp.exp2(-jnp.asarray(n, jnp.float32))).astype(out_dtype)


def fake_quant(r: jax.Array, n: jax.Array | int, bits: int = 8,
               unsigned: bool = False) -> jax.Array:
    """Eq. (1) in float arithmetic: dequant(quant(r)). Shape/dtype preserving."""
    lo, hi = int_bounds(bits, unsigned)
    nf = jnp.asarray(n, jnp.float32)
    scaled = r.astype(jnp.float32) * jnp.exp2(nf)
    q = jnp.clip(round_half_away(scaled), lo, hi)
    return (q * jnp.exp2(-nf)).astype(r.dtype)


@jax.custom_vjp
def fake_quant_ste(r: jax.Array, n: jax.Array, bits: int = 8,
                   unsigned: bool = False) -> jax.Array:
    """fake_quant with a straight-through estimator (gradient passes where
    the input is inside the representable range, zero where clipped)."""
    return fake_quant(r, n, bits, unsigned)


def _fq_fwd(r, n, bits, unsigned):
    lo, hi = int_bounds(bits, unsigned)
    nf = jnp.asarray(n, jnp.float32)
    scaled = r.astype(jnp.float32) * jnp.exp2(nf)
    inside = (scaled >= lo) & (scaled <= hi)
    return fake_quant(r, n, bits, unsigned), inside


def _fq_bwd(residuals, g):
    inside = residuals
    return (jnp.where(inside, g, 0).astype(g.dtype), None, None, None)


fake_quant_ste.defvjp(_fq_fwd, _fq_bwd)


def max_frac_bits(x: jax.Array) -> jax.Array:
    """Eq. (6): N^max = ceil(log2(max|x| + 1)) + 1.

    This is the number of *integer* bits needed to represent max|x|; the
    corresponding fractional bit for an ``n_bits`` code is
    ``(n_bits - 1) - N^max`` (Algorithm 1 line 7).
    """
    m = jnp.max(jnp.abs(x.astype(jnp.float32)))
    return jnp.ceil(jnp.log2(m + 1.0)) + 1.0


def search_window(x: jax.Array, tau: int = 4) -> tuple[int, int]:
    """Algorithm 1 lines 3-5: the narrowed search window [N^max - tau, N^max].

    Returns concrete python ints (the calibration loop is host-side grid
    search, per the paper: optimization runs on a single batch in minutes).
    """
    nmax = int(jax.device_get(max_frac_bits(x)))
    return nmax - tau, nmax


def shift_requant(acc: jax.Array, shift: jax.Array | int, bits: int = 8,
                  unsigned: bool = False, dtype=None) -> jax.Array:
    """The paper's hardware requantization: int32 accumulator → n-bit code.

    ``shift = (N_x + N_w) - N_o`` (Eq. 3/4).  A *right* shift by ``shift``
    with round-to-nearest(-away) and clip.  ``shift`` may be negative (left
    shift), matching the RTL range [1, 10] study but not restricted to it.

    Implemented with integer arithmetic only so it is bit-exact with an RTL
    shifter: for s >= 0,  out = (acc + (1 << (s-1))·sign) >> s  — we express
    it via jnp ops that lower to integer adds/shifts.
    """
    lo, hi = int_bounds(bits, unsigned)
    acc = acc.astype(jnp.int32)
    s = jnp.asarray(shift, jnp.int32)

    def right_shift(a, s_):
        # round-to-nearest-away on a right shift: add half the LSB weight.
        half = jnp.where(s_ > 0, (jnp.int32(1) << jnp.maximum(s_ - 1, 0)), 0)
        rounded = jnp.where(a >= 0, a + half, -((-a) + half))
        # arithmetic shift on the magnitude-rounded value
        return jnp.where(
            a >= 0,
            rounded >> jnp.maximum(s_, 0),
            -((-rounded) >> jnp.maximum(s_, 0)),
        )

    # negative shift = LEFT shift: saturate BEFORE shifting — int32 <<
    # wraps silently, so an accumulator past 2^31 / 2^|shift| would
    # sign-flip straight through the clip below (kernel-identical fix in
    # kernels/int8_matmul.py::_shift_requant_i32)
    ls = jnp.maximum(-s, 0)
    bound = jnp.int32(2**31 - 1) >> ls
    left = jnp.clip(acc, -bound, bound) << ls
    shifted = jnp.where(s >= 0, right_shift(acc, s), left)
    out = jnp.clip(shifted, lo, hi)
    if dtype is None:
        dtype = QuantParams(0, bits, unsigned).storage_dtype()
    return out.astype(dtype)
