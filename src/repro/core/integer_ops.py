"""Integer-arithmetic-only inference ops — Eq. (2)-(4) of the paper.

The deploy path stores two artifact sets (paper §1.2):
  * integer tensors  X^I, W^I, B^I  (int8 codes, int32 accumulators), and
  * per-edge *shift amounts* (e.g. ``(N_x + N_w) - N_b`` for the bias align,
    ``(N_x + N_w) - N_o`` for the output requant) — not the raw fractional
    bits.

Every op here takes/returns integer codes; floats never appear on the math
path.  These are the jnp reference semantics; the Pallas kernels in
``repro.kernels`` implement the same contract with fused VMEM epilogues and
are asserted bit-identical in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qscheme import QuantParams, int_bounds, shift_requant

__all__ = [
    "LinearQuantSpec",
    "int_linear",
    "int_conv2d",
    "int_residual_add",
    "bias_align",
]


@dataclasses.dataclass(frozen=True)
class LinearQuantSpec:
    """Shift bookkeeping for one unified module (Eq. 3).

    n_x, n_w, n_b, n_o are fractional bits of input, weight, bias, output.
    Derived hardware shifts:
      bias_shift   = (n_x + n_w) - n_b   (left-shift bias into the int32 acc)
      requant_shift= (n_x + n_w) - n_o   (right-shift acc into the n-bit code)
    """

    n_x: int
    n_w: int
    n_b: int
    n_o: int
    bits: int = 8
    out_unsigned: bool = False  # Fig. 1(b): post-ReLU output is unsigned

    @property
    def bias_shift(self) -> int:
        return (self.n_x + self.n_w) - self.n_b

    @property
    def requant_shift(self) -> int:
        return (self.n_x + self.n_w) - self.n_o


def bias_align(b_int: jax.Array, bias_shift: int) -> jax.Array:
    """Align an int8 bias code with the int32 accumulator grid (Eq. 3).

    The paper "carefully aligns biases with the convolution output by
    sacrificing smaller values": the int8 bias is *left*-shifted by
    ``(N_x + N_w) - N_b`` (which is >= 0 whenever the bias precision window
    sits above the accumulator LSB; negative shifts drop low bits).
    """
    b = b_int.astype(jnp.int32)
    s = jnp.asarray(bias_shift, jnp.int32)
    return jnp.where(s >= 0, b << jnp.maximum(s, 0),
                     shift_requant(b, jnp.maximum(-s, 0), bits=32))


def int_linear(x_int: jax.Array, w_int: jax.Array, b_int: Optional[jax.Array],
               spec: LinearQuantSpec, apply_relu: bool = False) -> jax.Array:
    """Integer-only linear layer: int8 x @ int8 w -> int32 -> shift -> int8.

    x_int: (..., K) int8 codes, w_int: (K, N) int8 codes, b_int: (N,) int8.
    ``apply_relu`` realizes Fig. 1(b): ReLU on the int32 accumulator (sign
    check only — free in hardware) *before* the single requantization, so the
    intermediate activation never exists in memory.
    """
    # upcast to int32 for the reference op: keeps exactness and supports
    # unsigned (post-ReLU) input codes; the Pallas kernel keeps int8 operands.
    acc = jax.lax.dot_general(
        x_int.astype(jnp.int32), w_int.astype(jnp.int32),
        dimension_numbers=(((x_int.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    if b_int is not None:
        acc = acc + bias_align(b_int, spec.bias_shift)
    if apply_relu:
        acc = jnp.maximum(acc, 0)
    return shift_requant(acc, spec.requant_shift, bits=spec.bits,
                         unsigned=spec.out_unsigned and apply_relu)


def int_conv2d(x_int: jax.Array, w_int: jax.Array, b_int: Optional[jax.Array],
               spec: LinearQuantSpec, stride: int = 1, padding: str = "SAME",
               apply_relu: bool = False) -> jax.Array:
    """Integer-only 2-D convolution (Eq. 2/3), NHWC x HWIO -> NHWC.

    The faithful path for the paper's own ResNet experiments.  int8 operands,
    int32 accumulation, bias align + single shift requant (+ optional fused
    ReLU per Fig. 1(b)).
    """
    acc = jax.lax.conv_general_dilated(
        x_int.astype(jnp.int32), w_int.astype(jnp.int32),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )
    if b_int is not None:
        acc = acc + bias_align(b_int, spec.bias_shift)
    if apply_relu:
        acc = jnp.maximum(acc, 0)
    return shift_requant(acc, spec.requant_shift, bits=spec.bits,
                         unsigned=spec.out_unsigned and apply_relu)


def int_residual_add(a_int: jax.Array, n_a: int, b_int: jax.Array, n_b: int,
                     n_o: int, bits: int = 8, apply_relu: bool = False) -> jax.Array:
    """Fig. 1(c)/(d): residual addition of two int8 codes on different grids.

    Both operands are left-shifted onto the finer common grid
    ``n_hi = max(n_a, n_b)`` (exact — no information loss), added in int32,
    then requantized once by ``n_hi - n_o``.  With ReLU (case c) the sign
    check happens on the int32 sum; without (case d) the signed code is kept.
    """
    n_hi = max(n_a, n_b)
    a = a_int.astype(jnp.int32) << (n_hi - n_a)
    b = b_int.astype(jnp.int32) << (n_hi - n_b)
    acc = a + b
    if apply_relu:
        acc = jnp.maximum(acc, 0)
    return shift_requant(acc, n_hi - n_o, bits=bits,
                         unsigned=apply_relu)
