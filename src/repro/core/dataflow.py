"""Dataflow-based unified-module construction — the paper's §1.2.1.

The paper's hypothesis: *fewer quantization operations incur less information
loss* (and fewer hardware requant units).  Given a layer graph, this module
applies the Fig. 1 fusion rules to decide where quantization points live:

  (a) bare linear/conv                      -> quantize after the op
  (b) linear/conv followed by ReLU           -> ONE quant point after ReLU,
      unsigned code, no intermediate writeback
  (c) residual add followed by ReLU          -> align shortcut/branch grids,
      ONE quant point after the add+ReLU
  (d) residual add without ReLU              -> ONE signed quant point after add
  BN/RMSNorm                                 -> folded into the adjacent linear
                                                (no quant point of its own)

The output is a :class:`QuantPlan`: an ordered list of
:class:`UnifiedModule` s, each owning exactly one output quantization point
plus its weight/bias points.  The plan drives (i) Algorithm-1 calibration
order (N_x of module k+1 = N_o of module k along each edge), (ii) the
integer serve path, and (iii) the hardware-cost bench (quant-op counts for
naive vs. joint placement).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "OpKind",
    "OpNode",
    "UnifiedModule",
    "QuantPlan",
    "build_plan",
    "QuantizedTensor",
    "count_quant_ops",
]


class OpKind(enum.Enum):
    LINEAR = "linear"          # matmul / conv — has weights (+bias)
    RELU = "relu"
    GELU = "gelu"              # smooth activations: quant point goes AFTER
    SILU_GATE = "silu_gate"    # SwiGLU gate product silu(a)*b
    ADD = "add"                # residual addition (two quantized operands)
    NORM = "norm"              # BatchNorm / RMSNorm — folded, never a q-point
    SOFTMAX = "softmax"        # stays high precision (paper quantizes none)
    EMBED = "embed"            # table lookup; output quantized like (a)
    OUTPUT = "output"          # graph sink


@dataclasses.dataclass
class OpNode:
    """One primitive op in the layer graph (SSA-ish: inputs are node names)."""

    name: str
    kind: OpKind
    inputs: tuple[str, ...] = ()
    has_bias: bool = False
    # residual ADD: which input is the shortcut (for alignment bookkeeping)
    meta: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class UnifiedModule:
    """A fused region with exactly one activation quantization point.

    ``case`` is the paper's Fig. 1 label.  ``ops`` lists the fused op names
    in execution order.  ``out_unsigned`` is True for case (b)/(c) where a
    ReLU precedes the quant point.
    """

    name: str
    case: str                       # 'a' | 'b' | 'c' | 'd' | 'embed'
    ops: tuple[str, ...]
    weight_points: tuple[str, ...]  # ops owning a weight quant point
    bias_points: tuple[str, ...]
    out_unsigned: bool
    inputs: tuple[str, ...]         # upstream unified-module names (N_x edges)


@dataclasses.dataclass
class QuantPlan:
    modules: list[UnifiedModule]
    n_naive_points: int   # quantize-after-every-op baseline (DoReFa placement)
    n_joint_points: int   # this plan's activation quant points

    def module(self, name: str) -> UnifiedModule:
        for m in self.modules:
            if m.name == name:
                return m
        raise KeyError(name)


def _consumers(nodes: Sequence[OpNode]) -> dict[str, list[OpNode]]:
    out: dict[str, list[OpNode]] = {n.name: [] for n in nodes}
    for n in nodes:
        for i in n.inputs:
            if i in out:
                out[i].append(n)
    return out


def build_plan(nodes: Sequence[OpNode]) -> QuantPlan:
    """Apply the Fig. 1 fusion rules over a topologically-ordered op list.

    Rules, in priority order (greedy over the topo order, single pass —
    mirrors the paper's by-construction restructuring, not a search):

      1. NORM nodes are absorbed into their unique LINEAR consumer (folding).
      2. LINEAR with a single RELU consumer fuses -> case (b).
      3. ADD with a single RELU consumer fuses -> case (c); bare ADD -> (d).
      4. LINEAR/EMBED otherwise -> case (a)/'embed'.
      5. GELU/SILU_GATE close the module of their producing LINEAR (quant
         point after the nonlinearity, the case-(b) generalization).
    """
    by_name = {n.name: n for n in nodes}
    cons = _consumers(nodes)
    absorbed: set[str] = set()     # ops already folded into a module
    modules: list[UnifiedModule] = []
    # naive baseline: one activation quant op after every value-producing op
    naive = sum(1 for n in nodes
                if n.kind not in (OpKind.NORM, OpKind.OUTPUT, OpKind.SOFTMAX))

    # map op name -> unified module that produces its output
    producer_mod: dict[str, str] = {}

    def upstream_modules(op: OpNode) -> tuple[str, ...]:
        ups = []
        for i in op.inputs:
            seen = i
            # walk through folded norms to the real producer
            while seen in by_name and by_name[seen].kind == OpKind.NORM:
                seen = by_name[seen].inputs[0]
            if seen in producer_mod:
                ups.append(producer_mod[seen])
        return tuple(dict.fromkeys(ups))

    for n in nodes:
        if n.name in absorbed or n.kind in (OpKind.NORM, OpKind.OUTPUT,
                                            OpKind.SOFTMAX):
            continue

        if n.kind in (OpKind.LINEAR, OpKind.EMBED):
            nexts = cons.get(n.name, [])
            fused_act = None
            if len(nexts) == 1 and nexts[0].kind in (OpKind.RELU, OpKind.GELU,
                                                     OpKind.SILU_GATE):
                fused_act = nexts[0]
            ops = (n.name,) + ((fused_act.name,) if fused_act else ())
            case = ("b" if fused_act and fused_act.kind == OpKind.RELU
                    else "a" if not fused_act else "b")
            if n.kind == OpKind.EMBED:
                case = "embed"
            m = UnifiedModule(
                name=f"um_{n.name}", case=case, ops=ops,
                weight_points=(n.name,) if n.kind == OpKind.LINEAR else (n.name,),
                bias_points=(n.name,) if n.has_bias else (),
                out_unsigned=bool(fused_act and fused_act.kind == OpKind.RELU),
                inputs=upstream_modules(n),
            )
            if fused_act:
                absorbed.add(fused_act.name)
                producer_mod[fused_act.name] = m.name
            producer_mod[n.name] = m.name
            modules.append(m)

        elif n.kind == OpKind.ADD:
            nexts = cons.get(n.name, [])
            fused_relu = None
            if len(nexts) == 1 and nexts[0].kind == OpKind.RELU:
                fused_relu = nexts[0]
            ops = (n.name,) + ((fused_relu.name,) if fused_relu else ())
            m = UnifiedModule(
                name=f"um_{n.name}", case="c" if fused_relu else "d",
                ops=ops, weight_points=(), bias_points=(),
                out_unsigned=fused_relu is not None,
                inputs=upstream_modules(n),
            )
            if fused_relu:
                absorbed.add(fused_relu.name)
                producer_mod[fused_relu.name] = m.name
            producer_mod[n.name] = m.name
            modules.append(m)

        elif n.kind in (OpKind.RELU, OpKind.GELU, OpKind.SILU_GATE):
            # un-fused activation (producer had multiple consumers): its own
            # quant point, case (b) semantics without the writeback saving.
            m = UnifiedModule(
                name=f"um_{n.name}", case="b", ops=(n.name,),
                weight_points=(), bias_points=(),
                out_unsigned=n.kind == OpKind.RELU,
                inputs=upstream_modules(n),
            )
            producer_mod[n.name] = m.name
            modules.append(m)

    return QuantPlan(modules=modules, n_naive_points=naive,
                     n_joint_points=len(modules))


def count_quant_ops(plan: QuantPlan) -> dict[str, int]:
    """Quant-op counts for the hardware-cost comparison (Table 5 bench)."""
    return {
        "naive_activation_points": plan.n_naive_points,
        "joint_activation_points": plan.n_joint_points,
        "weight_points": sum(len(m.weight_points) for m in plan.modules),
        "bias_points": sum(len(m.bias_points) for m in plan.modules),
        "saved": plan.n_naive_points - plan.n_joint_points,
    }


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Integer codes + the power-of-two grid they live on.

    ``codes`` is an int8/int16/int32 array, ``n`` the fractional bit.  ``n``
    is static metadata (part of the treedef), matching the paper's deploy
    artifact split: integer tensors + shift constants.
    """

    codes: jax.Array
    n: int
    bits: int = 8
    unsigned: bool = False

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        return (self.codes.astype(jnp.float32) * (2.0 ** (-self.n))).astype(dtype)

    @property
    def shape(self):
        return self.codes.shape

    def tree_flatten(self):
        return (self.codes,), (self.n, self.bits, self.unsigned)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)
