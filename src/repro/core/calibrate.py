"""Algorithm 1 — grid-search calibration of fractional bits, no fine-tuning.

For each unified module, search (N_w, N_b, N_o) in the narrowed windows
``[N^max - tau, N^max]`` (Eq. 6) minimizing the joint reconstruction error
``|| O - Q(f(X^q, W^q, B^q), N_o) ||_2`` (Eq. 5), where X^q is the *already
quantized* input from the upstream module (its N_o becomes our N_x) — this
sequential threading is what makes the quantization "joint" along the
dataflow.

Cost note (paper §1.2.2): the module output f(X^q, W^q, B^q) depends only on
(N_w, N_b), so we evaluate the op once per (i, j) grid cell and sweep all
N_o candidates on that single output with a vmapped requantization —
O(tau^2 Γ + tau^3) instead of the paper's O(tau^3 Γ).  Recorded as a
beyond-paper (algorithmic, exact) speedup in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qscheme import fake_quant, search_window

__all__ = [
    "CalibResult",
    "calibrate_linear_module",
    "calibrate_add_module",
    "calibrate_output_point",
    "CalibrationReport",
]


@dataclasses.dataclass
class CalibResult:
    """Optimal fractional bits for one unified module + its error curve."""

    n_w: Optional[int]
    n_b: Optional[int]
    n_o: int
    error: float            # the winning ||O - O^q||_2
    fp_norm: float          # ||O||_2, for relative-error reporting
    elapsed_s: float = 0.0

    @property
    def rel_error(self) -> float:
        return self.error / max(self.fp_norm, 1e-12)


@dataclasses.dataclass
class CalibrationReport:
    """Per-module results in dataflow order (benchmarks/Fig. 2 read this)."""

    results: dict[str, CalibResult] = dataclasses.field(default_factory=dict)
    total_s: float = 0.0

    def add(self, name: str, r: CalibResult) -> None:
        self.results[name] = r
        self.total_s += r.elapsed_s

    def shift_histogram(self) -> dict[int, int]:
        """Paper Fig. 2(b): distribution of chosen fractional bits."""
        hist: dict[int, int] = {}
        for r in self.results.values():
            for v in (r.n_w, r.n_o):
                if v is not None:
                    hist[v] = hist.get(v, 0) + 1
        return dict(sorted(hist.items()))


def _l2(a: jax.Array, b: jax.Array) -> jax.Array:
    d = (a.astype(jnp.float32) - b.astype(jnp.float32)).ravel()
    return jnp.sqrt(jnp.sum(d * d))


@functools.partial(jax.jit, static_argnames=("bits", "unsigned"))
def _sweep_n_o(o_pre: jax.Array, o_ref: jax.Array, n_o_cands: jax.Array,
               bits: int, unsigned: bool) -> jax.Array:
    """Errors of requantizing one pre-activation output at each N_o."""

    def err(n_o):
        return _l2(o_ref, fake_quant(o_pre, n_o, bits, unsigned))

    return jax.vmap(err)(n_o_cands)


def calibrate_linear_module(
    x_q: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    o_ref: jax.Array,
    apply_fn: Callable[[jax.Array, jax.Array, Optional[jax.Array]], jax.Array],
    *,
    bits: int = 8,
    tau: int = 4,
    out_unsigned: bool = False,
) -> CalibResult:
    """Algorithm 1 for a weight-bearing module (cases a/b and conv variants).

    Args:
      x_q: the module input, already fake-quantized on the upstream grid
        (N_x is implicit in the values — the paper threads it the same way).
      w, b: full-precision weights / bias (b may be None).
      o_ref: full-precision module output on x's *unquantized* ancestor —
        the reconstruction target O of Eq. 5.
      apply_fn: (x, w_q, b_q) -> float pre-quantization output; encapsulates
        the op (matmul/conv) and any fused nonlinearity (Fig. 1b).
    """
    t0 = time.perf_counter()
    # Algorithm 1 lines 3-7: windows in "integer bits", converted to
    # fractional bits via N = (bits - 1) - i.
    iw_lo, iw_hi = search_window(w, tau)
    cand_w = [(bits - 1) - i for i in range(iw_lo, iw_hi + 1)]
    if b is not None:
        ib_lo, ib_hi = search_window(b, tau)
        cand_b = [(bits - 1) - i for i in range(ib_lo, ib_hi + 1)]
    else:
        cand_b = [None]
    io_lo, io_hi = search_window(o_ref, tau)
    cand_o = jnp.asarray([(bits - 1) - k for k in range(io_lo, io_hi + 1)],
                         jnp.float32)

    fp_norm = float(jnp.linalg.norm(o_ref.astype(jnp.float32).ravel()))
    best = (None, None, int(cand_o[0]), np.inf)

    apply_jit = jax.jit(apply_fn)
    for n_w in cand_w:
        w_q = fake_quant(w, n_w, bits)
        for n_b in cand_b:
            b_q = None if n_b is None else fake_quant(b, n_b, bits)
            o_pre = apply_jit(x_q, w_q, b_q)
            errs = np.asarray(_sweep_n_o(o_pre, o_ref, cand_o, bits,
                                         out_unsigned))
            k = int(np.argmin(errs))
            if errs[k] < best[3]:
                best = (n_w, n_b, int(cand_o[k]), float(errs[k]))

    return CalibResult(n_w=best[0], n_b=best[1], n_o=best[2], error=best[3],
                       fp_norm=fp_norm, elapsed_s=time.perf_counter() - t0)


def calibrate_add_module(
    a_q: jax.Array,
    b_q: jax.Array,
    o_ref: jax.Array,
    *,
    bits: int = 8,
    tau: int = 4,
    out_unsigned: bool = False,
    apply_relu: bool = False,
) -> CalibResult:
    """Fig. 1(c)/(d): residual add — only N_o is free (operand grids are
    inherited from the upstream modules)."""
    t0 = time.perf_counter()
    o_pre = a_q.astype(jnp.float32) + b_q.astype(jnp.float32)
    if apply_relu:
        o_pre = jnp.maximum(o_pre, 0)
    io_lo, io_hi = search_window(o_ref, tau)
    cand_o = jnp.asarray([(bits - 1) - k for k in range(io_lo, io_hi + 1)],
                         jnp.float32)
    errs = np.asarray(_sweep_n_o(o_pre, o_ref, cand_o, bits, out_unsigned))
    k = int(np.argmin(errs))
    return CalibResult(
        n_w=None, n_b=None, n_o=int(cand_o[k]), error=float(errs[k]),
        fp_norm=float(jnp.linalg.norm(o_ref.astype(jnp.float32).ravel())),
        elapsed_s=time.perf_counter() - t0)


def calibrate_output_point(
    o_pre: jax.Array,
    o_ref: jax.Array,
    *,
    bits: int = 8,
    tau: int = 4,
    out_unsigned: bool = False,
) -> CalibResult:
    """Standalone activation quant point (un-fused nonlinearity, embeddings)."""
    t0 = time.perf_counter()
    io_lo, io_hi = search_window(o_ref, tau)
    cand_o = jnp.asarray([(bits - 1) - k for k in range(io_lo, io_hi + 1)],
                         jnp.float32)
    errs = np.asarray(_sweep_n_o(o_pre, o_ref, cand_o, bits, out_unsigned))
    k = int(np.argmin(errs))
    return CalibResult(
        n_w=None, n_b=None, n_o=int(cand_o[k]), error=float(errs[k]),
        fp_norm=float(jnp.linalg.norm(o_ref.astype(jnp.float32).ravel())),
        elapsed_s=time.perf_counter() - t0)
