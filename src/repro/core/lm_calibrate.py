"""Algorithm 1 applied to transformer-family models (the LM adaptation of
the paper's pipeline, DESIGN §3).

1. Run ONE full-precision forward on a single calibration batch (paper
   §2.1: "a single image") with activation capture on — every unified
   module streams its (input, weight, bias) to the host.
2. Per module, in dataflow order: N_x from the Eq. 6 max-window on the
   captured input; grid-search (N_w, [N_b,] N_o) minimizing the module's
   reconstruction error (Eq. 5).
3. The result is a ``QuantContext`` table driving fake/int execution.

Scanned layer stacks share one module name, hence one set of fractional
bits — the static-shift constraint that keeps the deploy path's requant
shifts compile-time constants (DESIGN §3).
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import qmodel
from repro.core.calibrate import (CalibrationReport, calibrate_linear_module)
from repro.core.qmodel import ModuleBits, QuantContext, QuantMode
from repro.core.qscheme import fake_quant, search_window

__all__ = ["calibrate_lm", "DATAFLOW_CHAIN"]

# Paper §2.2 sequential joint scheme: the upstream module's output grid N_o
# becomes the downstream module's input grid N_x, so the value flowing along
# the dataflow edge is quantized ONCE.  A transformer breaks the strict CNN
# chain (norms/softmax/SiLU sit between most matmuls), but two edges are
# range-preserving enough to inherit the grid (DESIGN §13):
#   attn/wo <- attn/wv : attention output rows are softmax-convex
#       combinations of V rows, so the o-projection input lives inside the
#       value projection's output range — V's grid is its natural grid.
#   mlp/w2 <- mlp/w1   : h = silu(g) * u with |silu(g)| <= |g|, so the gate
#       projection's grid bounds the gating factor; the windowed
#       (N_w, N_b, N_o) search absorbs the residual range shift from u.
# Keys are matched as module-name suffixes so prefixed blocks (e.g.
# 'shared/attn/wo') inherit the same way.
DATAFLOW_CHAIN = {"attn/wo": "attn/wv", "mlp/w2": "mlp/w1"}


def _upstream_of(name: str, chain) -> Optional[str]:
    """Resolve ``name``'s dataflow upstream under suffix-matched ``chain``."""
    for suffix, up in chain.items():
        if name == suffix or name.endswith("/" + suffix):
            return name[: len(name) - len(suffix)] + up
    return None


def calibrate_lm(forward_fn, params, batch, *, bits: int = 8, tau: int = 4,
                 sample_rows: int = 2048,
                 chain=None) -> tuple[QuantContext, CalibrationReport]:
    """Calibrate every qlinear module of an LM.

    forward_fn(params, batch, ctx) must run the model's forward (loss or
    logits — only the capture side effects matter).
    ``sample_rows`` subsamples token rows per module to bound the grid
    search cost (the paper calibrates on one image's worth of activations).

    ``chain`` maps a module-name suffix to its dataflow upstream; for each
    chained module the upstream's chosen ``N_o`` is inherited as ``N_x``
    (the paper's sequential joint scheme) and the module is calibrated on
    the already-quantized input ``fake_quant(x, N_x)`` — equivalent to
    calibrating the composed pair module-by-module.  Defaults to
    :data:`DATAFLOW_CHAIN`; pass ``{}`` to disable threading.  Capture
    order is call order, so the store iterates in dataflow order and every
    upstream is calibrated before its consumer.
    """
    if chain is None:
        chain = DATAFLOW_CHAIN
    with qmodel.capture_activations() as store:
        forward_fn(params, batch, QuantContext(mode=QuantMode.FP))
        jax.effects_barrier()

    report = CalibrationReport()
    table: dict[str, ModuleBits] = {}
    for name, (x, w, b) in store.items():
        x = jnp.asarray(x).reshape(-1, x.shape[-1])
        if x.shape[0] > sample_rows:
            x = x[:: x.shape[0] // sample_rows][:sample_rows]
        w = jnp.asarray(w)
        b = jnp.asarray(b) if b is not None and jnp.ndim(b) > 0 else None
        o_ref = x.astype(jnp.float32) @ w.astype(jnp.float32)
        if b is not None:
            o_ref = o_ref + b.astype(jnp.float32)

        def apply(xx, wq, bq):
            y = xx.astype(jnp.float32) @ wq.astype(jnp.float32)
            return y + bq.astype(jnp.float32) if bq is not None else y

        upstream = _upstream_of(name, chain)
        if upstream is not None and upstream in table:
            # threaded edge: inherit the upstream output grid (N_o -> N_x)
            # and calibrate (N_w, N_b, N_o) on the already-quantized input —
            # the value crossing this dataflow edge is quantized once.
            n_x = table[upstream].n_o
            r = calibrate_linear_module(fake_quant(x, n_x, bits), w, b,
                                        o_ref, apply, bits=bits, tau=tau)
        else:
            # unchained boundary: extend Algorithm 1's grid with the INPUT
            # grid N_x (the LM input is a fresh quant point per module
            # boundary, unlike the CNN chain where N_x is inherited): a
            # slightly finer-than-max grid often wins by clipping
            # activation outliers.
            nx_hi = (bits - 1) - search_window(x, 0)[1]
            best = None
            for n_x in (nx_hi, nx_hi + 1, nx_hi + 2):
                xq = fake_quant(x, n_x, bits)
                r = calibrate_linear_module(xq, w, b, o_ref, apply,
                                            bits=bits, tau=tau)
                if best is None or r.error < best[1].error:
                    best = (n_x, r)
            n_x, r = best
        report.add(name, r)
        table[name] = ModuleBits(n_x=n_x, n_w=r.n_w, n_b=r.n_b, n_o=r.n_o)
    return QuantContext(mode=QuantMode.FAKE, bits=bits, table=table), report
