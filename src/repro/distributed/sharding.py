"""Partition rules: param-path -> PartitionSpec for every architecture.

Strategy (DESIGN §5):
  * TP on the ``model`` axis: attention heads, MLP hidden, expert dim (EP),
    vocab (embedding rows + lm_head cols).
  * DP on ``data`` (x ``pod`` when multi-pod): batch dim of activations.
  * ZeRO-1: optimizer moments inherit the param spec PLUS data-axis
    sharding on the largest dim that divides evenly (opt_sharding_rules).

Rules are pattern-based on the flattened path (the same convention as
MaxText's logical-axis rules, without the indirection — paths here are
stable because the model zoo is ours).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_sharding_rules", "batch_sharding", "make_shardings",
           "cache_sharding_rules", "current_mesh", "DATA_AXES"]

DATA_AXES = ("pod", "data")  # gradient-reduction axes when both exist


def _dp(mesh: Mesh) -> Any:
    """The composite data-parallel axis spec entry for this mesh."""
    return tuple(a for a in DATA_AXES if a in mesh.axis_names) or None


# (regex on path, [candidate specs]).  First matching pattern wins; within a
# pattern the FIRST candidate whose sharded dims all DIVIDE the leaf shape is
# chosen (pjit in_shardings reject non-divisible dims, unlike constraints) —
# e.g. granite's 40 experts cannot shard over model=16, so its expert stacks
# fall back to contract-dim TP.
_RULES: list[tuple[str, list]] = [
    # embeddings / head: vocab over model, fall back to d_model
    (r"embed$",                 [P("model", None), P(None, "model"), P()]),
    (r"lm_head$",               [P(None, "model"), P("model", None), P()]),
    # attention (GQA): heads over model
    (r"attn/w[qkv]$",           [P(None, "model"), P("model", None)]),
    (r"attn/wo$",               [P("model", None), P(None, "model")]),
    (r"attn/b[qkv]$",           [P("model"), P()]),
    # MLA: latent down-projections replicated (small), up-projections by head
    (r"attn/wq_a$|attn/wkv_a$", [P(None, None)]),
    (r"attn/wq_b$|attn/wkv_b$", [P(None, "model"), P("model", None)]),
    # cross attention (whisper decoder)
    (r"cross/w[qkv]$",          [P(None, "model"), P("model", None)]),
    (r"cross/wo$",              [P("model", None), P(None, "model")]),
    (r"cross/b[qkv]$",          [P("model"), P()]),
    # dense MLP: hidden over model
    (r"mlp/w1$|mlp/w3$",        [P(None, "model"), P("model", None)]),
    (r"mlp/w2$",                [P("model", None), P(None, "model")]),
    # MoE: experts over model (EP); fall back to TP inside each expert
    (r"moe/router$",            [P(None, None)]),
    (r"moe/w[13]$",             [P("model", None, None),
                                 P(None, "model", None),
                                 P(None, None, "model")]),
    (r"moe/w2$",                [P("model", None, None),
                                 P(None, None, "model"),
                                 P(None, "model", None)]),
    (r"moe/shared/w[13]$",      [P(None, "model"), P("model", None)]),
    (r"moe/shared/w2$",         [P("model", None), P(None, "model")]),
    # mamba2: contract-dim sharding on in-proj (packed out dim must stay
    # whole for the z/xBC/dt split), free-dim on out-proj
    (r"ssm/w_in$",              [P("model", None)]),
    (r"ssm/w_out$",             [P(None, "model"), P("model", None)]),
    (r"ssm/conv_w$|ssm/conv_b$", [P()]),
    # rwkv6: contract-dim sharding (head layout stays local, DESIGN §5)
    (r"rwkv/w[rkvgo]$",         [P("model", None)]),
    (r"rwkv/wk_ffn$|rwkv/wr_ffn$", [P(None, "model"), P("model", None)]),
    (r"rwkv/wv_ffn$",           [P("model", None), P(None, "model")]),
    # zamba2 shared block input projection
    (r"shared/in_proj$|in_proj$", [P(None, "model"), P("model", None)]),
    # norms, gains, scalars: replicated
    (r".*",                     [P()]),
]


def _fit_rank(spec: P, ndim: int) -> list:
    """Pad/truncate a spec to the leaf's rank; stacked layer params have a
    leading scan axis -> prepend None."""
    entries = list(spec)
    if len(entries) < ndim:
        entries = [None] * (ndim - len(entries)) + entries
    elif len(entries) > ndim:
        entries = entries[-ndim:] if ndim else []
    return entries


def _divisible(entries: list, shape: tuple, mesh: Mesh) -> bool:
    for dim, e in zip(shape, entries):
        if e is not None and dim % _axis_size(mesh, e) != 0:
            return False
    return True


def _spec_for(path: str, shape: tuple, mesh: Mesh) -> P:
    ndim = len(shape)
    for pat, candidates in _RULES:
        if re.search(pat, path):
            for cand in candidates:
                entries = _fit_rank(cand, ndim)
                if _divisible(entries, shape, mesh):
                    return P(*entries)
            # last resort: strip non-dividing axes from the first candidate
            entries = [e if e is not None and shape[i] %
                       _axis_size(mesh, e) == 0 else None
                       for i, e in enumerate(_fit_rank(candidates[0], ndim))]
            return P(*entries)
    return P()


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _add_fsdp(spec: P, path: str, shape: tuple, mesh: Mesh,
              min_size: int = 1 << 20) -> P:
    """ZeRO-3/FSDP: additionally shard large leaves over the data axis.

    Placed on the first dim that (a) is not already sharded, (b) divides the
    data-axis size, and (c) is not the scan (layer-stack) dim.  GSPMD then
    all-gathers weights per scan iteration and reduce-scatters gradients —
    the MaxText 'fsdp' pattern; required for >30B configs (DESIGN §5).
    """
    if "data" not in mesh.axis_names:
        return spec
    n = 1
    for d in shape:
        n *= d
    if n < min_size:
        return spec
    dsize = mesh.shape["data"]
    entries = list(spec)
    start = 1 if ("blocks" in path and len(shape) == len(entries)) else 0
    # stacked leaves got their scan dim as a prepended None in _spec_for
    if len(entries) and entries[0] is None and "blocks" in path:
        start = 1
    for i in range(start, len(entries)):
        if entries[i] is None and shape[i] % dsize == 0 and shape[i] >= dsize:
            entries[i] = "data"
            return P(*entries)
    return spec


# serve-mode MoE: 2-D (expert x data) sharding so expert weights are NEVER
# gathered at decode (FSDP re-gathers 167 GB/token on deepseek-v3 decode —
# §Perf iteration V4); the expert einsums psum small partial outputs instead.
_SERVE_RULES: list[tuple[str, list]] = [
    (r"moe/w[13]$", [P("model", None, "data"), P("model", None, None),
                     P(None, "model", None)]),
    (r"moe/w2$",    [P("model", "data", None), P("model", None, None),
                     P(None, None, "model")]),
]


def param_sharding_rules(abstract_params: Any, mesh: Mesh,
                         fsdp: bool = True, serve: bool = False) -> Any:
    """PartitionSpec tree matching ``abstract_params`` (from eval_shape)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = []
    for p, leaf in flat:
        path = _path_str(p)
        spec = None
        if serve and "data" in mesh.axis_names:
            for pat, cands in _SERVE_RULES:
                if re.search(pat, path):
                    for cand in cands:
                        entries = _fit_rank(cand, leaf.ndim)
                        if _divisible(entries, leaf.shape, mesh):
                            spec = P(*entries)
                            break
                    break
        if spec is None:
            spec = _spec_for(path, leaf.shape, mesh)
            if fsdp:
                spec = _add_fsdp(spec, path, leaf.shape, mesh)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_sharding_rules(abstract_opt: Any, param_specs_by_path: dict,
                       mesh: Mesh) -> Any:
    """ZeRO-1: moments inherit their param's spec; the step counter is
    replicated.  (Further data-axis sharding of moments is a perf-pass
    option; baseline keeps moments param-aligned.)"""
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_opt)
    specs = []
    for p, leaf in flat:
        ps = _path_str(p)
        m = re.search(r"\.(m|v)[/.](.*)$", ps) or re.search(r"\.(m|v)$", ps)
        specs.append(_spec_for(ps, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_sharding(mesh: Mesh, batch_dims: int = 2) -> P:
    """(B, S, ...) activations: batch over the composite data axis."""
    dp = _dp(mesh)
    return P(dp, *([None] * (batch_dims - 1)))


def cache_sharding_rules(abstract_cache: Any, mesh: Mesh,
                         attn_kernel: str = "chunked",
                         attn_shard_axis: str = "model") -> Any:
    """KV caches: (L, B, S, H, D) -> batch over data when it divides, plus a
    kernel-dependent second axis; recurrent states on their head dim.

    ``attn_kernel='chunked'`` (default): SEQUENCE-sharded over model — the
    pure-JAX decode contracts over S with tiny softmax-stat psums.

    ``attn_kernel='flash'``: HEAD-sharded over model — the fused Pallas
    kernels run per-shard under shard_map (DESIGN §8) with whole GQA groups
    (and their power-of-two scales) resident per shard, so the cache must
    live partitioned on KV heads; a sequence-sharded cache would be
    all-gathered at the shard_map boundary every step.  Falls back to
    sequence sharding when kv_heads doesn't divide the axis (the flash
    resolver raises before that layout is ever used for flash)."""
    dp = _dp(mesh)
    dsize = 1
    for a in (dp if isinstance(dp, tuple) else (dp,) if dp else ()):
        dsize *= mesh.shape[a]

    def spec(path, leaf):
        nd = leaf.ndim
        ps = _path_str(path)
        batch_ok = leaf.shape[1] % dsize == 0 if nd >= 2 and dsize else False
        bdim = dp if batch_ok else None
        if "paged" in ps and nd == 5:          # (L, NB, BS, KVH, D) pool
            # serving-engine block pool (DESIGN §9): shared by every slot,
            # so no batch axis exists to shard — KV heads go over the
            # tensor axis (the shard_map-resident layout the paged kernel
            # expects), everything else stays whole.  Blocks of ONE
            # sequence land on every shard's local pool at the same
            # indices, which is why block tables can be replicated.
            hdim = (attn_shard_axis
                    if attn_shard_axis in mesh.axis_names
                    and leaf.shape[3] % mesh.shape[attn_shard_axis] == 0
                    else None)
            return P(None, None, None, hdim, None)
        if "memory" in ps:                     # (B, T, d)
            mdim = "model" if leaf.shape[2] % mesh.shape["model"] == 0 else None
            return P(bdim if leaf.shape[0] % max(dsize, 1) == 0 else None,
                     None, mdim)
        if nd == 5:                            # (L, B, S, H, D) stacked KV
            if (attn_kernel == "flash"
                    and attn_shard_axis in mesh.axis_names
                    and leaf.shape[3] % mesh.shape[attn_shard_axis] == 0):
                return P(None, bdim, None, attn_shard_axis, None)
            # SEQUENCE-sharded over model (flash-decode/context-parallel):
            # decode contracts over S, so partial scores reduce with tiny
            # stat psums; head-sharding instead re-gathers the whole cache
            # whenever kv_heads doesn't divide the axis (§Perf D2).
            sdim = "model" if leaf.shape[2] % mesh.shape["model"] == 0 else None
            return P(None, bdim, sdim, None, None)
        if nd == 4:                            # (L, B, S, lat) MLA latents
            sdim = "model" if leaf.shape[2] % mesh.shape["model"] == 0 else None
            return P(None, bdim, sdim, None)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_cache)
    return jax.tree_util.tree_unflatten(treedef,
                                        [spec(p, l) for p, l in flat])


def make_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activation sharding constraints (logical axes), MaxText-style
# ---------------------------------------------------------------------------
# GSPMD propagation alone loses the batch sharding through gathers/loss ops
# (observed: full-batch f32 logits temps, 255 GB/device).  Models therefore
# pin activations at module boundaries via ``constrain(x, logical_axes)``,
# which no-ops outside an ``activation_sharding(mesh)`` scope so CPU tests
# and single-device runs are untouched.

import contextlib
import threading

_TLS = threading.local()

_LOGICAL = {
    "batch": lambda mesh: _dp(mesh),
    "model": lambda mesh: "model",
    "vocab": lambda mesh: "model",
    "heads": lambda mesh: "model",
    "ff": lambda mesh: "model",
    "expert": lambda mesh: "model",
    None: lambda mesh: None,
}


@contextlib.contextmanager
def activation_sharding(mesh: Mesh):
    prev = getattr(_TLS, "mesh", None)
    _TLS.mesh = mesh
    try:
        yield
    finally:
        _TLS.mesh = prev


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def current_mesh() -> Optional[Mesh]:
    """The mesh of the active ``activation_sharding`` scope (None outside).

    Model code uses this to hand the physical mesh to kernel wrappers that
    partition work explicitly (shard_map'd flash attention, DESIGN §8) —
    the same source of truth ``constrain`` uses, so kernel sharding and
    activation constraints can never disagree about the mesh."""
    return getattr(_TLS, "mesh", None)


def data_shards() -> int:
    """Size of the composite data axis in the active activation-sharding
    scope (1 outside a scope).  Model code uses this for shard-local
    algorithms (hierarchical MoE dispatch) that degenerate gracefully on a
    single device."""
    mesh = getattr(_TLS, "mesh", None)
    if mesh is None:
        return 1
    return _axis_size(mesh, _dp(mesh))


def constrain(x: jax.Array, logical: tuple) -> jax.Array:
    """Pin x's sharding by logical axis names; drops axes that do not
    divide the corresponding dim (e.g. batch=1 decode, 20 heads on 16)."""
    mesh = getattr(_TLS, "mesh", None)
    if mesh is None:
        return x
    entries = []
    for dim, name in zip(x.shape, logical):
        e = _LOGICAL[name](mesh)
        entries.append(e if e is not None and dim % _axis_size(mesh, e) == 0
                       else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
