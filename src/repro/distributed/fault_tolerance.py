"""Fault tolerance for 1000+-node runs: heartbeats, straggler detection,
elastic re-meshing, and a supervising restart loop.

On a real cluster the signals come from the coordination service
(jax.distributed heartbeats / GCS preemption notices); here the mechanisms
are implemented host-side and driven by injectable clocks/failure events so
every policy is unit-testable on CPU.  The launch/train.py driver wires them
together:

  RunSupervisor.run() -> while True:
      restore latest committed checkpoint (Checkpointer)
      build mesh for the CURRENTLY healthy device count (ElasticPlanner)
      train until failure/preemption (HeartbeatMonitor watches step times)
      on failure: mark node dead, loop

Straggler mitigation: per-step host timings feed an EWMA; hosts slower than
``straggler_factor`` x the p50 for ``patience`` consecutive steps are
reported — the supervisor's policy is demote-to-spare (re-mesh without the
straggler) once spares exist, else log-and-continue.  (The *within-step*
mitigation — collective timeouts and backup workers — belongs to the XLA
runtime flags documented in launch/train.py.)
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional

__all__ = ["HeartbeatMonitor", "ElasticPlanner", "RunSupervisor",
           "MeshPlan"]


@dataclasses.dataclass
class HostStat:
    ewma: float = 0.0
    slow_streak: int = 0
    alive: bool = True
    last_beat: Optional[float] = None  # None until the first beat


class HeartbeatMonitor:
    """Tracks per-host step durations and liveness."""

    def __init__(self, n_hosts: int, timeout_s: float = 60.0,
                 straggler_factor: float = 1.5, patience: int = 5,
                 clock: Callable[[], float] = time.monotonic):
        self.stats = [HostStat() for _ in range(n_hosts)]
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.patience = patience
        self.clock = clock

    def beat(self, host: int, step_s: float) -> None:
        st = self.stats[host]
        st.ewma = step_s if st.ewma == 0 else 0.8 * st.ewma + 0.2 * step_s
        st.last_beat = self.clock()

    def _p50(self) -> float:
        vals = sorted(s.ewma for s in self.stats if s.alive and s.ewma > 0)
        return vals[len(vals) // 2] if vals else 0.0

    def check(self) -> dict:
        """Returns {'dead': [...], 'stragglers': [...]} and updates streaks."""
        now = self.clock()
        dead, stragglers = [], []
        p50 = self._p50()
        for i, st in enumerate(self.stats):
            if not st.alive:
                continue
            if st.last_beat is not None and now - st.last_beat > self.timeout_s:
                st.alive = False
                dead.append(i)
                continue
            if p50 > 0 and st.ewma > self.straggler_factor * p50:
                st.slow_streak += 1
                if st.slow_streak >= self.patience:
                    stragglers.append(i)
            else:
                st.slow_streak = 0
        return {"dead": dead, "stragglers": stragglers}

    def mark_dead(self, host: int) -> None:
        self.stats[host].alive = False

    def alive_count(self) -> int:
        return sum(s.alive for s in self.stats)


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple
    axes: tuple
    n_devices: int
    dropped: int       # devices idled to make a rectangular mesh


class ElasticPlanner:
    """Chooses the largest valid mesh for the surviving device count.

    Policy: keep the model axis FIXED (TP degree is baked into layer sizes
    and checkpoint layout); shrink the data axis to the largest value such
    that data*model <= devices; idle the remainder.  Re-sharding after a
    plan change is checkpoint-reload (params are data-replicated, so only
    the batch split changes) — the cheapest correct elastic step.
    """

    def __init__(self, model_axis: int, pod_size: Optional[int] = None):
        self.model_axis = model_axis
        self.pod_size = pod_size

    def plan(self, n_devices: int) -> MeshPlan:
        if n_devices < self.model_axis:
            raise RuntimeError(
                f"{n_devices} devices cannot host model axis "
                f"{self.model_axis} — unrecoverable without re-sharding "
                f"checkpoints to a smaller TP degree")
        data = n_devices // self.model_axis
        if self.pod_size and n_devices > self.pod_size:
            pods = n_devices // self.pod_size
            data_per_pod = self.pod_size // self.model_axis
            used = pods * data_per_pod * self.model_axis
            return MeshPlan(shape=(pods, data_per_pod, self.model_axis),
                            axes=("pod", "data", "model"),
                            n_devices=used, dropped=n_devices - used)
        used = data * self.model_axis
        return MeshPlan(shape=(data, self.model_axis),
                        axes=("data", "model"),
                        n_devices=used, dropped=n_devices - used)


class RunSupervisor:
    """Restart loop: run -> fail -> restore -> re-mesh -> continue.

    ``train_segment(plan, start_step) -> (last_step, failure | None)`` is the
    injectable work function (launch/train.py provides the real one; tests
    provide failure-injecting fakes).
    """

    def __init__(self, planner: ElasticPlanner, checkpointer,
                 train_segment: Callable, max_restarts: int = 100):
        self.planner = planner
        self.ckpt = checkpointer
        self.train_segment = train_segment
        self.max_restarts = max_restarts
        self.restarts = 0
        self.history: list[dict] = []

    def run(self, n_devices: int, total_steps: int) -> int:
        step = self.ckpt.latest_step() or 0
        devices = n_devices
        while step < total_steps:
            plan = self.planner.plan(devices)
            last_step, failure = self.train_segment(plan, step, total_steps)
            self.history.append({"from": step, "to": last_step,
                                 "devices": plan.n_devices,
                                 "failure": failure})
            step = last_step
            if failure is None:
                break
            self.restarts += 1
            if self.restarts > self.max_restarts:
                raise RuntimeError("restart budget exhausted")
            devices -= failure.get("lost_devices", 0)
            # resume from the last COMMITTED step, not the crashed one
            step = self.ckpt.latest_step() or 0
        return step
