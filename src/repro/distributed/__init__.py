from repro.distributed.sharding import (param_sharding_rules,  # noqa: F401
                                        batch_sharding, make_shardings)
from repro.distributed.fault_tolerance import (HeartbeatMonitor,  # noqa: F401
                                               ElasticPlanner, RunSupervisor)
