"""RWKV6 "Finch" block (arXiv:2404.05892): attention-free time-mix with
data-dependent per-channel decay + squared-ReLU channel-mix.

Recurrence per head (d_k = d_v = head_dim):
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T          (S: (d_k, d_v))
    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
with w_t = exp(-exp(w0 + lora_w(x_t)))  in (0,1) per channel (the
data-dependent decay that distinguishes Finch from RWKV5).

Training/prefill runs the chunked linear-attention form (cross-chunk state
scan + intra-chunk masked quadratic), O(S·chunk) memory — sub-quadratic, so
rwkv6 runs the long_500k cells.  Decode is the O(1) recurrence.

Quantization: r/k/v/g/o projections and both channel-mix matmuls go through
``qlinear``; the decay/recurrence stays fp32 (DESIGN §4 — power-of-two
rounding inside a 500k-step recurrence diverges).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from repro.models.scan_lib import scan as _scan

from repro.configs.base import ModelConfig
from repro.core.qmodel import QuantContext
from repro.models.common import linear, rmsnorm

__all__ = ["RWKVState", "init_rwkv6", "rwkv6_block", "rwkv6_decode"]

HEAD_DIM = 64  # rwkv6 fixed head size


class RWKVState(NamedTuple):
    x_prev_att: jax.Array   # (B, 1, d) last token seen by time-mix
    x_prev_ffn: jax.Array   # (B, 1, d) last token seen by channel-mix
    wkv: jax.Array          # (B, H, dk, dv) recurrent state


def _heads(cfg: ModelConfig) -> int:
    return cfg.d_model // HEAD_DIM


def zero_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> RWKVState:
    h = _heads(cfg)
    return RWKVState(
        x_prev_att=jnp.zeros((batch, 1, cfg.d_model), dtype),
        x_prev_ffn=jnp.zeros((batch, 1, cfg.d_model), dtype),
        wkv=jnp.zeros((batch, h, HEAD_DIM, HEAD_DIM), jnp.float32))


def init_rwkv6(init, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = _heads(cfg)
    lora = 64
    return {
        # time-mix interpolation weights (token-shift) for r,k,v,w,g
        "mu": init.dense((5, d)) * 0 + 0.5,
        "lora_mix_a": init.dense((d, 5 * 32)),
        "lora_mix_b": init.dense((5, 32, d)),
        "wr": init.dense((d, d)),
        "wk": init.dense((d, d)),
        "wv": init.dense((d, d)),
        "wg": init.dense((d, d)),
        "wo": init.dense((d, d)),
        "w0": init.dense((d,)) * 0 - 0.6,       # base decay logit
        "lora_w_a": init.dense((d, lora)),
        "lora_w_b": init.dense((lora, d)),
        "u": init.dense((h, HEAD_DIM)) * 0.1,   # first-token bonus
        "ln_x": init.ones((d,)),                # per-head group norm gain
        # channel-mix
        "mu_ffn": init.dense((2, d)) * 0 + 0.5,
        "wk_ffn": init.dense((d, int(cfg.d_ff))),
        "wv_ffn": init.dense((int(cfg.d_ff), d), fan_in=cfg.d_ff),
        "wr_ffn": init.dense((d, d)),
    }


def _token_shift(x: jax.Array, x_prev: Optional[jax.Array]) -> jax.Array:
    """x_{t-1} stream: shift right by one, first slot from state (or zero)."""
    if x_prev is None:
        x_prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def _ddlerp(x, xp, mu, la, lb):
    """RWKV6 data-dependent interpolation for the 5 mix streams."""
    # base mix then low-rank data-dependent correction
    xx = xp - x
    base = x + xx * mu[:, None, None]                       # (5, B, S, d)
    inner = jnp.tanh((x + xx * 0.5) @ la)                   # (B, S, 5*32)
    b_, s_, _ = inner.shape
    inner = inner.reshape(b_, s_, 5, 32).transpose(2, 0, 1, 3)
    corr = jnp.einsum("nbsr,nrd->nbsd", inner, lb.astype(x.dtype))
    return base + xx[None] * corr


def _wkv_chunked(r, k, v, w_log, u, chunk: int,
                 init_state: Optional[jax.Array]):
    """Chunked RWKV6 linear attention.

    r,k,v: (B,S,H,D); w_log: (B,S,H,D) = log decay (negative); u: (H,D).
    Returns out (B,S,H,D) and final state (B,H,D,D) [dk x dv].
    """
    b, s, h, d = r.shape
    pad = (-s) % chunk
    if pad:
        # zero k/r/v => padded tokens contribute nothing; zero log-decay
        # (w=1) => they do not decay the carried state.
        zeros = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(t, zeros) for t in (r, k, v))
        w_log = jnp.pad(w_log, zeros)
    s_pad = s + pad
    nc = s_pad // chunk
    rc = r.reshape(b, nc, chunk, h, d)
    kc = k.reshape(b, nc, chunk, h, d)
    vc = v.reshape(b, nc, chunk, h, d)
    wl = w_log.reshape(b, nc, chunk, h, d)
    # No clamping here: per-token log-decay is bounded in [-e^0.5, ~0] by the
    # block (see rwkv6_block), so |cum| <= 1.65 * chunk and with chunk <= 32
    # every exp() below stays finite in fp32 (e^53 << 3.4e38).  Clamping cum
    # instead would distort RELATIVE decays between late tokens in a chunk.
    cum = jnp.cumsum(wl, axis=2)                            # (B,NC,L,H,D)
    total = cum[:, :, -1]                                   # (B,NC,H,D)

    # intra-chunk:
    # out_t = sum_{s<t} (r_t * prod_{s+1..t-1+1?}) ... standard form:
    #   score_{t,s} = sum_d r_td k_sd exp(cum_{t-1,d} - cum_{s,d})  for s < t
    #   diag bonus:  s == t with u instead of decay
    q_dec = jnp.exp(cum - wl)                               # exp(cum_{t-1}) = exp(cum_t - w_t)
    k_dec = jnp.exp(-cum)
    att = jnp.einsum("bnthd,bnshd->bnhts", rc * q_dec, kc * k_dec)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strictly lower
    att = jnp.where(causal[None, None, None], att, 0.0)
    diag = jnp.einsum("bnthd,bnthd->bnth", rc * u[None, None, None], kc)
    y_intra = jnp.einsum("bnhts,bnshd->bnthd", att, vc) + \
        diag[..., None] * vc

    # cross-chunk state: S_next = diag(exp(total)) S + sum_s exp(total-cum_s) k_s v_s^T
    k_carry = jnp.exp(total[:, :, None] - cum) * kc
    st = jnp.einsum("bnshk,bnshv->bnhkv", k_carry, vc)

    s0 = (jnp.zeros((b, h, d, d), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(prev, inp):
        st_k, tot_k = inp                                   # (B,H,D,D),(B,H,D)
        new = jnp.exp(tot_k)[..., None] * prev + st_k
        return new, prev

    final, prevs = _scan(
        step, s0, (st.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2, 3)),
        unroll_cap=1)
    prevs = prevs.transpose(1, 0, 2, 3, 4)                  # (B,NC,H,Dk,Dv)

    y_inter = jnp.einsum("bnthk,bnhkv->bnthv", rc * q_dec, prevs)
    y = (y_intra + y_inter).reshape(b, s_pad, h, d)
    return y[:, :s], final


def rwkv6_block(ctx: QuantContext, p: dict, x: jax.Array, cfg: ModelConfig,
                state: Optional[RWKVState] = None, name: str = "rwkv",
                valid: Optional[jax.Array] = None
                ) -> tuple[jax.Array, jax.Array, RWKVState]:
    """Returns (time_mix_out, channel_mix(fn), new_state).  The caller adds
    residuals (pre-LN is applied by the caller, matching block assembly).

    ``valid`` (B, S) bool marks real tokens in a right-padded batch: invalid
    positions are made inert exactly like the chunk padding inside
    ``_wkv_chunked`` — r/k/v -> 0 (no contribution, no output) and
    w_log -> 0 (decay 1, carried state untouched) — so a row with zero
    valid tokens passes its wkv state through bit-exactly."""
    b, s, d = x.shape
    h = _heads(cfg)
    chunk = min(cfg.ssm.chunk if cfg.ssm else 32, s)

    xp = _token_shift(x, state.x_prev_att if state else None)
    mixed = _ddlerp(x, xp, p["mu"], p["lora_mix_a"], p["lora_mix_b"])
    xr, xk, xv, xw, xg = mixed[0], mixed[1], mixed[2], mixed[3], mixed[4]

    r = linear(ctx, f"{name}/wr", xr, p["wr"]).reshape(b, s, h, HEAD_DIM)
    k = linear(ctx, f"{name}/wk", xk, p["wk"]).reshape(b, s, h, HEAD_DIM)
    v = linear(ctx, f"{name}/wv", xv, p["wv"]).reshape(b, s, h, HEAD_DIM)
    g = jax.nn.silu(linear(ctx, f"{name}/wg", xg, p["wg"]))

    # data-dependent decay (fp32): w_log = -exp(w0 + lora_w(xw)) (negative).
    # The logit is clipped to <= 0.5 -> per-token decay >= exp(-1.65): a
    # stability floor that also bounds chunked-form exponentials (above).
    w_dd = (xw @ p["lora_w_a"].astype(x.dtype))
    w_dd = jnp.tanh(w_dd) @ p["lora_w_b"].astype(x.dtype)
    w_log = -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32) +
                              w_dd.astype(jnp.float32), -8.0, 0.5))
    w_log = w_log.reshape(b, s, h, HEAD_DIM)

    if valid is not None:
        m = valid[:, :, None, None]
        r = jnp.where(m, r, 0)
        k = jnp.where(m, k, 0)
        v = jnp.where(m, v, 0)
        w_log = jnp.where(m, w_log, 0.0)

    out, wkv = _wkv_chunked(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w_log, p["u"].astype(jnp.float32), chunk,
        state.wkv if state else None)
    out = out.reshape(b, s, d).astype(x.dtype)
    out = rmsnorm(out, p["ln_x"], cfg.norm_eps) * g
    att_out = linear(ctx, f"{name}/wo", out, p["wo"])

    new_state = RWKVState(
        x_prev_att=x[:, -1:], x_prev_ffn=x[:, -1:],  # ffn prev set by caller
        wkv=wkv)
    return att_out, None, new_state


def rwkv6_channel_mix(ctx: QuantContext, p: dict, x: jax.Array,
                      cfg: ModelConfig, x_prev: Optional[jax.Array] = None,
                      name: str = "rwkv_ffn") -> jax.Array:
    """Squared-ReLU channel mix — the paper's Fig. 1(b) fast path applies:
    ReLU precedes the quant point, so the code is unsigned."""
    xp = _token_shift(x, x_prev)
    mu = p["mu_ffn"]
    xk = x + (xp - x) * mu[0][None, None]
    xr = x + (xp - x) * mu[1][None, None]
    k = linear(ctx, f"{name}/wk", xk, p["wk_ffn"])
    k = jnp.square(jax.nn.relu(k))
    kv = linear(ctx, f"{name}/wv", k, p["wv_ffn"])
    return jax.nn.sigmoid(linear(ctx, f"{name}/wr", xr, p["wr_ffn"])) * kv


def rwkv6_decode(ctx: QuantContext, p: dict, x: jax.Array, cfg: ModelConfig,
                 state: RWKVState, name: str = "rwkv"
                 ) -> tuple[jax.Array, RWKVState]:
    """Single-token time-mix recurrence, O(1) in sequence length."""
    b, s, d = x.shape  # s == 1
    h = _heads(cfg)

    xp = state.x_prev_att
    mixed = _ddlerp(x, xp, p["mu"], p["lora_mix_a"], p["lora_mix_b"])
    xr, xk, xv, xw, xg = mixed[0], mixed[1], mixed[2], mixed[3], mixed[4]
    r = linear(ctx, f"{name}/wr", xr, p["wr"]).reshape(b, h, HEAD_DIM)
    k = linear(ctx, f"{name}/wk", xk, p["wk"]).reshape(b, h, HEAD_DIM)
    v = linear(ctx, f"{name}/wv", xv, p["wv"]).reshape(b, h, HEAD_DIM)
    g = jax.nn.silu(linear(ctx, f"{name}/wg", xg, p["wg"]))

    w_dd = jnp.tanh(xw @ p["lora_w_a"].astype(x.dtype)) @ \
        p["lora_w_b"].astype(x.dtype)
    w = jnp.exp(-jnp.exp(jnp.clip(
        p["w0"].astype(jnp.float32) + w_dd.astype(jnp.float32)[:, 0],
        -8.0, 0.5))).reshape(b, h, HEAD_DIM)

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    s_prev = state.wkv.astype(jnp.float32)                  # (B,H,Dk,Dv)
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    out = jnp.einsum("bhk,bhkv->bhv",
                     rf, s_prev + p["u"].astype(jnp.float32)[None, :, :, None] * kv)
    new_wkv = w[..., None] * s_prev + kv
    out = out.reshape(b, 1, d).astype(x.dtype)
    out = rmsnorm(out, p["ln_x"], cfg.norm_eps) * g
    att_out = linear(ctx, f"{name}/wo", out, p["wo"])
    new_state = RWKVState(x_prev_att=x, x_prev_ffn=state.x_prev_ffn,
                          wkv=new_wkv)
    return att_out, new_state
