"""Model zoo: composable JAX layers + per-family assembly (see model.py)."""
