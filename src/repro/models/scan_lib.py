"""Scan wrapper with an "analysis unroll" switch.

XLA's ``cost_analysis()`` counts a while-loop body ONCE, independent of trip
count — so every scanned structure (layer stacks, KV-chunk scans, SSD chunk
scans, gradient-accumulation loops) is invisible to the roofline unless
unrolled.  Production compiles keep rolled loops (small HLO, fast compile);
the roofline fit (benchmarks/roofline.py) re-lowers reduced-depth variants
under ``analysis_unroll()`` where every scan fully unrolls, making the cost
model exact, then extrapolates depth linearly.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Optional

import jax

_TLS = threading.local()

__all__ = ["scan", "analysis_unroll", "unrolling"]


@contextlib.contextmanager
def analysis_unroll():
    prev = getattr(_TLS, "unroll", False)
    _TLS.unroll = True
    try:
        yield
    finally:
        _TLS.unroll = prev


def unrolling() -> bool:
    return getattr(_TLS, "unroll", False)


def scan(f: Callable, init: Any, xs: Any = None, length: Optional[int] = None,
         unroll_cap: Optional[int] = None, **kw) -> Any:
    """``unroll_cap`` bounds analysis unrolling for scans whose bodies are
    negligible for the cost model (e.g. the O(B*H*D^2) cross-chunk state
    recurrences in rwkv/ssd — their heavy math is batched OUTSIDE the scan,
    so fully unrolling thousands of tiny steps would only bloat the HLO)."""
    if unrolling():
        n = length
        if n is None:
            n = jax.tree_util.tree_leaves(xs)[0].shape[0]
        n = int(n)
        if unroll_cap is not None:
            n = min(n, unroll_cap)
        kw = dict(kw, unroll=max(n, 1))
    return jax.lax.scan(f, init, xs, length=length, **kw)
