"""Attention layers: GQA (+qk_norm) and DeepSeek MLA, with chunked
(flash-style) softmax for long sequences and int8-quantized projections.

Quantization points (DESIGN §3): all projection matmuls run through
``qlinear`` (paper's unified modules); softmax / rope / norms stay in
fp32/bf16 — the paper likewise never quantizes its softmax.

Memory discipline: full-sequence attention materializes (B,H,S,S); at
S=32k that is petabytes.  ``chunked_attention`` scans over KV chunks with
an online softmax so the live tile is (B,H,qc,kc) — the pure-JAX analogue
of a flash kernel, and what makes the prefill_32k dry-run cells fit.

With ``cfg.attn_kernel = 'flash'`` the hot paths route through the fused
Pallas kernels in ``repro.kernels.flash_attention`` (DESIGN §2): int8
KV-cache codes are loaded straight into VMEM and bit-shift dequantized
in-register, so the bf16 cache copy and the HBM score round-trips
disappear.  ``chunked_attention`` stays the reference oracle and the
fallback.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from repro.models.scan_lib import scan as _scan

from repro.configs.base import MLAConfig, ModelConfig
from repro.core.qmodel import QuantContext
from repro.distributed.sharding import constrain, current_mesh
from repro.models.common import apply_rope, linear, rmsnorm

__all__ = ["KVCache", "MLACache", "PagedKVCache", "RaggedBatch", "init_gqa",
           "gqa_attention", "init_mla", "mla_attention", "chunked_attention"]


class KVCache(NamedTuple):
    k: jax.Array        # (B, S_max, KVH, D)
    v: jax.Array        # (B, S_max, KVH, D)


class MLACache(NamedTuple):
    c_kv: jax.Array     # (B, S_max, kv_lora)  — compressed latent
    k_pe: jax.Array     # (B, S_max, rope_dim) — shared rope key


class PagedKVCache(NamedTuple):
    """Serving-engine KV block pool (DESIGN §9): ALL slots' KV lives in one
    pool of fixed-size blocks; per-slot block tables (passed alongside, not
    stored here — they are host-managed ints) map logical block i to a pool
    block.  int8 Eq.-1 codes are written ONCE at their token's step and
    never requantized; block 0 is the trash block inactive slots write to.
    """
    k: jax.Array        # (NB, BS, KVH, D) — int8 codes or model dtype
    v: jax.Array        # (NB, BS, KVH, D)


class RaggedBatch(NamedTuple):
    """One MIXED serving step as a flattened token stream (DESIGN §12).

    Prefill chunks, decode rows, and speculative tails of every live slot
    are packed back to back into one (T,) stream; each sequence ``s``
    owns stream rows ``[q_start[s], q_start[s] + q_len[s])`` and sees
    ``kv_len[s]`` total KV rows.  ``dest`` is the host-precomputed
    flattened pool row (``block * block_size + pos % block_size``) each
    token's KV codes scatter to — padding rows point at the trash block.
    All arrays are int32; descriptors follow the contract in
    ``kernels.ragged_flash`` (q_start nondecreasing, windows disjoint,
    padding slots zeroed with trash-block tables).
    """
    dest: jax.Array          # (T,)       flattened pool row per token
    block_tables: jax.Array  # (S, NBmax) logical block -> pool block
    q_start: jax.Array       # (S,)
    q_len: jax.Array         # (S,)
    kv_len: jax.Array        # (S,)


# ---------------------------------------------------------------------------
# chunked online-softmax attention (flash-style, pure JAX)
# ---------------------------------------------------------------------------

def _repeat_kv(x: jax.Array, groups: int) -> jax.Array:
    """Expand KV heads for the pure-JAX fallback via broadcast-reshape.

    ``jnp.repeat`` lowers to a gather that materializes a ``groups``x copy
    of the cache in HBM; the broadcast of a size-1 axis is free until the
    reshape, which XLA fuses into the consuming dot.  Head order matches
    ``jnp.repeat(x, groups, axis=2)`` (each KV head's group is contiguous).
    """
    if groups == 1:
        return x
    b, s, h, d = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, groups, d))
    return x.reshape(b, s, h * groups, d)


import functools as _functools


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, kv_chunk: int = 1024,
                      q_offset: int = 0, scale: Optional[float] = None
                      ) -> jax.Array:
    """q: (B,Sq,H,Dk), k: (B,Skv,H,Dk), v: (B,Skv,H,Dv) -> (B,Sq,H,Dv).

    checkpoint'd (flash-attention style): the backward recomputes chunk
    scores/probabilities instead of saving per-chunk masks and p — saving
    them costs O(Sq * Skv / kv_chunk) stacked buffers under the chunk scan
    (observed 5 GB/device of pred masks alone at 4k train).  Decode calls
    (traced q_offset, no grad) skip the checkpoint wrapper.
    """
    if isinstance(q_offset, jax.Array):      # decode path: no backward
        return _chunked_attention(q, k, v, causal=causal, kv_chunk=kv_chunk,
                                  q_offset=q_offset, scale=scale)
    f = jax.checkpoint(_functools.partial(
        _chunked_attention, causal=causal, kv_chunk=kv_chunk,
        q_offset=q_offset, scale=scale))
    return f(q, k, v)


def _chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool, kv_chunk: int = 1024,
                       q_offset: int = 0, scale: Optional[float] = None
                       ) -> jax.Array:
    """Scans KV in chunks carrying (running max, denominator, weighted sum);
    exact softmax, O(Sq * kv_chunk) live memory.  ``q_offset`` is the
    absolute position of q[0] for causal masking (decode: S_past)."""
    b, sq, h, dk = q.shape
    skv = k.shape[1]
    dv = v.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    # cap the chunk count at 64: beyond that the scan overhead dominates
    # (and analysis unrolling would blow up the HLO for 512k decode)
    kv_chunk = max(kv_chunk, -(-skv // 64))
    kv_chunk = min(-(-kv_chunk // 128) * 128, skv)
    n_chunks = (skv + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # operands stay bf16 (no full-tensor f32 copies — they become stacked
    # f32 buffers under scan); all score/accumulator math is f32 via
    # preferred_element_type on the dots.
    qf = q.transpose(0, 2, 1, 3)             # (B,H,Sq,Dk)
    kc = k.reshape(b, n_chunks, kv_chunk, h, dk)
    vc = v.reshape(b, n_chunks, kv_chunk, h, dv)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inputs):
        m, l, acc = carry
        idx, k_blk, v_blk = inputs           # (B,kc,H,Dk) / (B,kc,H,Dv)
        kT = k_blk.transpose(0, 2, 3, 1)     # (B,H,Dk,kc)
        s = jnp.einsum("bhqd,bhdk->bhqk", qf, kT,
                       preferred_element_type=jnp.float32) * scale
        kv_pos = idx * kv_chunk + jnp.arange(kv_chunk)
        mask = kv_pos[None, :] < skv         # padding mask (1,kc)
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new = -inf): exp(-inf - -inf) -> use 0
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        # masked entries have s = -inf => exp yields exactly 0, so no second
        # mask pass is needed (saves a full (B,H,Sq,kc) f32 read+write per
        # chunk — §Perf iteration A)
        p = jnp.exp(s - safe_m[..., None])
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkv->bhqv", p.astype(v_blk.dtype),
            v_blk.transpose(0, 2, 1, 3),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = _scan(
        step, (m0, l0, a0),
        (jnp.arange(n_chunks), kc.transpose(1, 0, 2, 3, 4),
         vc.transpose(1, 0, 2, 3, 4)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Sq,H,Dv)


def _direct_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             q_offset) -> jax.Array:
    """Single-token attention over the full cache, no chunk scan.

    q: (B,1,H,D); k/v: (B,S,KVH,D) — the GQA grouping is contracted
    in-place (no `_repeat_kv` materialization: repeating a seq-sharded
    cache forces an involuntary GSPMD rematerialization, measured 2.1 GB
    f32 per layer).  Scores stay sequence-sharded; softmax/value
    reductions lower to (B,H,1)-sized stat psums (context-parallel
    decode).
    """
    b, s, kvh, dk = k.shape
    h = q.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(q.shape[-1])
    # replicate the (tiny) q over model so the score einsum computes on the
    # sequence-sharded cache IN PLACE; otherwise GSPMD keeps q's head
    # sharding and all-gathers the multi-GB cache instead.
    q = constrain(q, ("batch", None, None, None))
    qg = q.reshape(b, 1, kvh, g, dk)
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                    preferred_element_type=jnp.float32) * scale
    sc = constrain(sc, ("batch", None, None, None, "model"))
    kv_pos = jnp.arange(s)
    mask = kv_pos[None, None, None, None, :] <= q_offset
    sc = jnp.where(mask, sc, -jnp.inf)
    # softmax over the sharded axis: max/sum lower to (B,H,1) stat psums
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    # pin the TINY output replicated: otherwise the downstream heads
    # constraint propagates INTO this einsum and reshards the multi-GB v
    # (involuntary GSPMD remat); resharding (B,1,H,D) instead is free.
    out = constrain(out, ("batch", None, None, None, None))
    return out.reshape(b, 1, h, dk).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA (qwen3 / llama / whisper / chameleon / zamba2-shared)
# ---------------------------------------------------------------------------

def init_gqa(init, cfg: ModelConfig, prefix: str = "attn") -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    p = {
        "wq": init.dense((d, cfg.n_heads * hd)),
        "wk": init.dense((d, cfg.n_kv_heads * hd)),
        "wv": init.dense((d, cfg.n_kv_heads * hd)),
        "wo": init.dense((cfg.n_heads * hd, d)),
    }
    if cfg.attn_bias:
        p["bq"] = init.zeros((cfg.n_heads * hd,))
        p["bk"] = init.zeros((cfg.n_kv_heads * hd,))
        p["bv"] = init.zeros((cfg.n_kv_heads * hd,))
    if cfg.qk_norm:
        p["q_norm"] = init.ones((hd,))
        p["k_norm"] = init.ones((hd,))
    return p


def gqa_attention(ctx: QuantContext, p: dict, x: jax.Array, cfg: ModelConfig,
                  *, positions: jax.Array, cache: Optional[KVCache] = None,
                  cache_pos: Optional[jax.Array] = None,
                  causal: bool = True, kv_x: Optional[jax.Array] = None,
                  use_rope: bool = True, kv_chunk: int = 1024,
                  block_tables: Optional[jax.Array] = None,
                  ragged: Optional[RaggedBatch] = None,
                  name: str = "attn") -> tuple[jax.Array, Optional[KVCache]]:
    """GQA with optional qk_norm, KV cache (decode) and cross-attn (kv_x).

    cache semantics: if ``cache`` is given, new K/V are written at
    ``cache_pos`` (scalar step index) and attention runs over the full
    cache (decode); otherwise attention is over the local sequence.

    Paged serving (DESIGN §9): with ``cache`` a :class:`PagedKVCache` the
    new K/V codes are scattered into the block pool through
    ``block_tables`` at per-token absolute positions ``cache_pos`` (shape
    (B, S) — continuous batching decodes every slot at its own position)
    and attention runs over the pool via ``ops.paged_attention``.

    Unified ragged serving (DESIGN §12): with ``cache`` a
    :class:`PagedKVCache` and ``ragged`` a :class:`RaggedBatch`, ``x`` is
    the whole MIXED step as one (1, T, d) stream; codes scatter via the
    precomputed ``ragged.dest`` rows and attention runs in ONE
    ``ops.ragged_attention`` dispatch for every traffic class at once.
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    src = x if kv_x is None else kv_x

    q = linear(ctx, f"{name}/wq", x, p["wq"], p.get("bq"))
    k = linear(ctx, f"{name}/wk", src, p["wk"], p.get("bk"))
    # wo's input grid is THREADED from wv's output grid (DESIGN §13,
    # lm_calibrate.DATAFLOW_CHAIN): attention rows are softmax-convex
    # combinations of V rows, so the wo input lives inside wv's range.
    v = linear(ctx, f"{name}/wv", src, p["wv"], p.get("bv"))
    q = constrain(q.reshape(b, s, h, hd), ("batch", None, "heads", None))
    k = constrain(k.reshape(b, src.shape[1], kvh, hd),
                  ("batch", None, "heads", None))
    v = constrain(v.reshape(b, src.shape[1], kvh, hd),
                  ("batch", None, "heads", None))

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        kv_positions = positions if kv_x is None else jnp.arange(src.shape[1])[None]
        k = apply_rope(k, kv_positions, cfg.rope_theta)

    if isinstance(cache, PagedKVCache) and ragged is not None:
        # unified ragged path (DESIGN §12): the batch IS one flattened
        # (1, T) stream mixing prefill chunks, decode rows, and spec
        # tails.  Quantize once, scatter each token's codes to its
        # host-precomputed pool row (padding rows land in the trash
        # block), then attend in ONE ragged dispatch.
        assert b == 1, "ragged serving flattens the batch to (1, T)"
        nb_pool, bs_blk = cache.k.shape[0], cache.k.shape[1]
        kv_frac_bits = None
        if cache.k.dtype == jnp.int8:
            from repro.core.qscheme import quant
            kv_frac_bits = cfg.kv_cache_frac_bits
            k_c, v_c = quant(k, kv_frac_bits, 8), quant(v, kv_frac_bits, 8)
        else:
            k_c, v_c = k.astype(cache.k.dtype), v.astype(cache.v.dtype)
        k_pool = cache.k.reshape(nb_pool * bs_blk, kvh, hd).at[
            ragged.dest].set(k_c.reshape(-1, kvh, hd)).reshape(cache.k.shape)
        v_pool = cache.v.reshape(nb_pool * bs_blk, kvh, hd).at[
            ragged.dest].set(v_c.reshape(-1, kvh, hd)).reshape(cache.v.shape)
        from repro.kernels import ops as kops
        out = kops.ragged_attention(
            q[0], k_pool, v_pool, ragged.block_tables, ragged.q_start,
            ragged.q_len, ragged.kv_len, kv_frac_bits=kv_frac_bits,
            mesh=current_mesh(), shard_axis=cfg.attn_shard_axis)[None]
        out = constrain(out.reshape(b, s, h * hd), ("batch", None, "heads"))
        return (linear(ctx, f"{name}/wo", out, p["wo"]),
                PagedKVCache(k_pool, v_pool))

    if isinstance(cache, PagedKVCache):
        # serving-engine paged path (DESIGN §9): quantize ONCE, scatter the
        # codes into the slot's pool blocks at their absolute positions,
        # then attend over the pool.  ``cache_pos`` is (B, S): each slot in
        # the fixed-width batch is at its OWN live length (decode, S=1) or
        # its chunk's position range (chunked prefill, S=chunk).
        assert block_tables is not None and cache_pos is not None
        nb_pool, bs_blk = cache.k.shape[0], cache.k.shape[1]
        kv_frac_bits = None
        if cache.k.dtype == jnp.int8:
            from repro.core.qscheme import quant
            kv_frac_bits = cfg.kv_cache_frac_bits
            k_c, v_c = quant(k, kv_frac_bits, 8), quant(v, kv_frac_bits, 8)
        else:
            k_c, v_c = k.astype(cache.k.dtype), v.astype(cache.v.dtype)
        blk = jnp.take_along_axis(block_tables, cache_pos // bs_blk, axis=1)
        idx = (blk * bs_blk + cache_pos % bs_blk).reshape(-1)    # (B*S,)
        k_pool = cache.k.reshape(nb_pool * bs_blk, kvh, hd).at[idx].set(
            k_c.reshape(-1, kvh, hd)).reshape(cache.k.shape)
        v_pool = cache.v.reshape(nb_pool * bs_blk, kvh, hd).at[idx].set(
            v_c.reshape(-1, kvh, hd)).reshape(cache.v.shape)
        from repro.kernels import ops as kops
        out = kops.paged_attention(q, k_pool, v_pool, block_tables,
                                   cache_pos, kv_frac_bits=kv_frac_bits,
                                   mesh=current_mesh(),
                                   shard_axis=cfg.attn_shard_axis)
        out = constrain(out.reshape(b, s, h * hd), ("batch", None, "heads"))
        return (linear(ctx, f"{name}/wo", out, p["wo"]),
                PagedKVCache(k_pool, v_pool))

    # 'flash' routes the hot paths through the fused Pallas kernel
    # (DESIGN §2): int8 KV codes are read straight into VMEM and bit-shift
    # dequantized in-register, so the bf16 cache copy below is skipped.
    use_flash = cfg.attn_kernel == "flash"
    kv_frac_bits = None

    new_cache = None
    q_offset = 0
    if cache is not None:
        if cache.k.dtype == jnp.int8:
            # int8 KV cache: write Eq.-1 codes, read back via bit-shift
            # dequant (power-of-two grid, static fractional bits)
            from repro.core.qscheme import dequant, quant
            nkv = cfg.kv_cache_frac_bits
            k_c = quant(k, nkv, 8)
            v_c = quant(v, nkv, 8)
            k_full = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k_c, cache_pos, 1)
            v_full = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v_c, cache_pos, 1)
            new_cache = KVCache(k_full, v_full)
            if use_flash:
                # the whole point: no dequantized HBM copy — the kernel
                # consumes the codes directly
                k, v = k_full, v_full
                kv_frac_bits = nkv
            else:
                k = dequant(k_full, nkv, out_dtype=x.dtype)
                v = dequant(v_full, nkv, out_dtype=x.dtype)
        else:
            k_full = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k, cache_pos, 1)
            v_full = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v, cache_pos, 1)
            new_cache = KVCache(k_full, v_full)
            k, v = k_full, v_full
        q_offset = cache_pos

    groups = h // kvh
    if cache is not None and s == 1:
        if use_flash:
            # fused decode kernel: cache read in place (int8 codes straight
            # to VMEM), grouped heads share one KV tile DMA, traced position
            # arrives via scalar prefetch.  Under a multi-device mesh the
            # call crosses a shard_map boundary with the cache resident
            # HEAD-sharded on cfg.attn_shard_axis (DESIGN §8) — pin the
            # operands there so GSPMD hands them over without a reshard.
            from repro.kernels import ops as kops
            k = constrain(k, ("batch", None, "heads", None))
            v = constrain(v, ("batch", None, "heads", None))
            out = kops.flash_decode(q, k, v, pos=q_offset,
                                    kv_frac_bits=kv_frac_bits,
                                    mesh=current_mesh(),
                                    shard_axis=cfg.attn_shard_axis)
        else:
            # decode: direct attention over the SEQUENCE-sharded cache
            # (flash-decode): scores/values reduce over the seq axis, so the
            # only collectives are (B,H,1)-sized softmax stats — vs
            # re-gathering the whole cache when sharded on (non-dividing) kv
            # heads (§Perf iteration D2: 128 GB/step -> ~0 on qwen3-32b
            # decode_32k).  GQA grouping is contracted in place — no KV
            # repeat materializes.
            out = _direct_decode_attention(q, k, v, q_offset)
    elif use_flash and isinstance(q_offset, int):
        # prefill / train: q-tiled x kv-tiled fused kernel; GQA contracted
        # via the kernel index maps (no _repeat_kv), int8 codes (if any)
        # dequantized in-register.  q_offset is static here by construction.
        from repro.kernels import ops as kops
        k = constrain(k, ("batch", None, "heads", None))
        v = constrain(v, ("batch", None, "heads", None))
        out = kops.flash_attention(q, k, v, causal=causal and kv_x is None,
                                   q_offset=q_offset,
                                   kv_frac_bits=kv_frac_bits,
                                   mesh=current_mesh(),
                                   shard_axis=cfg.attn_shard_axis)
    else:
        if kv_frac_bits is not None:
            # flash requested but unusable (traced multi-token offset):
            # restore the reference dequantize-then-attend dataflow
            from repro.core.qscheme import dequant
            k = dequant(k, kv_frac_bits, out_dtype=x.dtype)
            v = dequant(v, kv_frac_bits, out_dtype=x.dtype)
        k = constrain(_repeat_kv(k, groups), ("batch", None, "heads", None))
        v = constrain(_repeat_kv(v, groups), ("batch", None, "heads", None))
        out = chunked_attention(q, k, v, causal=causal and kv_x is None,
                                kv_chunk=kv_chunk, q_offset=q_offset)
    out = constrain(out.reshape(b, s, h * hd), ("batch", None, "heads"))
    return linear(ctx, f"{name}/wo", out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V3 multi-head latent attention with absorbed decode
# ---------------------------------------------------------------------------

def init_mla(init, cfg: ModelConfig) -> dict:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": init.dense((d, m.q_lora_rank)),
        "q_norm": init.ones((m.q_lora_rank,)),
        "wq_b": init.dense((m.q_lora_rank, h * qk_head)),
        "wkv_a": init.dense((d, m.kv_lora_rank + m.qk_rope_head_dim)),
        "kv_norm": init.ones((m.kv_lora_rank,)),
        "wkv_b": init.dense((m.kv_lora_rank,
                             h * (m.qk_nope_head_dim + m.v_head_dim))),
        "wo": init.dense((h * m.v_head_dim, d)),
    }


def mla_attention(ctx: QuantContext, p: dict, x: jax.Array, cfg: ModelConfig,
                  *, positions: jax.Array, cache: Optional[MLACache] = None,
                  cache_pos: Optional[jax.Array] = None,
                  kv_chunk: int = 1024, name: str = "mla"
                  ) -> tuple[jax.Array, Optional[MLACache]]:
    """MLA forward.  Prefill/train: expanded K/V per token.  Decode: the
    *absorbed* formulation — W_uk folds into q, W_uv into the output, so
    attention runs in the (kv_lora + rope) latent space and the cache stays
    compressed.  That IS MLA's contribution; keeping it preserves the
    memory roofline the architecture was designed for."""
    m: MLAConfig = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope_d, vdim = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    cq = rmsnorm(linear(ctx, f"{name}/wq_a", x, p["wq_a"]), p["q_norm"],
                 cfg.norm_eps)
    q = linear(ctx, f"{name}/wq_b", cq, p["wq_b"])
    q = constrain(q.reshape(b, s, h, nope + rope_d),
                  ("batch", None, "heads", None))
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    kv_a = linear(ctx, f"{name}/wkv_a", x, p["wkv_a"])
    c_kv, k_pe = kv_a[..., :m.kv_lora_rank], kv_a[..., m.kv_lora_rank:]
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    scale = 1.0 / math.sqrt(nope + rope_d)

    if cache is None:
        # expanded path (train / prefill)
        kv = linear(ctx, f"{name}/wkv_b", c_kv, p["wkv_b"])
        kv = constrain(kv.reshape(b, s, h, nope + vdim),
                       ("batch", None, "heads", None))
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (b, s, h, rope_d))],
            axis=-1)
        qq = jnp.concatenate([q_nope, q_pe], axis=-1)
        if cfg.attn_kernel == "flash":
            # fused prefill kernel (groups=1; dk=nope+rope is padded to the
            # lane multiple inside the wrapper); shard_map'd over full heads
            # on a multi-device mesh (kvh == h here)
            from repro.kernels import ops as kops
            out = kops.flash_attention(qq, k, v, causal=True, scale=scale,
                                       mesh=current_mesh(),
                                       shard_axis=cfg.attn_shard_axis)
        else:
            out = chunked_attention(qq, k, v, causal=True, kv_chunk=kv_chunk,
                                    scale=scale)
        out = constrain(out.reshape(b, s, h * vdim), ("batch", None, "heads"))
        return linear(ctx, f"{name}/wo", out, p["wo"]), None

    # absorbed decode path — cache holds (c_kv, k_pe) only
    c_full = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_kv, cache_pos, 1)
    pe_full = jax.lax.dynamic_update_slice_in_dim(cache.k_pe, k_pe, cache_pos, 1)
    new_cache = MLACache(c_full, pe_full)

    w_kv_b = p["wkv_b"].reshape(m.kv_lora_rank, h, nope + vdim)
    w_uk = w_kv_b[..., :nope]                     # (lora, H, nope)
    w_uv = w_kv_b[..., nope:]                     # (lora, H, vdim)
    # absorb W_uk into q:  (B,S,H,nope) x (lora,H,nope) -> (B,S,H,lora)
    q_lat = jnp.einsum("bshd,lhd->bshl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    s_lat = jnp.einsum("bshl,btl->bhst", q_lat,
                       c_full.astype(jnp.float32))
    s_pe = jnp.einsum("bshd,btd->bhst", q_pe.astype(jnp.float32),
                      pe_full.astype(jnp.float32))
    scores = (s_lat + s_pe) * scale
    # positions: (B, S) absolute positions of the query tokens
    t_pos = jnp.arange(c_full.shape[1])
    mask = t_pos[None, None, None, :] <= positions[:, None, :, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhst,btl->bshl", probs,
                         c_full.astype(jnp.float32))
    out = jnp.einsum("bshl,lhv->bshv", ctx_lat, w_uv.astype(jnp.float32))
    out = out.reshape(b, s, h * vdim).astype(x.dtype)
    return linear(ctx, f"{name}/wo", out, p["wo"]), new_cache
