"""Feed-forward layers: SwiGLU MLP and token-choice MoE (shared + routed).

Quantization placement (paper Fig. 1(b) generalized): the SwiGLU gate
product ``silu(w1 x) * (w3 x)`` is ONE unified module — a single activation
quant point after the product feeds the down-projection, instead of three
separate points.  The MoE router stays fp32 (tiny, numerically sensitive —
same reasoning as softmax in the paper).

MoE dispatch is capacity-based sort-free scatter (MaxText-style):
  1. top-k routing, probs renormalized;
  2. each (token, k) pair gets a position-in-expert by ranking;
  3. pairs scatter into an (E, C, d) buffer (overflow dropped — standard
     token dropping), experts run as ONE batched einsum (MXU-friendly,
     shards E over the model axis = expert parallelism);
  4. results gather back weighted by router probs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.qmodel import QuantContext
from repro.distributed.sharding import constrain, data_shards
from repro.models.common import linear

__all__ = ["init_mlp", "mlp", "init_moe", "moe", "moe_capacity"]


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "relu_sq":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def init_mlp(init, d_model: int, d_ff: int, act: str = "silu") -> dict:
    p = {"w1": init.dense((d_model, d_ff)),
         "w2": init.dense((d_ff, d_model), fan_in=d_ff)}
    if act in ("silu",):  # gated
        p["w3"] = init.dense((d_model, d_ff))
    return p


def mlp(ctx: QuantContext, p: dict, x: jax.Array, act: str = "silu",
        name: str = "mlp") -> jax.Array:
    if "w3" in p:
        g = _act(linear(ctx, f"{name}/w1", x, p["w1"]), act)
        u = linear(ctx, f"{name}/w3", x, p["w3"])
        h = g * u   # unified-module boundary: ONE quant point after product
        # w2's input grid is THREADED from w1's output grid (DESIGN §13,
        # lm_calibrate.DATAFLOW_CHAIN): |silu(g)| <= |g| bounds the gate
        # factor, so h lives inside w1's calibrated range.
    else:
        h = _act(linear(ctx, f"{name}/w1", x, p["w1"]), act)
    h = constrain(h, ("batch",) + (None,) * (h.ndim - 2) + ("ff",))
    return linear(ctx, f"{name}/w2", h, p["w2"])


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _qexpert(ctx: QuantContext, name: str, a: jax.Array, w: jax.Array
             ) -> jax.Array:
    """Quantized batched expert matmul (E,C,d) x (E,d,f) -> (E,C,f).

    Per-expert weights share one fractional bit per tensor-stack (scan-
    homogeneous); int mode runs int8 x int8 -> int32 with a shift requant,
    the paper's Eq. 3 applied expert-parallel.
    """
    from repro.core.qmodel import QuantMode
    from repro.core.qscheme import dequant, fake_quant, quant, shift_requant

    dn = (((2,), (1,)), ((0,), (0,)))
    if ctx.mode == QuantMode.FP:
        return jax.lax.dot_general(a, w.astype(a.dtype), dn)
    mb = ctx.bits_for(name)
    if ctx.mode == QuantMode.FAKE:
        aq = fake_quant(a, mb.n_x, ctx.bits)
        wq = fake_quant(w, mb.n_w, ctx.bits).astype(a.dtype)
        return jax.lax.dot_general(aq, wq, dn)
    a_i = quant(a, mb.n_x, ctx.bits)
    w_i = w if w.dtype == jnp.int8 else quant(w, mb.n_w, ctx.bits)
    acc = jax.lax.dot_general(a_i, w_i, dn,
                              preferred_element_type=jnp.int32)
    o_i = shift_requant(acc, (mb.n_x + mb.n_w) - mb.n_o, bits=ctx.bits)
    return dequant(o_i, mb.n_o, out_dtype=a.dtype)


def moe_capacity(n_tokens: int, mcfg: MoEConfig) -> int:
    """Per-expert capacity C = ceil(T * top_k / E * cf), padded to 128 lanes."""
    c = int(n_tokens * mcfg.top_k / mcfg.n_experts * mcfg.capacity_factor)
    return max(128, -(-c // 128) * 128)


def init_moe(init, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, de = cfg.d_model, m.d_expert
    e = m.e_padded  # stacks padded to the TP axis; router covers real E only
    p = {
        "router": init.dense((d, m.n_experts)).astype(jnp.float32),
        # stacked expert weights: (E, d, de) — ONE batched matmul, EP-shardable
        "w1": init.dense((e, d, de)),
        "w3": init.dense((e, d, de)),
        "w2": init.dense((e, de, d), fan_in=de),
    }
    if m.n_shared:
        p["shared"] = init_mlp(init, d, m.d_expert * m.n_shared, cfg.act)
    return p


def moe(ctx: QuantContext, p: dict, x: jax.Array, cfg: ModelConfig,
        name: str = "moe") -> jax.Array:
    """Token-choice top-k MoE over a (B, S, d) activation.

    Dispatch is HIERARCHICAL (EP-style): ranking, dropping and the (TK, d)
    token-row intermediates are all computed per data-shard (the cumsum and
    gathers reshape to a leading ``data_shards()`` axis, so GSPMD keeps them
    local); each shard owns its own slice of every expert's capacity.  The
    only cross-device traffic is the expert-buffer exchange (the EP
    all-to-all) — a flat global ranking instead makes GSPMD replicate the
    (8.4M, 7168) dispatch rows and all-reduce them (observed 240 GB/device
    buffers on deepseek-v3 train_4k).
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    ds = data_shards()
    xt = constrain(x.reshape(t, d), ("batch", None))
    cap_local = -(-moe_capacity(t, m) // ds)
    cap = cap_local * ds

    # --- routing (fp32) ---
    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)             # (T, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalize

    # --- shard-local position-in-expert ranking ---
    tk = t * m.top_k
    tkl = tk // ds                                           # pairs per shard
    flat_e = constrain(top_e.reshape(ds, tkl), ("batch", None))
    flat_p = constrain(top_p.reshape(ds, tkl), ("batch", None))
    one_hot = jax.nn.one_hot(flat_e, m.e_padded, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(one_hot, axis=1) * one_hot         # local cumsum
    rank = jnp.sum(pos_in_e, axis=-1) - 1                    # (ds, TK/ds)
    keep = rank < cap_local                                  # drop overflow
    safe_rank = jnp.where(keep, rank, cap_local - 1)
    ds_iota = jnp.arange(ds)[:, None]

    # --- dispatch rows: pure broadcast (no gather -> no GSPMD reshard) ---
    rows = jnp.broadcast_to(xt[:, None, :], (t, m.top_k, d))
    rows = constrain(rows.reshape(ds, tkl, d), ("batch", None, None))
    rows = jnp.where(keep[..., None], rows, 0)

    # --- shard-local scatter into per-shard expert buffers ---
    # buf_parts dims: (shard, E, C_local, d); dynamic indices touch only
    # the UNSHARDED dims 1-2, so the scatter stays device-local.
    buf_parts = jnp.zeros((ds, m.e_padded, cap_local, d), xt.dtype)
    buf_parts = buf_parts.at[ds_iota, flat_e, safe_rank].add(rows)
    buf_parts = constrain(buf_parts, ("batch", None, None, None))

    # --- THE EP exchange: (shard, E, C_l, d) -> (E, shard*C_l, d) ---
    # a transpose across the sharded dim = all-to-all, the only global
    # communication in the MoE layer.
    buf = buf_parts.transpose(1, 0, 2, 3).reshape(m.e_padded, cap, d)
    buf = constrain(buf, ("expert", "batch", None))

    # --- expert FFN: batched SwiGLU einsum, E shards over the model axis ---
    g = jax.nn.silu(_qexpert(ctx, f"{name}/w1", buf, p["w1"]))
    u = _qexpert(ctx, f"{name}/w3", buf, p["w3"])
    h = constrain(g * u, ("expert", "batch", None))          # joint quant point
    out_buf = constrain(_qexpert(ctx, f"{name}/w2", h, p["w2"]),
                        ("expert", "batch", None))

    # --- reverse EP exchange + shard-local gather + combine ---
    out_parts = out_buf.reshape(m.e_padded, ds, cap_local, d) \
        .transpose(1, 0, 2, 3)
    out_parts = constrain(out_parts, ("batch", None, None, None))
    gathered = out_parts[ds_iota, flat_e, safe_rank]         # (ds, TK/ds, d)
    weighted = jnp.where(keep[..., None], gathered, 0) * \
        flat_p[..., None].astype(gathered.dtype)
    out = jnp.sum(weighted.reshape(t, m.top_k, d), axis=1).astype(x.dtype)
    out = constrain(out, ("batch", None))

    if m.n_shared:
        out = out + mlp(ctx, p["shared"], xt, cfg.act, name=f"{name}/shared")
    return out.reshape(b, s, d)
