"""Block assembly for every architecture family.

Blocks are homogeneous pure functions scanned over stacked params
(``jax.lax.scan`` keeps the HLO size O(1) in depth — 95-layer configs
compile in seconds instead of minutes, and remat policies apply uniformly).

Families:
  dense   — pre-norm GQA + SwiGLU (qwen3 / llama / deepseek-67b / chameleon)
  moe     — pre-norm attention (GQA or MLA) + MoE FFN (granite / deepseek-v3;
            deepseek-v3 keeps its first k layers dense — two scan stacks)
  encdec  — whisper: encoder (bidirectional) + decoder (causal + cross-attn)
  rwkv    — RWKV6 time-mix + channel-mix
  hybrid  — zamba2: groups of Mamba2 blocks + ONE shared GQA block applied
            between groups (two-level scan; shared params broadcast)
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from repro.models.scan_lib import scan as _scan

from repro.configs.base import ModelConfig
from repro.core.qmodel import QuantContext
from repro.distributed.sharding import constrain
from repro.models import attention as att
from repro.models import mlp as mlp_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.common import Initializer, linear, rmsnorm

__all__ = ["init_dense_block", "dense_block", "init_moe_block", "moe_block",
           "init_rwkv_block", "rwkv_block_fwd", "init_hybrid_group",
           "hybrid_group_fwd", "BlockCache"]

BlockCache = Any  # per-family cache pytree


# ---------------------------------------------------------------------------
# dense / moe transformer blocks
# ---------------------------------------------------------------------------

def init_dense_block(init: Initializer, cfg: ModelConfig) -> dict:
    p = {
        "ln1": init.ones((cfg.d_model,)),
        "ln2": init.ones((cfg.d_model,)),
        "mlp": mlp_lib.init_mlp(init, cfg.d_model, cfg.d_ff, cfg.act),
    }
    if cfg.mla is not None:
        p["attn"] = att.init_mla(init, cfg)
    else:
        p["attn"] = att.init_gqa(init, cfg)
    return p


def _attn_dispatch(ctx, p, x, cfg, positions, cache, cache_pos,
                   use_rope=True, block_tables=None, ragged=None):
    if cfg.mla is not None:
        if block_tables is not None or ragged is not None:
            raise NotImplementedError(
                "paged serving covers GQA caches only; MLA's compressed "
                "latent cache has no block-pool layout yet (DESIGN §9)")
        return att.mla_attention(ctx, p["attn"], x, cfg, positions=positions,
                                 cache=cache, cache_pos=cache_pos)
    return att.gqa_attention(ctx, p["attn"], x, cfg, positions=positions,
                             cache=cache, cache_pos=cache_pos,
                             use_rope=use_rope, block_tables=block_tables,
                             ragged=ragged)


def dense_block(ctx: QuantContext, p: dict, x: jax.Array, cfg: ModelConfig,
                *, positions: jax.Array, cache=None, cache_pos=None,
                use_rope: bool = True, block_tables=None, ragged=None):
    # W8A8 (DESIGN §13): all quantization lives inside the qlinear
    # modules; residual adds and rmsnorms run in float between module
    # grids, so a block over int8 weight codes is bit-identical to the
    # float-weight INT forward module-for-module (the parity rig's
    # full-layer case leans on exactly this).
    h, new_cache = _attn_dispatch(ctx, p, rmsnorm(x, p["ln1"], cfg.norm_eps),
                                  cfg, positions, cache, cache_pos, use_rope,
                                  block_tables, ragged)
    x = constrain(x + h, ("batch", None, None))
    x = x + mlp_lib.mlp(ctx, p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps),
                        cfg.act)
    return constrain(x, ("batch", None, None)), new_cache


def init_moe_block(init: Initializer, cfg: ModelConfig) -> dict:
    p = {
        "ln1": init.ones((cfg.d_model,)),
        "ln2": init.ones((cfg.d_model,)),
        "moe": mlp_lib.init_moe(init, cfg),
    }
    if cfg.mla is not None:
        p["attn"] = att.init_mla(init, cfg)
    else:
        p["attn"] = att.init_gqa(init, cfg)
    return p


def moe_block(ctx: QuantContext, p: dict, x: jax.Array, cfg: ModelConfig,
              *, positions: jax.Array, cache=None, cache_pos=None,
              block_tables=None):
    h, new_cache = _attn_dispatch(ctx, p, rmsnorm(x, p["ln1"], cfg.norm_eps),
                                  cfg, positions, cache, cache_pos,
                                  block_tables=block_tables)
    x = constrain(x + h, ("batch", None, None))
    x = x + mlp_lib.moe(ctx, p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
    return constrain(x, ("batch", None, None)), new_cache


# ---------------------------------------------------------------------------
# whisper enc-dec blocks (no rope; sinusoidal positions added at embed time)
# ---------------------------------------------------------------------------

def init_encoder_block(init: Initializer, cfg: ModelConfig) -> dict:
    return {
        "ln1": init.ones((cfg.d_model,)),
        "ln2": init.ones((cfg.d_model,)),
        "attn": att.init_gqa(init, cfg),
        "mlp": mlp_lib.init_mlp(init, cfg.d_model, cfg.d_ff, "gelu"),
    }


def encoder_block(ctx: QuantContext, p: dict, x: jax.Array, cfg: ModelConfig):
    h, _ = att.gqa_attention(ctx, p["attn"],
                             rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
                             positions=jnp.arange(x.shape[1])[None],
                             causal=False, use_rope=False)
    x = x + h
    x = x + mlp_lib.mlp(ctx, p["mlp"],
                        rmsnorm(x, p["ln2"], cfg.norm_eps), "gelu")
    return x


def init_decoder_block(init: Initializer, cfg: ModelConfig) -> dict:
    return {
        "ln1": init.ones((cfg.d_model,)),
        "ln_cross": init.ones((cfg.d_model,)),
        "ln2": init.ones((cfg.d_model,)),
        "attn": att.init_gqa(init, cfg),
        "cross": att.init_gqa(init, cfg),
        "mlp": mlp_lib.init_mlp(init, cfg.d_model, cfg.d_ff, "gelu"),
    }


def decoder_block(ctx: QuantContext, p: dict, x: jax.Array, memory: jax.Array,
                  cfg: ModelConfig, *, positions, cache=None, cache_pos=None):
    h, new_cache = att.gqa_attention(
        ctx, p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
        positions=positions, cache=cache, cache_pos=cache_pos,
        use_rope=True)  # rope in lieu of whisper's learned positions (DESIGN §7)
    x = x + h
    h, _ = att.gqa_attention(
        ctx, p["cross"], rmsnorm(x, p["ln_cross"], cfg.norm_eps), cfg,
        positions=positions, kv_x=memory, use_rope=False, name="cross")
    x = x + h
    x = x + mlp_lib.mlp(ctx, p["mlp"],
                        rmsnorm(x, p["ln2"], cfg.norm_eps), "gelu")
    return x, new_cache


# ---------------------------------------------------------------------------
# rwkv6 block
# ---------------------------------------------------------------------------

def init_rwkv_block(init: Initializer, cfg: ModelConfig) -> dict:
    return {
        "ln1": init.ones((cfg.d_model,)),
        "ln2": init.ones((cfg.d_model,)),
        "rwkv": rwkv_lib.init_rwkv6(init, cfg),
    }


def rwkv_block_fwd(ctx: QuantContext, p: dict, x: jax.Array, cfg: ModelConfig,
                   state: Optional[rwkv_lib.RWKVState] = None):
    att_in = rmsnorm(x, p["ln1"], cfg.norm_eps)
    att_out, _, st = rwkv_lib.rwkv6_block(ctx, p["rwkv"], att_in, cfg,
                                          state=state)
    x = x + att_out
    ffn_in = rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + rwkv_lib.rwkv6_channel_mix(
        ctx, p["rwkv"], ffn_in, cfg,
        x_prev=state.x_prev_ffn if state is not None else None)
    new_state = rwkv_lib.RWKVState(x_prev_att=att_in[:, -1:],
                                   x_prev_ffn=ffn_in[:, -1:], wkv=st.wkv)
    return x, new_state


def rwkv_block_paged(ctx: QuantContext, p: dict, x: jax.Array,
                     cfg: ModelConfig, state: rwkv_lib.RWKVState,
                     valid: jax.Array):
    """Right-padded batched RWKV block for the serving engine (§16).

    Every row advances by its own ``q_len = sum(valid)`` tokens in one
    fixed-shape call: invalid positions are inert inside the chunked WKV
    (r/k/v -> 0, log-decay -> 0), and the token-shift streams are gathered
    per-row at the last VALID position instead of ``[:, -1:]``.  Rows with
    ``q_len == 0`` (empty slots / trash-slab lanes) carry their state
    through bit-exactly."""
    b = x.shape[0]
    att_in = rmsnorm(x, p["ln1"], cfg.norm_eps)
    att_out, _, st = rwkv_lib.rwkv6_block(ctx, p["rwkv"], att_in, cfg,
                                          state=state, valid=valid)
    x = x + att_out
    ffn_in = rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + rwkv_lib.rwkv6_channel_mix(ctx, p["rwkv"], ffn_in, cfg,
                                       x_prev=state.x_prev_ffn)
    q_len = jnp.sum(valid.astype(jnp.int32), axis=1)
    last = jnp.maximum(q_len - 1, 0)
    rows = jnp.arange(b)
    keep = (q_len > 0)[:, None, None]
    new_state = rwkv_lib.RWKVState(
        x_prev_att=jnp.where(keep, att_in[rows, last][:, None],
                             state.x_prev_att),
        x_prev_ffn=jnp.where(keep, ffn_in[rows, last][:, None],
                             state.x_prev_ffn),
        wkv=st.wkv)
    return x, new_state


def rwkv_block_decode(ctx: QuantContext, p: dict, x: jax.Array,
                      cfg: ModelConfig, state: rwkv_lib.RWKVState):
    att_in = rmsnorm(x, p["ln1"], cfg.norm_eps)
    att_out, st = rwkv_lib.rwkv6_decode(ctx, p["rwkv"], att_in, cfg, state)
    x = x + att_out
    ffn_in = rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + rwkv_lib.rwkv6_channel_mix(ctx, p["rwkv"], ffn_in, cfg,
                                       x_prev=state.x_prev_ffn)
    new_state = rwkv_lib.RWKVState(x_prev_att=att_in, x_prev_ffn=ffn_in,
                                   wkv=st.wkv)
    return x, new_state


# ---------------------------------------------------------------------------
# zamba2 hybrid group: ``attn_every`` mamba blocks + shared GQA block
# ---------------------------------------------------------------------------

def init_mamba_block(init: Initializer, cfg: ModelConfig) -> dict:
    return {"ln": init.ones((cfg.d_model,)),
            "ssm": ssm_lib.init_mamba2(init, cfg)}


def init_shared_attn(init: Initializer, cfg: ModelConfig) -> dict:
    """The ONE shared transformer block (zamba2): sees concat(h, embed)."""
    d = cfg.d_model
    return {
        "in_proj": init.dense((2 * d, d), fan_in=2 * d),
        "ln1": init.ones((d,)),
        "ln2": init.ones((d,)),
        "attn": att.init_gqa(init, cfg),
        "mlp": mlp_lib.init_mlp(init, d, cfg.d_ff, cfg.act),
    }


def hybrid_group_fwd(ctx: QuantContext, group_p: dict, shared_p: dict,
                     x: jax.Array, x_embed: jax.Array, cfg: ModelConfig,
                     *, positions, ssm_states=None, attn_cache=None,
                     cache_pos=None, decode: bool = False,
                     block_tables=None, valid=None):
    """One group = ``attn_every`` stacked mamba blocks (inner scan) then the
    shared attention block.  ``group_p`` holds the stacked mamba block
    params (leading axis = attn_every); ssm_states likewise.

    Paged serving (§16) threads ``valid`` (B, S) into the Mamba blocks
    (invalid positions contribute nothing and do not decay the slab state)
    and ``block_tables`` into the shared attention block, whose cache then
    scatters through the block pool at per-token ``cache_pos``."""

    def inner(x_carry, inp):
        p_l, st_l = inp
        h_in = rmsnorm(x_carry, p_l["ln"], cfg.norm_eps)
        if decode:
            h, new_st = ssm_lib.mamba2_decode(ctx, p_l["ssm"], h_in, cfg, st_l)
        else:
            h, new_st = ssm_lib.mamba2(ctx, p_l["ssm"], h_in, cfg,
                                       init_state=st_l, valid=valid)
        return x_carry + h, new_st

    x, new_states = _scan(inner, x, (group_p, ssm_states))

    # shared attention block on concat(h, embedding) (zamba2 dataflow)
    z = jnp.concatenate([x, x_embed], axis=-1)
    z = linear(ctx, "shared/in_proj", z, shared_p["in_proj"])
    h, new_cache = att.gqa_attention(
        ctx, shared_p["attn"], rmsnorm(z, shared_p["ln1"], cfg.norm_eps),
        cfg, positions=positions, cache=attn_cache, cache_pos=cache_pos,
        block_tables=block_tables, name="shared/attn")
    z = z + h
    z = z + mlp_lib.mlp(ctx, shared_p["mlp"],
                        rmsnorm(z, shared_p["ln2"], cfg.norm_eps), cfg.act,
                        name="shared/mlp")
    return x + z, new_states, new_cache
