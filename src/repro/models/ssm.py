"""Mamba2 (SSD) block — used by zamba2-2.7b (hybrid) and as the generic
selective-SSM substrate.

Training/prefill uses the chunked state-space-duality algorithm: quadratic
attention *within* chunks (MXU-friendly matmuls) + a lax.scan carrying the
(H, P, N) state *across* chunks — O(S·chunk) memory, so the long_500k cells
stay sub-quadratic (the reason SSM/hybrid archs run that shape).

Decode is the O(1) recurrence: one conv-state shift + one state update.

Quantization applicability (DESIGN §4): in/out projections and the gate go
through ``qlinear`` (paper's scheme); the recurrent state update stays bf16 —
a power-of-two-rounded decay applied 500k times accumulates unbounded error,
so the paper's per-tensor scheme is *inapplicable inside the recurrence*.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from repro.models.scan_lib import scan as _scan

from repro.configs.base import ModelConfig, SSMConfig
from repro.core.qmodel import QuantContext
from repro.models.common import linear, rmsnorm

__all__ = ["SSMState", "init_mamba2", "mamba2", "mamba2_decode"]


class SSMState(NamedTuple):
    conv: jax.Array    # (B, d_conv-1, d_conv_in) rolling conv window
    ssm: jax.Array     # (B, H, P, N) recurrent state


def _dims(cfg: ModelConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    d_conv_in = d_inner + 2 * s.n_groups * s.d_state
    return s, d_inner, n_heads, d_conv_in


def zero_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMState:
    s, d_inner, n_heads, d_conv_in = _dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, s.d_conv - 1, d_conv_in), dtype),
        ssm=jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32))


def init_mamba2(init, cfg: ModelConfig) -> dict:
    s, d_inner, n_heads, d_conv_in = _dims(cfg)
    d = cfg.d_model
    return {
        # z (gate), xBC (conv path), dt — one fused in-projection
        "w_in": init.dense((d, d_inner + d_conv_in + n_heads)),
        "conv_w": init.dense((s.d_conv, d_conv_in), fan_in=s.d_conv),
        "conv_b": init.zeros((d_conv_in,)),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": init.ones((d_inner,)),
        "w_out": init.dense((d_inner, d), fan_in=d_inner),
    }


def _split_in(cfg: ModelConfig, zxbcdt: jax.Array):
    s, d_inner, n_heads, d_conv_in = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_conv_in]
    dt = zxbcdt[..., d_inner + d_conv_in:]
    return z, xbc, dt


def _causal_conv_train(xbc: jax.Array, w: jax.Array, b: jax.Array,
                       prev: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv over seq: xbc (B,S,C), w (K,C).

    ``prev`` is the (B, K-1, C) pre-conv window carried from the previous
    chunk (``SSMState.conv``); a zero window is exactly the classic
    left-zero-padding, so fresh sequences are unchanged and chunked
    prefill continues the conv stream without a boundary discontinuity."""
    k = w.shape[0]
    if prev is None:
        pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([prev.astype(xbc.dtype), xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for i in range(k):  # K=4: unrolled adds beat a conv call at this size
        out = out + pad[:, i:i + xbc.shape[1], :] * w[i]
    return out + b


def _ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
                 cmat: jax.Array, chunk: int, init_state: Optional[jax.Array]
                 ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (B,S,H,P) values; dt: (B,S,H) >0; a: (H,) = -exp(a_log) (negative);
    bmat/cmat: (B,S,G,N) with G groups broadcast over H.
    Returns y (B,S,H,P) and final state (B,H,P,N).
    """
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    pad = (-s) % chunk
    if pad:
        # zero x/dt => padded tokens neither contribute to nor decay the state
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_pad = s + pad
    nc = s_pad // chunk
    rep = h // g

    # per-token log decay  l_t = dt_t * a  (negative)
    la = dt * a[None, None, :]                              # (B,S,H)
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    lac = la.reshape(b, nc, chunk, h)
    bc = jnp.repeat(bmat.reshape(b, nc, chunk, g, n), rep, axis=3)
    cc = jnp.repeat(cmat.reshape(b, nc, chunk, g, n), rep, axis=3)

    cum = jnp.cumsum(lac, axis=2)                           # (B,NC,L,H)
    total = cum[:, :, -1]                                   # (B,NC,H)

    # ---- intra-chunk (quadratic in chunk length, MXU matmuls) ----
    # scores_{t,s} = (C_t . B_s) * exp(cum_t - cum_s) * dt_s  for s <= t
    cb = jnp.einsum("bnthm,bnshm->bnhts", cc, bc)           # (B,NC,H,L,L)
    decay = cum[..., :, None, :] - cum[..., None, :, :]     # (B,NC,L,L,H) t,s
    decay = decay.transpose(0, 1, 4, 2, 3)                  # (B,NC,H,L,L)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    w_ts = jnp.exp(jnp.where(causal, decay, -jnp.inf)) * cb
    w_ts = w_ts * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bnhts,bnshp->bnthp", w_ts, xc)

    # ---- chunk-boundary states ----
    # state contribution of chunk: sum_s exp(total - cum_s) dt_s x_s B_s^T
    w_s = jnp.exp(total[:, :, None, :] - cum) * dtc         # (B,NC,L,H)
    st = jnp.einsum("bnsh,bnshp,bnshm->bnhpm", w_s, xc, bc)

    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_step(prev, inp):
        st_k, tot_k = inp                                   # (B,H,P,N),(B,H)
        new = jnp.exp(tot_k)[:, :, None, None] * prev + st_k
        return new, prev                                    # emit state BEFORE chunk

    final, prevs = _scan(
        scan_step, s0,
        (st.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
        unroll_cap=1)
    prevs = prevs.transpose(1, 0, 2, 3, 4)                  # (B,NC,H,P,N)

    # ---- inter-chunk: y_t += C_t exp(cum_t) S_prev ----
    y_inter = jnp.einsum("bnthm,bnhpm->bnthp",
                         cc * jnp.exp(cum)[..., None], prevs)
    y = (y_intra + y_inter).reshape(b, s_pad, h, p)
    return y[:, :s], final


def mamba2(ctx: QuantContext, p: dict, x: jax.Array, cfg: ModelConfig,
           name: str = "ssm", init_state: Optional[SSMState] = None,
           valid: Optional[jax.Array] = None
           ) -> tuple[jax.Array, SSMState]:
    """Full-sequence Mamba2 forward (train / prefill). Returns final state.

    ``init_state`` continues a streamed sequence: its ``ssm`` seeds the
    SSD scan and its ``conv`` window seeds the causal conv, so chunked
    prefill matches the unchunked forward.  ``valid`` (B, S) bool masks
    trailing padding rows for the batched paged step: a masked position's
    dtp is zeroed, which makes it inert in the SSD recurrence (no decay:
    exp(0)=1, and no contribution: the dt multiplier is 0), and the
    returned conv window is gathered at each row's own valid length."""
    s, d_inner, n_heads, d_conv_in = _dims(cfg)
    b, seq, d = x.shape
    zxbcdt = linear(ctx, f"{name}/w_in", x, p["w_in"])
    z, xbc, dt = _split_in(cfg, zxbcdt)
    prev = init_state.conv if init_state is not None else None
    xbc = jax.nn.silu(
        _causal_conv_train(xbc, p["conv_w"], p["conv_b"], prev=prev))
    xs = xbc[..., :d_inner]
    bmat = xbc[..., d_inner:d_inner + s.n_groups * s.d_state]
    cmat = xbc[..., d_inner + s.n_groups * s.d_state:]
    bmat = bmat.reshape(b, seq, s.n_groups, s.d_state).astype(jnp.float32)
    cmat = cmat.reshape(b, seq, s.n_groups, s.d_state).astype(jnp.float32)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if valid is not None:
        dtp = jnp.where(valid[..., None], dtp, 0.0)
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(b, seq, n_heads, s.head_dim).astype(jnp.float32)

    chunk = min(s.chunk, seq)
    # checkpoint the SSD core (flash-style): its (B,NC,H,L,L) f32 intra-
    # chunk tensors otherwise persist for backward — 339 GB/device on
    # zamba2 train_4k (§Perf Z1); recompute them instead.
    ssd = jax.checkpoint(
        lambda xx, dd, bb, cc, st: _ssd_chunked(xx, dd, a, bb, cc, chunk, st))
    y, final = ssd(xh, dtp, bmat, cmat,
                   init_state.ssm if init_state else None)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(b, seq, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = linear(ctx, f"{name}/w_out", y, p["w_out"])
    # conv state = last d_conv-1 PRE-conv inputs (for streaming continuation)
    _, xbc_raw, _ = _split_in(cfg, zxbcdt)
    if valid is None:
        conv_tail = xbc_raw[:, -(s.d_conv - 1):, :]
    else:
        # per-row valid length: slide the window over [prev | xbc_raw] so a
        # row that fed q real tokens ends with the window covering its last
        # d_conv-1 REAL pre-conv rows (q=0 returns prev unchanged)
        win = (jnp.concatenate([prev.astype(xbc_raw.dtype), xbc_raw], axis=1)
               if prev is not None
               else jnp.pad(xbc_raw, ((0, 0), (s.d_conv - 1, 0), (0, 0))))
        q_len = jnp.sum(valid.astype(jnp.int32), axis=1)     # (B,)
        idx = q_len[:, None] + jnp.arange(s.d_conv - 1,
                                          dtype=jnp.int32)[None, :]
        conv_tail = jnp.take_along_axis(win, idx[..., None], axis=1)
    return out, SSMState(conv=conv_tail, ssm=final)


def mamba2_decode(ctx: QuantContext, p: dict, x: jax.Array, cfg: ModelConfig,
                  state: SSMState, name: str = "ssm"
                  ) -> tuple[jax.Array, SSMState]:
    """Single-token decode: x (B,1,d). O(1) in sequence length."""
    s, d_inner, n_heads, d_conv_in = _dims(cfg)
    b = x.shape[0]
    zxbcdt = linear(ctx, f"{name}/w_in", x, p["w_in"])
    z, xbc, dt = _split_in(cfg, zxbcdt)

    window = jnp.concatenate([state.conv, xbc], axis=1)      # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc_t = jax.nn.silu(conv_out)[:, None, :]
    new_conv = window[:, 1:, :]

    xs = xbc_t[..., :d_inner]
    bmat = xbc_t[..., d_inner:d_inner + s.n_groups * s.d_state]
    cmat = xbc_t[..., d_inner + s.n_groups * s.d_state:]
    bmat = bmat.reshape(b, s.n_groups, s.d_state).astype(jnp.float32)
    cmat = cmat.reshape(b, s.n_groups, s.d_state).astype(jnp.float32)
    rep = n_heads // s.n_groups
    bmat = jnp.repeat(bmat, rep, axis=1)                     # (B,H,N)
    cmat = jnp.repeat(cmat, rep, axis=1)
    dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dtp * a[None, :])                        # (B,H)
    xh = xs.reshape(b, n_heads, s.head_dim).astype(jnp.float32)

    upd = jnp.einsum("bh,bhp,bhn->bhpn", dtp, xh, bmat)
    new_ssm = decay[:, :, None, None] * state.ssm.astype(jnp.float32) + upd
    y = jnp.einsum("bhn,bhpn->bhp", cmat, new_ssm)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = linear(ctx, f"{name}/w_out", y, p["w_out"])
    return out, SSMState(conv=new_conv, ssm=new_ssm)
