"""The paper's own model family: ResNet with BatchNorm — the faithful
reproduction vehicle for Tables 1-3 / Fig. 2.

This path exercises every element of the paper verbatim:
  * BN folding into conv weights/biases at inference (paper §1.2.1),
  * Fig. 1 cases a-d (conv / conv+ReLU / residual+ReLU / residual),
  * Algorithm 1 sequential calibration over the dataflow plan,
  * the integer-only serve path (int8 codes + shift constants),
  * the unsigned post-ReLU fast path.

`quantize_resnet` returns both the calibrated fractional bits AND the
deployable integer artifacts, plus hooks used by the Fig. 2 stats bench.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.resnet_paper import ResNetConfig
from repro.core import calibrate, dataflow, integer_ops, qscheme
from repro.models.common import Initializer

__all__ = ["init_resnet", "resnet_forward", "fold_bn", "build_resnet_plan",
           "quantize_resnet", "resnet_int_forward", "QuantizedResNet"]


def init_resnet(cfg: ResNetConfig, key: jax.Array) -> dict:
    init = Initializer(key, jnp.float32)
    p: dict = {"stem": _conv_init(init, 3, cfg.stages[0], 3)}
    blocks = []
    for si, ch in enumerate(cfg.stages):
        for bi in range(cfg.blocks_per_stage):
            cin = cfg.stages[max(si - 1, 0)] if bi == 0 else ch
            blk = {
                "conv1": _conv_init(init, cin, ch, 3),
                "conv2": _conv_init(init, ch, ch, 3),
            }
            if cin != ch:
                blk["proj"] = _conv_init(init, cin, ch, 1)
            blocks.append(blk)
    p["blocks"] = blocks
    p["head"] = {"w": init.dense((cfg.stages[-1], cfg.n_classes))
                 .astype(jnp.float32),
                 "b": jnp.zeros((cfg.n_classes,), jnp.float32)}
    return p


def _conv_init(init: Initializer, cin: int, cout: int, k: int) -> dict:
    return {
        "w": init.dense((k, k, cin, cout), fan_in=k * k * cin)
        .astype(jnp.float32),
        "bn_gamma": jnp.ones((cout,), jnp.float32),
        "bn_beta": jnp.zeros((cout,), jnp.float32),
        "bn_mean": jnp.zeros((cout,), jnp.float32),
        "bn_var": jnp.ones((cout,), jnp.float32),
    }


def fold_bn(conv: dict, eps: float = 1e-5) -> tuple[jax.Array, jax.Array]:
    """Paper §1.2.1: merge BN into the conv's weights and bias.

    y = gamma * (conv(x) - mean) / sqrt(var + eps) + beta
      = conv(x; W * s) + (beta - mean * s),  s = gamma / sqrt(var + eps)
    """
    s = conv["bn_gamma"] / jnp.sqrt(conv["bn_var"] + eps)
    w = conv["w"] * s[None, None, None, :]
    b = conv["bn_beta"] - conv["bn_mean"] * s
    return w, b


def _conv(x, w, b, stride=1):
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def resnet_forward(p: dict, x: jax.Array, cfg: ResNetConfig,
                   collect: Optional[dict] = None) -> jax.Array:
    """FP reference forward (BN pre-folded).  ``collect`` captures
    intermediate module outputs for calibration (name -> array)."""

    def log(name, v):
        if collect is not None:
            collect[name] = v
        return v

    w, b = fold_bn(p["stem"])
    h = log("stem", jax.nn.relu(_conv(x, w, b)))
    bi = 0
    for si, ch in enumerate(cfg.stages):
        for blk_i in range(cfg.blocks_per_stage):
            blk = p["blocks"][bi]
            stride = 2 if (blk_i == 0 and si > 0) else 1
            w1, b1 = fold_bn(blk["conv1"])
            h1 = log(f"b{bi}/conv1", jax.nn.relu(_conv(h, w1, b1, stride)))
            w2, b2 = fold_bn(blk["conv2"])
            h2 = log(f"b{bi}/conv2", _conv(h1, w2, b2))       # case (a)
            if "proj" in blk:
                wp, bp = fold_bn(blk["proj"])
                sc = log(f"b{bi}/proj", _conv(h, wp, bp, stride))
            else:
                sc = h
            h = log(f"b{bi}/add", jax.nn.relu(h2 + sc))       # case (c)
            bi += 1
    pooled = jnp.mean(h, axis=(1, 2))
    return log("head", pooled @ p["head"]["w"] + p["head"]["b"])


def build_resnet_plan(cfg: ResNetConfig) -> dataflow.QuantPlan:
    """The op graph fed to the Fig. 1 fusion rules."""
    K = dataflow.OpKind
    nodes = [dataflow.OpNode("stem", K.LINEAR, ("in",), has_bias=True),
             dataflow.OpNode("stem_relu", K.RELU, ("stem",))]
    prev = "stem_relu"
    bi = 0
    for si, ch in enumerate(cfg.stages):
        for blk_i in range(cfg.blocks_per_stage):
            has_proj = (blk_i == 0 and si > 0) or (si == 0 and blk_i == 0 and False)
            n1, n2 = f"b{bi}/conv1", f"b{bi}/conv2"
            nodes += [
                dataflow.OpNode(n1, K.LINEAR, (prev,), has_bias=True),
                dataflow.OpNode(f"{n1}_relu", K.RELU, (n1,)),
                dataflow.OpNode(n2, K.LINEAR, (f"{n1}_relu",), has_bias=True),
            ]
            sc = prev
            if has_proj:
                nodes.append(dataflow.OpNode(f"b{bi}/proj", K.LINEAR, (prev,),
                                             has_bias=True))
                sc = f"b{bi}/proj"
            nodes += [
                dataflow.OpNode(f"b{bi}/add", K.ADD, (n2, sc)),
                dataflow.OpNode(f"b{bi}/add_relu", K.RELU, (f"b{bi}/add",)),
            ]
            prev = f"b{bi}/add_relu"
            bi += 1
    nodes.append(dataflow.OpNode("head", K.LINEAR, (prev,), has_bias=True))
    return dataflow.build_plan(nodes)


@dataclasses.dataclass
class QuantizedResNet:
    """Deploy artifacts: integer codes + shift bookkeeping (paper §1.2)."""

    weights: dict            # name -> int8 W codes
    biases: dict             # name -> int8 B codes
    specs: dict              # name -> LinearQuantSpec
    report: calibrate.CalibrationReport
    n_in: int                # input activation fractional bits


def quantize_resnet(p: dict, x_calib: jax.Array, cfg: ResNetConfig,
                    n_bits: int = 8, tau: int = 4) -> QuantizedResNet:
    """Algorithm 1 over the dataflow plan, sequential along the network.

    Follows the paper exactly: a single calibration batch, grid search per
    unified module, the chosen N_o threads forward as the next module's N_x.
    """
    collect: dict = {}
    resnet_forward(p, x_calib, cfg, collect=collect)
    report = calibrate.CalibrationReport()
    weights, biases, specs = {}, {}, {}

    # input quantization point (images in [0,1])
    n_in = (n_bits - 1) - calibrate.search_window(x_calib, 0)[1]
    xq = qscheme.fake_quant(x_calib, n_in, n_bits)

    def calibrate_conv(name, conv, x_in, n_x, o_ref, stride, relu, fuse_relu):
        w, b = fold_bn(conv)

        def apply(xx, wq, bq):
            y = _conv(xx, wq, bq, stride)
            return jax.nn.relu(y) if fuse_relu else y

        r = calibrate.calibrate_linear_module(
            x_in, w, b, o_ref, apply, bits=n_bits, tau=tau,
            out_unsigned=fuse_relu)
        report.add(name, r)
        weights[name] = qscheme.quant(w, r.n_w, n_bits)
        biases[name] = qscheme.quant(b, r.n_b, n_bits)
        specs[name] = integer_ops.LinearQuantSpec(
            n_x=n_x, n_w=r.n_w, n_b=r.n_b, n_o=r.n_o, bits=n_bits,
            out_unsigned=fuse_relu)
        return qscheme.fake_quant(apply(x_in, qscheme.fake_quant(w, r.n_w, n_bits),
                                        qscheme.fake_quant(b, r.n_b, n_bits)),
                                  r.n_o, n_bits, fuse_relu), r.n_o

    h, n_h = calibrate_conv("stem", p["stem"], xq, n_in, collect["stem"],
                            1, True, True)
    bi = 0
    for si, ch in enumerate(cfg.stages):
        for blk_i in range(cfg.blocks_per_stage):
            blk = p["blocks"][bi]
            stride = 2 if (blk_i == 0 and si > 0) else 1
            h1, n1 = calibrate_conv(f"b{bi}/conv1", blk["conv1"], h, n_h,
                                    collect[f"b{bi}/conv1"], stride, True, True)
            h2, n2 = calibrate_conv(f"b{bi}/conv2", blk["conv2"], h1, n1,
                                    collect[f"b{bi}/conv2"], 1, False, False)
            if "proj" in blk:
                sc, n_sc = calibrate_conv(f"b{bi}/proj", blk["proj"], h, n_h,
                                          collect[f"b{bi}/proj"], stride,
                                          False, False)
            else:
                sc, n_sc = h, n_h
            # Fig. 1(c): residual add + ReLU — one joint quant point
            a_int = qscheme.quant(h2, n2, n_bits)
            b_int = qscheme.quant(sc, n_sc, n_bits)
            r = calibrate.calibrate_add_module(
                qscheme.dequant(a_int, n2), qscheme.dequant(b_int, n_sc),
                collect[f"b{bi}/add"], bits=n_bits, out_unsigned=True,
                apply_relu=True)
            report.add(f"b{bi}/add", r)
            specs[f"b{bi}/add"] = (n2, n_sc, r.n_o)
            h = qscheme.fake_quant(jax.nn.relu(h2 + sc), r.n_o, n_bits, True)
            n_h = r.n_o
            bi += 1

    # classifier head (case a)
    pooled = jnp.mean(h, axis=(1, 2))

    def apply_head(xx, wq, bq):
        return xx @ wq + bq

    r = calibrate.calibrate_linear_module(
        pooled, p["head"]["w"], p["head"]["b"], collect["head"], apply_head,
        bits=n_bits, tau=tau)
    report.add("head", r)
    weights["head"] = qscheme.quant(p["head"]["w"], r.n_w, n_bits)
    biases["head"] = qscheme.quant(p["head"]["b"], r.n_b, n_bits)
    specs["head"] = integer_ops.LinearQuantSpec(
        n_x=n_h, n_w=r.n_w, n_b=r.n_b, n_o=r.n_o, bits=n_bits)

    return QuantizedResNet(weights=weights, biases=biases, specs=specs,
                           report=report, n_in=n_in)


def resnet_int_forward(q: QuantizedResNet, x: jax.Array, cfg: ResNetConfig
                       ) -> jax.Array:
    """Integer-only inference (Eq. 3/4): int8 codes end to end, bit shifts
    between modules, no floats until the final logits dequant."""
    xi = qscheme.quant(x, q.n_in, 8)
    hi = integer_ops.int_conv2d(xi, q.weights["stem"], q.biases["stem"],
                                q.specs["stem"], apply_relu=True)
    n_h = q.specs["stem"].n_o
    bi = 0
    for si, ch in enumerate(cfg.stages):
        for blk_i in range(cfg.blocks_per_stage):
            stride = 2 if (blk_i == 0 and si > 0) else 1
            s1 = q.specs[f"b{bi}/conv1"]
            h1 = integer_ops.int_conv2d(hi, q.weights[f"b{bi}/conv1"],
                                        q.biases[f"b{bi}/conv1"], s1,
                                        stride=stride, apply_relu=True)
            s2 = q.specs[f"b{bi}/conv2"]
            h2 = integer_ops.int_conv2d(h1, q.weights[f"b{bi}/conv2"],
                                        q.biases[f"b{bi}/conv2"], s2)
            if f"b{bi}/proj" in q.specs and isinstance(
                    q.specs[f"b{bi}/proj"], integer_ops.LinearQuantSpec):
                sp = q.specs[f"b{bi}/proj"]
                sc = integer_ops.int_conv2d(hi, q.weights[f"b{bi}/proj"],
                                            q.biases[f"b{bi}/proj"], sp,
                                            stride=stride)
                n_sc = sp.n_o
            else:
                sc, n_sc = hi, n_h
            n_a, n_b_, n_o = q.specs[f"b{bi}/add"]
            hi = integer_ops.int_residual_add(
                h2.astype(jnp.int32), n_a, sc.astype(jnp.int32), n_b_, n_o,
                apply_relu=True)
            n_h = n_o
            bi += 1
    # head: global average pool in int32 then int linear
    pooled = jnp.mean(qscheme.dequant(hi, n_h), axis=(1, 2))
    pi = qscheme.quant(pooled, q.specs["head"].n_x, 8)
    logits_i = integer_ops.int_linear(pi, q.weights["head"],
                                      q.biases["head"], q.specs["head"])
    return qscheme.dequant(logits_i, q.specs["head"].n_o)
