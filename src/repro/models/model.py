"""Top-level model API: init / forward / prefill / decode / loss for every
architecture family, built on the block zoo in ``transformer.py``.

Layer stacks are scanned (stacked params, leading axis = depth).  All entry
points are pure functions of (params, batch) with static (cfg, ctx), so they
jit/pjit directly and ``jax.eval_shape`` gives allocation-free param trees
for the dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from repro.models.scan_lib import scan as _scan

from repro.configs.base import ModelConfig
from repro.core.qmodel import QuantContext
from repro.distributed.sharding import constrain
from repro.models import attention as att
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models import transformer as tfm
from repro.models.common import Initializer, embed, rmsnorm, unembed

__all__ = ["init_params", "init_cache", "init_paged_cache",
           "init_paged_state", "forward", "prefill", "decode_step",
           "paged_step", "paged_recurrent_step", "ragged_step", "loss_fn"]


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _stack_init(key: jax.Array, n: int, fn, dtype) -> Any:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: fn(Initializer(k, dtype)))(keys)


def _sinusoid(seq: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)[None]


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dt = _dtype(cfg)
    k_embed, k_blocks, k_head, k_extra = jax.random.split(key, 4)
    init0 = Initializer(k_embed, dt)
    params: dict[str, Any] = {
        "embed": init0.dense((cfg.vocab_padded, cfg.d_model)),
        "ln_f": init0.ones((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = Initializer(k_head, dt).dense(
            (cfg.d_model, cfg.vocab_padded))

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["blocks"] = _stack_init(
            k_blocks, cfg.n_layers, lambda i: tfm.init_dense_block(i, cfg), dt)
    elif fam == "moe":
        nd = cfg.moe.n_dense_layers
        if nd:
            params["dense_blocks"] = _stack_init(
                k_extra, nd, lambda i: tfm.init_dense_block(i, cfg), dt)
        params["blocks"] = _stack_init(
            k_blocks, cfg.n_layers - nd,
            lambda i: tfm.init_moe_block(i, cfg), dt)
    elif fam == "audio":
        params["enc_blocks"] = _stack_init(
            k_extra, cfg.encdec.n_encoder_layers,
            lambda i: tfm.init_encoder_block(i, cfg), dt)
        params["ln_enc"] = init0.ones((cfg.d_model,))
        params["blocks"] = _stack_init(
            k_blocks, cfg.n_layers, lambda i: tfm.init_decoder_block(i, cfg), dt)
    elif fam == "ssm":
        params["blocks"] = _stack_init(
            k_blocks, cfg.n_layers, lambda i: tfm.init_rwkv_block(i, cfg), dt)
    elif fam == "hybrid":
        g = cfg.hybrid.attn_every
        n_groups = cfg.n_layers // g
        params["blocks"] = {"mamba": _stack_init(
            k_blocks, n_groups,
            lambda i: _stack_init(i.next_key(), g,
                                  lambda j: tfm.init_mamba_block(j, cfg), dt),
            dt)}
        params["shared"] = tfm.init_shared_attn(Initializer(k_extra, dt), cfg)
    else:
        raise ValueError(fam)
    return params


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Any:
    dt = _dtype(cfg)
    kv_dt = jnp.int8 if cfg.kv_cache_bits == 8 else dt  # Eq.-1 codes
    hd = cfg.resolved_head_dim
    fam = cfg.family

    def kv(n_layers):
        return att.KVCache(
            k=jnp.zeros((n_layers, batch, max_seq, cfg.n_kv_heads, hd), kv_dt),
            v=jnp.zeros((n_layers, batch, max_seq, cfg.n_kv_heads, hd), kv_dt))

    def mla(n_layers):
        m = cfg.mla
        return att.MLACache(
            c_kv=jnp.zeros((n_layers, batch, max_seq, m.kv_lora_rank), dt),
            k_pe=jnp.zeros((n_layers, batch, max_seq, m.qk_rope_head_dim), dt))

    if fam in ("dense", "vlm"):
        return {"kv": mla(cfg.n_layers) if cfg.mla else kv(cfg.n_layers)}
    if fam == "moe":
        nd = cfg.moe.n_dense_layers
        c = {"kv": mla(cfg.n_layers - nd) if cfg.mla else kv(cfg.n_layers - nd)}
        if nd:
            c["kv_dense"] = mla(nd) if cfg.mla else kv(nd)
        return c
    if fam == "audio":
        enc_seq = cfg.encdec.encoder_seq
        return {"kv": kv(cfg.n_layers),
                "memory": jnp.zeros((batch, enc_seq, cfg.d_model), dt)}
    if fam == "ssm":
        st = rwkv_lib.zero_state(cfg, batch, dt)
        return {"state": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), st)}
    if fam == "hybrid":
        g = cfg.hybrid.attn_every
        n_groups = cfg.n_layers // g
        st = ssm_lib.zero_state(cfg, batch, dt)
        return {
            "ssm": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_groups, g) + x.shape).copy(), st),
            "kv": kv(n_groups),
        }
    raise ValueError(fam)


def init_paged_cache(cfg: ModelConfig, num_blocks: int,
                     block_size: int) -> Any:
    """KV BLOCK POOL for the continuous-batching serving engine (DESIGN §9).

    Unlike :func:`init_cache` (one dense (B, S_max) cache per batch), the
    pool is a single (L, NB, BS, KVH, D) arena shared by every in-flight
    request; the host-side :class:`repro.serving.kv_pool.BlockPool` hands
    out blocks and per-sequence block tables.  Block 0 is the trash block
    (inactive slots write there), so ``num_blocks`` must be >= 2.
    """
    if cfg.family not in ("dense", "vlm") or cfg.mla is not None:
        raise NotImplementedError(
            f"paged serving covers GQA KV caches (family dense/vlm); "
            f"got family={cfg.family!r} mla={cfg.mla is not None}")
    if num_blocks < 2:
        raise ValueError("pool needs >= 2 blocks (block 0 is the trash "
                         "block inactive slots write to)")
    dt = _dtype(cfg)
    kv_dt = jnp.int8 if cfg.kv_cache_bits == 8 else dt  # Eq.-1 codes
    shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads,
             cfg.resolved_head_dim)
    return {"paged_kv": att.PagedKVCache(k=jnp.zeros(shape, kv_dt),
                                         v=jnp.zeros(shape, kv_dt))}


def init_paged_state(cfg: ModelConfig, num_slabs: int, *,
                     num_blocks: Optional[int] = None,
                     block_size: Optional[int] = None) -> Any:
    """STATE SLAB arenas for the fixed-slab recurrent substrate (DESIGN §16).

    One (L, S, ...) device arena per state component, S = ``num_slabs``;
    the host-side :class:`repro.serving.state_pool.StateSlabPool` hands out
    one slab per live sequence.  Slab 0 is the trash slab idle batch lanes
    read and write harmlessly (their q_len is 0, so the masked forward
    passes the slab state through bit-exactly), so ``num_slabs`` >= 2.

    With ``cfg.state_bits == 8`` the slabs hold Eq.-1 int8 codes on a
    per-slab power-of-two grid (``exp``, fixed at admission); ``None``
    keeps fp32 slabs — the parity-oracle mode.  The hybrid family also
    carries the shared attention block's KV pool (L = n_groups), sized by
    ``num_blocks`` / ``block_size`` exactly like :func:`init_paged_cache`.
    """
    if cfg.family not in ("ssm", "hybrid"):
        raise NotImplementedError(
            f"the fixed-slab substrate covers recurrent families "
            f"(ssm/hybrid); got family={cfg.family!r}")
    if num_slabs < 2:
        raise ValueError("state pool needs >= 2 slabs (slab 0 is the trash "
                         "slab idle lanes read and write)")
    dt = _dtype(cfg)
    st_dt = jnp.int8 if cfg.state_bits == 8 else jnp.float32
    exp = jnp.full((num_slabs,),
                   cfg.state_frac_bits if cfg.state_bits == 8 else 0,
                   jnp.int32)
    if cfg.family == "ssm":
        h = cfg.d_model // rwkv_lib.HEAD_DIM
        hd = rwkv_lib.HEAD_DIM
        ls = (cfg.n_layers, num_slabs)
        return {"state": {
            "x_att": jnp.zeros(ls + (cfg.d_model,), st_dt),
            "x_ffn": jnp.zeros(ls + (cfg.d_model,), st_dt),
            "wkv": jnp.zeros(ls + (h, hd, hd), st_dt)},
            "exp": exp}
    if num_blocks is None or block_size is None:
        raise ValueError("hybrid slabs need num_blocks/block_size for the "
                         "shared attention block's KV pool")
    if num_blocks < 2:
        raise ValueError("pool needs >= 2 blocks (block 0 is the trash "
                         "block inactive slots write to)")
    g = cfg.hybrid.attn_every
    n_groups = cfg.n_layers // g
    st = ssm_lib.zero_state(cfg, num_slabs)
    ssm_states = jax.tree.map(
        lambda z: jnp.broadcast_to(
            z.astype(st_dt), (n_groups, g) + z.shape).copy(), st)
    kv_dt = jnp.int8 if cfg.kv_cache_bits == 8 else dt
    kv_shape = (n_groups, num_blocks, block_size, cfg.n_kv_heads,
                cfg.resolved_head_dim)
    return {"ssm": ssm_states, "exp": exp,
            "paged_kv": att.PagedKVCache(k=jnp.zeros(kv_shape, kv_dt),
                                         v=jnp.zeros(kv_shape, kv_dt))}


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

_REMAT_POLICIES = {
    "full": None,  # jax.checkpoint default: save nothing, recompute all
    "dots": "dots_with_no_batch_dims_saveable",
}


def forward(params: dict, batch: dict, cfg: ModelConfig, ctx: QuantContext,
            *, remat: bool | str = False, cache: Any = None
            ) -> tuple[jax.Array, Any]:
    """Full-sequence forward.  If ``cache`` is given (prefill), K/V (or
    recurrent states) are written into it and returned.  Returns
    (logits fp32 (B,S,V), cache).

    remat: False (save everything) | 'full' / True (recompute each block in
    backward — the production default: saved state per layer is ONE bf16
    residual) | 'dots' (save matmul outputs).

    W8A8 deploy (DESIGN §13): ``params`` may be a ``QuantizedParams.tree``
    — matmul weights as int8 codes — in which case ``ctx`` MUST be the
    matching INT-mode context (qlinear raises otherwise); embeddings and
    norm gains stay float, so embed/rmsnorm paths are unchanged.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    dt = _dtype(cfg)
    x = constrain(embed(params["embed"], tokens, dt), ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    fam = cfg.family
    new_cache = None

    def maybe_remat(f):
        if not remat:
            return f
        if remat in (True, "full"):
            return jax.checkpoint(f)
        return jax.checkpoint(
            f, policy=getattr(jax.checkpoint_policies, _REMAT_POLICIES[remat]))

    if fam in ("dense", "vlm", "moe"):
        block = tfm.moe_block if fam == "moe" else tfm.dense_block

        def body_nocache(x, p_l):
            y, _ = block(ctx, p_l, x, cfg, positions=positions)
            return y, None

        def body_cache(x, inp):
            p_l, c_l = inp
            y, c = block(ctx, p_l, x, cfg, positions=positions,
                         cache=c_l, cache_pos=0)
            return y, c

        if fam == "moe" and cfg.moe.n_dense_layers:
            def dense_body_nocache(x, p_l):
                y, _ = tfm.dense_block(ctx, p_l, x, cfg, positions=positions)
                return y, None

            def dense_body_cache(x, inp):
                p_l, c_l = inp
                y, c = tfm.dense_block(ctx, p_l, x, cfg, positions=positions,
                                       cache=c_l, cache_pos=0)
                return y, c

            if cache is None:
                x, _ = _scan(maybe_remat(dense_body_nocache), x,
                                    params["dense_blocks"])
            else:
                x, kvd = _scan(maybe_remat(dense_body_cache), x,
                                      (params["dense_blocks"], cache["kv_dense"]))
        if cache is None:
            x, _ = _scan(maybe_remat(body_nocache), x, params["blocks"])
        else:
            x, kvm = _scan(maybe_remat(body_cache), x,
                                  (params["blocks"], cache["kv"]))
            new_cache = {"kv": kvm}
            if fam == "moe" and cfg.moe.n_dense_layers:
                new_cache["kv_dense"] = kvd

    elif fam == "audio":
        memory = _encode(params, batch, cfg, ctx, remat)

        def dec_body(x, inp):
            p_l, c_l = inp
            y, c = tfm.decoder_block(ctx, p_l, x, memory, cfg,
                                     positions=positions, cache=c_l,
                                     cache_pos=0 if c_l is not None else None)
            return y, c

        if cache is None:
            def dec_nocache(x, p_l):
                y, _ = tfm.decoder_block(ctx, p_l, x, memory, cfg,
                                         positions=positions)
                return y, None
            x, _ = _scan(maybe_remat(dec_nocache), x, params["blocks"])
        else:
            x, kvm = _scan(maybe_remat(dec_body), x,
                                  (params["blocks"], cache["kv"]))
            new_cache = {"kv": kvm, "memory": memory}

    elif fam == "ssm":
        states = cache["state"] if cache is not None else jax.tree.map(
            lambda z: jnp.broadcast_to(z, (cfg.n_layers,) + z.shape).copy(),
            rwkv_lib.zero_state(cfg, b, dt))

        def body(x, inp):
            p_l, st_l = inp
            y, st = tfm.rwkv_block_fwd(ctx, p_l, x, cfg, state=st_l)
            return y, st

        x, new_states = _scan(maybe_remat(body), x,
                                     (params["blocks"], states))
        if cache is not None:
            new_cache = {"state": new_states}

    elif fam == "hybrid":
        g = cfg.hybrid.attn_every
        n_groups = cfg.n_layers // g
        c = cache if cache is not None else init_cache(cfg, b, s)
        x_embed = x

        def body(carry, inp):
            x_c = carry
            p_g, ssm_g, kv_g = inp
            y, st, kv = tfm.hybrid_group_fwd(
                ctx, p_g, params["shared"], x_c, x_embed, cfg,
                positions=positions, ssm_states=ssm_g,
                attn_cache=kv_g, cache_pos=0)
            return y, (st, kv)

        x, (new_ssm, new_kv) = _scan(
            maybe_remat(body), x,
            (params["blocks"]["mamba"], c["ssm"], c["kv"]))
        if cache is not None:
            new_cache = {"ssm": new_ssm, "kv": new_kv}
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = constrain(unembed(ctx, x, head), ("batch", None, "vocab"))
    return logits, new_cache


def _encode(params, batch, cfg, ctx, remat=False):
    """Whisper encoder over stub frame embeddings (frontend per assignment)."""
    feats = batch["encoder_features"]                       # (B, T, d) stub
    x = feats.astype(_dtype(cfg)) + _sinusoid(
        feats.shape[1], cfg.d_model, _dtype(cfg))

    def body(x, p_l):
        return tfm.encoder_block(ctx, p_l, x, cfg), None

    f = jax.checkpoint(body) if remat else body
    x, _ = _scan(f, x, params["enc_blocks"])
    return rmsnorm(x, params["ln_enc"], cfg.norm_eps)


def prefill(params, batch, cfg, ctx, max_seq: Optional[int] = None):
    b, s = batch["tokens"].shape
    cache = init_cache(cfg, b, max_seq or s)
    logits, cache = forward(params, batch, cfg, ctx, cache=cache)
    return logits[:, -1], cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_step(params: dict, tokens: jax.Array, cache: Any, pos: jax.Array,
                cfg: ModelConfig, ctx: QuantContext, batch: Optional[dict] = None
                ) -> tuple[jax.Array, Any]:
    """One serving step: tokens (B, 1) at absolute position ``pos`` (scalar),
    KV/state cache from prefill.  Returns (logits (B, V), new cache)."""
    b = tokens.shape[0]
    dt = _dtype(cfg)
    x = embed(params["embed"], tokens, dt)
    positions = jnp.broadcast_to(pos[None, None] if pos.ndim == 0 else pos,
                                 (b, 1))
    fam = cfg.family
    new_cache = dict(cache) if isinstance(cache, dict) else cache

    if fam in ("dense", "vlm", "moe"):
        block = tfm.moe_block if fam == "moe" else tfm.dense_block

        def body(x, inp):
            p_l, c_l = inp
            y, c = block(ctx, p_l, x, cfg, positions=positions,
                         cache=c_l, cache_pos=pos)
            return y, c

        if fam == "moe" and cfg.moe.n_dense_layers:
            def dbody(x, inp):
                p_l, c_l = inp
                y, c = tfm.dense_block(ctx, p_l, x, cfg, positions=positions,
                                       cache=c_l, cache_pos=pos)
                return y, c
            x, kvd = _scan(dbody, x,
                                  (params["dense_blocks"], cache["kv_dense"]))
            new_cache["kv_dense"] = kvd
        x, kvm = _scan(body, x, (params["blocks"], cache["kv"]))
        new_cache["kv"] = kvm

    elif fam == "audio":
        memory = cache["memory"]

        def body(x, inp):
            p_l, c_l = inp
            y, c = tfm.decoder_block(ctx, p_l, x, memory, cfg,
                                     positions=positions, cache=c_l,
                                     cache_pos=pos)
            return y, c

        x, kvm = _scan(body, x, (params["blocks"], cache["kv"]))
        new_cache["kv"] = kvm

    elif fam == "ssm":
        def body(x, inp):
            p_l, st_l = inp
            y, st = tfm.rwkv_block_decode(ctx, p_l, x, cfg, st_l)
            return y, st

        x, st = _scan(body, x, (params["blocks"], cache["state"]))
        new_cache["state"] = st

    elif fam == "hybrid":
        x_embed = x

        def body(x_c, inp):
            p_g, ssm_g, kv_g = inp
            y, st, kv = tfm.hybrid_group_fwd(
                ctx, p_g, params["shared"], x_c, x_embed, cfg,
                positions=positions, ssm_states=ssm_g, attn_cache=kv_g,
                cache_pos=pos, decode=True)
            return y, (st, kv)

        x, (st, kv) = _scan(body, x,
                                   (params["blocks"]["mamba"], cache["ssm"],
                                    cache["kv"]))
        new_cache = {"ssm": st, "kv": kv}
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(ctx, x, head)
    return logits[:, 0], new_cache


def paged_step(params: dict, tokens: jax.Array, cache: Any,
               positions: jax.Array, block_tables: jax.Array,
               cfg: ModelConfig, ctx: QuantContext) -> tuple[jax.Array, Any]:
    """One serving-engine step over the paged KV block pool (DESIGN §9).

    tokens (B, C) at PER-TOKEN absolute ``positions`` (B, C);
    ``block_tables`` (B, NBmax) maps each slot's logical blocks to pool
    blocks.  Covers BOTH engine shapes: continuous-batching decode
    (B = n_slots, C = 1 — every slot at its own live length) and chunked
    prefill (B = 1, C = chunk bucket).  Returns (logits fp32 (B, C, V),
    new cache); the engine samples from the last REAL token's row.
    """
    b, c = tokens.shape
    if cfg.family not in ("dense", "vlm") or cfg.mla is not None:
        raise NotImplementedError(
            f"paged_step covers GQA dense/vlm families; got {cfg.family!r}")
    dt = _dtype(cfg)
    x = constrain(embed(params["embed"], tokens, dt), ("batch", None, None))

    def body(x, inp):
        p_l, c_l = inp
        y, cl = tfm.dense_block(ctx, p_l, x, cfg, positions=positions,
                                cache=c_l, cache_pos=positions,
                                block_tables=block_tables)
        return y, cl

    x, kv = _scan(body, x, (params["blocks"], cache["paged_kv"]))
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(ctx, x, head)
    return logits, {"paged_kv": kv}


def paged_recurrent_step(params: dict, tokens: jax.Array, cache: Any,
                         slab_ids: jax.Array, q_len: jax.Array,
                         positions: Optional[jax.Array],
                         block_tables: Optional[jax.Array],
                         cfg: ModelConfig, ctx: QuantContext
                         ) -> tuple[jax.Array, Any]:
    """One serving step on the fixed-slab recurrent substrate (DESIGN §16).

    ``tokens`` (B, C) right-padded; ``q_len`` (B,) real tokens per row
    (prefill chunks use c_real <= C, decode rows 1, idle lanes 0 parked on
    the trash slab); ``slab_ids`` (B,) each row's state slab.  The whole
    gathered state is dequantized ONCE on its per-slab po2 grid
    (``cache['exp']``), the masked forward advances every row by its own
    q_len in one fixed shape, and the new state requantizes ONCE before
    scattering back — so the requant count per token is independent of
    context length (the paper's dataflow thesis on recurrent state).
    Idle lanes pass their slab through bit-exactly (inert masking), which
    keeps duplicate trash-slab scatters deterministic.

    For the hybrid family, per-token ``positions`` (B, C) (invalid entries
    pointed past the last real block) and ``block_tables``
    (B, NBmax + 1, last column = trash block) drive the shared attention
    block's KV pool exactly like :func:`paged_step`; pure recurrent
    families ignore both.  Returns (logits fp32 (B, V) at each row's last
    real token, new cache).
    """
    from repro.core.qscheme import dequant, quant
    b, c = tokens.shape
    if cfg.family not in ("ssm", "hybrid"):
        raise NotImplementedError(
            f"paged_recurrent_step covers ssm/hybrid families; "
            f"got {cfg.family!r}")
    dt = _dtype(cfg)
    x = constrain(embed(params["embed"], tokens, dt), ("batch", None, None))
    valid = jnp.arange(c, dtype=jnp.int32)[None, :] < q_len[:, None]
    int8 = cfg.state_bits == 8
    exps = cache["exp"][slab_ids]                            # (B,) int32

    if cfg.family == "ssm":
        st = cache["state"]

        def load(a, out_dt):
            g = a[:, slab_ids]                               # (L, B, ...)
            if int8:
                n = exps.reshape((1, b) + (1,) * (g.ndim - 2))
                return dequant(g, n, out_dtype=out_dt)
            return g.astype(out_dt)

        states = rwkv_lib.RWKVState(
            x_prev_att=load(st["x_att"], dt)[:, :, None, :],
            x_prev_ffn=load(st["x_ffn"], dt)[:, :, None, :],
            wkv=load(st["wkv"], jnp.float32))

        def body(x, inp):
            p_l, st_l = inp
            y, st2 = tfm.rwkv_block_paged(ctx, p_l, x, cfg, st_l, valid)
            return y, st2

        x, ns = _scan(body, x, (params["blocks"], states))

        def store(old, new):
            if int8:
                n = exps.reshape((1, b) + (1,) * (new.ndim - 2))
                codes = quant(new, n, 8)
            else:
                codes = new.astype(old.dtype)
            return old.at[:, slab_ids].set(codes)

        new_cache = {"state": {
            "x_att": store(st["x_att"], ns.x_prev_att[:, :, 0]),
            "x_ffn": store(st["x_ffn"], ns.x_prev_ffn[:, :, 0]),
            "wkv": store(st["wkv"], ns.wkv)},
            "exp": cache["exp"]}

    else:
        x_embed = x
        st = cache["ssm"]

        def load_h(a, out_dt):
            g = a[:, :, slab_ids]                            # (G, g, B, ...)
            if int8:
                n = exps.reshape((1, 1, b) + (1,) * (g.ndim - 3))
                return dequant(g, n, out_dtype=out_dt)
            return g.astype(out_dt)

        states = ssm_lib.SSMState(conv=load_h(st.conv, dt),
                                  ssm=load_h(st.ssm, jnp.float32))

        def body(x_c, inp):
            p_g, ssm_g, kv_g = inp
            y, st2, kv2 = tfm.hybrid_group_fwd(
                ctx, p_g, params["shared"], x_c, x_embed, cfg,
                positions=positions, ssm_states=ssm_g, attn_cache=kv_g,
                cache_pos=positions, block_tables=block_tables, valid=valid)
            return y, (st2, kv2)

        x, (ns, nkv) = _scan(
            body, x, (params["blocks"]["mamba"], states, cache["paged_kv"]))

        def store_h(old, new):
            if int8:
                n = exps.reshape((1, 1, b) + (1,) * (new.ndim - 3))
                codes = quant(new, n, 8)
            else:
                codes = new.astype(old.dtype)
            return old.at[:, :, slab_ids].set(codes)

        new_cache = {"ssm": ssm_lib.SSMState(conv=store_h(st.conv, ns.conv),
                                             ssm=store_h(st.ssm, ns.ssm)),
                     "exp": cache["exp"], "paged_kv": nkv}

    rows = jnp.arange(b)
    last = jnp.maximum(q_len - 1, 0)
    xe = x[rows, last][:, None, :]                           # (B, 1, d)
    xe = rmsnorm(xe, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(ctx, xe, head)
    return logits[:, 0], new_cache


def ragged_step(params: dict, tokens: jax.Array, cache: Any,
                positions: jax.Array, ragged: att.RaggedBatch,
                cfg: ModelConfig, ctx: QuantContext) -> tuple[jax.Array, Any]:
    """One UNIFIED serving step over a flattened mixed token stream.

    ``tokens``/``positions`` are (T,) — every live token of the step
    (prefill chunks, decode rows, speculative tails) packed back to
    back; ``ragged`` carries the per-sequence descriptors and the
    flattened pool destinations (DESIGN §12).  Returns (logits fp32
    (T, V), new cache); the engine samples per sequence from the rows
    its descriptor names.  Padding rows (covered by no descriptor)
    produce garbage logits that no descriptor samples.
    """
    if cfg.family not in ("dense", "vlm") or cfg.mla is not None:
        raise NotImplementedError(
            f"ragged_step covers GQA dense/vlm families; got {cfg.family!r}")
    dt = _dtype(cfg)
    x = constrain(embed(params["embed"], tokens[None], dt),
                  ("batch", None, None))

    def body(x, inp):
        p_l, c_l = inp
        y, cl = tfm.dense_block(ctx, p_l, x, cfg, positions=positions[None],
                                cache=c_l, cache_pos=positions[None],
                                ragged=ragged)
        return y, cl

    x, kv = _scan(body, x, (params["blocks"], cache["paged_kv"]))
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(ctx, x, head)
    return logits[0], {"paged_kv": kv}


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def loss_fn(params: dict, batch: dict, cfg: ModelConfig, ctx: QuantContext,
            *, remat: bool | str = "full") -> tuple[jax.Array, dict]:
    """Next-token cross entropy (fp32 logsumexp) + z-loss regularizer.

    The gold-logit pick uses a fused one-hot reduction instead of
    take_along_axis: a vocab-dim gather makes GSPMD re-shard the logits to
    full-batch (observed 33 GB/device temps); the one-hot product keeps
    both batch and vocab shardings intact.
    """
    logits, _ = forward(params, batch, cfg, ctx, remat=remat)
    targets = batch["labels"]
    logits = logits[:, :-1]
    targets = targets[:, 1:]
    # stable CE with bf16 logits and f32 reduction accumulators: max/exp per
    # element in bf16 (transient), sums in f32 — no (B,S,V) f32 buffers.
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    expd = jnp.exp(logits - m)
    sumexp = jnp.sum(expd, axis=-1, dtype=jnp.float32)
    lse = jnp.log(sumexp) + m[..., 0].astype(jnp.float32)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1, dtype=jnp.float32)
    nll = lse - gold
    mask = batch.get("mask")
    if mask is not None:
        mask = mask[:, 1:].astype(nll.dtype)
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        loss = jnp.mean(nll)
    z_loss = 1e-4 * jnp.mean(lse * lse)
    metrics = {"nll": loss, "z_loss": z_loss,
               "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}
    return loss + z_loss, metrics
