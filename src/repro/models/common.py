"""Shared model building blocks: norms, rope, embeddings, initializers.

All layers are functional: ``f(params, x, ...) -> y`` with params as plain
dict pytrees, so stacks of layers can be ``jax.lax.scan``'d (params stacked
on a leading layer axis) and sharded with NamedSharding without framework
machinery.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qmodel import QuantContext, qlinear

__all__ = ["rmsnorm", "fold_rmsnorm", "rope_freqs", "apply_rope", "embed",
           "unembed", "dense_init", "Initializer", "linear"]


def rmsnorm(x: jax.Array, gain: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 accumulation but NO full-tensor f32 materialization.

    ``x.astype(f32)`` upcasts of the whole activation get hoisted out of
    scan loops by XLA into (L,B,S,d) f32 buffers (observed +8.6 GB/device);
    instead the mean-square uses a bf16xbf16->f32 dot (native mixed
    accumulation) and the scale is applied in the activation dtype.
    """
    ms = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32) / x.shape[-1]
    scale = jax.lax.rsqrt(ms + eps)[..., None] * gain.astype(jnp.float32)
    return x * scale.astype(x.dtype)


def fold_rmsnorm(gain: jax.Array, w: jax.Array) -> jax.Array:
    """Paper's BN-folding analogue: absorb a norm gain into the following
    linear's weight (W <- diag(g) @ W) so the norm emits no quant point."""
    return gain[:, None].astype(w.dtype) * w


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D). positions: (..., S) int32.

    Angles are computed in f32 (positions up to 512k need the mantissa) but
    the rotation itself runs in the activation dtype: upcasting x to f32
    here creates whole-(L,B,S,d) f32 buffers once XLA hoists the convert
    out of the layer scan (see rmsnorm note).  bf16 cos/sin adds rotation
    error of the same order as bf16 matmul rounding.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos = jnp.cos(angles).astype(x.dtype)
    sin = jnp.sin(angles).astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def linear(ctx: QuantContext, name: str, x: jax.Array, w: jax.Array,
           b: Optional[jax.Array] = None) -> jax.Array:
    """Unified-module linear — alias keeping model code terse."""
    return qlinear(ctx, name, x, w, b)


def embed(table: jax.Array, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return table[tokens].astype(dtype)


def unembed(ctx: QuantContext, x: jax.Array, table: jax.Array) -> jax.Array:
    """LM head.  Logits stay in the activation dtype; the loss accumulates
    its reductions in f32 (f32 logits would add ~4x2 GB/device of transients
    at vocab 128k x 1M tokens)."""
    return qlinear(ctx, "lm_head", x, table)


class Initializer:
    """Deterministic, cheap initializer. fan-in scaled normal."""

    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self._key = key
        self.dtype = dtype

    def next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def dense(self, shape, fan_in: Optional[int] = None) -> jax.Array:
        fan = fan_in if fan_in is not None else shape[0]
        std = 1.0 / math.sqrt(max(fan, 1))
        return (jax.random.truncated_normal(
            self.next_key(), -2.0, 2.0, shape, jnp.float32) * std
        ).astype(self.dtype)

    def ones(self, shape) -> jax.Array:
        return jnp.ones(shape, jnp.float32)

    def zeros(self, shape) -> jax.Array:
        return jnp.zeros(shape, self.dtype)


def dense_init(key: jax.Array, dtype=jnp.bfloat16) -> Initializer:
    return Initializer(key, dtype)
