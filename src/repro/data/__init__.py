from repro.data.pipeline import (SyntheticLMStream, ShardedLoader,  # noqa: F401
                                 make_calibration_batch)
