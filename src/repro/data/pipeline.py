"""Deterministic synthetic data pipeline with sharded placement + prefetch.

Real deployments swap ``SyntheticLMStream`` for a tokenized corpus reader;
everything downstream (sharded placement, double-buffered prefetch,
checkpointable position) is production-shaped:

  * determinism: batch(step) is a pure function of (seed, step) — restart at
    step k reproduces the exact stream, so checkpoint/resume and elastic
    re-sharding do not perturb training;
  * sharded placement: batches are device_put with the train-step's input
    NamedSharding before being handed to jit (no host round-trip after);
  * prefetch: a background thread keeps ``depth`` batches in flight, hiding
    host latency behind the step (compute/IO overlap).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLMStream", "ShardedLoader", "make_calibration_batch"]


class SyntheticLMStream:
    """Zipf-ish synthetic token stream with enough structure that loss
    decreases under training (n-gram correlations), deterministic per step."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, encoder_seq: Optional[int] = None,
                 d_model: Optional[int] = None):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.encoder_seq = encoder_seq
        self.d_model = d_model

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        b, s = self.global_batch, self.seq_len
        # zipf marginals + first-order repetition structure
        base = rng.zipf(1.3, size=(b, s)).astype(np.int64)
        base = np.minimum(base - 1, self.vocab_size - 1)
        rep = rng.random((b, s)) < 0.3
        shifted = np.roll(base, 1, axis=1)
        tokens = np.where(rep, shifted, base).astype(np.int32)
        out = {"tokens": tokens, "labels": tokens,
               "mask": np.ones((b, s), np.float32)}
        if self.encoder_seq is not None:
            out["encoder_features"] = rng.standard_normal(
                (b, self.encoder_seq, self.d_model), dtype=np.float32)
        return out


class ShardedLoader:
    """Double-buffered prefetch of sharded batches.

    ``shardings`` maps batch keys to NamedSharding (or None = replicate).
    ``state()``/``restore()`` expose the stream position for checkpointing.
    """

    def __init__(self, stream: SyntheticLMStream, shardings: dict,
                 start_step: int = 0, depth: int = 2):
        self.stream = stream
        self.shardings = shardings
        self.depth = depth
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _place(self, batch: dict) -> dict:
        out = {}
        for k, v in batch.items():
            sh = self.shardings.get(k)
            out[k] = jax.device_put(v, sh) if sh is not None else jnp.asarray(v)
        return out

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._place(self.stream.batch(step))),
                            timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __next__(self) -> tuple[int, dict]:
        step, batch = self._q.get()
        self._step = step + 1
        return step, batch

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield next(self)

    def state(self) -> dict:
        return {"step": self._step, "seed": self.stream.seed}

    @classmethod
    def restore(cls, stream: SyntheticLMStream, shardings: dict,
                state: dict, depth: int = 2) -> "ShardedLoader":
        stream.seed = state["seed"]
        return cls(stream, shardings, start_step=state["step"], depth=depth)

    def close(self):
        self._stop.set()
        while not self._q.empty():
            self._q.get_nowait()
        self._thread.join(timeout=2)


def make_calibration_batch(vocab_size: int, seq_len: int, batch: int,
                           seed: int = 17) -> dict:
    """The paper calibrates on a single batch ("a single image", §2.1)."""
    return SyntheticLMStream(vocab_size, seq_len, batch, seed).batch(0)
