"""Sharded, async, crash-safe checkpointing.

Layout per step:
    <dir>/step_000123/
        shard_00000.npz      (this process's param/opt leaves, by flat index)
        manifest.json        (step, tree structure hash, leaf index -> file,
                              data-pipeline state, mesh shape)
        COMMIT               (written LAST — a checkpoint without COMMIT is
                              garbage-collected on restore, so a preemption
                              mid-write can never be resumed from)

Async: ``save`` snapshots device arrays to host (blocking only for the
device->host copy), then a worker thread serializes — the train step resumes
while bytes hit disk.  ``wait()`` joins outstanding writes (called before
exit and by tests).

Restore is elastic-aware: leaves are stored UNSHARDED per process here
(single-process container); on a real multi-host pod each process writes its
addressable shards and restore re-shards to the *current* mesh — the hooks
(``target_shardings``) are in place, so a job restarted on a smaller data
axis reloads cleanly (fault-tolerance path, see distributed/fault_tolerance).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Checkpointer"]


def _tree_signature(tree: Any) -> str:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    desc = ";".join(f"{jax.tree_util.keystr(p)}:{l.shape}:{l.dtype}"
                    for p, l in paths)
    return hashlib.sha1(desc.encode()).hexdigest()


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, extra: Optional[dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        # snapshot to host now; serialize in the background
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        sig = _tree_signature(state)

        def work():
            try:
                path = os.path.join(self.dir, f"step_{step:09d}")
                tmp = path + ".tmp"
                os.makedirs(tmp, exist_ok=True)
                leaves = jax.tree_util.tree_leaves(host)
                # npz has no bfloat16: store a uint16 view + dtype metadata
                dtypes = [str(l.dtype) for l in leaves]
                stored = [l.view(np.uint16) if str(l.dtype) == "bfloat16"
                          else l for l in leaves]
                np.savez(os.path.join(tmp, "shard_00000.npz"),
                         **{f"leaf_{i}": l for i, l in enumerate(stored)})
                manifest = {"step": step, "signature": sig,
                            "n_leaves": len(leaves), "dtypes": dtypes,
                            "extra": extra or {}}
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                with open(os.path.join(tmp, "COMMIT"), "w") as f:
                    f.write("ok")
                if os.path.exists(path):
                    shutil.rmtree(path)
                os.rename(tmp, path)
                self._gc()
            except BaseException as e:  # surfaced on next save/wait
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, d)
            if d.startswith("step_") and not d.endswith(".tmp") \
                    and os.path.exists(os.path.join(full, "COMMIT")):
                out.append(int(d.split("_")[1]))
            elif d.startswith("step_") and os.path.isdir(full) \
                    and not os.path.exists(os.path.join(full, "COMMIT")):
                shutil.rmtree(full, ignore_errors=True)  # uncommitted garbage
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, abstract_state: Any, step: Optional[int] = None,
                target_shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``abstract_state``; if
        ``target_shardings`` is given each leaf is device_put with it (the
        elastic re-shard path)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        if manifest["signature"] != _tree_signature(abstract_state):
            raise ValueError("checkpoint tree signature mismatch — "
                             "restoring into a different model/optimizer?")
        data = np.load(os.path.join(path, "shard_00000.npz"))
        import ml_dtypes
        leaves = []
        for i in range(manifest["n_leaves"]):
            leaf = data[f"leaf_{i}"]
            if manifest.get("dtypes", [None] * (i + 1))[i] == "bfloat16":
                leaf = leaf.view(ml_dtypes.bfloat16)
            leaves.append(jnp.asarray(leaf))
        treedef = jax.tree_util.tree_structure(abstract_state)
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if target_shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else
                jnp.asarray(x), state, target_shardings)
        return state, manifest["extra"]
