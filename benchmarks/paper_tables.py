"""Reproductions of the paper's Tables 1-5 and Fig. 2 on offline-feasible
workloads (synthetic data, CPU) — one function per table.

The paper's datasets (ImageNet/KITTI) are not available offline; per
DESIGN.md §7 we report the paper's own optimization objective
(reconstruction error / FP-vs-quant prediction agreement) on (a) the
paper-faithful ResNet path and (b) a small LM from the assigned-arch
families.  Relative orderings between methods are the reproduction target.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.resnet_paper import CONFIG as RESNET_CFG, ResNetConfig
from repro.core import hwcost
from repro.core.baselines import codebook_quant, scale_quant
from repro.core.dataflow import count_quant_ops
from repro.core.qmodel import QuantContext, QuantMode
from repro.core.qscheme import fake_quant, search_window
from repro.data import SyntheticLMStream
from repro.models import model as M
from repro.models import resnet as R


def _resnet_setup(cfg=None, seed=0, n=32):
    cfg = cfg or ResNetConfig(stages=(8, 16), blocks_per_stage=2, img_size=24)
    params = R.init_resnet(cfg, jax.random.PRNGKey(seed))
    x = jnp.asarray(np.random.default_rng(seed).uniform(
        0, 1, size=(n, cfg.img_size, cfg.img_size, 3)), jnp.float32)
    return cfg, params, x


def _lm_setup(arch="llama3_2_1b", seed=0):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    stream = SyntheticLMStream(cfg.vocab_size, 64, 8, seed=seed)
    batch = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
    return cfg, params, batch


def _agreement(a, b):
    return float(np.mean(np.argmax(np.asarray(a, np.float32), -1) ==
                         np.argmax(np.asarray(b, np.float32), -1)))


def _quantize_weights(params, fn):
    return jax.tree.map(
        lambda p: fn(p) if p.ndim >= 2 else p, params)


# ---------------------------------------------------------------------------

def table1_accuracy() -> list[str]:
    """FP vs 8-bit quantized network, ours (bit-shift) vs scaling factor.

    Paper Table 1: ~1.8% top-1 drop for ours, comparable to scaling-factor
    methods.  Metric here: prediction agreement with the FP model (higher
    is better) + relative output error.
    """
    rows = []
    cfg, params, x = _resnet_setup()
    t0 = time.perf_counter()
    logits_fp = R.resnet_forward(params, x, cfg)
    q = R.quantize_resnet(params, x, cfg)
    logits_ours = R.resnet_int_forward(q, x, cfg)
    agree_ours = _agreement(logits_fp, logits_ours)
    rel = float(jnp.linalg.norm(logits_ours - logits_fp) /
                jnp.linalg.norm(logits_fp))
    rows.append(f"table1.resnet.ours_bitshift,"
                f"{1e6*(time.perf_counter()-t0):.0f},"
                f"agree={agree_ours:.3f};rel_err={rel:.4f}")

    # LM: ours (Algorithm-1-calibrated bit-shift W8A8) vs scaling-factor
    # W8A8 (IOA/TensorRT-style) — paper Table 1's comparison, like for like
    import dataclasses
    from repro.core.lm_calibrate import calibrate_lm
    cfg, params, batch = _lm_setup()
    lf, _ = M.forward(params, batch, cfg, QuantContext(mode=QuantMode.FP))
    t0 = time.perf_counter()
    ctx_cal, _ = calibrate_lm(lambda p, b, c: M.forward(p, b, cfg, c),
                              params, batch)
    calib_us = 1e6 * (time.perf_counter() - t0)
    lq, _ = M.forward(params, batch, cfg, ctx_cal)
    rows.append(f"table1.lm.ours_bitshift_calibrated,{calib_us:.0f},"
                f"agree={_agreement(lf, lq):.3f}")
    li, _ = M.forward(params, batch, cfg,
                      dataclasses.replace(ctx_cal, mode=QuantMode.INT))
    rows.append(f"table1.lm.ours_integer_deploy,0,"
                f"agree={_agreement(lf, li):.3f}")
    ls, _ = M.forward(params, batch, cfg, QuantContext(mode=QuantMode.FAKE_SF))
    rows.append(f"table1.lm.scaling_factor_w8a8,0,"
                f"agree={_agreement(lf, ls):.3f}")
    return rows


def table2_calibration_time() -> list[str]:
    """Joint-quantization wall time scales ~linearly with depth (minutes,
    not fine-tuning days — paper Table 2)."""
    rows = []
    for depth in (1, 2, 3):
        cfg, params, x = _resnet_setup(
            ResNetConfig(stages=(8, 16), blocks_per_stage=depth, img_size=24))
        t0 = time.perf_counter()
        q = R.quantize_resnet(params, x, cfg)
        dt = time.perf_counter() - t0
        rows.append(f"table2.calib_time.depth{depth},"
                    f"{1e6*dt:.0f},modules={len(q.report.results)};"
                    f"seconds={dt:.2f}")
    return rows


def table3_bitwidths() -> list[str]:
    """Method comparison at matched bit widths (paper Table 3): bit-shift
    (ours, W8A8) vs scaling factor (W8) vs codebook (W4)."""
    rows = []
    cfg, params, batch = _lm_setup()
    lf, _ = M.forward(params, batch, cfg, QuantContext(mode=QuantMode.FP))

    def w_only(fn, label):
        p2 = _quantize_weights(params, fn)
        lq, _ = M.forward(p2, batch, cfg, QuantContext(mode=QuantMode.FP))
        rows.append(f"table3.{label},0,agree={_agreement(lf, lq):.3f}")

    def best_po2(p, bits=8):
        lo, hi = search_window(p, 3)
        cands = [(8 - 1) - i for i in range(lo, hi + 1)]
        errs = [float(jnp.linalg.norm(fake_quant(p, n, bits) - p))
                for n in cands]
        return fake_quant(p, cands[int(np.argmin(errs))], bits)

    w_only(best_po2, "bitshift_w8")
    w_only(lambda p: scale_quant(p, 8), "scaling_factor_w8")
    w_only(lambda p: codebook_quant(p, 4), "codebook_w4")
    from repro.core.lm_calibrate import calibrate_lm
    ctx_cal, _ = calibrate_lm(lambda p, b, c: M.forward(p, b, cfg, c),
                              params, batch)
    lq, _ = M.forward(params, batch, cfg, ctx_cal)
    rows.append(f"table3.bitshift_w8a8_joint,0,agree={_agreement(lf, lq):.3f}")
    return rows


def table4_bitwidth_quality() -> list[str]:
    """Quality vs bit width (paper Table 4: 8-bit ~ FP, 7-bit close,
    6-bit collapses)."""
    rows = []
    cfg, params, x = _resnet_setup()
    logits_fp = R.resnet_forward(params, x, cfg)
    for bits in (8, 7, 6):
        q = R.quantize_resnet(params, x, cfg, n_bits=bits)
        lq = R.resnet_int_forward(q, x, cfg)
        rows.append(f"table4.resnet.{bits}bit,0,"
                    f"agree={_agreement(logits_fp, lq):.3f}")
    return rows


def table5_hwcost() -> list[str]:
    """Hardware cost of the requant op kinds x the quant-op counts of the
    dataflow plan (paper Table 5 + the ~15x/~9x abstract claims)."""
    rows = []
    for kind in ("bit_shifting", "scaling_factor", "codebook"):
        c = hwcost.TABLE5[kind]
        rows.append(f"table5.unit.{kind},0,power_mw={c.power_mw};"
                    f"area_um2={c.area_um2};energy_pj={c.energy_pj:.1f}")
    ratio_p = hwcost.TABLE5["codebook"].power_mw / \
        hwcost.TABLE5["bit_shifting"].power_mw
    ratio_a = hwcost.TABLE5["codebook"].area_um2 / \
        hwcost.TABLE5["bit_shifting"].area_um2
    rows.append(f"table5.claims,0,codebook_vs_shift_power={ratio_p:.1f}x;"
                f"area={ratio_a:.1f}x")

    # quant-op counts: naive vs joint placement on the resnet plan
    plan = R.build_resnet_plan(RESNET_CFG)
    counts = count_quant_ops(plan)
    # per-activation-tensor requant energy at ImageNet-ish activation sizes
    act_elems = 56 * 56 * 64
    for kind in ("bit_shifting", "scaling_factor", "codebook"):
        naive = hwcost.estimate(kind, counts["naive_activation_points"]
                                * act_elems)
        joint = hwcost.estimate(kind, counts["joint_activation_points"]
                                * act_elems)
        rows.append(f"table5.energy.{kind},0,"
                    f"naive_uj={naive.energy_uj:.1f};"
                    f"joint_uj={joint.energy_uj:.1f};"
                    f"saved={100*(1-joint.energy_uj/naive.energy_uj):.0f}%")
    return rows


def fig2_stats() -> list[str]:
    """Fig. 2: per-module MSE along depth + the shift-value histogram."""
    cfg, params, x = _resnet_setup()
    q = R.quantize_resnet(params, x, cfg)
    rows = []
    adds = [(k, r) for k, r in q.report.results.items() if k.endswith("add")]
    convs = [(k, r) for k, r in q.report.results.items() if "conv" in k]
    rows.append("fig2a.add_rel_err,0," + ";".join(
        f"{k}={r.rel_error:.4f}" for k, r in adds))
    rows.append("fig2a.conv_rel_err,0," + ";".join(
        f"{k}={r.rel_error:.4f}" for k, r in convs[:6]))
    hist = q.report.shift_histogram()
    rows.append("fig2b.shift_histogram,0," + ";".join(
        f"n{k}={v}" for k, v in hist.items()))
    # paper: adds have larger MSE than the convs feeding them
    mean_add = np.mean([r.rel_error for _, r in adds])
    mean_conv = np.mean([r.rel_error for _, r in convs])
    rows.append(f"fig2a.claim_add_gt_conv,0,"
                f"add={mean_add:.4f};conv={mean_conv:.4f};"
                f"holds={bool(mean_add > mean_conv)}")
    return rows
