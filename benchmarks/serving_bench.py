"""Continuous batching vs static batching, and the shared-prefix cache.

Part 1 serves the SAME synthetic Poisson workload (mixed prompt/generation
lengths, ``launch.serve.poisson_workload``) two ways:

* **continuous** — the paged-pool serving engine (DESIGN §9): slot-based
  continuous batching, chunked prefill, int8-KV blocks written once.
* **static**     — the pre-engine dataflow: FCFS groups of ``n_slots``
  requests, prompts padded to the group max, one dense cache per group,
  every request decoded to the group's max generation length.  The three
  wastes this baseline pays — tail steps for short generations, prompt
  padding, and batch-formation waiting — are exactly what continuous
  batching removes.

Part 2 is the SHARED-PREFIX workload (DESIGN §10): every request carries
the same N-token system prompt, served by the engine WITH the
content-addressed prefix cache vs WITHOUT it at equal pool size.  The
cache is primed once (the system prompt quantized exactly once), then the
measured passes report hit-rate, TTFT, prefill chunks, quant-ops-avoided
(Table-5 accounting) and pool residency.

Part 3 is SPECULATIVE DECODING (DESIGN §11) on a repetitive workload
(tiled-pattern prompts — greedy decode locks into cycles the n-gram
self-drafter predicts): the engine with ``spec_k`` drafts verified per
step vs the same engine without, at equal pool size.  Gates: greedy
speculative decode must be TOKEN-IDENTICAL to the plain engine,
acceptance rate > 0.5, tokens per (slot, verify-step) > 1.3, and the
structural step-count win must hold (fewer total decode-phase steps for
the same tokens).

Part 4 is the UNIFIED RAGGED DISPATCH (DESIGN §12) on MIXED TRAFFIC:
prefill-heavy requests (long prompt, short generation) and decode-heavy
requests (short prompt, long generation) interleaved on one Poisson
clock, so most steps carry prefill chunks AND decode rows AND would have
needed several per-shape dispatches.  The ragged engine (one work-list,
one executable) vs the legacy per-shape trio at equal pool size.  Gates:
greedy token parity, jit-compile count (distinct ragged step shapes)
<= 4, strictly fewer padded tokens AND fewer dispatches than the
bucketed baseline, tokens/s no worse (gross-regression bound, CI timers
being what they are), and decode TPOT p99 no worse with concurrent
prefill in the same steps.

Part 5 is the OBSERVABILITY layer (DESIGN §14) on the same mixed
traffic: the disabled-hook cost is microbenchmarked against the fastest
steady step (<1% gate), the tiny-capacity trace ring must wrap without
growing, the exported Chrome trace must validate, trace-derived latency
percentiles must match the legacy report lists to float tolerance, the
phase-split energy proxy must reconcile EXACTLY with the Table-5
requant counters, and the report schema is diffed against the golden
contract.  The enabled run's trace JSON and prometheus exposition are
written next to the results as CI artifacts.

Part 6 is the WORKLOAD FLIGHT RECORDER + SLO monitor (DESIGN §15): a
mixed greedy + speculative + shared-prefix Poisson workload is captured
on the deterministic virtual clock, JSON round-tripped, and replayed on
a fresh identically-configured engine (gates: token-identical outputs,
ZERO-line scheduler-decision diff, matching config fingerprint) plus
cross-config on the legacy per-shape engine (gates: non-empty decision
diff, fingerprint mismatch, greedy tokens still identical).  Two SLO
runs on record-mode engines check burn-rate alerting: impossibly tight
objectives must fire ``slo.alert`` into the tracer, generous ones must
stay silent.  ``check_history`` self-checks the bench-history
regression detector (run-vs-itself passes, a synthetically degraded
copy fails); the committed-ledger comparison runs in CI via
``python -m benchmarks.bench_history --regress``.

Part 7 is the RECURRENT SUBSTRATE (DESIGN §16): the same Poisson
arrival process served by the attention block-table engine, the RWKV6
fixed-slab engine, and the zamba2 hybrid (attention layers on block
tables AND Mamba layers on slabs in one jitted step), at two context
lengths.  Gates (all deterministic): every engine — including the
attention baseline, which doubles as the no-transformer-regression
check — is token-identical to its dense fp32 oracle; RWKV6
requant-ops/token lands strictly below the equal-length attention
baseline; and requant/token must stay ~flat short→long on the slab
substrate while the attention baseline's multiplies (the paper's
context-free state-requant thesis, measured).

All runners execute the workload once UNTIMED first (jit warm-up: CPU
smoke compilation dwarfs compute and its jitter would swamp the signal),
then once timed — the reported tokens/s are steady-state wall-clock.

    PYTHONPATH=src python -m benchmarks.serving_bench [--json out] [--check]

Results persist to BENCH_serving.json (acceptance artifacts: continuous
must beat static in tokens/s on the mixed-length workload; the prefix
cache must show hit-rate > 0.9 AND strictly better TTFT p50 than the
no-cache baseline on the shared-prefix workload).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.qmodel import QuantContext, QuantMode
from repro.launch import steps as S
from repro.launch.serve import poisson_workload, serve_engine
from repro.models import model as M
from repro.serving.engine import _pct, summarize_step_times
from repro.serving.scheduler import chunk_bucket

ARCH = "qwen3_1_7b"
# wide batches are the serving regime AND the regime where the static
# baseline's structural waste is stable: a group of 8 decodes to the
# group's MAX generation length, and E[max] - E[mean] grows with group
# width, so the comparison doesn't hinge on one seed's group composition
N_REQUESTS = 16
N_SLOTS = 8
BLOCK_SIZE = 16
# alternating timed passes per runner; tokens/s gates on the BEST wall.
# Shared CI/sandbox CPUs show >2x contention spikes that land on whole
# phases — best-of-N with interleaving is the standard antidote, and the
# structural step-count advantage (reported alongside) is deterministic.
N_PASSES = 3
# chunk == the longest workload prompt: single-call prefills at bench
# scale (a (1,8) chunk costs nearly as much as a (1,32) one on CPU — the
# per-call floor dominates), while the chunking machinery itself is
# exercised by the tests with smaller chunks
CHUNK = 32
PROMPT_LENS = (8, 16, 24, 32)
# the wide generation spread is the point: a static batch decodes every
# member to the group max, so short generations ride dead slots
GEN_LENS = (4, 8, 16, 48)
# saturation regime: arrivals far faster than service, so the queue is
# never empty — batching policy (backfill vs fixed groups) is what is
# being measured.  At low offered load continuous batching degenerates to
# occupancy ~1 by construction (there is nothing to batch) while the
# static baseline trades TTFT for full groups; that regime measures the
# workload, not the engine.
RATE = 1000.0

# -- shared-prefix workload (DESIGN §10) ------------------------------------
# the system prompt dominates each request: 16 full blocks of shared
# prefix vs a <= 2-block unique tail, so the WARM block hit rate is
# 16/17..16/18 ~ 0.92 and the cache deletes ~90% of prefill work.  One
# request repeats the bare system prompt (tail 0): its feed is FULLY
# cached, which exercises the last-block copy-on-write path.
SP_PREFIX = 256
SP_TAILS = (8, 16, 24, 32)
SP_GENS = (4, 8)
SP_REQUESTS = 16

# -- speculative decoding workload (DESIGN §11) -----------------------------
# repetitive prompts (a short random pattern tiled) push greedy decode of
# the smoke model into short cycles — exactly the continuation shape the
# model-free n-gram self-drafter predicts.  Long generations let the
# cycle establish; measured acceptance ~0.57 and ~1.85 tokens per
# (slot, verify step) at spec_k=4 clear the gates with margin.
SPEC_K = 4
SPEC_PAT_LEN = 4
SPEC_PAT_REPS = 8
SPEC_GEN = 48
SPEC_REQUESTS = 8

# -- mixed-traffic ragged workload (DESIGN §12) -----------------------------
# alternating prefill-heavy (long prompt, 2-4 gen) and decode-heavy
# (short prompt, 32-48 gen) requests on one Poisson clock: decode-heavy
# requests occupy slots for the whole run, so nearly every prefill chunk
# lands in a step that ALSO carries live decode rows — the legacy engine
# pays one dispatch per phase per step plus pow2 bucket padding, the
# ragged engine packs the same rows into one work-list.  Prompt lengths
# are deliberately NOT bucket-aligned (21/27/5/9-token prompts): real
# traffic isn't, and per-phase pow2 bucketing pays for it twice (prefill
# chunk bucket + decode slot padding) where the ragged stream rounds the
# one combined total
RAGGED_REQUESTS = 16
RAGGED_PF = ((21, 27), (2, 4))         # prefill-heavy (prompts, gens)
RAGGED_DC = ((5, 9), (32, 48))         # decode-heavy  (prompts, gens)

# -- observability workload (DESIGN §14) ------------------------------------
# the mixed-traffic trace again (prefill chunks + decode rows + spec
# tails in the same steps — every hook site fires), served by the SAME
# engine build with tracing off vs on.  The disabled-cost gate is a
# measured microbenchmark: the per-site guard (`tr is not None and
# tr.enabled`) is timed directly, multiplied by the MEASURED guard
# evaluations per step (ring events + per-token marks of the enabled
# twin), and compared against the fastest steady step — CI-timer-proof,
# unlike differencing two noisy tokens/s numbers.  The ring capacity is
# deliberately tiny so the bounded-buffer contract (never grows past
# capacity, drops are counted) is exercised, not just asserted.
OBS_SPEC_K = 2
OBS_TRACE_CAP = 128

# -- flight recorder + SLO workloads (DESIGN §15) ---------------------------
# the capture workload deliberately mixes all three decision-heavy
# features (shared-prefix CoW, n-gram speculation, plain greedy) so the
# recorded scheduler-decision stream covers admits, chunk boundaries,
# cache hits, CoW copies, spec verify and retract; the SLO runs reuse
# the headline Poisson workload on record-mode (virtual-clock) engines
# so TTFT — and therefore the alert verdicts — are deterministic.
FR_REQUESTS = 12
FR_SHARED_PREFIX = 12
SLO_WINDOW_S = 1.0

# -- true-W8A8 workload (DESIGN §13) ----------------------------------------
# same mixed-length Poisson trace as the headline section, three engines:
# fp32, dense-INT (float weights, on-the-fly quantization — the repo's
# reference integer forward), and W8A8 (pre-quantized int8 weight codes
# via quantize_params).  The HARD parity gate is W8A8 vs dense-INT: the
# int8 passthrough makes their codes identical by construction, so any
# token drift is a kernel/container regression this PR introduced.  The
# fp comparison is reported but only loosely gated — free-running greedy
# argmax on RANDOM-INIT smoke weights flips on near-uniform logits
# (measured: the paper's own float fake-quant scheme agrees with fp only
# ~0.85 teacher-forced at this scale), so a tight fp gate would measure
# the workload, not the quantizer.
W8A8_REQUESTS = 16

# -- recurrent-substrate workload (DESIGN §16) ------------------------------
# the same Poisson arrival process served from THREE substrates: the
# attention block-table engine (bench-scale qwen), the RWKV6 fixed-slab
# engine and the zamba2 hybrid (attention layers on block tables, Mamba
# layers on slabs, one jitted step).  Two workload lengths make the
# paper's context-free thesis measurable: attention's Table-5 requant
# accounting grows with context (each decode token's counterfactual
# re-quantizes the whole cached range), a state slab requantizes ONCE
# per engine step regardless of context — so requant_ops_per_token must
# ROUGHLY HOLD FLAT from the short to the long workload on RWKV6 while
# the attention baseline multiplies.  All engines run greedy fp32 with
# fp32 slabs: token parity vs the dense oracle is then exact, and the
# requant-per-token gauge is storage-mode-independent by construction
# (int8 slabs count the same ops as performed instead of avoided).
REC_REQUESTS = 8
REC_LONG = ((48, 56, 64), (32, 40, 48))    # (prompts, gens)
REC_SHORT = ((8, 12, 16), (8, 10, 12))
REC_CHUNK = 64
REC_SLOTS = 4


class StaticRunner:
    """Static-batch baseline sharing one pair of jitted steps across
    runs, so a warm-up pass actually warms the timed pass."""

    def __init__(self, cfg, params, ctx, *, n_slots: int,
                 max_model_len: int):
        self.params = params
        self.n_slots = n_slots
        self.max_model_len = max_model_len
        self.prefill_fn = jax.jit(
            S.build_prefill_step(cfg, ctx, max_seq=max_model_len))
        # same courtesy the engine gets: donate the dense cache so the
        # per-step dynamic_update_slice doesn't copy the whole arena
        self.serve_fn = jax.jit(S.build_serve_step(cfg, ctx),
                                donate_argnums=(2,))

    def run(self, requests) -> dict:
        n_slots, max_model_len = self.n_slots, self.max_model_len
        reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
        t0, skip = time.perf_counter(), 0.0
        now = lambda: time.perf_counter() - t0 + skip
        step_times: dict[str, list] = {}

        def timed(tag, fn, *args):
            t = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            step_times.setdefault(tag, []).append(time.perf_counter() - t)
            return out

        ttft, e2e = [], []
        gen_tokens = 0
        decode_steps = 0
        for g0 in range(0, len(reqs), n_slots):
            group = reqs[g0:g0 + n_slots]
            # the batch cannot form before its last member arrives
            if group[-1].arrival > now():
                skip += group[-1].arrival - now()
            # same pow2 bucketing the engine's scheduler uses, capped at
            # the model length instead of the prefill chunk
            p_max = chunk_bucket(max(len(r.prompt) for r in group),
                                 max_model_len, floor=8)
            g_max = max(r.max_new_tokens for r in group)
            batch = np.zeros((n_slots, p_max), np.int32)
            for i, r in enumerate(group):
                batch[i, :len(r.prompt)] = r.prompt
            logits, cache = timed(f"prefill_{n_slots}x{p_max}",
                                  self.prefill_fn, self.params,
                                  {"tokens": jnp.asarray(batch)})
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            t_first = now()
            done_at = {r.rid: t_first for r in group
                       if r.max_new_tokens == 1}
            for r in group:
                ttft.append(t_first - r.arrival)
            for i in range(g_max - 1):
                tok, cache = timed(f"decode_{n_slots}x1", self.serve_fn,
                                   self.params, tok, cache,
                                   jnp.asarray(p_max + i, jnp.int32))
                t_i = now()
                for r in group:
                    if r.max_new_tokens == i + 2:
                        done_at[r.rid] = t_i
            decode_steps += g_max - 1
            t_end = now()
            for r in group:
                gen_tokens += r.max_new_tokens
                e2e.append(done_at.get(r.rid, t_end) - r.arrival)
        wall = now()
        return {
            "completed": len(reqs), "gen_tokens": gen_tokens,
            "wall_s": round(wall, 4),
            "tokens_per_s": round(gen_tokens / wall, 2),
            "decode_steps": decode_steps,
            "ttft_s": {"p50": _pct(ttft, 50), "p99": _pct(ttft, 99)},
            "e2e_s": {"p50": _pct(e2e, 50), "p99": _pct(e2e, 99)},
            "step_shapes": summarize_step_times(step_times),
        }


# bench scale: big enough that a decode step is device compute, not
# per-call dispatch — at the 2-layer/d64 smoke scale the ~0.5 ms jax
# dispatch floor is the whole step and any batching policy measures noise
BENCH_SCALE = dict(dtype="float32", n_layers=4, d_model=256, n_heads=8,
                   n_kv_heads=4, d_ff=1024, head_dim=32)


def bench_serving(*, n_requests: int = N_REQUESTS, seed: int = 0) -> dict:
    cfg = dataclasses.replace(get_smoke_config(ARCH).scaled(**BENCH_SCALE),
                              kv_cache_bits=8)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    ctx = QuantContext(mode=QuantMode.FP)
    max_need = max(PROMPT_LENS) + max(GEN_LENS)
    max_model_len = -(-max_need // BLOCK_SIZE) * BLOCK_SIZE

    workload = lambda: poisson_workload(
        cfg.vocab_size, n_requests=n_requests, rate=RATE,
        prompt_lens=PROMPT_LENS, gen_lens=GEN_LENS, seed=seed)

    # warm both runners (jit compile every shape), then alternate timed
    # passes so CPU contention spikes can't bias one whole phase
    cont = serve_engine(
        ARCH, requests=workload(), n_slots=N_SLOTS, block_size=BLOCK_SIZE,
        chunk=CHUNK, max_model_len=max_model_len, mode="fp",
        calibrate=False, seed=seed,
        cfg_overrides=dict(BENCH_SCALE, kv_cache_bits=8))
    engine = cont["engine"]
    static = StaticRunner(cfg, params, ctx, n_slots=N_SLOTS,
                          max_model_len=max_model_len)
    static.run(workload())                         # warm-up

    crep = srep = None
    c_walls, s_walls = [], []
    for _ in range(N_PASSES):
        engine.reset_metrics()
        crep = engine.run(workload())
        c_walls.append(crep["wall_s"])
        srep = static.run(workload())
        s_walls.append(srep["wall_s"])
    c_best, s_best = min(c_walls), min(s_walls)
    crep["wall_s_passes"] = c_walls
    srep["wall_s_passes"] = s_walls
    crep["wall_s_best"] = c_best
    srep["wall_s_best"] = s_best
    crep["tokens_per_s"] = round(crep["gen_tokens"] / c_best, 2)
    srep["tokens_per_s"] = round(srep["gen_tokens"] / s_best, 2)

    return {
        "backend": jax.default_backend(),
        "note": "tokens_per_s = gen_tokens / wall_s_best (best of the "
                "alternating passes); wall_s, step_shapes and the latency "
                "percentiles describe the LAST pass only",
        "workload": {"n_requests": n_requests, "rate_req_s": RATE,
                     "prompt_lens": PROMPT_LENS, "gen_lens": GEN_LENS,
                     "n_slots": N_SLOTS, "block_size": BLOCK_SIZE,
                     "chunk": CHUNK, "seed": seed, "passes": N_PASSES},
        "continuous": crep,
        "static": srep,
        "speedup_tokens_per_s": round(
            crep["tokens_per_s"] / srep["tokens_per_s"], 3),
        # deterministic structural comparison, immune to timer noise: the
        # decode steps each policy needs for the same useful tokens
        "decode_steps": {"continuous": crep["decode_steps"],
                         "static": srep["decode_steps"]},
    }


def bench_shared_prefix(*, seed: int = 0) -> dict:
    """Prefix cache ON vs OFF on the repeated-system-prompt workload at
    equal pool size (DESIGN §10).  The cached engine is primed once with
    the bare system prompt (quantizing it exactly once), then both
    engines serve the same Poisson workload; alternating timed passes,
    TTFT gates on the best pass (CI timer-noise antidote), and the
    structural numbers (hit rate, prefill chunks, quant ops) are
    deterministic."""
    from repro.serving import Request

    max_need = SP_PREFIX + max(SP_TAILS) + max(SP_GENS)
    max_model_len = -(-max_need // BLOCK_SIZE) * BLOCK_SIZE

    # same prefix construction as poisson_workload(seed): first draw
    prefix = np.random.default_rng(seed).integers(
        0, get_smoke_config(ARCH).vocab_size, size=SP_PREFIX
        ).astype(np.int32)

    def workload():
        reqs = poisson_workload(
            get_smoke_config(ARCH).vocab_size, n_requests=SP_REQUESTS,
            rate=RATE, prompt_lens=SP_TAILS, gen_lens=SP_GENS, seed=seed,
            shared_prefix=SP_PREFIX)
        # one bare-system-prompt repeat: fully-cached feed -> COW path
        reqs[SP_REQUESTS // 2].prompt = prefix.copy()
        return reqs

    def build(with_cache: bool):
        return serve_engine(
            ARCH, requests=workload(), n_slots=N_SLOTS,
            block_size=BLOCK_SIZE, chunk=CHUNK,
            max_model_len=max_model_len, mode="fp", calibrate=False,
            seed=seed, prefix_cache=with_cache,
            cfg_overrides=dict(BENCH_SCALE, kv_cache_bits=8))["engine"]

    cached = build(True)       # warm-up run included in serve_engine
    nocache = build(False)

    # prime the shared prefix ONCE (one quantization pass), then measure
    # the warm steady state: metrics reset, cache kept
    cached.reset_metrics(flush_cache=True)
    cached.run([Request(rid=10_000, prompt=prefix.copy(),
                        max_new_tokens=1)])
    crep = nrep = None
    c_ttft, n_ttft = [], []
    for _ in range(N_PASSES):
        cached.reset_metrics(flush_cache=False)
        crep = cached.run(workload())
        c_ttft.append(crep["ttft_s"]["p50"])
        nocache.reset_metrics()
        nrep = nocache.run(workload())
        n_ttft.append(nrep["ttft_s"]["p50"])
    crep["ttft_p50_passes"] = c_ttft
    nrep["ttft_p50_passes"] = n_ttft

    pc = crep["prefix_cache"]
    return {
        "workload": {"n_requests": SP_REQUESTS, "shared_prefix": SP_PREFIX,
                     "tail_lens": SP_TAILS, "gen_lens": SP_GENS,
                     "n_slots": N_SLOTS, "block_size": BLOCK_SIZE,
                     "chunk": CHUNK, "rate_req_s": RATE, "seed": seed,
                     "passes": N_PASSES},
        "note": "cached engine primed once with the bare system prompt; "
                "ttft_p50_best is the best of the alternating passes, "
                "hit/chunk/quant-op numbers describe the LAST pass",
        "cached": crep,
        "no_cache": nrep,
        "hit_rate": pc["hit_rate"],
        "token_hit_rate": pc["token_hit_rate"],
        "cow_copies": pc["cow_copies"],
        "quant_ops_avoided": pc["quant_ops_avoided"],
        "ttft_p50_best": {"cached": min(c_ttft), "no_cache": min(n_ttft)},
        "prefill_chunks": {"cached": crep["prefill_chunks"],
                           "no_cache": nrep["prefill_chunks"]},
        "peak_live_blocks": {"cached": crep["pool"]["peak_live_blocks"],
                             "no_cache": nrep["pool"]["peak_live_blocks"]},
    }


def bench_spec_decode(*, seed: int = 0) -> dict:
    """Speculative vs plain decode on the repetitive self-drafting
    workload at equal pool size (DESIGN §11).  Greedy, so the comparison
    is deterministic: the spec engine must emit EXACTLY the plain
    engine's tokens, and the structural numbers (acceptance, tokens per
    slot-step, verify/decode step counts, retracted blocks, wasted quant
    ops) are timer-independent; wall clock rides along best-of-N."""
    from repro.serving import Request

    max_need = SPEC_PAT_LEN * SPEC_PAT_REPS + SPEC_GEN
    max_model_len = -(-max_need // BLOCK_SIZE) * BLOCK_SIZE

    def workload():
        rng = np.random.default_rng(seed)
        reqs = []
        for i in range(SPEC_REQUESTS):
            pat = rng.integers(0, get_smoke_config(ARCH).vocab_size,
                               size=SPEC_PAT_LEN).astype(np.int32)
            reqs.append(Request(rid=i, prompt=np.tile(pat, SPEC_PAT_REPS),
                                max_new_tokens=SPEC_GEN))
        return reqs

    def build(spec_k: int):
        return serve_engine(
            ARCH, requests=workload(), n_slots=N_SLOTS,
            block_size=BLOCK_SIZE, chunk=CHUNK,
            max_model_len=max_model_len, mode="fp", calibrate=False,
            seed=seed, spec_k=spec_k,
            cfg_overrides=dict(BENCH_SCALE, kv_cache_bits=8))["engine"]

    spec = build(SPEC_K)          # warm-up run included in serve_engine
    plain = build(0)
    parity = all(
        np.array_equal(spec.outputs()[r.rid], plain.outputs()[r.rid])
        for r in workload())

    srep = prep = None
    s_walls, p_walls = [], []
    for _ in range(N_PASSES):
        spec.reset_metrics()
        srep = spec.run(workload())
        s_walls.append(srep["wall_s"])
        plain.reset_metrics()
        prep = plain.run(workload())
        p_walls.append(prep["wall_s"])

    sp = srep["speculative"]
    return {
        "workload": {"n_requests": SPEC_REQUESTS,
                     "prompt": f"{SPEC_PAT_LEN}-token pattern x "
                               f"{SPEC_PAT_REPS}",
                     "gen": SPEC_GEN, "spec_k": SPEC_K,
                     "n_slots": N_SLOTS, "block_size": BLOCK_SIZE,
                     "chunk": CHUNK, "seed": seed, "passes": N_PASSES},
        "note": "token_parity compares greedy outputs spec vs plain on "
                "the identical workload/pool; wall_s_best is best of the "
                "alternating passes, structural numbers the LAST pass",
        "token_parity": parity,
        "acceptance_rate": sp["acceptance_rate"],
        "tokens_per_step": sp["tokens_per_step"],
        "verify_steps": sp["verify_steps"],
        "retracts": sp["retracts"],
        "retracted_blocks": sp["retracted_blocks"],
        "requant_ops_wasted": sp["requant_ops_wasted"],
        # total decode-phase steps each engine needed for the SAME tokens
        "decode_phase_steps": {
            "spec": srep["spec_steps"] + srep["decode_steps"],
            "plain": prep["decode_steps"]},
        "wall_s_best": {"spec": min(s_walls), "plain": min(p_walls)},
        "wall_s_passes": {"spec": s_walls, "plain": p_walls},
        "speculative": sp,
    }


def bench_ragged_mixed(*, seed: int = 0) -> dict:
    """Unified ragged dispatch vs the legacy per-shape trio on mixed
    traffic at equal pool size (DESIGN §12).  Greedy, so token parity is
    deterministic, as are the structural numbers the gates lean on:
    distinct compiled step shapes, dispatched/padded tokens, and total
    dispatch count.  Wall clock and TPOT ride along best-of-N."""
    from repro.serving import Request

    vocab = get_smoke_config(ARCH).vocab_size
    max_need = max(max(RAGGED_PF[0]) + max(RAGGED_PF[1]),
                   max(RAGGED_DC[0]) + max(RAGGED_DC[1]))
    max_model_len = -(-max_need // BLOCK_SIZE) * BLOCK_SIZE

    def workload():
        rng = np.random.default_rng(seed)
        t, reqs = 0.0, []
        for i in range(RAGGED_REQUESTS):
            t += float(rng.exponential(1.0 / RATE))
            prompts, gens = RAGGED_PF if i % 2 == 0 else RAGGED_DC
            reqs.append(Request(
                rid=i,
                prompt=rng.integers(0, vocab, size=int(rng.choice(prompts))
                                    ).astype(np.int32),
                max_new_tokens=int(rng.choice(gens)), arrival=t))
        return reqs

    def build(ragged: bool):
        return serve_engine(
            ARCH, requests=workload(), n_slots=N_SLOTS,
            block_size=BLOCK_SIZE, chunk=CHUNK,
            max_model_len=max_model_len, mode="fp", calibrate=False,
            seed=seed, ragged=ragged,
            cfg_overrides=dict(BENCH_SCALE, kv_cache_bits=8))["engine"]

    rag = build(True)             # warm-up run included in serve_engine
    leg = build(False)
    parity = all(
        np.array_equal(rag.outputs()[r.rid], leg.outputs()[r.rid])
        for r in workload())

    rrep = lrep = None
    r_walls, l_walls = [], []
    r_tpot, l_tpot = [], []
    for _ in range(N_PASSES):
        rag.reset_metrics()
        rrep = rag.run(workload())
        r_walls.append(rrep["wall_s"])
        r_tpot.append(rrep["tpot_s"]["p99"])
        leg.reset_metrics()
        lrep = leg.run(workload())
        l_walls.append(lrep["wall_s"])
        l_tpot.append(lrep["tpot_s"]["p99"])

    ragged_shapes = [k for k in rrep["step_shapes"]
                     if k.startswith("ragged_")]
    legacy_dispatches = (lrep["prefill_chunks"] + lrep["decode_steps"]
                         + lrep["spec_steps"])
    return {
        "workload": {"n_requests": RAGGED_REQUESTS,
                     "prefill_heavy": RAGGED_PF, "decode_heavy": RAGGED_DC,
                     "n_slots": N_SLOTS, "block_size": BLOCK_SIZE,
                     "chunk": CHUNK, "rate_req_s": RATE, "seed": seed,
                     "passes": N_PASSES},
        "note": "token_parity compares greedy outputs ragged vs legacy "
                "per-shape on the identical workload/pool; tokens_per_s "
                "and tpot_p99_best are best of the alternating passes, "
                "structural numbers the LAST pass",
        "token_parity": parity,
        "compiled_step_shapes": len(ragged_shapes),
        "ragged_step_shapes": sorted(ragged_shapes),
        "dispatches": {"ragged": rrep["ragged_steps"],
                       "legacy": legacy_dispatches},
        "dispatched_tokens": {"ragged": rrep["dispatched_tokens"],
                              "legacy": lrep["dispatched_tokens"]},
        "padded_tokens": {"ragged": rrep["padded_tokens"],
                          "legacy": lrep["padded_tokens"]},
        "padding_frac": {"ragged": rrep["padding_frac"],
                         "legacy": lrep["padding_frac"]},
        "tokens_per_s_best": {
            "ragged": round(rrep["gen_tokens"] / min(r_walls), 2),
            "legacy": round(lrep["gen_tokens"] / min(l_walls), 2)},
        "tpot_p99_best": {"ragged": min(r_tpot), "legacy": min(l_tpot)},
        "wall_s_passes": {"ragged": r_walls, "legacy": l_walls},
        "ragged": rrep,
        "legacy": lrep,
    }


def bench_w8a8(*, seed: int = 0) -> dict:
    """True W8A8 serving vs fp32 and the dense-INT reference engine on
    the identical Poisson workload at equal pool size (DESIGN §13).
    Token agreement vs dense-INT is deterministic and expected to be
    EXACTLY 1.0; tokens/s and dispatch counts ride along best-of-N —
    this section's throughput gate is about not regressing the dispatch
    count, not MXU speed (the CPU fallback runs the jnp integer path)."""
    max_need = max(PROMPT_LENS) + max(GEN_LENS)
    max_model_len = -(-max_need // BLOCK_SIZE) * BLOCK_SIZE

    def workload():
        return poisson_workload(
            get_smoke_config(ARCH).vocab_size, n_requests=W8A8_REQUESTS,
            rate=RATE, prompt_lens=PROMPT_LENS, gen_lens=GEN_LENS,
            seed=seed)

    def build(**kw):
        return serve_engine(
            ARCH, requests=workload(), n_slots=N_SLOTS,
            block_size=BLOCK_SIZE, chunk=CHUNK,
            max_model_len=max_model_len, seed=seed,
            cfg_overrides=dict(BENCH_SCALE, kv_cache_bits=8), **kw)

    # serve_engine's internal run doubles as the jit warm-up pass.  The
    # three builds share seed -> same init params and calibration batch,
    # and calibration is deterministic -> w8a8 and int-ref run the SAME
    # grids; w8a8 additionally pre-quantizes the weights to int8 codes.
    w8 = build(w8a8=True)
    intref = build(mode="int", calibrate=True)
    fp = build(mode="fp", calibrate=False)
    assert w8["quantized"] is not None and w8["quantized"].converted

    def agreement(a_eng, b_eng):
        num = den = 0
        for r in workload():
            a, b = a_eng.outputs()[r.rid], b_eng.outputs()[r.rid]
            n = min(len(a), len(b))
            num += int(np.sum(a[:n] == b[:n]))
            den += max(len(a), len(b))
        return round(num / den, 4)

    w8rep = fprep = irep = None
    w8_walls, fp_walls, ir_walls = [], [], []
    for _ in range(N_PASSES):
        w8["engine"].reset_metrics()
        w8rep = w8["engine"].run(workload())
        w8_walls.append(w8rep["wall_s"])
        intref["engine"].reset_metrics()
        irep = intref["engine"].run(workload())
        ir_walls.append(irep["wall_s"])
        fp["engine"].reset_metrics()
        fprep = fp["engine"].run(workload())
        fp_walls.append(fprep["wall_s"])

    hw = w8rep["hwcost"]
    return {
        "workload": {"n_requests": W8A8_REQUESTS, "rate_req_s": RATE,
                     "prompt_lens": PROMPT_LENS, "gen_lens": GEN_LENS,
                     "n_slots": N_SLOTS, "block_size": BLOCK_SIZE,
                     "chunk": CHUNK, "seed": seed, "passes": N_PASSES},
        "note": "agreement_int_ref must be 1.0 (identical codes by the "
                "int8 passthrough contract); agreement_fp is reported "
                "for context — random-init smoke weights make free-"
                "running greedy agreement fragile for ANY quantizer",
        "agreement_int_ref": agreement(w8["engine"], intref["engine"]),
        "agreement_fp": agreement(w8["engine"], fp["engine"]),
        "converted_tensors": len(w8["quantized"].converted),
        "tokens_per_s_best": {
            "w8a8": round(w8rep["gen_tokens"] / min(w8_walls), 2),
            "int_ref": round(irep["gen_tokens"] / min(ir_walls), 2),
            "fp": round(fprep["gen_tokens"] / min(fp_walls), 2)},
        "wall_s_passes": {"w8a8": w8_walls, "int_ref": ir_walls,
                          "fp": fp_walls},
        # the structural gate: same work-list shapes, same dispatch count
        "dispatched_tokens": {"w8a8": w8rep["dispatched_tokens"],
                              "fp": fprep["dispatched_tokens"]},
        "ragged_steps": {"w8a8": w8rep["ragged_steps"],
                         "fp": fprep["ragged_steps"]},
        "forward_quant_ops_per_token": hw["forward_quant_ops_per_token"],
        "requant_ops_forward": hw["requant_ops_forward"],
        "energy_uj_forward_bit_shift": hw["energy_uj_forward_bit_shift"],
        "energy_uj_forward_if_scaling_factor":
            hw["energy_uj_forward_if_scaling_factor"],
        "w8a8": w8rep,
        "fp": fprep,
    }


def bench_obs(*, seed: int = 0, artifacts: str | None = None) -> dict:
    """Observability layer on the mixed-traffic workload (DESIGN §14):
    disabled-hook overhead, ring-buffer bounds, trace-derived latency
    parity with the legacy report lists, exact energy reconciliation,
    and the report-schema diff against the golden contract.  With
    ``artifacts``, exports the enabled run's Chrome trace JSON and the
    prometheus metrics exposition next to the bench results."""
    from repro.obs.schema import diff_schema, schema_of
    from repro.obs.trace import validate_chrome_trace
    from repro.serving import Request

    vocab = get_smoke_config(ARCH).vocab_size
    max_need = max(max(RAGGED_PF[0]) + max(RAGGED_PF[1]),
                   max(RAGGED_DC[0]) + max(RAGGED_DC[1]))
    max_model_len = -(-max_need // BLOCK_SIZE) * BLOCK_SIZE

    def workload():
        rng = np.random.default_rng(seed)
        t, reqs = 0.0, []
        for i in range(RAGGED_REQUESTS):
            t += float(rng.exponential(1.0 / RATE))
            prompts, gens = RAGGED_PF if i % 2 == 0 else RAGGED_DC
            reqs.append(Request(
                rid=i,
                prompt=rng.integers(0, vocab, size=int(rng.choice(prompts))
                                    ).astype(np.int32),
                max_new_tokens=int(rng.choice(gens)), arrival=t))
        return reqs

    def build(**kw):
        return serve_engine(
            ARCH, requests=workload(), n_slots=N_SLOTS,
            block_size=BLOCK_SIZE, chunk=CHUNK,
            max_model_len=max_model_len, mode="fp", calibrate=False,
            seed=seed, spec_k=OBS_SPEC_K,
            cfg_overrides=dict(BENCH_SCALE, kv_cache_bits=8), **kw)["engine"]

    off = build()                  # hooks present, tracing disabled
    on = build(trace=True, trace_capacity=OBS_TRACE_CAP)

    orep = nrep = None
    o_walls, n_walls = [], []
    for _ in range(N_PASSES):
        off.reset_metrics()
        orep = off.run(workload())
        o_walls.append(orep["wall_s"])
        on.reset_metrics()
        nrep = on.run(workload())
        n_walls.append(nrep["wall_s"])

    # -- disabled-guard microbenchmark (the <1% gate) ----------------------
    # time the EXACT disabled-path pattern every hook site compiles to
    tr = off.tracer
    n_iter = 200_000
    t0 = time.perf_counter()
    for _ in range(n_iter):
        if tr is not None and tr.enabled:      # pragma: no cover
            raise AssertionError
    guard_s = (time.perf_counter() - t0) / n_iter
    # measured guard evaluations per step: every ring event and every
    # per-token mark of the ENABLED twin evaluated the same guard on the
    # disabled engine; x2 for sites whose guard ran but emitted nothing
    steps = max(nrep["ragged_steps"] + nrep["prefill_chunks"]
                + nrep["decode_steps"] + nrep["spec_steps"], 1)
    guards_per_step = 2.0 * (on.tracer.n_emitted
                             + nrep["gen_tokens"]) / steps
    steady = [e["steady_s"] for e in orep["step_shapes"].values()
              if isinstance(e, dict) and e.get("steady_s")]
    steady_step_s = min(steady) if steady else None
    overhead_frac = (guard_s * guards_per_step / steady_step_s
                     if steady_step_s else 0.0)

    # -- ring bound / trace integrity --------------------------------------
    chrome = on.tracer.to_chrome()
    trace_errors = validate_chrome_trace(chrome)
    obs = nrep["obs"]

    # -- trace-derived latency parity (float tolerance) --------------------
    def latency_delta(rep):
        worst = 0.0
        for sec in ("ttft_s", "tpot_s", "e2e_s"):
            for p in ("p50", "p99"):
                a, b = rep[sec][p], rep["timeline"][sec][p]
                if (a is None) != (b is None):
                    return float("inf")
                if a is not None:
                    worst = max(worst, abs(a - b))
        return worst

    # -- schema + energy reconciliation ------------------------------------
    schema_errors = diff_schema(schema_of(on.metrics), spec=True,
                                cache=True)
    hw, en = nrep["hwcost"], nrep["energy"]
    energy_gap = en["total_quant_ops"] - (
        hw["requant_ops_performed"] + hw["requant_ops_forward"])

    paths = {}
    if artifacts:
        paths["trace"] = f"{artifacts}_trace.json"
        with open(paths["trace"], "w") as fh:
            json.dump(chrome, fh)
        paths["metrics"] = f"{artifacts}_metrics.prom"
        with open(paths["metrics"], "w") as fh:
            fh.write(on.metrics.to_prometheus())

    return {
        "workload": {"n_requests": RAGGED_REQUESTS,
                     "prefill_heavy": RAGGED_PF, "decode_heavy": RAGGED_DC,
                     "spec_k": OBS_SPEC_K, "trace_capacity": OBS_TRACE_CAP,
                     "n_slots": N_SLOTS, "seed": seed, "passes": N_PASSES},
        "note": "overhead_frac_disabled is a measured microbenchmark "
                "(guard cost x guards/step / fastest steady step), not a "
                "difference of noisy tokens/s; tokens_per_s_best off/on "
                "is reported for context only",
        "guard_ns": round(guard_s * 1e9, 2),
        "guards_per_step": round(guards_per_step, 1),
        "steady_step_s": steady_step_s,
        "overhead_frac_disabled": round(overhead_frac, 6),
        "tokens_per_s_best": {
            "trace_off": round(orep["gen_tokens"] / min(o_walls), 2),
            "trace_on": round(nrep["gen_tokens"] / min(n_walls), 2)},
        "wall_s_passes": {"trace_off": o_walls, "trace_on": n_walls},
        "ring": {"capacity": obs["trace_capacity"],
                 "held": obs["trace_events"],
                 "emitted": obs["trace_emitted"],
                 "dropped": obs["trace_dropped"]},
        "trace_events_exported": len(chrome["traceEvents"]),
        "trace_errors": trace_errors,
        "latency_delta_off": latency_delta(orep),
        "latency_delta_on": latency_delta(nrep),
        "energy_recon_gap": energy_gap,
        "energy": en,
        "schema_errors": schema_errors,
        "artifacts": paths,
        "trace_on_report": nrep,
    }


def bench_flight_recorder(*, seed: int = 0,
                          artifacts: str | None = None) -> dict:
    """Workload flight recorder (DESIGN §15): capture a mixed
    greedy + speculative + shared-prefix Poisson workload on the
    deterministic virtual clock, round-trip the record through JSON,
    replay it on a FRESH identically-configured engine (gate:
    token-identical outputs AND a zero-line scheduler-decision diff),
    then replay it cross-config on the legacy per-shape engine (gate:
    the decision diff is NON-empty — the A/B instrument actually
    resolves structural scheduling differences)."""
    from repro.obs.replay import WorkloadRecord, replay_workload
    from repro.serving import Request

    vocab = get_smoke_config(ARCH).vocab_size

    def workload():
        rng = np.random.default_rng(seed + 7)
        prefix = rng.integers(0, vocab, size=FR_SHARED_PREFIX
                              ).astype(np.int32)
        t, reqs = 0.0, []
        for i in range(FR_REQUESTS):
            t += float(rng.exponential(1.0 / RATE))
            if i % 3 == 0:     # shared-prefix (prefix-cache + CoW traffic)
                tail = rng.integers(0, vocab, size=int(rng.choice((4, 8)))
                                    ).astype(np.int32)
                prompt = np.concatenate([prefix, tail])
            elif i % 3 == 1:   # repetitive prompt the n-gram drafter wins on
                prompt = np.tile(rng.integers(0, vocab, size=3),
                                 6).astype(np.int32)
            else:              # plain greedy
                prompt = rng.integers(0, vocab,
                                      size=int(rng.choice((5, 9)))
                                      ).astype(np.int32)
            reqs.append(Request(rid=i, prompt=prompt,
                                max_new_tokens=int(rng.choice((4, 8, 12))),
                                arrival=t))
        return reqs

    need = max(len(r.prompt) + r.max_new_tokens for r in workload())
    max_model_len = -(-need // BLOCK_SIZE) * BLOCK_SIZE

    def build(**kw):
        return serve_engine(
            ARCH, requests=workload(), n_slots=N_SLOTS,
            block_size=BLOCK_SIZE, chunk=CHUNK,
            max_model_len=max_model_len, mode="fp", calibrate=False,
            seed=seed, spec_k=OBS_SPEC_K, prefix_cache=True,
            cfg_overrides=dict(BENCH_SCALE, kv_cache_bits=8), **kw)

    paths = {}
    record_to: str | bool = True
    if artifacts:
        paths["record"] = f"{artifacts}_record.json"
        record_to = paths["record"]
    cap = build(record=record_to)
    rec = cap["record"]

    # round-trip through the on-disk format before replaying: the
    # replayed record is the PORTABLE one, not the in-memory object
    rec2 = (WorkloadRecord.load(paths["record"]) if artifacts
            else WorkloadRecord.from_json(rec.to_json()))

    same = replay_workload(rec2, build(record=True)["engine"])
    legacy = replay_workload(rec2, build(record=True,
                                         ragged=False)["engine"])

    return {
        "workload": {"n_requests": FR_REQUESTS,
                     "shared_prefix": FR_SHARED_PREFIX,
                     "spec_k": OBS_SPEC_K, "rate_req_s": RATE,
                     "n_slots": N_SLOTS, "block_size": BLOCK_SIZE,
                     "chunk": CHUNK, "seed": seed},
        "note": "capture and replay both run the virtual clock, so the "
                "decision streams are bit-comparable; the legacy replay "
                "is the cross-config A/B (same tokens expected under "
                "greedy decode, different scheduler decisions)",
        "fingerprint": rec.fingerprint,
        "decisions": rec.meta["n_decisions"],
        "requests": rec.meta["n_requests"],
        "wall_s_virtual": rec.meta["wall_s_virtual"],
        "replay": {
            "token_identical": same.token_identical,
            "diff_lines": len(same.decision_diff),
            "fingerprint_match": same.fingerprint_match,
            "mismatched_rids": same.mismatched_rids},
        "replay_diff_lines": len(same.decision_diff),
        "cross_config": {
            "engine": "legacy per-shape trio (ragged=False)",
            "token_identical": legacy.token_identical,
            "diff_lines": len(legacy.decision_diff),
            "fingerprint_match": legacy.fingerprint_match,
            "diff_head": legacy.decision_diff[:8]},
        "artifacts": paths,
    }


def check_flight_recorder(fr: dict) -> None:
    """Acceptance gates for the flight recorder (ISSUE 9)."""
    rp = fr["replay"]
    if not rp["token_identical"]:
        raise SystemExit(
            f"replay is NOT token-identical to the capture: rids "
            f"{rp['mismatched_rids']} diverged")
    if rp["diff_lines"] != 0:
        raise SystemExit(
            f"replay produced a {rp['diff_lines']}-line scheduler-"
            f"decision diff on an identically-configured engine — "
            f"capture/replay is not deterministic")
    if not rp["fingerprint_match"]:
        raise SystemExit(
            "replay engine fingerprint differs from the record's on an "
            "identically-configured engine")
    if fr["decisions"] <= 0:
        raise SystemExit("capture recorded no scheduler decisions")
    cc = fr["cross_config"]
    if cc["fingerprint_match"]:
        raise SystemExit(
            "legacy engine matched the ragged record's fingerprint — "
            "the config fingerprint is not discriminating")
    if cc["diff_lines"] == 0:
        raise SystemExit(
            "legacy-engine replay produced an EMPTY decision diff vs "
            "the ragged capture — the A/B instrument resolves nothing")
    if not cc["token_identical"]:
        raise SystemExit(
            "legacy-engine replay broke greedy token parity — replay "
            "re-injection is perturbing the sampled tokens")


def bench_slo(*, seed: int = 0) -> dict:
    """SLO burn-rate monitoring (DESIGN §15) on record-mode engines
    (virtual clock => deterministic TTFT/latency, so the alert verdicts
    are reproducible): one OVERLOAD run whose objectives are set
    impossibly tight (every request violates, the burn rate crosses the
    threshold, ``slo.alert`` fires into the tracer) and one HEALTHY run
    with generous objectives (no alert)."""
    from repro.obs.slo import SLObjective

    def run(objectives):
        out = serve_engine(
            ARCH, n_requests=N_REQUESTS, rate=RATE, n_slots=N_SLOTS,
            block_size=BLOCK_SIZE, chunk=CHUNK, mode="fp",
            calibrate=False, seed=seed,
            cfg_overrides=dict(BENCH_SCALE, kv_cache_bits=8),
            record=True, slo=objectives)
        eng = out["engine"]
        rep = out["report"]
        names = [name for (_ph, name, *_rest) in eng.tracer.events]
        return {
            "objectives": [o.name for o in objectives],
            "alerts_fired": rep["slo"]["alerts_fired"],
            "alerts_active": rep["slo"]["alerts_active"],
            "evaluations": rep["slo"]["evaluations"],
            "worst_burn_rate": rep["slo"]["worst_burn_rate"],
            "alert_events": names.count("slo.alert"),
            "recover_events": names.count("slo.recover"),
            "status": rep["slo"]["status"],
        }

    def objectives(ttft_s, energy_uj):
        return [
            SLObjective(name="ttft_p_ok", metric="ttft", target=ttft_s,
                        budget_frac=0.05, window_s=SLO_WINDOW_S,
                        burn_threshold=1.0, min_samples=1),
            SLObjective(name="energy_per_token",
                        metric="energy.proxy_uj_per_token",
                        target=energy_uj, budget_frac=0.05,
                        window_s=SLO_WINDOW_S, burn_threshold=1.0,
                        min_samples=1),
        ]

    overload = run(objectives(ttft_s=1e-6, energy_uj=1e-9))
    healthy = run(objectives(ttft_s=1e6, energy_uj=1e12))
    return {
        "workload": {"n_requests": N_REQUESTS, "rate_req_s": RATE,
                     "n_slots": N_SLOTS, "window_s": SLO_WINDOW_S,
                     "seed": seed},
        "note": "overload = impossibly tight targets (TTFT 1us, energy "
                "1e-9 uJ/token) so every sample violates; healthy = "
                "generous targets; both on the virtual clock",
        "overload": overload,
        "healthy": healthy,
    }


def bench_recurrent_substrate(*, seed: int = 0) -> dict:
    """Attention vs RWKV6 (fixed slabs) vs zamba2 (hybrid) on the SAME
    Poisson arrival process at two context lengths (DESIGN §16).

    Every number gated here is deterministic: greedy fp32 token parity
    vs the per-request dense-cache oracle (the attention engine's parity
    doubles as the no-transformer-regression gate for this refactor),
    and the Table-5 requant-per-token counters, which depend only on the
    workload shape — never the wall clock."""
    from repro.serving import Request

    def workload(vocab, prompts, gens):
        rng = np.random.default_rng(seed)
        t, reqs = 0.0, []
        for i in range(REC_REQUESTS):
            t += float(rng.exponential(1.0 / RATE))
            reqs.append(Request(
                rid=i,
                prompt=rng.integers(0, vocab, size=int(rng.choice(prompts))
                                    ).astype(np.int32),
                max_new_tokens=int(rng.choice(gens)), arrival=t))
        return reqs

    def oracle_parity(eng, cfg, reqs):
        ctx = QuantContext(mode=QuantMode.FP)
        outs = eng.outputs()
        # one shared dense-cache size and ONE jitted prefill/decode pair
        # for the whole row (the masked tail leaves numerics unchanged).
        # Eager M.decode_step would re-specialize per concrete step
        # index and leak ~90 JIT code mappings per token at bench scale
        # — across three rows of eight requests that runs the process
        # into the kernel's vm.max_map_count and XLA dies with
        # "Cannot allocate memory".
        max_seq = max(len(r.prompt) + r.max_new_tokens for r in reqs)
        pf = jax.jit(lambda p, toks: M.prefill(
            p, {"tokens": toks}, cfg, ctx, max_seq=max_seq))
        dstep = jax.jit(lambda p, tok, cache, pos: M.decode_step(
            p, tok, cache, pos, cfg, ctx))
        for r in reqs:
            p_len = len(r.prompt)
            logits, cache = pf(eng.params, jnp.asarray(r.prompt[None]))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            want = [int(tok[0, 0])]
            for i in range(r.max_new_tokens - 1):
                l, cache = dstep(eng.params, tok, cache,
                                 jnp.asarray(p_len + i, jnp.int32))
                tok = jnp.argmax(l, -1)[:, None].astype(jnp.int32)
                want.append(int(tok[0, 0]))
            if outs[r.rid].tolist() != want:
                return False
        return True

    def run(arch, prompts, gens, *, parity=False, **kw):
        from repro.configs import get_smoke_config as smoke
        vocab = smoke(arch).vocab_size
        reqs = workload(vocab, prompts, gens)
        out = serve_engine(arch, requests=reqs, n_slots=REC_SLOTS,
                           block_size=BLOCK_SIZE, chunk=REC_CHUNK,
                           mode="fp", calibrate=False, seed=seed, **kw)
        eng, rep = out["engine"], out["report"]
        row = {
            "substrate": rep["substrate"],
            "requant_ops_per_token": rep["hwcost"]["requant_ops_per_token"],
            "uj_per_token": rep["energy"]["proxy_uj_per_token"],
            "gen_tokens": rep["gen_tokens"],
            "completed": rep["completed"],
        }
        if rep.get("state_pool") is not None:
            row["state_quant_ops_per_step"] = \
                rep["state_pool"]["state_quant_ops_per_step"]
            eng.state_pool.check_invariants()
            assert eng.state_pool.n_live == 0
        if parity:
            row["token_parity"] = oracle_parity(eng, eng.cfg, reqs)
        return row

    att_kw = dict(cfg_overrides=dict(BENCH_SCALE, kv_cache_bits=8))
    rec_kw = dict(cfg_overrides=dict(dtype="float32"))
    long_p, long_g = REC_LONG
    short_p, short_g = REC_SHORT
    rows = {
        "attention": run(ARCH, long_p, long_g, parity=True, **att_kw),
        "rwkv6": run("rwkv6_3b", long_p, long_g, parity=True, **rec_kw),
        "hybrid": run("zamba2_2_7b", long_p, long_g, parity=True,
                      **rec_kw),
    }
    short = {
        "attention": run(ARCH, short_p, short_g, **att_kw),
        "rwkv6": run("rwkv6_3b", short_p, short_g, **rec_kw),
    }
    growth = {
        k: round(rows[k]["requant_ops_per_token"]
                 / short[k]["requant_ops_per_token"], 3)
        for k in short
    }
    return {
        "workload": {"n_requests": REC_REQUESTS, "rate_req_s": RATE,
                     "n_slots": REC_SLOTS, "block_size": BLOCK_SIZE,
                     "chunk": REC_CHUNK, "long": REC_LONG,
                     "short": REC_SHORT, "seed": seed},
        "note": "long-workload rows carry the parity + requant gates; "
                "context_growth = requant_ops_per_token long/short — "
                "~flat on the slab substrate, multiplicative on "
                "attention (the paper's context-free state-requant "
                "thesis, measured)",
        "long": rows,
        "short": short,
        "context_growth": growth,
        "parity_all": all(r["token_parity"] for r in rows.values()),
    }


def check_recurrent_substrate(rc: dict) -> None:
    """Acceptance gates for the fixed-slab substrate (ISSUE 10)."""
    for name, row in rc["long"].items():
        if not row["token_parity"]:
            raise SystemExit(
                f"{name} engine is NOT token-identical to its dense "
                f"fp32 oracle on the long recurrent-substrate workload"
                + ("" if name != "attention" else
                   " — the §16 refactor regressed the transformer path"))
    att = rc["long"]["attention"]["requant_ops_per_token"]
    rec = rc["long"]["rwkv6"]["requant_ops_per_token"]
    if not rec < att:
        raise SystemExit(
            f"RWKV6 requant ops/token {rec} not strictly below the "
            f"equal-length attention baseline's {att}")
    # the context-free thesis: attention's per-token requant accounting
    # multiplies with context, the slab substrate's stays ~flat
    g = rc["context_growth"]
    if g["attention"] < 2.0:
        raise SystemExit(
            f"attention requant/token grew only {g['attention']}x from "
            f"short to long contexts — the baseline accounting is off")
    if not 0.7 < g["rwkv6"] < 1.3:
        raise SystemExit(
            f"RWKV6 requant/token moved {g['rwkv6']}x from short to "
            f"long contexts — slab requant is no longer context-free")


def check_slo(sl: dict) -> None:
    """Acceptance gates for SLO burn-rate monitoring (ISSUE 9)."""
    ov, ok = sl["overload"], sl["healthy"]
    if ov["alerts_fired"] < 1:
        raise SystemExit(
            f"overload run fired {ov['alerts_fired']} alerts despite "
            f"impossibly tight objectives — the burn-rate monitor is "
            f"not evaluating")
    if ov["alert_events"] < 1:
        raise SystemExit(
            "overload alert never reached the tracer — slo.alert "
            "events are not being emitted")
    if not ov["worst_burn_rate"] or ov["worst_burn_rate"] <= 1.0:
        raise SystemExit(
            f"overload worst burn rate {ov['worst_burn_rate']} never "
            f"crossed the threshold 1.0")
    if ok["alerts_fired"] != 0 or ok["alerts_active"] != 0:
        raise SystemExit(
            f"healthy run fired {ok['alerts_fired']} alerts "
            f"({ok['alerts_active']} active) under generous objectives "
            f"— false positives")
    if ov["evaluations"] <= 0 or ok["evaluations"] <= 0:
        raise SystemExit("SLO monitor reported zero evaluations")


def check_history(bench: dict) -> None:
    """Self-contained gate for bench-history regression detection
    (ISSUE 9): the fresh run must PASS against itself as baseline, and
    a synthetically degraded copy (throughput x0.3, parity broken) must
    FAIL.  The committed-ledger comparison runs separately in CI via
    ``python -m benchmarks.bench_history --regress``."""
    from benchmarks.bench_history import entry_of, regress
    baseline = [entry_of(bench)]
    fails = regress(bench, baseline)
    if fails:
        raise SystemExit(
            f"bench-history claims the run regressed vs ITSELF: {fails}")
    degraded = json.loads(json.dumps(bench))
    degraded["continuous"]["tokens_per_s"] *= 0.3
    degraded["w8a8"]["agreement_int_ref"] *= 0.5
    fails = regress(degraded, baseline)
    if not any(f.startswith("continuous.tokens_per_s") for f in fails):
        raise SystemExit(
            "bench-history passed a run with tokens/s degraded to 30% "
            "— the throughput tolerance is not detecting regressions")
    if not any(f.startswith("w8a8.agreement_int_ref") for f in fails):
        raise SystemExit(
            "bench-history passed a run with broken W8A8 parity — the "
            "zero-tolerance class is not enforced")


def check_obs(ob: dict) -> None:
    """Acceptance gates for the observability layer (ISSUE 8)."""
    if ob["overhead_frac_disabled"] >= 0.01:
        raise SystemExit(
            f"disabled obs hooks cost {ob['overhead_frac_disabled']:.2%} "
            f"of the fastest steady step (guard {ob['guard_ns']}ns x "
            f"{ob['guards_per_step']} sites/step) — over the 1% budget")
    ring = ob["ring"]
    if ring["held"] > ring["capacity"]:
        raise SystemExit(
            f"trace ring holds {ring['held']} events > capacity "
            f"{ring['capacity']} — the buffer is not bounded")
    if ring["emitted"] - ring["dropped"] != ring["held"]:
        raise SystemExit(
            f"ring accounting broken: emitted {ring['emitted']} - "
            f"dropped {ring['dropped']} != held {ring['held']}")
    if ring["dropped"] <= 0:
        raise SystemExit(
            f"workload emitted only {ring['emitted']} events — the tiny "
            f"ring never wrapped, so the bound went unexercised")
    if ob["trace_errors"]:
        raise SystemExit(
            f"exported trace violates the Chrome trace-event schema: "
            f"{ob['trace_errors'][:3]}")
    for key in ("latency_delta_off", "latency_delta_on"):
        if ob[key] > 1e-9:
            raise SystemExit(
                f"trace-derived latency percentiles diverge from the "
                f"legacy report lists by {ob[key]} ({key})")
    if ob["energy_recon_gap"] != 0:
        raise SystemExit(
            f"energy phase attribution out by {ob['energy_recon_gap']} "
            f"quant ops vs the Table-5 hwcost counters — the split must "
            f"reconcile EXACTLY")
    if ob["schema_errors"]:
        raise SystemExit(
            f"report schema drifted from GOLDEN_SCHEMA: "
            f"{ob['schema_errors'][:5]}")


def check_w8a8(w8: dict) -> None:
    """Acceptance gates for the true-W8A8 section (ISSUE 7)."""
    if w8["agreement_int_ref"] < 0.99:
        raise SystemExit(
            f"W8A8 engine agrees with the dense-INT reference on only "
            f"{w8['agreement_int_ref']:.1%} of tokens — pre-quantized "
            f"codes must be bit-identical to on-the-fly quantization")
    # context floor, far above the 1/vocab ~ 0.4% chance rate; a tight
    # fp gate at smoke scale measures random-weight argmax stability,
    # not quantization quality (see the section comment; measured 0.25
    # free-running at seed 0 vs 0.85 teacher-forced)
    if w8["agreement_fp"] <= 0.2:
        raise SystemExit(
            f"W8A8 vs fp token agreement {w8['agreement_fp']:.1%} is at "
            f"chance level — the calibrated forward is broken")
    if w8["requant_ops_forward"] <= 0 or \
            w8["energy_uj_forward_bit_shift"] <= 0:
        raise SystemExit(
            "W8A8 run reported no full-forward requant work — Table-5 "
            "forward accounting is not wired")
    disp = w8["dispatched_tokens"]
    if disp["w8a8"] != disp["fp"]:
        raise SystemExit(
            f"W8A8 engine dispatched {disp['w8a8']} tokens vs the fp "
            f"engine's {disp['fp']} on the identical workload — the "
            f"int8 path is perturbing scheduling/bucketing")
    # The throughput gate compares against the dense-INT reference: same
    # integer forward, so pre-quantizing the weights must not cost wall
    # clock (it SAVES the per-step weight quantization).  fp is reported
    # but not wall-clock-gated: on CPU the int8 path is emulated (quant +
    # int32 matmul + shifts in jnp — measured ~0.65x of one f32 matmul),
    # and the ISSUE's gate is about not regressing the dispatch count,
    # not MXU speed; the fp dispatch-count equality above IS that gate.
    tps = w8["tokens_per_s_best"]
    if tps["w8a8"] < 0.9 * tps["int_ref"]:
        raise SystemExit(
            f"W8A8 tokens/s {tps['w8a8']} grossly below the dense-INT "
            f"reference's {tps['int_ref']} — pre-quantized weights made "
            f"the same integer forward slower")
    if tps["w8a8"] < tps["int_ref"]:
        print("WARNING: W8A8 tokens/s below the dense-INT reference "
              "despite skipping weight quantization — likely CI timer "
              "noise")


def check_ragged_mixed(rm: dict) -> None:
    """Acceptance gates for the unified ragged dispatch (ISSUE 6)."""
    if not rm["token_parity"]:
        raise SystemExit(
            "ragged engine is NOT token-identical to the per-shape "
            "engine on the mixed-traffic workload")
    if rm["compiled_step_shapes"] > 4:
        raise SystemExit(
            f"ragged engine compiled {rm['compiled_step_shapes']} step "
            f"shapes {rm['ragged_step_shapes']} > 4 — the pow2 token "
            f"bucketing is leaking shapes")
    # deterministic structural wins: the whole point of the work-list
    if rm["padded_tokens"]["ragged"] >= rm["padded_tokens"]["legacy"]:
        raise SystemExit(
            f"ragged dispatched {rm['padded_tokens']['ragged']} padded "
            f"tokens vs legacy's {rm['padded_tokens']['legacy']} — no "
            f"padding win on mixed traffic")
    if rm["dispatches"]["ragged"] >= rm["dispatches"]["legacy"]:
        raise SystemExit(
            f"ragged needed {rm['dispatches']['ragged']} dispatches vs "
            f"legacy's {rm['dispatches']['legacy']} — no fusion win")
    # wall-clock gates with the same gross-regression philosophy as the
    # continuous-vs-static gate: CI timers spike, structure doesn't
    tps = rm["tokens_per_s_best"]
    if tps["ragged"] < 0.9 * tps["legacy"]:
        raise SystemExit(
            f"ragged tokens/s {tps['ragged']} grossly below the "
            f"per-shape engine's {tps['legacy']}")
    if tps["ragged"] < tps["legacy"]:
        print("WARNING: ragged tokens/s below per-shape despite the "
              "dispatch/padding advantage — likely CI timer noise")
    tpot = rm["tpot_p99_best"]
    if tpot["ragged"] > 1.25 * tpot["legacy"]:
        raise SystemExit(
            f"ragged decode TPOT p99 {tpot['ragged']:.4f}s grossly "
            f"worse than per-shape {tpot['legacy']:.4f}s under "
            f"concurrent prefill")


def check_spec_decode(sd: dict) -> None:
    """Acceptance gates for the speculative-decoding section (ISSUE 5)."""
    if not sd["token_parity"]:
        raise SystemExit(
            "greedy speculative decode is NOT token-identical to the "
            "plain engine on the same workload")
    if not sd["acceptance_rate"] or sd["acceptance_rate"] <= 0.5:
        raise SystemExit(
            f"draft acceptance rate {sd['acceptance_rate']} <= 0.5 on "
            f"the repetitive self-drafting workload")
    if not sd["tokens_per_step"] or sd["tokens_per_step"] <= 1.3:
        raise SystemExit(
            f"speculative tokens/step {sd['tokens_per_step']} <= 1.3 on "
            f"the repetitive self-drafting workload")
    steps = sd["decode_phase_steps"]
    if steps["spec"] >= steps["plain"]:
        raise SystemExit(
            f"speculation needed {steps['spec']} decode-phase steps vs "
            f"the plain engine's {steps['plain']} — no structural win")


def check_shared_prefix(sp: dict) -> None:
    """Acceptance gates for the shared-prefix section (ISSUE 4)."""
    if sp["hit_rate"] <= 0.9:
        raise SystemExit(
            f"prefix-cache hit rate {sp['hit_rate']:.3f} <= 0.9 on the "
            f"repeated-system-prompt workload")
    if sp["cow_copies"] < 1:
        raise SystemExit("fully-cached repeat request triggered no COW")
    # structural (timer-independent): the cache must delete most prefill
    if sp["prefill_chunks"]["cached"] >= sp["prefill_chunks"]["no_cache"]:
        raise SystemExit(
            f"cached engine ran {sp['prefill_chunks']['cached']} prefill "
            f"chunks vs {sp['prefill_chunks']['no_cache']} without cache")
    ttft = sp["ttft_p50_best"]
    if not ttft["cached"] < ttft["no_cache"]:
        raise SystemExit(
            f"cached TTFT p50 {ttft['cached']:.4f}s not strictly better "
            f"than no-cache {ttft['no_cache']:.4f}s at equal pool size")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--requests", type=int, default=N_REQUESTS)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless continuous batching beats "
                         "the static baseline in tokens/s, the prefix "
                         "cache clears its hit-rate/TTFT gates, and the "
                         "W8A8 engine matches the dense-INT reference "
                         "token-for-token at equal dispatch count")
    args = ap.parse_args()

    import sys

    def sec(fn, **kw):
        # every section compiles its own engines/oracles and nothing is
        # shared across sections; dropping the executables between them
        # keeps the process under the kernel's vm.max_map_count (the
        # full bench otherwise accumulates >65k JIT code mappings and
        # XLA starts failing with "Cannot allocate memory")
        jax.clear_caches()
        print(f"[serving_bench] {fn.__name__} ...", file=sys.stderr,
              flush=True)
        return fn(seed=args.seed, **kw)

    out = bench_serving(n_requests=args.requests, seed=args.seed)
    out["shared_prefix"] = sec(bench_shared_prefix)
    out["spec_decode"] = sec(bench_spec_decode)
    out["ragged_mixed"] = sec(bench_ragged_mixed)
    out["w8a8"] = sec(bench_w8a8)
    stem = args.json[:-5] if args.json.endswith(".json") else args.json
    out["obs"] = sec(bench_obs, artifacts=stem)
    out["flight_recorder"] = sec(bench_flight_recorder, artifacts=stem)
    out["slo"] = sec(bench_slo)
    out["recurrent_substrate"] = sec(bench_recurrent_substrate)
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    c, s = out["continuous"], out["static"]
    print(f"continuous: {c['tokens_per_s']} tok/s "
          f"({c['decode_steps']} decode steps), "
          f"ttft p50 {c['ttft_s']['p50']:.3f}s, "
          f"e2e p99 {c['e2e_s']['p99']:.3f}s")
    print(f"static:     {s['tokens_per_s']} tok/s "
          f"({s['decode_steps']} decode steps), "
          f"ttft p50 {s['ttft_s']['p50']:.3f}s, "
          f"e2e p99 {s['e2e_s']['p99']:.3f}s")
    print(f"speedup (steady tokens/s): {out['speedup_tokens_per_s']}x | "
          f"decode steps {out['decode_steps']['continuous']} vs "
          f"{out['decode_steps']['static']}")
    sp = out["shared_prefix"]
    print(f"shared-prefix ({sp['workload']['shared_prefix']} tokens): "
          f"hit-rate {sp['hit_rate']:.1%}, {sp['cow_copies']} COW, "
          f"ttft p50 {sp['ttft_p50_best']['cached']:.3f}s vs "
          f"{sp['ttft_p50_best']['no_cache']:.3f}s no-cache, "
          f"prefill chunks {sp['prefill_chunks']['cached']} vs "
          f"{sp['prefill_chunks']['no_cache']}, "
          f"{sp['quant_ops_avoided']} quant ops avoided")
    sd = out["spec_decode"]
    print(f"spec decode (K={sd['workload']['spec_k']}): "
          f"parity={'OK' if sd['token_parity'] else 'FAIL'}, "
          f"acceptance {sd['acceptance_rate']:.1%}, "
          f"{sd['tokens_per_step']} tok/slot-step, decode-phase steps "
          f"{sd['decode_phase_steps']['spec']} vs "
          f"{sd['decode_phase_steps']['plain']} plain, "
          f"{sd['retracted_blocks']} blocks retracted, "
          f"{sd['requant_ops_wasted']} quant ops on rejected drafts")
    rm = out["ragged_mixed"]
    print(f"ragged mixed-traffic: "
          f"parity={'OK' if rm['token_parity'] else 'FAIL'}, "
          f"{rm['compiled_step_shapes']} compiled shapes "
          f"{rm['ragged_step_shapes']}, dispatches "
          f"{rm['dispatches']['ragged']} vs "
          f"{rm['dispatches']['legacy']} legacy, padded tokens "
          f"{rm['padded_tokens']['ragged']} vs "
          f"{rm['padded_tokens']['legacy']}, "
          f"{rm['tokens_per_s_best']['ragged']} vs "
          f"{rm['tokens_per_s_best']['legacy']} tok/s, tpot p99 "
          f"{rm['tpot_p99_best']['ragged']:.4f}s vs "
          f"{rm['tpot_p99_best']['legacy']:.4f}s")
    w8 = out["w8a8"]
    print(f"w8a8 ({w8['converted_tensors']} int8 weight tensors): "
          f"int-ref agreement {w8['agreement_int_ref']:.1%}, "
          f"fp agreement {w8['agreement_fp']:.1%}, "
          f"{w8['tokens_per_s_best']['w8a8']} vs "
          f"{w8['tokens_per_s_best']['int_ref']} int-ref vs "
          f"{w8['tokens_per_s_best']['fp']} fp tok/s, dispatched "
          f"{w8['dispatched_tokens']['w8a8']} vs "
          f"{w8['dispatched_tokens']['fp']} fp, forward requant "
          f"{w8['requant_ops_forward']} ops = "
          f"{w8['energy_uj_forward_bit_shift']:.1f} uJ shift-based "
          f"(vs {w8['energy_uj_forward_if_scaling_factor']:.1f} uJ "
          f"scaling-factor)")
    ob = out["obs"]
    print(f"obs: disabled-hook overhead "
          f"{ob['overhead_frac_disabled']:.3%} of the fastest steady "
          f"step ({ob['guard_ns']}ns guard x {ob['guards_per_step']} "
          f"sites/step), ring {ob['ring']['held']}/"
          f"{ob['ring']['capacity']} held ({ob['ring']['dropped']} "
          f"dropped of {ob['ring']['emitted']}), "
          f"{ob['trace_events_exported']} events exported, latency "
          f"delta {ob['latency_delta_on']}, energy proxy "
          f"{ob['energy']['proxy_uj_per_token']} uJ/token, "
          f"{len(ob['schema_errors'])} schema errors"
          + (f" -> {ob['artifacts']}" if ob["artifacts"] else ""))
    fr = out["flight_recorder"]
    print(f"flight recorder: {fr['requests']} requests, "
          f"{fr['decisions']} decisions captured "
          f"(fingerprint {fr['fingerprint']}, virtual "
          f"{fr['wall_s_virtual']:.3f}s), replay "
          f"token_identical={fr['replay']['token_identical']} "
          f"diff={fr['replay']['diff_lines']} lines, legacy A/B diff "
          f"{fr['cross_config']['diff_lines']} lines "
          f"(tokens "
          f"{'match' if fr['cross_config']['token_identical'] else 'DIVERGE'})"
          + (f" -> {fr['artifacts']}" if fr["artifacts"] else ""))
    sl = out["slo"]
    print(f"slo: overload fired {sl['overload']['alerts_fired']} alerts "
          f"({sl['overload']['alert_events']} traced, worst burn "
          f"{sl['overload']['worst_burn_rate']}), healthy fired "
          f"{sl['healthy']['alerts_fired']} over "
          f"{sl['healthy']['evaluations']} evaluations")
    rc = out["recurrent_substrate"]
    print(f"recurrent substrate: "
          f"parity={'OK' if rc['parity_all'] else 'FAIL'}, requant "
          f"ops/token attention {rc['long']['attention']['requant_ops_per_token']} "
          f"vs rwkv6 {rc['long']['rwkv6']['requant_ops_per_token']} vs "
          f"hybrid {rc['long']['hybrid']['requant_ops_per_token']} (long "
          f"workload); short->long growth attention "
          f"{rc['context_growth']['attention']}x vs rwkv6 "
          f"{rc['context_growth']['rwkv6']}x (context-free slab requant)")
    if args.check:
        check_shared_prefix(sp)
        check_spec_decode(sd)
        check_ragged_mixed(rm)
        check_w8a8(w8)
        check_obs(ob)
        check_flight_recorder(fr)
        check_slo(sl)
        check_recurrent_substrate(rc)
        check_history(out)
        # the deterministic gate is the structural one — continuous must
        # need strictly fewer decode steps for the same useful tokens;
        # wall clock only fails on a GROSS regression, because shared CI
        # runners show contention spikes best-of-N can't fully absorb
        steps = out["decode_steps"]
        if steps["continuous"] >= steps["static"]:
            raise SystemExit(
                f"continuous batching needed {steps['continuous']} decode "
                f"steps vs static's {steps['static']} — no structural win")
        if out["speedup_tokens_per_s"] < 0.9:
            raise SystemExit(
                f"continuous batching grossly slower than static: "
                f"{out['speedup_tokens_per_s']}x")
        if out["speedup_tokens_per_s"] <= 1.0:
            print("WARNING: wall-clock speedup <= 1.0 despite the "
                  "decode-step advantage — likely CI timer noise")


if __name__ == "__main__":
    main()
