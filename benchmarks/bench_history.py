"""Bench-history regression detection for ``serving_bench`` (ISSUE 9).

Every ``serving_bench`` run produces a ``BENCH_serving.json`` snapshot;
this module folds those snapshots into a committed, append-only
``BENCH_history.jsonl`` ledger and compares fresh runs against the best
historical baseline with *noise-aware* per-metric tolerances.

Ledger schema (one JSON object per line):

    {"schema": 1,
     "fingerprint": "ab12...",        # sha256[:16] of backend + workloads
     "backend": "cpu",
     "run": {"seed": 0, ...},         # free-form provenance (optional)
     "metrics": {"continuous.tokens_per_s": 855.5, ...}}

The fingerprint hashes everything that *defines* the experiment (backend
plus each section's ``workload`` dict) and nothing that *measures* it,
so only runs of the identical workload are comparable.  ``--regress``
picks, per metric, the best value among history entries with a matching
fingerprint ("best-of-N" across the committed history) and fails when
the fresh run falls outside that metric's relative tolerance in the bad
direction.  Timing metrics get loose tolerances (shared CI runners show
contention spikes); structural counters get tight ones; deterministic
parity metrics get zero.

CLI::

    python -m benchmarks.bench_history \
        --bench BENCH_serving.json --history BENCH_history.jsonl \
        --regress            # exit 1 when the fresh run regressed
    python -m benchmarks.bench_history --bench ... --append
"""
from __future__ import annotations

import argparse
import hashlib
import json
import math
import os
from dataclasses import dataclass
from typing import Any, Optional

HISTORY_SCHEMA = 1


# ---------------------------------------------------------------------------
# tracked metrics
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Tracked:
    """One scalar the history ledger follows.

    ``path`` is a dotted path into the BENCH_serving.json dict.
    ``higher`` says which direction is good; ``rel_tol`` is the relative
    slack allowed in the *bad* direction before the run counts as a
    regression (0.0 = exact match required, for deterministic parities).
    """

    path: str
    higher: bool
    rel_tol: float


# Tolerance classes: wall-clock throughput/latency on shared CI runners
# is the noisiest (0.35-0.6); structural counters (decode steps, padded
# tokens, dispatch counts) wobble only with scheduler changes (0.15-
# 0.25); deterministic parities and compile counts must not move (0.0).
TRACKED: tuple[Tracked, ...] = (
    Tracked("continuous.tokens_per_s", higher=True, rel_tol=0.60),
    Tracked("speedup_tokens_per_s", higher=True, rel_tol=0.35),
    Tracked("decode_steps.continuous", higher=False, rel_tol=0.15),
    Tracked("shared_prefix.hit_rate", higher=True, rel_tol=0.01),
    Tracked("shared_prefix.quant_ops_avoided", higher=True, rel_tol=0.15),
    Tracked("shared_prefix.prefill_chunks.cached", higher=False,
            rel_tol=0.15),
    Tracked("spec_decode.acceptance_rate", higher=True, rel_tol=0.15),
    Tracked("spec_decode.tokens_per_step", higher=True, rel_tol=0.15),
    Tracked("spec_decode.decode_phase_steps.spec", higher=False,
            rel_tol=0.15),
    Tracked("ragged_mixed.compiled_step_shapes", higher=False, rel_tol=0.0),
    Tracked("ragged_mixed.dispatches.ragged", higher=False, rel_tol=0.15),
    Tracked("ragged_mixed.padded_tokens.ragged", higher=False, rel_tol=0.25),
    Tracked("ragged_mixed.tokens_per_s_best.ragged", higher=True,
            rel_tol=0.60),
    Tracked("w8a8.agreement_int_ref", higher=True, rel_tol=0.0),
    Tracked("w8a8.requant_ops_forward", higher=False, rel_tol=0.10),
    Tracked("w8a8.tokens_per_s_best.w8a8", higher=True, rel_tol=0.60),
    Tracked("obs.overhead_frac_disabled", higher=False, rel_tol=0.60),
    Tracked("obs.energy.proxy_uj_per_token", higher=False, rel_tol=0.20),
    Tracked("flight_recorder.decisions", higher=False, rel_tol=0.0),
    Tracked("flight_recorder.replay_diff_lines", higher=False, rel_tol=0.0),
    Tracked("slo.overload.alerts_fired", higher=True, rel_tol=0.0),
    Tracked("slo.healthy.alerts_fired", higher=False, rel_tol=0.0),
    # §16 fixed-slab substrate: all three are pure functions of the
    # workload shape — zero tolerance
    Tracked("recurrent_substrate.parity_all", higher=True, rel_tol=0.0),
    Tracked("recurrent_substrate.long.rwkv6.requant_ops_per_token",
            higher=False, rel_tol=0.0),
    Tracked("recurrent_substrate.long.attention.requant_ops_per_token",
            higher=True, rel_tol=0.0),
)


def _dig(d: Any, path: str) -> Optional[float]:
    """Resolve a dotted path; None when any hop is missing/non-numeric."""
    cur = d
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    if isinstance(cur, bool):
        return float(cur)
    if isinstance(cur, (int, float)) and math.isfinite(cur):
        return float(cur)
    return None


def extract(bench: dict) -> dict[str, float]:
    """The tracked scalars present in one BENCH_serving.json dict.

    Missing paths are simply skipped: older snapshots (pre-obs, pre-
    flight-recorder) stay loadable and comparable on their common
    subset.
    """
    out: dict[str, float] = {}
    for t in TRACKED:
        v = _dig(bench, t.path)
        if v is not None:
            out[t.path] = v
    return out


# ---------------------------------------------------------------------------
# fingerprint
# ---------------------------------------------------------------------------

def fingerprint_of(bench: dict) -> str:
    """sha256[:16] over what defines the experiment, not what it measured.

    Hashes the backend string plus every section's ``workload`` dict
    (request counts, arrival rate, prompt/gen shapes, pool geometry,
    seeds, pass counts).  Two runs share a fingerprint iff their numbers
    are comparable.
    """
    ident: dict[str, Any] = {"backend": bench.get("backend")}
    if isinstance(bench.get("workload"), dict):
        ident["workload"] = bench["workload"]
    for key in sorted(bench):
        sec = bench[key]
        if isinstance(sec, dict) and isinstance(sec.get("workload"), dict):
            ident[key] = sec["workload"]
    blob = json.dumps(ident, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# ledger I/O
# ---------------------------------------------------------------------------

def entry_of(bench: dict, run: Optional[dict] = None) -> dict:
    """One history-ledger line for a finished bench run."""
    return {
        "schema": HISTORY_SCHEMA,
        "fingerprint": fingerprint_of(bench),
        "backend": bench.get("backend"),
        "run": dict(run or {}),
        "metrics": extract(bench),
    }


def load_history(path: str) -> list[dict]:
    """Parse a JSONL ledger; missing file -> empty history."""
    if not os.path.exists(path):
        return []
    entries = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                e = json.loads(line)
            except json.JSONDecodeError as exc:
                raise ValueError(f"{path}:{ln}: bad JSON ({exc})") from exc
            if e.get("schema") != HISTORY_SCHEMA:
                raise ValueError(
                    f"{path}:{ln}: schema {e.get('schema')!r} != "
                    f"{HISTORY_SCHEMA}")
            entries.append(e)
    return entries


def append_entry(path: str, entry: dict) -> None:
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")


# ---------------------------------------------------------------------------
# regression check
# ---------------------------------------------------------------------------

def _baseline(history: list[dict], fingerprint: str,
              t: Tracked) -> Optional[float]:
    """Best-of-N historical value for one metric (matching runs only)."""
    vals = [e["metrics"][t.path] for e in history
            if e.get("fingerprint") == fingerprint
            and t.path in e.get("metrics", {})]
    if not vals:
        return None
    return max(vals) if t.higher else min(vals)


def regress(bench: dict, history: list[dict]) -> list[str]:
    """Regression messages for a fresh run vs the committed history.

    Empty list = pass.  A run whose fingerprint matches no history entry
    passes trivially (nothing is comparable) — callers should treat that
    as "new baseline needed", not success, and we print a warning.
    """
    fp = fingerprint_of(bench)
    cur = extract(bench)
    comparable = [e for e in history if e.get("fingerprint") == fp]
    if not comparable:
        print(f"WARNING: no history entry matches fingerprint {fp} "
              f"({len(history)} entries total) — nothing to compare")
        return []
    failures: list[str] = []
    for t in TRACKED:
        if t.path not in cur:
            continue
        base = _baseline(history, fp, t)
        if base is None:
            continue
        val = cur[t.path]
        # the allowed floor/ceiling in the bad direction
        slack = abs(base) * t.rel_tol
        if t.higher:
            bound = base - slack
            bad = val < bound - 1e-12
        else:
            bound = base + slack
            bad = val > bound + 1e-12
        if bad:
            arrow = ">=" if t.higher else "<="
            failures.append(
                f"{t.path}: {val:g} vs baseline {base:g} "
                f"(needs {arrow} {bound:g}, rel_tol {t.rel_tol:g}, "
                f"{'higher' if t.higher else 'lower'} is better)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", default="BENCH_serving.json",
                    help="fresh serving_bench snapshot to evaluate")
    ap.add_argument("--history", default="BENCH_history.jsonl",
                    help="committed append-only ledger")
    ap.add_argument("--append", action="store_true",
                    help="fold the fresh run into the ledger")
    ap.add_argument("--regress", action="store_true",
                    help="exit 1 when the fresh run regressed vs the "
                         "best matching history entry")
    ap.add_argument("--seed", type=int, default=None,
                    help="provenance only: seed recorded in the entry")
    args = ap.parse_args()

    with open(args.bench) as f:
        bench = json.load(f)
    history = load_history(args.history)
    fp = fingerprint_of(bench)
    cur = extract(bench)
    print(f"bench {args.bench}: fingerprint {fp}, "
          f"{len(cur)} tracked metrics, history {args.history}: "
          f"{len(history)} entries "
          f"({sum(1 for e in history if e.get('fingerprint') == fp)} "
          f"comparable)")

    failed = False
    if args.regress:
        failures = regress(bench, history)
        if failures:
            print(f"REGRESSIONS ({len(failures)}):")
            for msg in failures:
                print(f"  {msg}")
            failed = True
        else:
            print("regression check: PASS")

    if args.append:
        run = {} if args.seed is None else {"seed": args.seed}
        append_entry(args.history, entry_of(bench, run=run))
        print(f"appended entry (fingerprint {fp}) to {args.history}")

    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
