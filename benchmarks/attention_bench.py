"""Attention dataflow benchmark: fused int8-KV flash kernel vs pure JAX.

Sweeps S in {1k, 8k, 32k} x {bf16, int8} KV x {flash kernel, pure-JAX
chunked}, reporting µs/call (wall-clock over jitted calls) and the analytic
HBM KV bytes moved per call (DESIGN.md §2 bytes model — the quantity the
paper's dataflow argument is about).

On CPU the kernel runs in Pallas interpret mode, which is not a timing
proxy; kernel µs are only measured on a real TPU backend (pass
``--time-kernel`` to force).  The bytes model needs no hardware — that is
the acceptance metric tracked across PRs (BENCH_attention.json).

Usage:
    PYTHONPATH=src python -m benchmarks.attention_bench [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qscheme import quant
from repro.kernels import ops
from repro.models.attention import chunked_attention

# decode-shaped cell: serving's steady state, where KV reads dominate
BATCH, HEADS, KV_HEADS, HEAD_DIM = 1, 8, 2, 128
NKV = 4
SIZES = (1024, 8192, 32768)


def _timeit(fn, *args, reps: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _decode_cell(s: int, int8_kv: bool, rng: np.random.Generator):
    groups = HEADS // KV_HEADS
    # bf16 throughout — the serving dtype the kv="bf16" label claims
    q = jnp.asarray(rng.normal(size=(BATCH, 1, HEADS, HEAD_DIM)),
                    jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(BATCH, s, KV_HEADS, HEAD_DIM)),
                    jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(BATCH, s, KV_HEADS, HEAD_DIM)),
                    jnp.bfloat16)
    if int8_kv:
        k, v = quant(k, NKV, 8), quant(v, NKV, 8)
    pos = jnp.asarray(s - 1, jnp.int32)
    return q, k, v, pos, groups


def _jax_path(q, k, v, pos, groups):
    """The dataflow the kernel deletes: dequantize the whole cache to HBM,
    repeat the groups, then chunked attention — the exact fallback the
    kernel is validated against (ops._dequant_then_repeat)."""
    del groups  # derived inside the shared fallback helper
    kr, vr = ops._dequant_then_repeat(q, k, v, NKV)
    return chunked_attention(q, kr, vr, causal=True, q_offset=pos)


def bench_attention(sizes=SIZES, *, time_kernel: bool | None = None,
                    reps: int = 3) -> list[dict]:
    """Returns one row per (S, kv dtype, path) cell."""
    if time_kernel is None:
        time_kernel = jax.default_backend() == "tpu"
    rng = np.random.default_rng(0)
    rows = []
    for s in sizes:
        for int8_kv in (False, True):
            q, k, v, pos, groups = _decode_cell(s, int8_kv, rng)
            kv_bits = 8 if int8_kv else 16
            common = dict(seq=s, kv=("int8" if int8_kv else "bf16"),
                          batch=BATCH, kv_heads=KV_HEADS, head_dim=HEAD_DIM)
            jax_fn = jax.jit(lambda q_, k_, v_, p: _jax_path(
                q_, k_, v_, p, groups))
            rows.append(dict(
                common, path="jax_chunked",
                us_per_call=round(_timeit(jax_fn, q, k, v, pos, reps=reps), 1),
                kv_bytes=ops.attention_kv_bytes(
                    s, KV_HEADS, HEAD_DIM, HEAD_DIM, kv_bits=kv_bits,
                    fused=False, batch=BATCH, groups=groups)))
            flash_us = None
            if time_kernel:
                flash_fn = jax.jit(lambda q_, k_, v_, p: ops.flash_decode(
                    q_, k_, v_, pos=p,
                    kv_frac_bits=NKV if int8_kv else None))
                flash_us = round(_timeit(flash_fn, q, k, v, pos, reps=reps), 1)
            rows.append(dict(
                common, path="flash_fused", us_per_call=flash_us,
                kv_bytes=ops.attention_kv_bytes(
                    s, KV_HEADS, HEAD_DIM, HEAD_DIM, kv_bits=kv_bits,
                    fused=True, batch=BATCH)))
    return rows


def rows_to_csv(rows):
    """CSV rows in the benchmarks/run.py ``name,us_per_call,derived``
    contract; derived = analytic KV bytes per call."""
    for r in rows:
        name = f"attn_{r['path']}_s{r['seq']}_{r['kv']}"
        us = r["us_per_call"] if r["us_per_call"] is not None else 0
        yield f"{name},{us},kv_bytes={r['kv_bytes']}"


def bench_rows(sizes=SIZES, **kw):
    """run.py entry point: run the sweep, persist BENCH_attention.json,
    yield CSV rows."""
    rows = bench_attention(sizes, **kw)
    with open("BENCH_attention.json", "w") as f:
        json.dump({"backend": jax.default_backend(), "rows": rows}, f,
                  indent=2)
    yield from rows_to_csv(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_attention.json")
    ap.add_argument("--sizes", type=int, nargs="+", default=list(SIZES))
    ap.add_argument("--time-kernel", action="store_true",
                    help="time the Pallas kernel even off-TPU (interpret "
                         "mode: orders of magnitude slow, not a proxy)")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()
    rows = bench_attention(tuple(args.sizes),
                           time_kernel=args.time_kernel or None,
                           reps=args.reps)
    payload = {"backend": jax.default_backend(), "rows": rows}
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print("name,us_per_call,derived")
    for line in rows_to_csv(rows):
        print(line)
    # headline ratio the paper's argument predicts (>= 3x at 8k, see tests)
    by = {(r["seq"], r["kv"], r["path"]): r for r in rows}
    for s in args.sizes:
        f_ = by.get((s, "int8", "flash_fused"))
        d_ = by.get((s, "int8", "jax_chunked"))
        if f_ and d_:
            print(f"attn_kv_bytes_ratio_s{s},0,"
                  f"ratio={d_['kv_bytes'] / f_['kv_bytes']:.2f}")


if __name__ == "__main__":
    main()
