"""Exact roofline terms per (arch x shape) via the depth-fit methodology.

For each cell: lower 2-3 reduced-depth full-width variants with EVERY scan
unrolled (``scan_lib.analysis_unroll``) so XLA's cost analysis counts all
work, then combine with the affine depth weights from
``configs.depth_variants`` to reconstruct the full-depth per-device cost.
Gradient accumulation multiplies the fitted per-micro cost by accum_steps
(the optimizer/update tail is counted once — measured from the accum=1
variant directly, since fits run at accum=1).

Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--arch A --shape S]
        [--mode fp|int] [--multi-pod] [--json rooflines.json]
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

from repro.configs import (ARCH_IDS, depth_variants, get_config,  # noqa: E402
                           supported_shapes)
from repro.configs.base import SHAPES  # noqa: E402
from repro.launch import analysis as A  # noqa: E402
from repro.launch import dryrun as D  # noqa: E402
from repro.launch import steps as S  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.scan_lib import analysis_unroll  # noqa: E402


def fitted_sample(arch: str, shape_name: str, mesh, mode: str = "fp",
                  fsdp: bool | None = None) -> tuple[A.CostSample, dict]:
    cfg = get_config(arch)
    shape0 = SHAPES[shape_name]
    # the fsdp policy must follow the FULL config, not the reduced variants
    if fsdp is None:
        fsdp = True if shape0.kind == "train" else S.serve_needs_fsdp(
            cfg, mesh, bytes_per_param=1 if mode == "int" else 2)
    variants, weights = depth_variants(cfg)
    total = None
    meta = {"variants": [], "fsdp": fsdp}
    for vcfg, w in zip(variants, weights):
        t0 = time.time()
        with analysis_unroll():
            _, compiled, _ = D.lower_cell(
                arch, shape_name, mesh, mode=mode, cfg=vcfg, accum_steps=1,
                fsdp=fsdp)
        s = A.sample_of(compiled)
        meta["variants"].append({
            "n_layers": vcfg.n_layers, "weight": w,
            "flops": s.flops, "compile_s": round(time.time() - t0, 1)})
        total = s.scaled(w) if total is None else total + s.scaled(w)
    shape = SHAPES[shape_name]
    # fits run at accum=1; a production accum>1 step repeats the same math
    meta["accum_steps"] = S.default_accum_steps(cfg, shape, mesh) \
        if shape.kind == "train" else 1
    return total, meta


def analyze_cell(arch: str, shape_name: str, mesh, mode: str = "fp") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    sample, meta = fitted_sample(arch, shape_name, mesh, mode)
    terms = A.roofline_terms(sample)
    mf = D.model_flops(cfg, shape)
    n_dev = mesh.devices.size
    t_bound = max(terms["t_compute_s"], terms["t_memory_s"],
                  terms["t_collective_s"])
    ideal = mf / (n_dev * A.PEAK_FLOPS)
    return {
        "arch": arch, "shape": shape_name, "mode": mode, "devices": n_dev,
        "hlo_flops_per_device": sample.flops,
        "hlo_bytes_per_device": sample.bytes_hbm,
        "collectives_per_device": sample.collectives,
        **terms,
        "model_flops_global": mf,
        "useful_flops_ratio": mf / (sample.flops * n_dev),
        # fraction of roofline: ideal model-flops time / achieved bound
        "roofline_fraction": ideal / t_bound if t_bound else 0.0,
        "fit": meta,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mode", default="fp", choices=["fp", "fake", "int"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cells = []
    if args.arch:
        cells = [(args.arch, args.shape)]
    else:
        for arch in ARCH_IDS:
            if arch == "resnet_paper":
                continue
            for shp in supported_shapes(get_config(arch)):
                cells.append((arch, shp))

    records, failures = [], []
    hdr = (f"{'arch':>22} {'shape':>12} {'compute':>9} {'memory':>9} "
           f"{'collect':>9} {'dominant':>10} {'useful':>7} {'roofl%':>7}")
    print(hdr)
    for arch, shp in cells:
        try:
            r = analyze_cell(arch, shp, mesh, args.mode)
            records.append(r)
            print(f"{arch:>22} {shp:>12} "
                  f"{r['t_compute_s']*1e3:8.2f}ms {r['t_memory_s']*1e3:8.2f}ms "
                  f"{r['t_collective_s']*1e3:8.2f}ms {r['dominant']:>10} "
                  f"{r['useful_flops_ratio']:7.3f} "
                  f"{100*r['roofline_fraction']:6.1f}%")
            sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            failures.append({"arch": arch, "shape": shp, "error": repr(e)})
            print(f"{arch:>22} {shp:>12}  FAILED: {e!r}", file=sys.stderr)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"records": records, "failures": failures}, f,
                      indent=1, default=str)
    print(f"\n{len(records)} cells analyzed, {len(failures)} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
