"""Benchmark driver: one function per paper table (+ Fig. 2) plus the
attention dataflow sweep (``attention_bench.bench_rows``, which also
persists BENCH_attention.json for the cross-PR perf trajectory).

Prints ``name,us_per_call,derived`` CSV rows.  The roofline/dry-run benches
need the 512-device env and run as separate modules:

    PYTHONPATH=src python -m benchmarks.roofline   --json rooflines.json
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import attention_bench as A
    from benchmarks import paper_tables as T

    print("name,us_per_call,derived")
    ok = True
    for fn in (T.table1_accuracy, T.table2_calibration_time,
               T.table3_bitwidths, T.table4_bitwidth_quality,
               T.table5_hwcost, T.fig2_stats, A.bench_rows):
        try:
            for row in fn():
                print(row)
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            ok = False
            print(f"{fn.__name__},0,ERROR={e!r}")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
