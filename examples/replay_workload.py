"""Capture a serving workload and replay it deterministically.

    PYTHONPATH=src python examples/replay_workload.py
    PYTHONPATH=src python examples/replay_workload.py --legacy-ab

The flight recorder (DESIGN §15) runs the engine on a VIRTUAL clock
(``record=True``): idle gaps jump straight to the next arrival and
every step advances time by a fixed ``virtual_dt``, so the arrival ->
admission composition — and therefore every scheduler decision — is a
pure function of the workload and the engine config.  The capture
freezes arrivals, prompts, sampling params, seeds, the emitted tokens
and the full scheduler-decision stream into a JSON
:class:`~repro.obs.replay.WorkloadRecord`.

Replaying it on a fresh, identically-configured engine must reproduce
the run EXACTLY: token-identical outputs and a zero-line decision
diff.  Replaying on a *different* config (``--legacy-ab`` uses the
legacy per-shape engine) keeps greedy token parity while the decision
diff localizes exactly where the two schedulers diverged — a line-
level A/B instrument for scheduler changes.

The same flow is scriptable from the CLI:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b \
        --engine --requests 8 --record /tmp/rec.json
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b \
        --replay /tmp/rec.json
"""
import argparse
import tempfile


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--record", default=None,
                    help="where to save the record (default: temp file)")
    ap.add_argument("--legacy-ab", action="store_true",
                    help="also replay cross-config on the legacy "
                         "per-shape engine and show the decision diff")
    args = ap.parse_args()

    from repro.launch.serve import serve_engine
    from repro.obs.replay import WorkloadRecord, replay_workload

    path = args.record or tempfile.mktemp(suffix="_record.json")

    # -- capture ----------------------------------------------------------
    def build(**kw):
        kw.setdefault("record", True)
        return serve_engine(args.arch, n_requests=args.requests,
                            rate=200.0, n_slots=4, mode="fp",
                            calibrate=False, seed=args.seed, spec_k=2,
                            **kw)

    cap = build(record=path)
    rec = cap["record"]
    print(f"captured {rec.meta['n_requests']} requests, "
          f"{rec.meta['n_decisions']} scheduler decisions, "
          f"{rec.meta['wall_s_virtual']:.3f}s virtual "
          f"(fingerprint {rec.fingerprint}) -> {path}")

    # -- exact replay on a fresh engine -----------------------------------
    rec = WorkloadRecord.load(path)            # the portable artifact
    res = replay_workload(rec, build()["engine"])
    print(f"replay: token_identical={res.token_identical}, "
          f"decision diff {len(res.decision_diff)} lines, "
          f"fingerprint_match={res.fingerprint_match} "
          f"-> {'EXACT' if res.ok else 'DIVERGED'}")
    assert res.ok, "identical config must replay exactly"

    # -- cross-config A/B --------------------------------------------------
    if args.legacy_ab:
        res = replay_workload(rec, build(ragged=False)["engine"])
        print(f"\nlegacy per-shape A/B: "
              f"token_identical={res.token_identical}, "
              f"decision diff {len(res.decision_diff)} lines "
              f"(fingerprints differ: {not res.fingerprint_match})")
        for line in res.decision_diff[:30]:
            print(f"  {line}")
        if len(res.decision_diff) > 30:
            print(f"  ... {len(res.decision_diff) - 30} more lines")


if __name__ == "__main__":
    main()
