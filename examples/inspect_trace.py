"""Inspect a serving trace exported by the observability layer.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b \
        --engine --requests 8 --trace /tmp/trace.json
    PYTHONPATH=src python examples/inspect_trace.py /tmp/trace.json

The file is Chrome trace-event JSON (DESIGN §14): load it at
https://ui.perfetto.dev (or chrome://tracing) to see the lanes —
engine steps, jitted dispatches (with padded-token counts and
compile-vs-steady flags), scheduler admissions/preemptions, pool
alloc/evict/retract, prefix-cache hits, and one span per request
from admission to completion.

This script does the same offline: validates the schema, then prints
a lane-by-lane span summary and the per-request timelines with
trace-derived TTFT/TPOT.
"""
import argparse
import json
from collections import defaultdict


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome trace-event JSON "
                                  "(serve --engine --trace OUT.json)")
    ap.add_argument("--top", type=int, default=8,
                    help="spans to list per lane (by total duration)")
    args = ap.parse_args()

    with open(args.trace) as f:
        obj = json.load(f)

    from repro.obs import validate_chrome_trace
    problems = validate_chrome_trace(obj)
    if problems:
        raise SystemExit("invalid trace:\n  " + "\n  ".join(problems))

    events = obj["traceEvents"]
    meta = obj.get("otherData", {})
    # tid -> lane name from the thread_name metadata events
    lanes = {e["tid"]: e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    spans = [e for e in events if e["ph"] == "X"]
    instants = [e for e in events if e["ph"] in ("i", "I")]
    print(f"{args.trace}: {len(events)} events "
          f"({len(spans)} spans, {len(instants)} instants), "
          f"ring dropped={meta.get('dropped_events', '?')} "
          f"capacity={meta.get('ring_capacity', '?')}")

    per_lane = defaultdict(lambda: defaultdict(lambda: [0, 0.0]))
    for e in spans:
        agg = per_lane[lanes.get(e["tid"], f"tid{e['tid']}")][e["name"]]
        agg[0] += 1
        agg[1] += e.get("dur", 0.0)
    for lane in sorted(per_lane):
        print(f"\n[{lane}]")
        rows = sorted(per_lane[lane].items(),
                      key=lambda kv: -kv[1][1])[:args.top]
        for name, (n, dur) in rows:
            print(f"  {name:<32s} x{n:<5d} total {dur / 1e3:9.3f} ms")

    # per-request timelines live in the 'requests' lane: one span per
    # request (admission -> done) plus a first_token instant for TTFT
    reqs = [e for e in spans if lanes.get(e["tid"]) == "requests"]
    if reqs:
        print(f"\n[timelines] {len(reqs)} requests")
        for e in sorted(reqs, key=lambda e: e["ts"])[:args.top]:
            a = e["args"]
            # span runs admit -> done; true e2e is measured from arrival
            e2e = (e["ts"] + e.get("dur", 0.0)) / 1e6 - a["arrival_s"]
            fmt = lambda v: f"{1e3 * v:8.2f} ms" if v is not None else "       --"
            print(f"  {e['name']:<12s} e2e {1e3 * e2e:9.3f} ms  "
                  f"ttft {fmt(a.get('ttft_s'))}  "
                  f"tpot {fmt(a.get('tpot_s'))}")

    print("\nopen in Perfetto: https://ui.perfetto.dev  ->  Open trace file")


if __name__ == "__main__":
    main()
