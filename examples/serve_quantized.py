"""Batched int8 serving across architecture families.

    PYTHONPATH=src python examples/serve_quantized.py [--arch qwen3_1_7b]

Calibrates with Algorithm 1 on one batch, converts to the integer deploy
path, then serves batched requests (prefill + greedy decode), comparing
tokens against the FP path.

With ``--sharded`` the flash-serving pass runs on a 2-device (data=1,
model=2) mesh: the fused Pallas attention executes per-shard under
shard_map, KV heads (whole GQA groups) partitioned over the model axis
with their power-of-two scales resident (DESIGN §8).  Equivalent CLI:

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b \
        --attn-kernel flash --mesh 1x2
"""
import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--sharded", action="store_true",
                    help="also run flash serving on a 2-device mesh "
                         "(forces 2 virtual CPU devices when needed)")
    args = ap.parse_args()

    if args.sharded:
        # must happen before jax initializes its backends; append to any
        # pre-existing flags rather than losing them (or being lost)
        import os
        flag = "xla_force_host_platform_device_count"
        flags = os.environ.get("XLA_FLAGS", "")
        if flag not in flags:
            os.environ["XLA_FLAGS"] = f"{flags} --{flag}=2".strip()

    from repro.launch.serve import serve

    fp = serve(args.arch, mode="fp", calibrate=False, gen=args.gen)
    q = serve(args.arch, mode="int", calibrate=True, gen=args.gen)
    agree = float(np.mean(fp["tokens"] == q["tokens"]))
    print(f"\n[{args.arch}] int8 vs FP greedy tokens: {agree:.2%} agreement")
    print(f"fp  sample: {fp['tokens'][0]}")
    print(f"int sample: {q['tokens'][0]}")
    print(f"decode: fp {1e3*fp['decode_s_per_tok']:.1f} ms/tok | "
          f"int {1e3*q['decode_s_per_tok']:.1f} ms/tok "
          f"(CPU interpret-mode kernels; int8 wins on TPU via 2x MXU "
          f"throughput + 4x smaller weight reads)")

    if args.sharded:
        import jax
        if len(jax.devices()) < 2:
            print("\n[sharded] skipped: only 1 device visible (set "
                  "XLA_FLAGS=--xla_force_host_platform_device_count=2)")
            return
        # dims at which the fused kernels GENUINELY launch per shard
        # (smoke head_dim=16 would take the chunked fallback inside the
        # shard_map): head_dim=128 + max_seq=128 satisfy the decode
        # kernel's lane/tile requirements, prompt 120 >= 16 the prefill's
        # fp32 so greedy tokens are comparable across implementations
        # (bf16 near-tie argmax flips mid-rollout are not a parity signal)
        kern = dict(gen=8, prompt_len=120, mode="int", calibrate=True,
                    cfg_overrides={"head_dim": 128, "kv_cache_bits": 8,
                                   "dtype": "float32"})
        ref = serve(args.arch, **kern)
        sh = serve(args.arch, attn_kernel="flash", mesh_shape=(1, 2),
                   **kern)
        agree_sh = float(np.mean(sh["tokens"] == ref["tokens"]))
        print(f"\n[{args.arch}] 2-device shard_map fused flash vs "
              f"1-device chunked int8 tokens: {agree_sh:.2%} agreement")
        print(f"sharded-flash decode: {1e3*sh['decode_s_per_tok']:.1f} "
              f"ms/tok on a (data=1, model=2) mesh — KV heads split "
              f"across shards, int8 codes + scales resident (DESIGN §8)")


if __name__ == "__main__":
    main()
