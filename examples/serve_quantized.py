"""Batched int8 serving across architecture families.

    PYTHONPATH=src python examples/serve_quantized.py [--arch qwen3_1_7b]

Calibrates with Algorithm 1 on one batch, converts to the integer deploy
path, then serves batched requests (prefill + greedy decode), comparing
tokens against the FP path.
"""
import argparse

import numpy as np

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    fp = serve(args.arch, mode="fp", calibrate=False, gen=args.gen)
    q = serve(args.arch, mode="int", calibrate=True, gen=args.gen)
    agree = float(np.mean(fp["tokens"] == q["tokens"]))
    print(f"\n[{args.arch}] int8 vs FP greedy tokens: {agree:.2%} agreement")
    print(f"fp  sample: {fp['tokens'][0]}")
    print(f"int sample: {q['tokens'][0]}")
    print(f"decode: fp {1e3*fp['decode_s_per_tok']:.1f} ms/tok | "
          f"int {1e3*q['decode_s_per_tok']:.1f} ms/tok "
          f"(CPU interpret-mode kernels; int8 wins on TPU via 2x MXU "
          f"throughput + 4x smaller weight reads)")


if __name__ == "__main__":
    main()
