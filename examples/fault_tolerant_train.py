"""Fault-tolerant training demo: the supervisor restart loop surviving an
injected node failure with elastic re-meshing + checkpoint resume.

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_smoke_config
from repro.core.qmodel import QuantContext, QuantMode
from repro.data import SyntheticLMStream
from repro.distributed.fault_tolerance import ElasticPlanner, RunSupervisor
from repro.models import model as M
from repro.optim import adamw


def main():
    cfg = get_smoke_config("qwen3_1_7b")
    ctx = QuantContext(mode=QuantMode.FP)
    opt = adamw(weight_decay=0.0)
    stream = SyntheticLMStream(cfg.vocab_size, 64, 4, seed=0)
    tmp = tempfile.mkdtemp(prefix="repro_ft_")
    ck = Checkpointer(tmp)

    @jax.jit
    def step(p, s, batch):
        (loss, _), g = jax.value_and_grad(
            lambda pp: M.loss_fn(pp, batch, cfg, ctx, remat=False),
            has_aux=True)(p)
        p2, s2 = opt.update(g, s, p, 1e-3)
        return p2, s2, loss

    state = {"params": M.init_params(cfg, jax.random.PRNGKey(0)),
             "opt": None}
    state["opt"] = opt.init(state["params"])
    crash_at = {"step": 12, "armed": True}

    def train_segment(plan, start, total):
        print(f"  [segment] mesh {plan.shape} from step {start}")
        if start > 0:
            restored, extra = ck.restore(jax.eval_shape(lambda: state))
            state.update(restored)
        for i in range(start + 1, total + 1):
            b = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
            state["params"], state["opt"], loss = step(
                state["params"], state["opt"], b)
            if i % 5 == 0:
                ck.save(i, dict(state), extra={"step": i}, blocking=True)
                print(f"    step {i} loss {float(loss):.3f} (checkpointed)")
            if crash_at["armed"] and i == crash_at["step"]:
                crash_at["armed"] = False
                print("    !! injected node failure (16 devices lost)")
                return i, {"lost_devices": 16}
        return total, None

    sup = RunSupervisor(ElasticPlanner(model_axis=16), ck, train_segment)
    final = sup.run(n_devices=256, total_steps=25)
    print(f"finished at step {final} after {sup.restarts} restart(s); "
          f"history: {[(h['devices'], h['from'], h['to']) for h in sup.history]}")


if __name__ == "__main__":
    main()
