"""Quickstart: the paper's full pipeline on its own model family (ResNet).

    PYTHONPATH=src python examples/quickstart.py

1. Build a ResNet with BatchNorm, fold BN into conv weights (paper §1.2.1).
2. Build the dataflow plan (Fig. 1 unified modules) — count quant points.
3. Calibrate fractional bits with Algorithm 1 (grid search, no fine-tune).
4. Run the integer-only deploy path (int8 codes + bit shifts) and compare
   with the FP reference.
5. Price the requantization hardware (Table 5 model).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.resnet_paper import ResNetConfig
from repro.core import hwcost
from repro.core.dataflow import count_quant_ops
from repro.models import resnet as R


def main():
    cfg = ResNetConfig(stages=(16, 32), blocks_per_stage=2, img_size=32)
    params = R.init_resnet(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).uniform(
        0, 1, size=(16, cfg.img_size, cfg.img_size, 3)), jnp.float32)

    plan = R.build_resnet_plan(cfg)
    counts = count_quant_ops(plan)
    print(f"[dataflow] unified modules: {len(plan.modules)} | "
          f"quant points joint={counts['joint_activation_points']} vs "
          f"naive={counts['naive_activation_points']} "
          f"(saved {counts['saved']})")

    print("[calibrate] running Algorithm 1 ...")
    q = R.quantize_resnet(params, x, cfg)
    print(f"  {len(q.report.results)} modules in {q.report.total_s:.1f}s, "
          f"shift histogram {q.report.shift_histogram()}")

    logits_fp = R.resnet_forward(params, x, cfg)
    logits_int = R.resnet_int_forward(q, x, cfg)
    rel = float(jnp.linalg.norm(logits_int - logits_fp) /
                jnp.linalg.norm(logits_fp))
    agree = float(np.mean(np.argmax(np.asarray(logits_fp), -1) ==
                          np.argmax(np.asarray(logits_int), -1)))
    print(f"[deploy] integer-only path: rel_err={rel:.4f} "
          f"prediction agreement={agree:.3f}")

    n_requants = counts["joint_activation_points"] * 32 * 32 * 32
    for kind in ("bit_shifting", "scaling_factor", "codebook"):
        r = hwcost.estimate(kind, n_requants)
        print(f"[hwcost] {kind:15s} {r.energy_uj:8.1f} uJ "
              f"({r.vs_bit_shift_energy:.1f}x bit-shift)")


if __name__ == "__main__":
    main()
