"""End-to-end training driver example: train an LM for a few hundred steps
on the synthetic pipeline, with checkpointing, then quantize and compare.

    PYTHONPATH=src python examples/train_small_lm.py [--steps 300] [--m100]

Default is a CPU-friendly ~1M-param smoke config; --m100 selects a ~100M
llama-style config (the full end-to-end driver scale from the assignment —
expect hours on CPU, minutes on real accelerators).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.lm_calibrate import calibrate_lm
from repro.core.qmodel import QuantContext, QuantMode
from repro.launch.train import train
from repro.models import model as M
from repro.data import SyntheticLMStream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--m100", action="store_true",
                    help="~100M-param config instead of smoke scale")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_example")
    args = ap.parse_args()

    arch = "llama3_2_1b"
    out = train(arch, args.steps, batch=8, seq=128,
                ckpt_dir=args.ckpt_dir, smoke=not args.m100)
    print(f"\ntrained {args.steps} steps: loss "
          f"{out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")

    # post-training quantization of the trained model (paper pipeline)
    cfg = get_smoke_config(arch)
    if args.m100:
        from repro.configs import get_config
        cfg = get_config(arch)
    params = out["params"]
    stream = SyntheticLMStream(cfg.vocab_size, 128, 8, seed=123)
    batch = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
    ctx_cal, report = calibrate_lm(
        lambda p, b, c: M.forward(p, b, cfg, c), params, batch)
    lf, _ = M.forward(params, batch, cfg, QuantContext(mode=QuantMode.FP))
    li, _ = M.forward(params, batch, cfg,
                      dataclasses.replace(ctx_cal, mode=QuantMode.INT))
    agree = float(np.mean(np.argmax(np.asarray(lf, np.float32), -1) ==
                          np.argmax(np.asarray(li, np.float32), -1)))
    print(f"post-training int8 deploy: prediction agreement {agree:.3f} "
          f"(calibration {report.total_s:.1f}s, no fine-tuning)")


if __name__ == "__main__":
    main()
