"""Continuous-batching serving on the paged int8-KV block pool.

    PYTHONPATH=src python examples/continuous_batching.py [--arch qwen3_1_7b]

Submits a mixed-length Poisson workload to the serving engine
(DESIGN §9): requests are admitted FCFS into a fixed-width slot batch as
others finish, prompts prefill in chunks under a token budget, and every
request's KV lives as int8 blocks (power-of-two scales) that are written
once and never requantized while resident.  The demo also re-runs one
request standalone through the dense-cache path to show the paged engine
is token-exact, and prints the paper-Table-5 requant-energy accounting.

``--shared-prefix N`` (default 48) prepends the same N-token system
prompt to every request: the content-addressed prefix cache (DESIGN §10)
quantizes it once and serves every later request from the SAME physical
blocks — the demo prints the hit rate and the quantization ops that
sharing deleted.  ``--shared-prefix 0`` turns the demo off.

``--spec-k K`` (default 0 = off) turns on speculative decoding
(DESIGN §11): the model-free n-gram self-drafter proposes up to K
continuation tokens per slot, one paged verify step scores them all,
accepted tokens commit to the pool and the rejected tail's blocks are
RETRACTED before they can publish — the demo prints the acceptance
rate, tokens per step, and the quantization ops spent on rejected
drafts (the waste the paper's write-once dataflow makes visible).
Greedy outputs are token-identical with speculation on or off.

By default every step is ONE unified ragged dispatch (DESIGN §12):
prefill chunks, decode rows, and speculative tails ride a single
flattened work-list instead of per-shape phase dispatches.  ``--ragged``
(the default) additionally replays the same workload through the legacy
per-shape engine and prints dispatch counts and padding waste side by
side; ``--no-ragged`` serves with the legacy engine only.
"""
import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--shared-prefix", type=int, default=48,
                    help="N-token system prompt shared by every request "
                         "(0 disables the prefix-cache demo)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft up to K tokens per "
                         "slot and verify them in one paged step "
                         "(0 disables)")
    ap.add_argument("--ragged", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="--ragged (default): unified ragged work-list "
                         "dispatch, with a legacy per-shape replay for "
                         "the A/B numbers; --no-ragged: legacy per-shape "
                         "engine only")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.launch.serve import serve_engine
    from repro.models import model as M

    def run(ragged):
        return serve_engine(args.arch, n_requests=args.requests, rate=50.0,
                            n_slots=4, block_size=16, chunk=16, mode="fp",
                            calibrate=False, temperature=args.temperature,
                            shared_prefix=args.shared_prefix,
                            spec_k=args.spec_k, ragged=ragged)

    out = run(args.ragged)
    rep = out["report"]
    print(f"[{args.arch}] {rep['completed']}/{rep['n_requests']} requests, "
          f"{rep['gen_tokens']} tokens in {rep['wall_s']}s "
          f"({rep['tokens_per_s']} tok/s incl. compile)")
    print(f"pool: {rep['pool']['peak_live_blocks']} peak blocks "
          f"({rep['pool']['peak_utilization']:.0%} of "
          f"{rep['pool']['num_blocks'] - 1}), "
          f"{rep['pool']['evictions']} evictions")
    hw = rep["hwcost"]
    print(f"requant ops: {hw['requant_ops_performed']} performed "
          f"(write-once int8 blocks) vs "
          f"{hw['requant_ops_performed'] + hw['requant_ops_avoided']} for a "
          f"dequantize-per-step cache — "
          f"{hw['energy_uj_bit_shift']:.2f} uJ vs "
          f"{hw['energy_uj_if_requant_per_step']:.2f} uJ bit-shift "
          f"({hw['energy_uj_if_scaling_factor']:.2f} uJ scaling-factor, "
          f"paper Table 5)")
    pc = rep.get("prefix_cache")
    if pc is not None and args.shared_prefix:
        print(f"prefix cache (shared {args.shared_prefix}-token system "
              f"prompt): hit-rate {pc['hit_rate']:.1%}, "
              f"{pc['cached_prefill_tokens']} prefill tokens served from "
              f"cache, {pc['quant_ops_avoided']} quantization ops never "
              f"ran, {pc['cow_copies']} COW copies, "
              f"{pc['resident_cached_blocks']} blocks still resident for "
              f"the next request")
    sp = rep.get("speculative")
    if sp is not None:
        print(f"speculative (K={sp['spec_k']}, {sp['drafter']}): "
              f"acceptance {sp['acceptance_rate']}, "
              f"{sp['tokens_per_step']} tokens/step over "
              f"{sp['verify_steps']} verify steps; "
              f"{sp['retracted_blocks']} rejected-tail blocks retracted, "
              f"{sp['requant_ops_wasted']} quant ops spent on rejected "
              f"drafts (never published)")
    for rid, toks in sorted(out["outputs"].items())[:4]:
        print(f"  req {rid}: {toks[:12].tolist()}")

    if args.ragged:
        # A/B: the SAME workload through the legacy per-shape engine —
        # dispatch counts and padding waste side by side (DESIGN §12)
        leg = run(False)
        lrep = leg["report"]
        r_disp = rep["ragged_steps"]
        l_disp = (lrep["prefill_chunks"] + lrep["decode_steps"]
                  + lrep["spec_steps"])
        print("ragged vs per-shape (same workload):")
        print(f"  dispatches:   {r_disp} unified ragged steps vs "
              f"{l_disp} legacy ({lrep['prefill_chunks']} prefill + "
              f"{lrep['decode_steps']} decode + {lrep['spec_steps']} "
              f"verify)")
        print(f"  padding:      {rep['padded_tokens']}/"
              f"{rep['dispatched_tokens']} tokens padded "
              f"({rep['padding_frac']:.1%}) vs {lrep['padded_tokens']}/"
              f"{lrep['dispatched_tokens']} ({lrep['padding_frac']:.1%}) "
              f"legacy")
        if args.temperature == 0.0:
            same = all(np.array_equal(out["outputs"][r.rid],
                                      leg["outputs"][r.rid])
                       for r in out["requests"])
            print(f"  greedy tokens: "
                  f"{'identical' if same else 'MISMATCH'}")

    if args.temperature == 0.0:
        # token-exactness spot check: replay request 0 through the DENSE
        # cache path (one request, no paging) — greedy tokens must agree
        req = next(r for r in out["requests"] if r.rid == 0)
        cfg = out["engine"].cfg
        ctx = out["engine"].ctx
        params = out["engine"].params
        P = len(req.prompt)
        logits, cache = M.prefill(params, {"tokens": jnp.asarray(
            req.prompt[None])}, cfg, ctx, max_seq=P + req.max_new_tokens)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        oracle = [int(tok[0, 0])]
        for i in range(req.max_new_tokens - 1):
            l, cache = M.decode_step(params, tok, cache,
                                     jnp.asarray(P + i, jnp.int32), cfg, ctx)
            tok = jnp.argmax(l, -1)[:, None].astype(jnp.int32)
            oracle.append(int(tok[0, 0]))
        agree = np.array_equal(out["outputs"][0], np.asarray(oracle))
        print(f"paged engine vs dense-cache oracle (req 0): "
              f"{'exact match' if agree else 'MISMATCH'}")


if __name__ == "__main__":
    main()
