"""Continuous-batching serving on the paged int8-KV block pool.

    PYTHONPATH=src python examples/continuous_batching.py [--arch qwen3_1_7b]

Submits a mixed-length Poisson workload to the serving engine
(DESIGN §9): requests are admitted FCFS into a fixed-width slot batch as
others finish, prompts prefill in chunks under a token budget, and every
request's KV lives as int8 blocks (power-of-two scales) that are written
once and never requantized while resident.  The demo also re-runs one
request standalone through the dense-cache path to show the paged engine
is token-exact, and prints the paper-Table-5 requant-energy accounting.

``--shared-prefix N`` (default 48) prepends the same N-token system
prompt to every request: the content-addressed prefix cache (DESIGN §10)
quantizes it once and serves every later request from the SAME physical
blocks — the demo prints the hit rate and the quantization ops that
sharing deleted.  ``--shared-prefix 0`` turns the demo off.

``--spec-k K`` (default 0 = off) turns on speculative decoding
(DESIGN §11): the model-free n-gram self-drafter proposes up to K
continuation tokens per slot, one paged verify step scores them all,
accepted tokens commit to the pool and the rejected tail's blocks are
RETRACTED before they can publish — the demo prints the acceptance
rate, tokens per step, and the quantization ops spent on rejected
drafts (the waste the paper's write-once dataflow makes visible).
Greedy outputs are token-identical with speculation on or off.

By default every step is ONE unified ragged dispatch (DESIGN §12):
prefill chunks, decode rows, and speculative tails ride a single
flattened work-list instead of per-shape phase dispatches.  ``--ragged``
(the default) additionally replays the same workload through the legacy
per-shape engine and prints dispatch counts and padding waste side by
side; ``--no-ragged`` serves with the legacy engine only.

``--model rwkv6_3b`` / ``--model zamba2_2_7b`` serve a RECURRENT or
HYBRID arch from the fixed-slab substrate instead (DESIGN §16): each
sequence's O(1) state lives in one pool slab requantized once per
engine step (zamba2 runs its attention layers on paged KV blocks AND
its Mamba layers on slabs in the same jitted step).  The demo then
checks EVERY request token-exact against the dense fp32 recurrent
oracle and serves the equal-length workload through the attention
engine too, printing both requant-ops/token — the recurrent number
lands below the attention baseline because slab requantization is
context-free (prefix cache and speculation don't apply: recurrent
state is a running summary, not addressable token history).
"""
import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", "--model", dest="arch", default="qwen3_1_7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--shared-prefix", type=int, default=48,
                    help="N-token system prompt shared by every request "
                         "(0 disables the prefix-cache demo)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft up to K tokens per "
                         "slot and verify them in one paged step "
                         "(0 disables)")
    ap.add_argument("--ragged", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="--ragged (default): unified ragged work-list "
                         "dispatch, with a legacy per-shape replay for "
                         "the A/B numbers; --no-ragged: legacy per-shape "
                         "engine only")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.launch.serve import serve_engine
    from repro.models import model as M
    from repro.serving import substrate_for

    sub = substrate_for(get_smoke_config(args.arch))
    recurrent = sub.fixed_state
    if recurrent and (args.shared_prefix or args.spec_k):
        print(f"note: {args.arch} serves from the {sub.kind} substrate — "
              f"prefix cache and speculation need addressable/rollback-"
              f"able token history, disabling both for this run")
        args.shared_prefix = args.spec_k = 0
    # long contexts are where the §16 context-free slab requant pays:
    # attention's per-token accounting grows with the cached range
    lens = (dict(prompt_lens=(48, 56, 64), gen_lens=(32, 40, 48))
            if recurrent else {})

    def run(ragged, arch=None, **kw):
        if recurrent and arch is None:
            # token-exactness vs the dense fp32 oracle needs fp32 end to
            # end: the fixed-shape recurrent step reorders bf16 sums
            kw.setdefault("cfg_overrides", dict(dtype="float32"))
        return serve_engine(arch or args.arch, n_requests=args.requests,
                            rate=50.0, n_slots=4, block_size=16,
                            chunk=64 if recurrent else 16, mode="fp",
                            calibrate=False, temperature=args.temperature,
                            shared_prefix=args.shared_prefix,
                            spec_k=args.spec_k, ragged=ragged,
                            **lens, **kw)

    out = run(args.ragged)
    rep = out["report"]
    print(f"[{args.arch}] {rep['completed']}/{rep['n_requests']} requests, "
          f"{rep['gen_tokens']} tokens in {rep['wall_s']}s "
          f"({rep['tokens_per_s']} tok/s incl. compile)")
    if rep["pool"] is not None:
        print(f"pool: {rep['pool']['peak_live_blocks']} peak blocks "
              f"({rep['pool']['peak_utilization']:.0%} of "
              f"{rep['pool']['num_blocks'] - 1}), "
              f"{rep['pool']['evictions']} evictions")
    sl = rep.get("state_pool")
    if sl is not None:
        print(f"state slabs ({rep['substrate']}): {sl['peak_live_slabs']} "
              f"peak of {sl['num_slabs'] - 1}, one per sequence; "
              f"{sl['state_quant_ops_per_step']} state elems requantized "
              f"per step per sequence — independent of context length")
    hw = rep["hwcost"]
    print(f"requant ops: {hw['requant_ops_performed']} performed "
          f"(write-once int8 blocks) vs "
          f"{hw['requant_ops_performed'] + hw['requant_ops_avoided']} for a "
          f"dequantize-per-step cache — "
          f"{hw['energy_uj_bit_shift']:.2f} uJ vs "
          f"{hw['energy_uj_if_requant_per_step']:.2f} uJ bit-shift "
          f"({hw['energy_uj_if_scaling_factor']:.2f} uJ scaling-factor, "
          f"paper Table 5)")
    pc = rep.get("prefix_cache")
    if pc is not None and args.shared_prefix:
        print(f"prefix cache (shared {args.shared_prefix}-token system "
              f"prompt): hit-rate {pc['hit_rate']:.1%}, "
              f"{pc['cached_prefill_tokens']} prefill tokens served from "
              f"cache, {pc['quant_ops_avoided']} quantization ops never "
              f"ran, {pc['cow_copies']} COW copies, "
              f"{pc['resident_cached_blocks']} blocks still resident for "
              f"the next request")
    sp = rep.get("speculative")
    if sp is not None:
        print(f"speculative (K={sp['spec_k']}, {sp['drafter']}): "
              f"acceptance {sp['acceptance_rate']}, "
              f"{sp['tokens_per_step']} tokens/step over "
              f"{sp['verify_steps']} verify steps; "
              f"{sp['retracted_blocks']} rejected-tail blocks retracted, "
              f"{sp['requant_ops_wasted']} quant ops spent on rejected "
              f"drafts (never published)")
    for rid, toks in sorted(out["outputs"].items())[:4]:
        print(f"  req {rid}: {toks[:12].tolist()}")

    if args.ragged and not recurrent:
        # A/B: the SAME workload through the legacy per-shape engine —
        # dispatch counts and padding waste side by side (DESIGN §12)
        leg = run(False)
        lrep = leg["report"]
        r_disp = rep["ragged_steps"]
        l_disp = (lrep["prefill_chunks"] + lrep["decode_steps"]
                  + lrep["spec_steps"])
        print("ragged vs per-shape (same workload):")
        print(f"  dispatches:   {r_disp} unified ragged steps vs "
              f"{l_disp} legacy ({lrep['prefill_chunks']} prefill + "
              f"{lrep['decode_steps']} decode + {lrep['spec_steps']} "
              f"verify)")
        print(f"  padding:      {rep['padded_tokens']}/"
              f"{rep['dispatched_tokens']} tokens padded "
              f"({rep['padding_frac']:.1%}) vs {lrep['padded_tokens']}/"
              f"{lrep['dispatched_tokens']} ({lrep['padding_frac']:.1%}) "
              f"legacy")
        if args.temperature == 0.0:
            same = all(np.array_equal(out["outputs"][r.rid],
                                      leg["outputs"][r.rid])
                       for r in out["requests"])
            print(f"  greedy tokens: "
                  f"{'identical' if same else 'MISMATCH'}")

    if args.temperature == 0.0:
        # token-exactness check against the DENSE cache path (one
        # request at a time, no paging) — greedy tokens must agree.
        # Attention: spot-check request 0; recurrent/hybrid: EVERY
        # request (a recycled slab that skipped zero-on-admission only
        # diverges a few decode tokens in, so one request isn't enough)
        cfg = out["engine"].cfg
        ctx = out["engine"].ctx
        params = out["engine"].params
        to_check = (out["requests"] if recurrent
                    else [next(r for r in out["requests"] if r.rid == 0)])
        # one shared cache size + one jitted prefill/decode pair: the
        # eager dense path re-specializes per concrete step index and
        # leaks JIT code mappings across a many-request oracle sweep
        max_seq = max(len(r.prompt) + r.max_new_tokens
                      for r in to_check)
        pf = jax.jit(lambda p, toks: M.prefill(
            p, {"tokens": toks}, cfg, ctx, max_seq=max_seq))
        dstep = jax.jit(lambda p, tok, cache, pos: M.decode_step(
            p, tok, cache, pos, cfg, ctx))
        ok = True
        for req in to_check:
            P = len(req.prompt)
            logits, cache = pf(params, jnp.asarray(req.prompt[None]))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            oracle = [int(tok[0, 0])]
            for i in range(req.max_new_tokens - 1):
                l, cache = dstep(params, tok, cache,
                                 jnp.asarray(P + i, jnp.int32))
                tok = jnp.argmax(l, -1)[:, None].astype(jnp.int32)
                oracle.append(int(tok[0, 0]))
            ok &= np.array_equal(out["outputs"][req.rid],
                                 np.asarray(oracle))
        label = (f"all {len(to_check)} requests" if recurrent
                 else "req 0")
        print(f"paged engine vs dense fp32 oracle ({label}): "
              f"{'exact match' if ok else 'MISMATCH'}")

    if recurrent:
        # equal-length attention baseline: the SAME Poisson workload
        # shape through the transformer engine — the paper's dataflow
        # argument in one line: attention requants scale with the cached
        # context, slab requants don't.  The smoke recurrent configs
        # keep the REAL models' O(1) state-geometry constants, so the
        # baseline uses the serving bench's transformer geometry
        # (4L/d256) instead of the tiny 2L/d64 smoke dims.
        base = run(args.ragged, arch="qwen3_1_7b", cfg_overrides=dict(
            dtype="float32", n_layers=4, d_model=256, n_heads=8,
            n_kv_heads=4, d_ff=1024, head_dim=32, kv_cache_bits=8))
        b = base["report"]["hwcost"]["requant_ops_per_token"]
        total = rep["hwcost"]["requant_ops_per_token"]
        share = rep["state_pool"]["state_ops_per_token"]
        verdict = "BELOW" if share < b else "NOT BELOW"
        print(f"requant ops/token, equal-length workload: attention "
              f"baseline {b}; {args.arch} total {total}, of which the "
              f"recurrent (slab) substrate pays {share} — context-free "
              f"state requant is {verdict} the attention baseline")


if __name__ == "__main__":
    main()
