"""Property tests for Algorithm-1 calibration (Eq. 5/6, DESIGN §3/§13).

Runs under real hypothesis when installed, else the deterministic
sampled-sweep shim in ``tests/_hyp_stub.py`` (the tier-1 container ships
no hypothesis).  Properties:

  * the chosen (N_w, N_b, N_o) always lie inside the Eq.-6 narrowed
    windows ``[N^max - tau, N^max]`` of their tensors;
  * the winning reconstruction error is monotone non-increasing in tau
    (a wider window can only add candidates);
  * threading N_o -> N_x across two chained modules (``chain=``) equals
    calibrating the downstream module on the already-quantized upstream
    output — the paper's sequential joint scheme, stated as an equality;
  * calibration is deterministic for a fixed seed.
"""
import numpy as np
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # tier-1 container
    from tests._hyp_stub import given, settings, st

from repro.core.calibrate import calibrate_linear_module
from repro.core.lm_calibrate import calibrate_lm
from repro.core.qmodel import qlinear
from repro.core.qscheme import fake_quant, search_window


def _mats(seed, with_bias):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, rng.uniform(0.3, 3.0), (32, 16)),
                    jnp.float32)
    w = jnp.asarray(rng.normal(0, rng.uniform(0.01, 0.5), (16, 12)),
                    jnp.float32)
    b = jnp.asarray(rng.normal(0, 0.1, (12,)), jnp.float32) \
        if with_bias else None
    return x, w, b


def _apply(xx, wq, bq):
    y = xx.astype(jnp.float32) @ wq.astype(jnp.float32)
    return y + bq.astype(jnp.float32) if bq is not None else y


def _o_ref(x, w, b):
    y = x.astype(jnp.float32) @ w.astype(jnp.float32)
    return y + b.astype(jnp.float32) if b is not None else y


def _cands(t, tau, bits=8):
    lo, hi = search_window(t, tau)
    return {(bits - 1) - i for i in range(lo, hi + 1)}


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), tau=st.integers(1, 5),
       with_bias=st.booleans())
def test_chosen_bits_inside_eq6_windows(seed, tau, with_bias):
    x, w, b = _mats(seed, with_bias)
    o_ref = _o_ref(x, w, b)
    r = calibrate_linear_module(fake_quant(x, 4), w, b, o_ref, _apply,
                                tau=tau)
    assert r.n_w in _cands(w, tau)
    assert (r.n_b is None) == (b is None)
    if b is not None:
        assert r.n_b in _cands(b, tau)
    assert r.n_o in _cands(o_ref, tau)
    assert np.isfinite(r.error) and r.error >= 0
    assert r.fp_norm > 0


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), tau=st.integers(1, 4),
       with_bias=st.booleans())
def test_error_monotone_non_increasing_in_tau(seed, tau, with_bias):
    """Eq. 6 widens with tau: every tau-window candidate is also a
    (tau+2)-window candidate, so the best error cannot get worse."""
    x, w, b = _mats(seed, with_bias)
    o_ref = _o_ref(x, w, b)
    xq = fake_quant(x, 4)
    r_narrow = calibrate_linear_module(xq, w, b, o_ref, _apply, tau=tau)
    r_wide = calibrate_linear_module(xq, w, b, o_ref, _apply, tau=tau + 2)
    assert r_wide.error <= r_narrow.error + 1e-6


def _two_module_forward(params, batch, ctx):
    h = qlinear(ctx, "m1", batch["x"], params["w1"])
    return qlinear(ctx, "m2", h, params["w2"])


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_threading_equals_calibrating_on_quantized_input(seed):
    """The chain edge m1 -> m2 must make calibrate_lm's m2 result EQUAL
    to hand-calibrating m2 on fake_quant(h, m1.n_o) — the one place the
    sequential joint scheme is more than bookkeeping."""
    rng = np.random.default_rng(seed)
    params = {"w1": jnp.asarray(rng.normal(0, 0.3, (16, 12)), jnp.float32),
              "w2": jnp.asarray(rng.normal(0, 0.3, (12, 8)), jnp.float32)}
    batch = {"x": jnp.asarray(rng.normal(0, 1.0, (32, 16)), jnp.float32)}
    ctx, report = calibrate_lm(_two_module_forward, params, batch,
                               chain={"m2": "m1"})
    m1, m2 = ctx.table["m1"], ctx.table["m2"]
    assert m2.n_x == m1.n_o

    # the upstream float output IS m2's captured input
    h = _o_ref(batch["x"], params["w1"], None)
    manual = calibrate_linear_module(
        fake_quant(h, m1.n_o), params["w2"], None,
        _o_ref(h, params["w2"], None), _apply)
    assert (m2.n_w, m2.n_b, m2.n_o) == (manual.n_w, manual.n_b, manual.n_o)
    assert np.isclose(report.results["m2"].error, manual.error, rtol=1e-5)

    # chain={} must disable threading: m2 goes through the fresh-input
    # N_x search instead of inheriting m1's output grid
    ctx_off, _ = calibrate_lm(_two_module_forward, params, batch, chain={})
    nx_hi = (8 - 1) - search_window(h, 0)[1]
    assert ctx_off.table["m2"].n_x in (nx_hi, nx_hi + 1, nx_hi + 2)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_deterministic_for_fixed_inputs(seed):
    rng = np.random.default_rng(seed)
    params = {"w1": jnp.asarray(rng.normal(0, 0.3, (16, 12)), jnp.float32),
              "w2": jnp.asarray(rng.normal(0, 0.3, (12, 8)), jnp.float32)}
    batch = {"x": jnp.asarray(rng.normal(0, 1.0, (32, 16)), jnp.float32)}
    ctx_a, rep_a = calibrate_lm(_two_module_forward, params, batch)
    ctx_b, rep_b = calibrate_lm(_two_module_forward, params, batch)
    assert dict(ctx_a.table) == dict(ctx_b.table)
    for name in rep_a.results:
        assert rep_a.results[name].error == rep_b.results[name].error
