"""Multi-device parity harness for the shard_map'd flash kernels (DESIGN §8).

Runs on a forced 4-device CPU backend (``conftest.py`` sets
``--xla_force_host_platform_device_count=4``; the CI ``multidevice`` job
exports it explicitly).  Every combination of

    {prefill, decode} x GQA {1, 4} x KV {int8, bf16} x mesh {1x1, 2x2,
    4x1, 1x4}   ((data, model) shapes)

is compared against the SINGLE-DEVICE pure-JAX ``chunked_attention``
oracle evaluated in fp32 — the sharded fused path must agree to fp32
tolerances, and it must NOT demote to the chunked path on multi-device
meshes (the pre-PR-2 behavior this harness exists to prevent).

Dims are chosen so the Pallas kernel genuinely launches on EVERY shard of
every mesh (per-shard sq >= 16, skv >= 128, dk = dv = 128, cache length
with an MXU tile divisor); smaller dims would silently compare the
fallback against itself.  kvh = 4 divides every model-axis size used, so
whole GQA groups land on each shard.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qscheme import dequant, quant
from repro.kernels import ops
from repro.models.attention import _repeat_kv, chunked_attention

NKV = 4                # Eq.-1 fractional bits for the int8 KV grid
B, SQ, SMAX = 4, 256, 256
KVH, DK, DV = 4, 128, 128

MESHES = {"1x1": (1, 1), "2x2": (2, 2), "4x1": (4, 1), "1x4": (1, 4)}


def _mesh(name):
    d, m = MESHES[name]
    if jax.device_count() < d * m:
        pytest.skip(f"needs {d * m} devices, have {jax.device_count()}")
    return jax.make_mesh((d, m), ("data", "model"))


def _make_qkv(seed, groups, kv):
    """Returns (q, k, v) as the kernel sees them and (qf, kf, vf) as the
    fp32 oracle sees them (dequantized codes / upcast bf16)."""
    h = KVH * groups
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, SQ, h, DK)), jnp.float32)
    kf = jnp.asarray(rng.normal(size=(B, SMAX, KVH, DK)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(B, SMAX, KVH, DV)), jnp.float32)
    if kv == "int8":
        k, v = quant(kf, NKV, 8), quant(vf, NKV, 8)
        return q, k, v, q, dequant(k, NKV), dequant(v, NKV)
    q16 = q.astype(jnp.bfloat16)
    k16, v16 = kf.astype(jnp.bfloat16), vf.astype(jnp.bfloat16)
    return (q16, k16, v16, q16.astype(jnp.float32),
            k16.astype(jnp.float32), v16.astype(jnp.float32))


def _tol(kv):
    # acceptance: atol <= 2e-2 vs the fp32 chunked reference.  fp32/int8
    # differs only by reassociation; bf16 carries the cast error.
    return dict(atol=2e-2, rtol=2e-2) if kv == "bf16" else \
        dict(atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("mesh_name", sorted(MESHES))
@pytest.mark.parametrize("kv", ["int8", "bf16"])
@pytest.mark.parametrize("groups", [1, 4])
def test_prefill_parity(groups, kv, mesh_name):
    mesh = _mesh(mesh_name)
    q, k, v, qf, kf, vf = _make_qkv(3, groups, kv)
    nkv = NKV if kv == "int8" else None
    out = ops.flash_attention(q, k, v, causal=True, kv_frac_bits=nkv,
                              mesh=mesh)
    ref = chunked_attention(qf, _repeat_kv(kf, groups),
                            _repeat_kv(vf, groups), causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(kv))


@pytest.mark.parametrize("mesh_name", sorted(MESHES))
@pytest.mark.parametrize("kv", ["int8", "bf16"])
@pytest.mark.parametrize("groups", [1, 4])
def test_decode_parity(groups, kv, mesh_name):
    mesh = _mesh(mesh_name)
    q, k, v, qf, kf, vf = _make_qkv(5, groups, kv)
    q, qf = q[:, :1], qf[:, :1]
    nkv = NKV if kv == "int8" else None
    for pos in (0, 131, SMAX - 1):
        pos_t = jnp.asarray(pos, jnp.int32)       # traced, like a real step
        out = ops.flash_decode(q, k, v, pos=pos_t, kv_frac_bits=nkv,
                               mesh=mesh)
        ref = chunked_attention(qf, _repeat_kv(kf, groups),
                                _repeat_kv(vf, groups), causal=True,
                                q_offset=pos_t)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   err_msg=f"pos={pos}", **_tol(kv))


def test_sharded_grad_parity():
    """Float-KV training path: the custom VJP (fused forward, chunked-
    recompute backward) must differentiate correctly THROUGH the shard_map
    boundary — gradients match differentiating the oracle directly."""
    mesh = _mesh("2x2")
    q, k, v, qf, kf, vf = _make_qkv(7, 4, "int8")  # fp32 q; use float KV
    k, v = kf, vf

    def loss_flash(q_, k_, v_):
        out = ops.flash_attention(q_, k_, v_, causal=True, mesh=mesh)
        return jnp.sum(out ** 2)

    def loss_ref(q_, k_, v_):
        out = chunked_attention(q_, _repeat_kv(k_, 4), _repeat_kv(v_, 4),
                                causal=True)
        return jnp.sum(out ** 2)

    g_fl = jax.jit(jax.grad(loss_flash, argnums=(0, 1, 2)))(q, k, v)
    g_ref = jax.jit(jax.grad(loss_ref, argnums=(0, 1, 2)))(q, k, v)
    for a, b in zip(g_fl, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=5e-3)


# ---------------------------------------------------------------------------
# no silent fallback / explicit errors (launch/steps resolver)
# ---------------------------------------------------------------------------

def test_no_demotion_on_multi_device_mesh():
    """_resolve_attn_kernel must KEEP flash on a multi-device mesh whose
    tensor axis divides the KV heads (pre-PR-2 it silently demoted to
    chunked — the hottest serving path ran unfused)."""
    from repro.configs import get_smoke_config
    from repro.launch.steps import _resolve_attn_kernel
    cfg = get_smoke_config("qwen3_1_7b")          # n_kv_heads = 2
    mesh = _mesh("2x2")                           # model axis = 2, divides
    out = _resolve_attn_kernel(cfg, "flash", mesh)
    assert out.attn_kernel == "flash"


def test_non_dividing_mesh_raises():
    """Mesh shapes that would split a GQA group get an explicit error at
    build time, never a silent fallback."""
    from repro.configs import get_smoke_config
    from repro.launch.steps import _resolve_attn_kernel, build_serve_step
    from repro.core.qmodel import QuantContext, QuantMode
    cfg = get_smoke_config("qwen3_1_7b")          # n_kv_heads = 2
    mesh = _mesh("1x4")                           # model axis = 4: 2 % 4 != 0
    with pytest.raises(NotImplementedError,
                       match=r"must divide the KV head count \(2"):
        _resolve_attn_kernel(cfg, "flash", mesh)
    # the step builders surface the same error
    with pytest.raises(NotImplementedError, match="KV head count"):
        build_serve_step(cfg, QuantContext(mode=QuantMode.FP),
                         attn_kernel="flash", mesh=mesh)


def test_mla_resolver_checks_full_head_count():
    """MLA's flash prefill shards kvh == n_heads (n_kv_heads is nominal
    there): the build-time check must validate the head count the kernel
    actually partitions."""
    from repro.configs import get_smoke_config
    from repro.launch.steps import _resolve_attn_kernel
    mesh = _mesh("1x4")
    cfg = get_smoke_config("deepseek_v3_671b")    # MLA, n_heads = 4
    # nominal n_kv_heads would NOT divide, but n_heads does -> accepted
    cfg = dataclasses.replace(cfg, n_kv_heads=2)
    assert _resolve_attn_kernel(cfg, "flash", mesh).attn_kernel == "flash"
    # and an MLA head count that doesn't divide is refused with the
    # MLA-labeled message
    bad = dataclasses.replace(cfg, n_heads=6)
    with pytest.raises(NotImplementedError, match="n_heads for MLA"):
        _resolve_attn_kernel(bad, "flash", mesh)


def test_non_model_shard_axis_raises():
    """Only 'model' is threaded through the cache/activation sharding
    rules; other axes must be refused, not silently reshard the cache."""
    from repro.configs import get_smoke_config
    from repro.launch.steps import _resolve_attn_kernel
    cfg = dataclasses.replace(get_smoke_config("qwen3_1_7b"),
                              attn_shard_axis="data")
    with pytest.raises(NotImplementedError, match="attn_shard_axis"):
        _resolve_attn_kernel(cfg, "flash", _mesh("2x2"))


def test_ops_level_divisibility_backstop():
    """Direct ops calls (no cfg) hit the same check inside the wrapper."""
    mesh = _mesh("1x4")
    q, k, v, *_ = _make_qkv(9, 1, "int8")
    with pytest.raises(NotImplementedError, match=r"KV head count \(3\)"):
        ops.flash_attention(q[:, :, :3], k[:, :, :3], v[:, :, :3],
                            causal=True, kv_frac_bits=NKV, mesh=mesh)


# ---------------------------------------------------------------------------
# model-level: sharded flash serve step vs single-device chunked
# ---------------------------------------------------------------------------

def test_end_to_end_sharded_flash_decode():
    """jit'd serve step on a (1, 2) mesh with attn_kernel='flash' + int8 KV
    cache matches the single-device chunked dequantize-then-attend path:
    the full steps -> model -> shard_map'd kernel wiring, including the
    head-sharded cache constraint."""
    from repro.configs import get_smoke_config
    from repro.core.qmodel import QuantContext, QuantMode
    from repro.launch import steps as S
    from repro.models import model as M
    if jax.device_count() < 2:
        pytest.skip("needs 2 devices")
    ctx = QuantContext(mode=QuantMode.FP)
    cfg8 = dataclasses.replace(
        get_smoke_config("qwen3_1_7b").scaled(dtype="float32",
                                              head_dim=128),
        kv_cache_bits=8)                          # n_heads=4, n_kv_heads=2
    cfg8f = dataclasses.replace(cfg8, attn_kernel="flash")
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    params = M.init_params(cfg8, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 121), 0,
                              cfg8.vocab_size)
    pre = {"tokens": toks[:, :120]}

    # reference: single-device chunked
    _, cache = M.prefill(params, pre, cfg8, ctx, max_seq=128)
    l_ref, _ = M.decode_step(params, toks[:, 120:], cache,
                             jnp.asarray(120), cfg8, ctx)

    # sharded flash: builders thread the mesh; prefill writes int8 codes
    prefill_fn = jax.jit(S.build_prefill_step(cfg8f, ctx, mesh=mesh,
                                              max_seq=128))
    serve_fn = jax.jit(S.build_serve_step(cfg8f, ctx, mesh=mesh))
    _, cache_f = prefill_fn(params, pre)
    assert cache_f["kv"].k.dtype == jnp.int8
    tok_f, _ = serve_fn(params, toks[:, 120:], cache_f, jnp.asarray(120))

    tok_ref = jnp.argmax(l_ref, axis=-1).astype(jnp.int32)[:, None]
    np.testing.assert_array_equal(np.asarray(tok_f), np.asarray(tok_ref))


def test_flash_cache_rules_head_sharded():
    """cache_sharding_rules(attn_kernel='flash') keeps the KV cache
    partitioned on heads (shard residency) instead of sequence."""
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.distributed import sharding as shd
    from repro.launch import steps as S
    mesh = _mesh("2x2")
    cfg = get_smoke_config("qwen3_1_7b")          # n_kv_heads = 2
    cache_abs = S.abstract_cache(cfg, batch=4, max_seq=128)
    flash = shd.cache_sharding_rules(cache_abs, mesh, attn_kernel="flash")
    chunked = shd.cache_sharding_rules(cache_abs, mesh)
    assert flash["kv"].k[3] == "model" and flash["kv"].k[2] is None
    assert chunked["kv"].k[2] == "model" and chunked["kv"].k[3] is None
