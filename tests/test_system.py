"""End-to-end system tests: train -> calibrate (Algorithm 1, no fine-tune)
-> integer serve; plus train-loop determinism across checkpoint restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_smoke_config
from repro.core.qmodel import ModuleBits, QuantContext, QuantMode
from repro.data import SyntheticLMStream
from repro.models import model as M
from repro.optim import adamw, warmup_cosine


@pytest.fixture(scope="module")
def trained():
    """Train a tiny LM a few hundred steps on the synthetic stream."""
    cfg = get_smoke_config("llama3_2_1b").scaled(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(weight_decay=0.0)
    state = opt.init(params)
    stream = SyntheticLMStream(cfg.vocab_size, 32, 8, seed=0)
    lr = warmup_cosine(3e-3, 20, 200)
    ctx = QuantContext(mode=QuantMode.FP)

    @jax.jit
    def step(p, s, batch):
        (loss, _), g = jax.value_and_grad(
            lambda pp: M.loss_fn(pp, batch, cfg, ctx, remat=False),
            has_aux=True)(p)
        p2, s2 = opt.update(g, s, p, lr(s.step))
        return p2, s2, loss

    losses = []
    for i in range(200):
        b = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
        params, state, loss = step(params, state, b)
        losses.append(float(loss))
    return cfg, params, stream, losses


def test_training_reduces_loss(trained):
    cfg, params, stream, losses = trained
    assert np.mean(losses[-20:]) < 0.8 * np.mean(losses[:20])


def test_fake_quant_model_tracks_fp(trained):
    """Paper Table 1 analogue: 8-bit fake-quant model's predictions agree
    with the FP model (no fine-tuning)."""
    cfg, params, stream, _ = trained
    batch = {k: jnp.asarray(v) for k, v in stream.batch(999).items()}
    fp_ctx = QuantContext(mode=QuantMode.FP)
    q_ctx = QuantContext(mode=QuantMode.FAKE)
    lf, _ = M.forward(params, batch, cfg, fp_ctx)
    lq, _ = M.forward(params, batch, cfg, q_ctx)
    agree = float(jnp.mean((jnp.argmax(lf, -1) == jnp.argmax(lq, -1))
                           .astype(jnp.float32)))
    assert agree > 0.9, f"prediction agreement {agree}"


def test_int_serve_matches_fake(trained):
    """Integer decode path is consistent with the fake-quant arithmetic."""
    cfg, params, stream, _ = trained
    batch = {"tokens": jnp.asarray(stream.batch(998)["tokens"][:, :31])}
    for mode in (QuantMode.FAKE, QuantMode.INT):
        ctx = QuantContext(mode=mode)
        logits, cache = M.prefill(params, batch, cfg, ctx, max_seq=32)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits2, _ = M.decode_step(params, tok, cache, jnp.asarray(31),
                                   cfg, ctx)
        assert bool(jnp.all(jnp.isfinite(logits2)))


def test_train_restore_determinism(tmp_path):
    """Checkpoint at step k, restart, reach the same loss at step k+n —
    the fault-tolerance correctness contract."""
    cfg = get_smoke_config("qwen3_1_7b").scaled(dtype="float32")
    opt = adamw(weight_decay=0.0)
    ctx = QuantContext(mode=QuantMode.FP)
    stream = SyntheticLMStream(cfg.vocab_size, 16, 4, seed=5)

    @jax.jit
    def step(p, s, batch):
        (loss, _), g = jax.value_and_grad(
            lambda pp: M.loss_fn(pp, batch, cfg, ctx, remat=False),
            has_aux=True)(p)
        p2, s2 = opt.update(g, s, p, 1e-3)
        return p2, s2, loss

    def run(p, s, lo, hi):
        loss = None
        for i in range(lo, hi):
            b = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
            p, s, loss = step(p, s, b)
        return p, s, float(loss)

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    params, state, _ = run(params, state, 0, 5)
    ck = Checkpointer(str(tmp_path))
    ck.save(5, {"params": params, "opt": state}, blocking=True)
    _, _, loss_direct = run(params, state, 5, 10)

    restored, _ = ck.restore(jax.eval_shape(
        lambda: {"params": params, "opt": state}))
    _, _, loss_resumed = run(restored["params"], restored["opt"], 5, 10)
    assert abs(loss_direct - loss_resumed) < 1e-5
