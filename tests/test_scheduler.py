"""Scheduler property tests: fairness and no-starvation (DESIGN §9).

The scheduler is pure host bookkeeping, so these tests drive the full
WAITING→PREFILL→DECODE→DONE lifecycle with a FAKE model (every "decode"
emits token 1) under random arrival traces and verify: every request
completes in bounded steps (no starvation), admission is strictly FCFS in
arrival order (head-of-line blocking — a late small request never
overtakes an early large one), preempted requests resume and still emit
exactly ``max_new_tokens``, and the pool ends empty with invariants held
throughout.
"""
import numpy as np
import pytest

from repro.serving.kv_pool import BlockPool
from repro.serving.scheduler import (Request, RequestState, Scheduler,
                                     chunk_bucket)
from tests._hyp_stub import given, settings, st

MAX_LEN = 32


def _mk_requests(rng, n, max_len=MAX_LEN):
    reqs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(0.1))
        p = int(rng.integers(1, max_len - 1))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, 100, size=p).astype(np.int32),
            max_new_tokens=int(rng.integers(1, max_len - p + 1)),
            arrival=t))
    return reqs


def _drive(sched: Scheduler, requests, max_iters=10_000):
    """Fake-model engine loop mirroring ServingEngine.step's structure
    (including the §10 prefix-cache paths: COW before a chunk writes into
    a shared block, commits that publish completed blocks)."""
    pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
    pool = sched.pool
    clock = 0.0
    iters = 0
    while pending or not sched.idle:
        iters += 1
        assert iters < max_iters, "scheduler made no progress (starvation)"
        clock += 0.01
        if sched.idle and pending and pending[0].arrival > clock:
            clock = pending[0].arrival
        while pending and pending[0].arrival <= clock:
            sched.submit(pending.pop(0))
        sched.admit(clock)
        # chunked prefill under the budget
        budget = sched.prefill_token_budget
        for req in sched.prefill_jobs():
            while budget > 0 and req.state is RequestState.PREFILL:
                start = req.n_prefilled
                c = min(sched.chunk, len(req.feed) - start, budget)
                bs = pool.block_size
                preempted = False
                for idx in range(start // bs, -(-(start + c) // bs)):
                    if idx >= pool.n_blocks_of(req.rid):
                        break
                    if not pool.block_writable(req.rid, idx):
                        if sched.cow_for_prefill(req, idx, clock) is None:
                            preempted = True     # req itself evicted
                            break
                if preempted:
                    break
                req.n_prefilled += c
                req.n_ctx = req.n_prefilled
                pool.commit(req.rid, start, req.feed[start:start + c])
                budget -= c
                if req.n_prefilled == len(req.feed):
                    tok = 1                      # fake first sampled token
                    if req.t_first is None:
                        req.t_first = clock
                    done = req.finished_by(tok, sched.max_model_len)
                    req.generated.append(tok)
                    if done:
                        sched.finish(req, clock)
                    else:
                        req.state = RequestState.DECODE
        # one decode step over all live slots
        for req in list(sched.decode_reqs()):
            if req.slot is None or req.state is not RequestState.DECODE:
                continue                         # preempted this iteration
            if not sched.grow_for_decode(req, clock):
                continue
            pool.commit(req.rid, req.n_ctx, [req.generated[-1]])
            req.n_ctx += 1
            tok = 1
            done = req.finished_by(tok, sched.max_model_len)
            req.generated.append(tok)
            if done:
                sched.finish(req, clock)
        sched.pool.check_invariants()
    return iters


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100_000), slots=st.integers(1, 4),
       blocks=st.integers(9, 24))
def test_random_traces_complete_fcfs(seed, slots, blocks):
    rng = np.random.default_rng(seed)
    pool = BlockPool(num_blocks=blocks, block_size=4)
    sched = Scheduler(pool, n_slots=slots, chunk=8, max_model_len=MAX_LEN)
    reqs = _mk_requests(rng, int(rng.integers(3, 12)))
    _drive(sched, reqs)
    # every request completed with exactly its token budget — preemption
    # (if any) resumed without dropping or duplicating generated tokens
    assert len(sched.done) == len(reqs)
    for r in reqs:
        assert r.state is RequestState.DONE
        assert len(r.generated) == r.max_new_tokens
        assert r.t_first is not None and r.t_done is not None
    # FIRST admissions are strictly FCFS in (arrival, rid) order: a later
    # request never overtakes an earlier one into the batch
    first_admission = []
    for rid in sched.admission_log:
        if rid not in first_admission:
            first_admission.append(rid)
    by_arrival = [r.rid for r in sorted(reqs,
                                        key=lambda r: (r.arrival, r.rid))]
    assert first_admission == by_arrival
    # pool fully drained
    assert pool.n_live == 0
    pool.check_invariants()


def test_tight_pool_preempts_youngest_and_completes():
    """Pool sized so concurrent decodes MUST collide: the youngest-admitted
    request is evicted (oldest always progresses), resumes, and still
    produces its full token count."""
    pool = BlockPool(num_blocks=6, block_size=4)   # 5 usable = 20 rows
    sched = Scheduler(pool, n_slots=2, chunk=8, max_model_len=20)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 100, size=7).astype(
        np.int32), max_new_tokens=12, arrival=0.0) for i in range(3)]
    _drive(sched, reqs)
    assert len(sched.done) == 3
    assert pool.stats.evictions > 0
    assert all(len(r.generated) == 12 for r in reqs)
    # the earliest-admitted request is never the chosen victim while a
    # younger runner exists
    oldest = min(reqs, key=lambda r: (r.t_admit, r.rid))
    youngest_preempted = max(r.preemptions for r in reqs)
    assert youngest_preempted > 0 and oldest.preemptions == 0
    assert pool.n_live == 0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100_000), slots=st.integers(1, 4),
       blocks=st.integers(10, 24))
def test_shared_prefix_traces_complete_with_cache(seed, slots, blocks):
    """Same completeness/FCFS/invariant guarantees with the prefix cache
    ON and a workload dominated by a shared system prompt: requests
    re-attach each other's published blocks (cached_tokens > 0 once the
    prefix is published), duplicates exercise the full-feed COW path, and
    preemption/resume still yields exactly max_new_tokens per request."""
    rng = np.random.default_rng(seed)
    pool = BlockPool(num_blocks=blocks, block_size=4, prefix_cache=True)
    sched = Scheduler(pool, n_slots=slots, chunk=8, max_model_len=MAX_LEN)
    shared = rng.integers(0, 100, size=12).astype(np.int32)
    reqs, t = [], 0.0
    for i in range(int(rng.integers(4, 10))):
        t += float(rng.exponential(0.1))
        if rng.random() < 0.3:
            prompt = shared.copy()               # exact repeat: COW path
        else:
            tail = rng.integers(0, 100, size=int(rng.integers(1, 6)))
            prompt = np.concatenate([shared, tail.astype(np.int32)])
        reqs.append(Request(
            rid=i, prompt=prompt,
            max_new_tokens=int(rng.integers(1, MAX_LEN - len(prompt) + 1)),
            arrival=t))
    _drive(sched, reqs)
    assert len(sched.done) == len(reqs)
    for r in reqs:
        assert len(r.generated) == r.max_new_tokens
    assert pool.n_live == 0
    pool.check_invariants()


def test_cached_admission_skips_prefill_and_cows_full_hits():
    """Deterministic cache behavior on a single slot (strictly sequential
    service): the second request attaches the published 3-block prefix
    (cached_tokens == 12), and an exact repeat of the prompt is a
    FULL-feed hit — prefill shrinks to the one re-fed token (cached 11)
    whose write copy-on-writes the last shared block."""
    pool = BlockPool(num_blocks=24, block_size=4, prefix_cache=True)
    sched = Scheduler(pool, n_slots=1, chunk=8, max_model_len=32)
    shared = np.arange(12, dtype=np.int32)
    reqs = [
        Request(rid=0, prompt=shared.copy(), max_new_tokens=2, arrival=0.0),
        Request(rid=1, prompt=np.concatenate(
            [shared, np.asarray([77, 78], np.int32)]),
            max_new_tokens=2, arrival=0.1),
        Request(rid=2, prompt=shared.copy(), max_new_tokens=2, arrival=0.2),
    ]
    _drive(sched, reqs)
    assert len(sched.done) == 3
    assert reqs[0].cached_tokens == 0              # cold
    assert reqs[1].cached_tokens == 12             # 3 full blocks attached
    assert reqs[2].cached_tokens == 11             # full hit, last token re-fed
    assert pool.cache.stats.cow_copies == 1
    assert pool.cache.stats.hits == 6
    pool.check_invariants()


def test_big_early_request_not_starved_by_small_late_ones():
    """Head-of-line blocking: while the big request 0 waits for blocks,
    later small requests must NOT be admitted around it."""
    pool = BlockPool(num_blocks=8, block_size=4)   # 28 rows
    sched = Scheduler(pool, n_slots=2, chunk=8, max_model_len=28)
    rng = np.random.default_rng(1)
    big = Request(rid=0, prompt=rng.integers(0, 100, size=20).astype(
        np.int32), max_new_tokens=8, arrival=0.0)
    small = [Request(rid=i, prompt=rng.integers(0, 100, size=2).astype(
        np.int32), max_new_tokens=2, arrival=0.001 * i)
        for i in range(1, 6)]
    _drive(sched, [big] + small)
    assert len(sched.done) == 6
    assert sched.admission_log[0] == 0             # big admitted first
    assert big.t_done is not None


def test_submit_validation():
    pool = BlockPool(num_blocks=8, block_size=4)
    sched = Scheduler(pool, n_slots=1, chunk=8, max_model_len=16)
    with pytest.raises(ValueError, match="max_model_len"):
        sched.submit(Request(rid=0, prompt=np.arange(10, dtype=np.int32),
                             max_new_tokens=10))
    with pytest.raises(ValueError, match="empty prompt"):
        sched.submit(Request(rid=1, prompt=np.zeros((0,), np.int32),
                             max_new_tokens=2))
    # a scheduler whose max_model_len exceeds pool capacity could deadlock
    with pytest.raises(ValueError, match="pool capacity"):
        Scheduler(BlockPool(num_blocks=3, block_size=4), n_slots=1,
                  chunk=8, max_model_len=16)


def test_chunk_bucket_bounded_pow2():
    for chunk in (8, 16, 64):
        seen = set()
        for n in range(1, chunk + 1):
            b = chunk_bucket(n, chunk)
            assert b >= n and b <= chunk
            assert b & (b - 1) == 0                # power of two
            seen.add(b)
        assert len(seen) <= chunk.bit_length()     # bounded compile set
        assert chunk_bucket(5 * chunk, chunk) == chunk


def test_grow_for_spec_degrades_before_preempting():
    """DESIGN §11 variable growth: the speculative tail is optional — a
    draft count the pool cannot hold shrinks to what fits, WITHOUT
    preempting a peer; only the mandatory single-token growth may."""
    pool = BlockPool(num_blocks=9, block_size=4)   # 8 usable = 32 rows
    sched = Scheduler(pool, n_slots=2, chunk=16, max_model_len=32)
    pool.alloc_seq(0, 13)     # 4 blocks, 3 spare rows in the last
    pool.alloc_seq(1, 13)     # 4 blocks -> 8 live, 0 free
    ra = Request(rid=0, prompt=np.arange(12, dtype=np.int32),
                 max_new_tokens=8)
    rb = Request(rid=1, prompt=np.arange(12, dtype=np.int32),
                 max_new_tokens=8)
    for req, slot, t in ((ra, 0, 0.0), (rb, 1, 0.1)):
        req.state = RequestState.DECODE
        req.slot = slot
        req.n_ctx = 13
        req.t_admit = t
        sched.slots[slot] = req
    # seq 0 wants 6 drafts; its own last block has 3 spare rows (one of
    # which the mandatory fed token takes) and the pool has no free
    # blocks -> degrade to 2 drafts, NO eviction
    granted = sched.grow_for_spec(ra, 1.0, 6)
    assert granted == 2
    assert pool.stats.seq_evictions == 0
    assert sched.slots[1] is rb                    # peer untouched
    assert pool.n_blocks_of(0) == 4                # no new block needed
    pool.check_invariants()


def test_grow_for_spec_mandatory_row_preempts_youngest():
    """When even the non-speculative +1 row needs a block, grow_for_spec
    falls back to the §9 youngest-first preemption retry."""
    pool = BlockPool(num_blocks=5, block_size=4)   # 4 usable = 16 rows
    sched = Scheduler(pool, n_slots=2, chunk=16, max_model_len=16)
    pool.alloc_seq(0, 8)      # 2 blocks
    pool.alloc_seq(1, 8)      # 2 blocks -> pool exhausted
    old = Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                  max_new_tokens=8)
    young = Request(rid=1, prompt=np.arange(8, dtype=np.int32),
                    max_new_tokens=8)
    for req, slot, t in ((old, 0, 0.0), (young, 1, 0.5)):
        req.state = RequestState.DECODE
        req.slot = slot
        req.n_ctx = 8
        req.t_admit = t
        sched.slots[slot] = req
    granted = sched.grow_for_spec(old, 1.0, 3)
    # the youngest was evicted to make room for the OLD request's row;
    # the draft count was computed under pressure (0 spare pre-eviction)
    assert granted == 0
    assert young.state is RequestState.WAITING
    assert young.preemptions == 1
    assert pool.n_blocks_of(0) == 3
    pool.check_invariants()


def test_cow_failure_retry_under_pool_pressure():
    """ISSUE 5 satellite: the CoW-failure retry path.  A COW that cannot
    get a destination block under pool pressure preempts the youngest
    runner and retries; when the writer itself is youngest, it returns
    None (the engine's zero-progress contract) and its state flips —
    which is exactly what the engine's prefill progress guard relies on."""
    # 6 usable blocks, BS=4.  Seq 0 publishes a 3-block prefix; seq 1
    # attaches all 3 shared blocks (fully-cached feed) and must COW the
    # last one to re-feed — after fillers exhaust the free list.
    pool = BlockPool(num_blocks=7, block_size=4, prefix_cache=True)
    sched = Scheduler(pool, n_slots=2, chunk=16, max_model_len=24)
    feed = np.arange(12, dtype=np.int32)
    pool.alloc_seq(0, 12)
    pool.commit(0, 0, feed)                        # 3 published blocks
    pool.alloc_seq(99, 8)                          # filler: 2 blocks
    plan = pool.plan_seq(len(feed), token_ids=feed)
    assert plan.feasible and len(plan.hit_blocks) == 3
    pool.alloc_seq(1, 12, plan=plan)               # pure attach: no alloc
    pool.alloc_seq(98, 4)                          # filler: last free block
    assert pool.n_free == 0
    owner = Request(rid=0, prompt=feed.copy(), max_new_tokens=4)
    writer = Request(rid=1, prompt=feed.copy(), max_new_tokens=4)
    for req, slot, t, state in ((owner, 0, 0.0, RequestState.DECODE),
                                (writer, 1, 0.2, RequestState.PREFILL)):
        req.state = state
        req.slot = slot
        req.n_ctx = 12 if req is owner else 11
        req.t_admit = t
        sched.slots[slot] = req
    # the writer is the YOUNGEST active: the CoW retry must preempt the
    # writer ITSELF and report None — never loop forever
    assert not pool.block_writable(1, 2)
    out = sched.cow_for_prefill(writer, 2, 1.0)
    assert out is None
    assert writer.state is RequestState.WAITING
    assert writer.preemptions == 1
    assert owner.slot == 0                         # older peer survived
    pool.check_invariants()
    # with pressure relieved, the SAME shared-attach + COW succeeds and
    # yields a fresh private destination (the source keeps its key)
    pool.free_seq(99)                              # 2 blocks back
    plan = pool.plan_seq(len(feed), token_ids=feed)
    pool.alloc_seq(2, 12, plan=plan)
    re_writer = Request(rid=2, prompt=feed.copy(), max_new_tokens=4)
    re_writer.state = RequestState.PREFILL
    re_writer.slot = 1
    re_writer.n_ctx = 11
    re_writer.t_admit = 2.0
    sched.slots[1] = re_writer
    pair = sched.cow_for_prefill(re_writer, 2, 2.0)
    assert pair is not None
    src_blk, dst_blk = pair
    assert src_blk != dst_blk and pool.block_writable(2, 2)
    assert pool.cache.is_published(src_blk)
    pool.check_invariants()
