"""Roofline analysis plumbing: collective parser + cost-sample algebra."""
import jax.numpy as jnp

from repro.launch.analysis import (CostSample, collective_traffic,
                                   roofline_terms)


HLO = """
HloModule test
ENTRY main {
  %p = f32[128,512]{1,0} parameter(0)
  %ar = f32[128,512]{1,0} all-reduce(%p), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag = bf16[64,1024]{1,0} all-gather(%x), replica_groups=[2,8]<=[16], dimensions={0}
  %rs = f32[16,256]{1,0} reduce-scatter(%y), replica_groups=[4,4]<=[16], dimensions={0}
  %cp = s8[1024]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %done = f32[8] all-reduce-done(%start)
  %gte = f32[4] get-tuple-element(%all-reduce.5), index=0
}
"""


def test_parser_kinds_and_ring_model():
    t = collective_traffic(HLO)
    assert t["all-reduce"] == 2 * 128 * 512 * 4 * 3 / 4
    assert t["all-gather"] == 64 * 1024 * 2 * 7 / 8
    assert t["reduce-scatter"] == 16 * 256 * 4 * 3
    assert t["collective-permute"] == 1024
    assert t["total"] == sum(v for k, v in t.items() if k != "total")


def test_parser_ignores_done_and_gte_lines():
    t = collective_traffic(HLO)
    # only ONE all-reduce counted (the -done and gte lines don't match)
    assert t["all-reduce"] == 2 * 128 * 512 * 4 * 3 / 4


def test_cost_sample_algebra():
    a = CostSample(10.0, 100.0, {"all-reduce": 5.0, "total": 5.0})
    b = CostSample(1.0, 10.0, {"all-gather": 2.0, "total": 2.0})
    c = a.scaled(2.0) + b
    assert c.flops == 21.0 and c.bytes_hbm == 210.0
    assert c.collectives["total"] == 12.0


def test_roofline_terms_dominance():
    t = roofline_terms(CostSample(197e12, 0.0, {"total": 0.0}))
    assert t["dominant"] == "compute" and abs(t["t_compute_s"] - 1.0) < 1e-9
    t = roofline_terms(CostSample(0.0, 819e9, {"total": 0.0}))
    assert t["dominant"] == "memory"
