"""Property tests for the content-addressed prefix cache (DESIGN §10).

The refcounted pool + cache must keep, under arbitrary interleavings of
alloc/share/divert(COW)/extend/free/evict:

* no orphans, no double ownership drift: every non-trash block is exactly
  one of free / idle-cached / live, and ``refcount == number of owning
  sequences`` (``BlockPool.check_invariants``);
* double frees raise, never corrupt;
* COW never mutates a shared block — the writer gets a FRESH private
  block, the source keeps its key and its other readers;
* eviction (LRU reclaim) only ever touches refcount-0 idle blocks;
* identical prefixes resolve to the SAME physical blocks (that is the
  whole point), different scale exponents or different histories never do.
"""
import numpy as np
import pytest

from repro.serving.kv_pool import TRASH_BLOCK, BlockPool, BlockPoolError
from repro.serving.prefix_cache import ROOT_KEY, block_key
from tests._hyp_stub import given, settings, st

BS = 4


def _pool(num_blocks=24, **kw):
    kw.setdefault("scale_exp", 4)
    return BlockPool(num_blocks, BS, prefix_cache=True, **kw)


def _prefill(pool, sid, feed, start, c):
    """Engine-shaped prefill piece: COW anything shared in the write
    range, then commit (publishing completed blocks)."""
    c = min(c, len(feed) - start)
    for idx in range(start // BS, -(-(start + c) // BS)):
        if idx >= pool.n_blocks_of(sid):
            break
        if not pool.block_writable(sid, idx):
            r_before = int(pool.refcount[pool.seq_blocks(sid)[idx]])
            src, dst = pool.cow(sid, idx)
            # COW never mutates the shared block: the source keeps its
            # key, its other readers, or at worst parks idle-cached
            assert dst != src and pool.cache.is_published(src)
            assert int(pool.refcount[src]) == r_before - 1
            assert int(pool.refcount[dst]) == 1
            assert not pool.cache.is_published(dst)
    pool.commit(sid, start, feed[start:start + c])
    return start + c


# ---------------------------------------------------------------------------
# random interleaved traces
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_random_trace_invariants(seed):
    rng = np.random.default_rng(seed)
    pool = _pool(num_blocks=int(rng.integers(10, 40)))
    shared = rng.integers(0, 50, size=8 * BS).astype(np.int32)
    live: dict[int, dict] = {}     # sid -> {feed, written, prefilled}
    next_sid = 0
    for _ in range(80):
        op = int(rng.integers(5))
        if op == 0:                # admit: shared-prefix prompt, plan+alloc
            sid, next_sid = next_sid, next_sid + 1
            pfx = int(rng.integers(0, len(shared) + 1))
            tail = rng.integers(50, 99, size=int(rng.integers(1, 10)))
            feed = np.concatenate([shared[:pfx],
                                   tail.astype(np.int32)])
            plan = pool.plan_seq(len(feed), token_ids=feed)
            if plan.feasible:
                blocks = pool.alloc_seq(sid, len(feed), plan=plan)
                assert TRASH_BLOCK not in blocks
                hit = min(plan.hit_tokens, len(feed) - 1)
                assert blocks[:len(plan.hit_blocks)] == plan.hit_blocks
                live[sid] = {"feed": list(feed), "written": hit}
            else:
                with pytest.raises(BlockPoolError):
                    pool.alloc_seq(sid, len(feed), plan=plan)
        elif op == 1 and live:     # chunked prefill with COW
            sid = int(rng.choice(list(live)))
            s = live[sid]
            if s["written"] < len(s["feed"]):
                s["written"] = _prefill(
                    pool, sid, np.asarray(s["feed"], np.int32),
                    s["written"], int(rng.integers(1, 9)))
        elif op == 2 and live:     # decode: grow one row, commit token
            sid = int(rng.choice(list(live)))
            s = live[sid]
            if s["written"] == len(s["feed"]):
                tok = int(rng.integers(50, 99))
                try:
                    pool.extend(sid, len(s["feed"]) + 1)
                except BlockPoolError:
                    continue       # pool pressure: engine would preempt
                s["feed"].append(tok)
                # the decode row's block is ALWAYS writable: tails are
                # private by the COW-at-prefill invariant
                assert pool.block_writable(
                    sid, (len(s["feed"]) - 1) // BS)
                pool.commit(sid, len(s["feed"]) - 1, [tok])
                s["written"] += 1
        elif op == 3 and live:     # finish
            sid = int(rng.choice(list(live)))
            pool.free_seq(sid)
            del live[sid]
        elif op == 4 and live:     # preempt (release references)
            sid = int(rng.choice(list(live)))
            pool.evict(sid)
            del live[sid]
        pool.check_invariants()
        # live accounting: every owned block is reachable from a live seq
        expect = len({b for sid in live for b in pool.seq_blocks(sid)})
        assert pool.n_live == expect
    for sid in list(live):
        pool.free_seq(sid)
    pool.check_invariants()
    assert pool.n_live == 0
    # cached idle blocks remain resident (that is the point); flushing
    # returns every block to the free stack
    pool.flush_cache()
    pool.check_invariants()
    assert pool.n_free == pool.num_blocks - 1 and pool.n_cached == 0


# ---------------------------------------------------------------------------
# sharing / COW / eviction specifics
# ---------------------------------------------------------------------------

def _alloc_committed(pool, sid, feed):
    """Alloc + fully prefill (commit) a sequence; returns its blocks."""
    plan = pool.plan_seq(len(feed), token_ids=feed)
    blocks = pool.alloc_seq(sid, len(feed), plan=plan)
    _prefill(pool, sid, feed, min(plan.hit_tokens, len(feed) - 1),
             len(feed))
    return blocks, plan


def test_identical_prefixes_share_physical_blocks():
    pool = _pool()
    feed = np.arange(3 * BS + 2, dtype=np.int32)   # 3 full blocks + tail
    a, _ = _alloc_committed(pool, 0, feed)
    b, plan = _alloc_committed(pool, 1, feed)
    # the acceptance assertion: SAME physical block ids for the prefix
    assert b[:3] == a[:3] and plan.hit_tokens == 3 * BS
    assert b[3] != a[3]                            # private tails differ
    assert (pool.refcount[a[:3]] == 2).all()
    pool.check_invariants()
    # and the tail block was never published (partial)
    assert not pool.cache.is_published(a[3])


def test_full_hit_cow_leaves_source_intact():
    pool = _pool()
    feed = np.arange(2 * BS, dtype=np.int32)       # block-aligned feed
    a, _ = _alloc_committed(pool, 0, feed)
    plan = pool.plan_seq(len(feed), token_ids=feed)
    assert plan.hit_tokens == len(feed)            # fully cached
    assert plan.need_new == 1                      # COW reservation
    b = pool.alloc_seq(1, len(feed), plan=plan)
    assert b == a                                  # attached, both blocks
    # engine re-feeds the last token -> last block must COW
    assert not pool.block_writable(1, 1)
    src, dst = pool.cow(1, 1)
    assert (src, dst) == (a[1], pool.seq_blocks(1)[1]) and dst != a[1]
    # seq 0's table is untouched, the cache still serves the source
    assert pool.seq_blocks(0) == a
    assert pool.cache.is_published(src)
    assert pool.cache.stats.cow_copies == 1
    pool.check_invariants()


def test_chain_key_encodes_history_and_scale_exp():
    t = np.arange(BS, dtype=np.int32)
    assert block_key(ROOT_KEY, t, 4) != block_key(ROOT_KEY, t, 5)
    k1 = block_key(ROOT_KEY, t, 4)
    assert block_key(k1, t, 4) != k1               # same tokens, new parent
    pool = _pool()
    feed = np.arange(2 * BS, dtype=np.int32)
    _alloc_committed(pool, 0, feed)
    # same tokens at a different scale exponent: must MISS (the exponent
    # is a per-shard kernel constant — shared blocks must share it)
    plan = pool.plan_seq(len(feed), token_ids=feed, scale_exp=5)
    assert plan.hit_tokens == 0 and not plan.hit_blocks


def test_preempted_sequence_blocks_survive_for_resume():
    pool = _pool()
    feed = np.arange(2 * BS + 1, dtype=np.int32)
    a, _ = _alloc_committed(pool, 0, feed)
    pool.evict(0)                                  # preemption: release
    assert pool.stats.seq_evictions == 1
    assert pool.n_cached == 2                      # full blocks stay cached
    plan = pool.plan_seq(len(feed), token_ids=feed)
    assert plan.hit_blocks == a[:2]                # resume re-attaches
    pool.alloc_seq(0, len(feed), plan=plan)
    assert pool.seq_blocks(0)[:2] == a[:2]
    pool.check_invariants()


def test_lru_reclaim_oldest_idle_only_under_pressure():
    pool = _pool(num_blocks=7)                     # 6 usable
    f1 = np.arange(2 * BS, dtype=np.int32)
    f2 = 100 + np.arange(2 * BS, dtype=np.int32)
    a, _ = _alloc_committed(pool, 0, f1)
    b, _ = _alloc_committed(pool, 1, f2)
    pool.free_seq(0)                               # a idle (older)
    pool.free_seq(1)                               # b idle (newer)
    assert pool.n_cached == 4 and pool.n_free == 6
    # a LIVE reader pins its blocks against reclaim
    plan = pool.plan_seq(len(f2), token_ids=f2)
    pool.alloc_seq(2, len(f2), plan=plan)          # re-attach b
    # force reclaim: 2 fresh blocks needed, free stack has 2 left
    pool.alloc_seq(3, 2 * BS)
    assert pool.stats.cache_evictions == 0         # no pressure yet
    pool.alloc_seq(4, 2 * BS)                      # must reclaim from idle
    assert pool.stats.cache_evictions == 2
    # the reclaimed blocks are a's (oldest idle); b's stay — still live
    assert not pool.cache.is_published(a[0])
    assert not pool.cache.is_published(a[1])
    assert pool.cache.is_published(b[0]) and pool.cache.is_published(b[1])
    assert pool.seq_blocks(2) == b                 # live reader untouched
    pool.check_invariants()
    # and the evicted prefix now misses
    assert pool.plan_seq(len(f1), token_ids=f1).hit_tokens == 0


def test_cached_blocks_count_as_allocatable():
    pool = _pool(num_blocks=5)                     # 4 usable
    _alloc_committed(pool, 0, np.arange(4 * BS, dtype=np.int32))
    pool.free_seq(0)
    assert pool.n_free == 4 and pool.n_cached == 4
    assert pool.can_alloc(4)                       # reclaimable on demand
    pool.alloc_seq(1, 4 * BS)
    assert pool.stats.cache_evictions == 4
    pool.check_invariants()


def test_double_free_and_stale_plan_raise():
    pool = _pool()
    feed = np.arange(BS, dtype=np.int32)
    _alloc_committed(pool, 0, feed)
    pool.free_seq(0)
    with pytest.raises(BlockPoolError, match="double free"):
        pool.free_seq(0)
    with pytest.raises(BlockPoolError, match="double free"):
        pool.evict(0)
    # a plan made before the cache content changed must not attach blindly
    plan = pool.plan_seq(len(feed), token_ids=feed)
    assert plan.hit_blocks
    pool.flush_cache()
    with pytest.raises(BlockPoolError, match="stale plan"):
        pool.alloc_seq(1, len(feed), plan=plan)
    pool.check_invariants()


def test_cow_of_writable_block_is_refused():
    pool = _pool()
    pool.alloc_seq(0, BS)                          # private, unpublished
    with pytest.raises(BlockPoolError, match="writable"):
        pool.cow(0, 0)


def test_concurrent_identical_prompts_publish_once():
    """Two sequences prefill the same prompt before either publishes:
    the second publish attempt finds the key taken and stays anonymous —
    no corruption, and later requests hit the first copy."""
    pool = _pool()
    feed = np.arange(2 * BS, dtype=np.int32)
    pa = pool.plan_seq(len(feed), token_ids=feed)
    a = pool.alloc_seq(0, len(feed), plan=pa)
    pb = pool.plan_seq(len(feed), token_ids=feed)
    assert not pb.hit_blocks                       # nothing published yet
    b = pool.alloc_seq(1, len(feed), plan=pb)
    _prefill(pool, 0, feed, 0, len(feed))
    _prefill(pool, 1, feed, 0, len(feed))
    assert set(a).isdisjoint(b)                    # physically separate
    assert pool.cache.is_published(a[0]) and not pool.cache.is_published(b[0])
    plan = pool.plan_seq(len(feed), token_ids=feed)
    assert plan.hit_blocks == a                    # hits the first copy
    pool.check_invariants()


# ---------------------------------------------------------------------------
# speculative rollback (DESIGN §11)
# ---------------------------------------------------------------------------

def test_retracted_speculative_rows_never_publish():
    """The §11 rollback contract: speculative tail blocks carry no
    content key (commit never covered them), retract returns them to the
    FREE stack — not the idle cache — and the cache's key maps never see
    a rejected token."""
    pool = _pool()
    feed = np.arange(2 * BS, dtype=np.int32)
    _alloc_committed(pool, 0, feed)                # 2 published blocks
    published_before = len(pool.cache)
    # speculative growth: 2 extra blocks' worth of drafted rows, written
    # but NEVER committed
    tail = pool.extend(0, 4 * BS)
    assert len(tail) == 2
    for blk in tail:
        assert not pool.cache.is_published(blk)
    freed = pool.retract(0, 2 * BS)                # reject everything
    assert freed == 2
    assert len(pool.cache) == published_before     # no new keys, ever
    assert all(not pool.cache.is_published(b) for b in tail)
    assert all(b in pool._free for b in tail)      # free, not idle-cached
    pool.check_invariants()
    pool.free_seq(0)
    pool.check_invariants()


def test_retract_refuses_committed_and_shared_rows():
    """Rollback must never touch committed state: retracting past the
    commit point trips the chain-state cross-check, and a shared
    (published, refcount > 1) tail block refuses block-level."""
    pool = _pool()
    feed = np.arange(3 * BS, dtype=np.int32)
    _alloc_committed(pool, 0, feed)                # 3 committed blocks
    # published-block guard: the full committed tail block refuses
    with pytest.raises(BlockPoolError, match="shared/published"):
        pool.retract(0, 2 * BS)
    # chain-state guard: a PARTIAL tail block is unpublished, so only the
    # commit-position cross-check can catch rows already committed there
    feed9 = np.arange(100, 100 + 2 * BS + 2, dtype=np.int32)
    _alloc_committed(pool, 9, feed9)
    with pytest.raises(AssertionError, match="already committed"):
        pool.retract(9, 2 * BS)
    pool.free_seq(9)
    # shared-block guard: seq 1 attaches the published chain, then tries
    # to retract INTO it (simulating a caller bug) — the block-level
    # refcount/published check refuses before anything mutates
    plan = pool.plan_seq(len(feed), token_ids=feed)
    pool.alloc_seq(1, len(feed), plan=plan)
    assert plan.hit_tokens > 0
    with pytest.raises(BlockPoolError, match="shared/published"):
        pool.retract(1, 0)
    pool.check_invariants()
    pool.free_seq(0)
    pool.free_seq(1)
    pool.check_invariants()


def test_interleaved_commit_retract_traces_keep_invariants():
    """Speculate -> commit the accepted prefix -> retract the rejected
    tail, interleaved with sharing and eviction: refcounts stay exact,
    published keys always re-derive from committed tokens only, and idle
    parking/LRU reclaim never sees a speculative block."""
    for seed in range(10):
        rng = np.random.default_rng(seed)
        pool = _pool(num_blocks=int(rng.integers(12, 30)))
        shared = rng.integers(0, 40, size=4 * BS).astype(np.int32)
        live: dict[int, dict] = {}
        streams: list[dict] = []       # every seq ever admitted (kept
        next_sid = 0                   # after free: its keys may survive)
        for _ in range(70):
            op = int(rng.integers(4))
            if op == 0:                    # admit (maybe shared prefix)
                sid, next_sid = next_sid, next_sid + 1
                pfx = int(rng.integers(0, len(shared) + 1))
                tail = rng.integers(40, 80, size=int(
                    rng.integers(1, 8))).astype(np.int32)
                feed = np.concatenate([shared[:pfx], tail])
                plan = pool.plan_seq(len(feed), token_ids=feed)
                if plan.feasible:
                    pool.alloc_seq(sid, len(feed), plan=plan)
                    hit = min(plan.hit_tokens, len(feed) - 1)
                    live[sid] = {"feed": list(feed), "written": hit}
                    streams.append(live[sid])
            elif op == 1 and live:         # prefill a chunk (commits)
                sid = int(rng.choice(list(live)))
                s = live[sid]
                if s["written"] < len(s["feed"]):
                    s["written"] = _prefill(
                        pool, sid, np.asarray(s["feed"], np.int32),
                        s["written"], int(rng.integers(1, 9)))
            elif op == 2 and live:         # speculative verify round
                sid = int(rng.choice(list(live)))
                s = live[sid]
                if s["written"] < len(s["feed"]):
                    continue               # still prefilling
                k = int(rng.integers(1, 6))
                try:
                    pool.extend(sid, len(s["feed"]) + k)
                except BlockPoolError:
                    continue               # pressure: engine degrades k
                acc = int(rng.integers(0, k + 1))   # accepted drafts
                toks = [int(t) for t in rng.integers(40, 80, size=acc)]
                pool.commit(sid, len(s["feed"]), toks)
                s["feed"].extend(toks)
                s["written"] += acc
                pool.retract(sid, len(s["feed"]))
            elif op == 3 and live:         # finish or preempt
                sid = int(rng.choice(list(live)))
                (pool.free_seq if rng.integers(2) else pool.evict)(sid)
                del live[sid]
            pool.check_invariants()
        # every published key must re-derive from some sequence's
        # committed token stream prefix — never from a rejected draft
        legal = set()
        for s in streams:
            parent = ROOT_KEY
            toks = np.asarray(s["feed"], np.int32)
            for b in range(s["written"] // BS):
                parent = block_key(parent, toks[b * BS:(b + 1) * BS], 4)
                legal.add(parent)
        for key in pool.cache._by_key:
            assert key in legal, \
                "published key not derivable from any COMMITTED token " \
                "stream — a rejected speculative row leaked into the cache"
        for sid in list(live):
            pool.free_seq(sid)
        pool.check_invariants()
        assert pool.n_live == 0
