"""Fault-tolerance policies: heartbeats, stragglers, elastic re-mesh,
supervised restart loop with checkpoint resume."""
import jax.numpy as jnp
import pytest

from repro.checkpoint import Checkpointer
from repro.distributed.fault_tolerance import (ElasticPlanner,
                                               HeartbeatMonitor,
                                               RunSupervisor)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_straggler_detection_needs_patience():
    clock = FakeClock()
    mon = HeartbeatMonitor(4, straggler_factor=1.5, patience=3, clock=clock)
    for step in range(6):
        clock.t += 1
        for h in range(4):
            mon.beat(h, 2.0 if h == 2 else 1.0)   # host 2 is 2x slower
        res = mon.check()
        if step < 2:
            assert res["stragglers"] == []
    assert 2 in mon.check()["stragglers"]


def test_dead_host_detection_by_timeout():
    clock = FakeClock()
    mon = HeartbeatMonitor(3, timeout_s=10, clock=clock)
    for h in range(3):
        mon.beat(h, 1.0)
    clock.t = 5
    mon.beat(0, 1.0)
    mon.beat(1, 1.0)          # host 2 silent since t=0
    clock.t = 12
    res = mon.check()
    assert res["dead"] == [2]
    assert mon.alive_count() == 2


def test_elastic_planner_shrinks_data_axis():
    p = ElasticPlanner(model_axis=16)
    plan = p.plan(256)
    assert plan.shape == (16, 16) and plan.dropped == 0
    plan = p.plan(250)           # lost 6 devices
    assert plan.shape == (15, 16) and plan.dropped == 250 - 240
    with pytest.raises(RuntimeError):
        p.plan(8)                # cannot host the TP degree


def test_elastic_planner_multi_pod():
    p = ElasticPlanner(model_axis=16, pod_size=256)
    plan = p.plan(512)
    assert plan.shape == (2, 16, 16) and plan.axes[0] == "pod"


def test_supervisor_restart_resumes_from_committed_step(tmp_path):
    ck = Checkpointer(str(tmp_path))
    calls = []

    def train_segment(plan, start, total):
        calls.append((plan.n_devices, start))
        for s in range(start + 1, min(start + 5, total) + 1):
            ck.save(s, {"w": jnp.zeros(())}, blocking=True)
        last = min(start + 5, total)
        if len(calls) == 1:          # inject one failure with 16 lost devices
            return last, {"lost_devices": 16}
        return last, None

    sup = RunSupervisor(ElasticPlanner(model_axis=16), ck, train_segment)
    final = sup.run(n_devices=256, total_steps=10)
    assert final == 10
    assert sup.restarts == 1
    assert calls[0][0] == 256 and calls[1][0] == 240  # re-meshed smaller
    assert calls[1][1] == 5                            # resumed at commit
