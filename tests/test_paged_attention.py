"""Paged attention parity vs the dense-cache oracle (DESIGN §9).

Grid: {int8, bf16} KV x GQA {1, 4}, per-slot positions, SHUFFLED block
tables (blocks physically scattered through the pool — catching any
implicit logical==physical assumption), plus the fused-kernel fallback
shapes, multi-token chunk queries, and a 4-device shard_map case riding
``tests/conftest.py``'s forced CPU mesh.  The dense oracle is the
pure-JAX ``chunked_attention`` over the dequantized, repeated cache — the
exact dataflow the paged kernel deletes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qscheme import dequant, quant
from repro.kernels import ops
from repro.models.attention import _repeat_kv, chunked_attention

NKV = 4
B, SMAX, DK = 4, 256, 128
POS = (0, 131, 255, 77)         # per-slot live positions, incl. edges


def _build_pool(seed, kvh, groups, kv, *, bs=128, smax=SMAX, dk=DK):
    """Dense (B, S, KVH, D) K/V chopped into blocks scattered through a
    pool via a SHUFFLED block table; returns kernel + oracle views."""
    rng = np.random.default_rng(seed)
    h = kvh * groups
    nbmax = smax // bs
    q = jnp.asarray(rng.normal(size=(B, 1, h, dk)), jnp.float32)
    kf = jnp.asarray(rng.normal(size=(B, smax, kvh, dk)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(B, smax, kvh, dk)), jnp.float32)
    if kv == "int8":
        kc, vc = quant(kf, NKV, 8), quant(vf, NKV, 8)
        kd, vd = dequant(kc, NKV), dequant(vc, NKV)
        nkv = NKV
    else:
        kc, vc = kf.astype(jnp.bfloat16), vf.astype(jnp.bfloat16)
        kd, vd = kc.astype(jnp.float32), vc.astype(jnp.float32)
        q = q.astype(jnp.bfloat16)
        nkv = None
    nb = 1 + B * nbmax
    bt = rng.permutation(np.arange(1, nb)).reshape(B, nbmax).astype(np.int32)
    kp = np.zeros((nb, bs, kvh, dk), np.asarray(kc).dtype)
    vp = np.zeros_like(kp)
    for b_ in range(B):
        for i in range(nbmax):
            kp[bt[b_, i]] = np.asarray(kc[b_, i * bs:(i + 1) * bs])
            vp[bt[b_, i]] = np.asarray(vc[b_, i * bs:(i + 1) * bs])
    return (q, jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(bt),
            q.astype(jnp.float32), kd, vd, nkv)


def _tol(kv):
    return dict(atol=2e-2, rtol=2e-2) if kv == "bf16" else \
        dict(atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("kv", ["int8", "bf16"])
@pytest.mark.parametrize("groups", [1, 4])
def test_paged_decode_parity(groups, kv):
    """Fused paged kernel (MXU-aligned shapes) vs dense chunked oracle at
    per-slot positions through a shuffled block table."""
    q, kp, vp, bt, qf, kd, vd, nkv = _build_pool(3, 2, groups, kv)
    pos = jnp.asarray(np.asarray(POS, np.int32))[:, None]
    out = ops.paged_attention(q, kp, vp, bt, pos, kv_frac_bits=nkv)
    for b_ in range(B):
        ref = chunked_attention(
            qf[b_:b_ + 1], _repeat_kv(kd[b_:b_ + 1], groups),
            _repeat_kv(vd[b_:b_ + 1], groups), causal=True,
            q_offset=jnp.asarray(POS[b_], jnp.int32))
        np.testing.assert_allclose(
            np.asarray(out[b_], np.float32), np.asarray(ref[0], np.float32),
            err_msg=f"slot {b_} pos {POS[b_]}", **_tol(kv))


@pytest.mark.parametrize("kv", ["int8", "bf16"])
def test_paged_decode_fallback_small_dims(kv):
    """Engine smoke shapes (block 16, head_dim 16) refuse the kernel and
    take the reference gather path — same contract."""
    q, kp, vp, bt, qf, kd, vd, nkv = _build_pool(5, 2, 2, kv, bs=16,
                                                 smax=64, dk=16)
    pos = jnp.asarray(np.asarray([0, 17, 63, 31], np.int32))[:, None]
    out = ops.paged_attention(q, kp, vp, bt, pos, kv_frac_bits=nkv)
    for b_ in range(B):
        ref = chunked_attention(
            qf[b_:b_ + 1], _repeat_kv(kd[b_:b_ + 1], 2),
            _repeat_kv(vd[b_:b_ + 1], 2), causal=True,
            q_offset=jnp.asarray(int(pos[b_, 0]), jnp.int32))
        np.testing.assert_allclose(
            np.asarray(out[b_], np.float32), np.asarray(ref[0], np.float32),
            **_tol(kv))


def test_paged_chunk_prefill_parity():
    """Multi-token chunk (C > 1) with per-query positions — the chunked-
    prefill path — matches the dense oracle at the chunk's offset."""
    q, kp, vp, bt, qf, kd, vd, nkv = _build_pool(7, 2, 2, "int8")
    rng = np.random.default_rng(11)
    C, start = 32, 100
    qc = jnp.asarray(rng.normal(size=(1, C, 4, DK)), jnp.float32)
    qpos = (start + jnp.arange(C))[None]
    out = ops.paged_attention(qc, kp, vp, bt[:1], qpos, kv_frac_bits=nkv)
    ref = chunked_attention(qc, _repeat_kv(kd[:1], 2), _repeat_kv(vd[:1], 2),
                            causal=True, q_offset=jnp.asarray(start,
                                                              jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("mesh_shape", [(2, 2), (1, 4)])
@pytest.mark.parametrize("groups", [1, 4])
def test_paged_decode_sharded_parity(groups, mesh_shape):
    """4-device shard_map case: pool head-sharded over 'model', block
    tables + positions replicated across it — must match the single-device
    oracle exactly like the dense flash path does (DESIGN §8/§9)."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices (tests/conftest.py forces them)")
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    q, kp, vp, bt, qf, kd, vd, nkv = _build_pool(9, 4, groups, "int8")
    pos = jnp.asarray(np.asarray(POS, np.int32))[:, None]
    out = ops.paged_attention(q, kp, vp, bt, pos, kv_frac_bits=nkv,
                              mesh=mesh)
    for b_ in range(B):
        ref = chunked_attention(
            qf[b_:b_ + 1], _repeat_kv(kd[b_:b_ + 1], groups),
            _repeat_kv(vd[b_:b_ + 1], groups), causal=True,
            q_offset=jnp.asarray(POS[b_], jnp.int32))
        np.testing.assert_allclose(
            np.asarray(out[b_], np.float32), np.asarray(ref[0], np.float32),
            err_msg=f"slot {b_}", atol=1e-4, rtol=1e-4)


def test_paged_non_dividing_heads_raise():
    """Same no-silent-fallback contract as the dense kernels: a tensor
    axis that would split a GQA group is refused at the ops level."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    q, kp, vp, bt, *_ , nkv = _build_pool(13, 2, 1, "int8")
    pos = jnp.asarray(np.asarray(POS, np.int32))[:, None]
    with pytest.raises(NotImplementedError, match=r"KV head count \(2\)"):
        ops.paged_attention(q, kp, vp, bt, pos, kv_frac_bits=nkv, mesh=mesh)


def test_paged_pool_sharding_rule_head_sharded():
    """cache_sharding_rules places the pool head-sharded on 'model' with
    NO batch/sequence sharding (the pool is shared by every slot)."""
    import dataclasses as dc
    from repro.configs import get_smoke_config
    from repro.distributed import sharding as shd
    from repro.launch import steps as S
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = dc.replace(get_smoke_config("qwen3_1_7b"), kv_cache_bits=8)
    abs_pool = S.abstract_paged_cache(cfg, num_blocks=9, block_size=16)
    spec = shd.cache_sharding_rules(abs_pool, mesh, attn_kernel="flash")
    k_spec = spec["paged_kv"].k
    assert k_spec[3] == "model"
    assert all(k_spec[i] is None for i in (0, 1, 2, 4))


def test_int8_pool_requires_frac_bits():
    q, kp, vp, bt, *_ = _build_pool(15, 2, 1, "int8")
    pos = jnp.asarray(np.asarray(POS, np.int32))[:, None]
    with pytest.raises(ValueError, match="kv_frac_bits"):
        ops.paged_attention(q, kp, vp, bt, pos)
