"""Property tests for the serving engine's KV block pool (DESIGN §9).

Invariants under random alloc/extend/free/evict traces WITHOUT the prefix
cache (the refcounted sharing/COW paths live in tests/test_prefix_cache.py):
the non-trash blocks always partition into {free} ∪ {owned-by-exactly-one
-sequence}, double frees raise instead of corrupting, the trash block is
never handed out, utilization accounting matches ownership, and a live
block's Eq.-1 scale exponent never changes (codes are never requantized
while resident).
"""
import numpy as np
import pytest

from repro.serving.kv_pool import TRASH_BLOCK, BlockPool, BlockPoolError
from tests._hyp_stub import given, settings, st


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_random_trace_invariants(seed):
    rng = np.random.default_rng(seed)
    pool = BlockPool(num_blocks=int(rng.integers(2, 25)),
                     block_size=int(rng.integers(1, 9)), scale_exp=4)
    live: dict[int, int] = {}          # seq id -> tokens
    next_sid = 0
    for _ in range(60):
        op = int(rng.integers(4))
        if op == 0:                    # alloc a fresh sequence
            sid, next_sid = next_sid, next_sid + 1
            ntok = int(rng.integers(1, 40))
            if pool.can_alloc(pool.blocks_for(ntok)):
                blocks = pool.alloc_seq(sid, ntok)
                assert TRASH_BLOCK not in blocks
                live[sid] = ntok
            else:
                with pytest.raises(BlockPoolError):
                    pool.alloc_seq(sid, ntok)
        elif op == 1 and live:         # extend an existing sequence
            sid = int(rng.choice(list(live)))
            total = live[sid] + int(rng.integers(0, 20))
            before = pool.n_blocks_of(sid)
            try:
                new = pool.extend(sid, total)
                live[sid] = max(live[sid], total)
                assert pool.n_blocks_of(sid) == before + len(new)
            except BlockPoolError:     # atomic refusal: nothing changed
                assert pool.n_blocks_of(sid) == before
        elif op == 2 and live:         # free
            sid = int(rng.choice(list(live)))
            pool.free_seq(sid)
            del live[sid]
        elif op == 3 and live:         # evict (preemption path)
            sid = int(rng.choice(list(live)))
            pool.evict(sid)
            del live[sid]
        pool.check_invariants()
        # utilization accounting matches ownership exactly
        expect = sum(pool.blocks_for(n) for n in live.values())
        assert pool.n_live == expect
        assert pool.n_free == pool.num_blocks - 1 - expect
    for sid in list(live):
        pool.free_seq(sid)
    pool.check_invariants()
    assert pool.n_live == 0 and pool.utilization == 0.0
    assert pool.stats.frees + 0 == pool.stats.allocs  # all blocks returned


def test_double_free_raises():
    pool = BlockPool(num_blocks=4, block_size=8)
    pool.alloc_seq(7, 10)
    pool.free_seq(7)
    with pytest.raises(BlockPoolError, match="double free"):
        pool.free_seq(7)
    pool.check_invariants()


def test_double_alloc_raises():
    pool = BlockPool(num_blocks=6, block_size=8)
    pool.alloc_seq(1, 8)
    with pytest.raises(BlockPoolError, match="already allocated"):
        pool.alloc_seq(1, 8)


def test_trash_block_reserved():
    pool = BlockPool(num_blocks=5, block_size=4)
    blocks = pool.alloc_seq(0, 16)             # everything allocatable
    assert TRASH_BLOCK not in blocks and len(blocks) == 4
    assert not pool.can_alloc(1)               # trash is NOT allocatable
    # reading a table for an unknown sequence fails fast (decoding a
    # freed sequence against trash garbage must never happen silently)
    with pytest.raises(BlockPoolError, match="unknown sequence"):
        pool.table_row(999, 4)


def test_table_row_logical_order_and_padding():
    pool = BlockPool(num_blocks=10, block_size=4)
    blocks = pool.alloc_seq(3, 9)              # 3 blocks
    blocks += pool.extend(3, 14)               # +1 block
    row = pool.table_row(3, 6)
    assert row[:4].tolist() == blocks
    assert (row[4:] == TRASH_BLOCK).all()
    with pytest.raises(BlockPoolError, match="table"):
        pool.table_row(3, 2)                   # table too narrow


def test_scale_exp_written_once_and_uniform():
    pool = BlockPool(num_blocks=8, block_size=4, scale_exp=4)
    pool.alloc_seq(0, 8, scale_exp=5)
    pool.extend(0, 20)                         # inherits the seq's exponent
    assert pool.seq_scale_exp(0) == 5
    pool.alloc_seq(1, 4)                       # pool default
    assert pool.seq_scale_exp(1) == 4
    # a requantized (mutated) block is detected, never silently served
    blk = pool.table_row(0, 5)[0]
    pool.scale_exp[blk] = 2
    with pytest.raises(BlockPoolError, match="requantized"):
        pool.seq_scale_exp(0)


def test_exhaustion_counts_failures():
    pool = BlockPool(num_blocks=3, block_size=4)
    pool.alloc_seq(0, 8)
    with pytest.raises(BlockPoolError, match="exhausted"):
        pool.alloc_seq(1, 4)
    assert pool.stats.alloc_failures == 1
    with pytest.raises(BlockPoolError, match="exhausted"):
        pool.extend(0, 12)
    assert pool.stats.alloc_failures == 2


def test_evictions_counted_block_granular():
    """Regression (ISSUE 4 small fix): ``PoolStats.evictions`` counts
    evicted BLOCKS as documented (it used to count sequences); the
    per-sequence count and cache reclaims get their own counters."""
    pool = BlockPool(num_blocks=8, block_size=4)
    pool.alloc_seq(0, 12)                          # 3 blocks
    pool.alloc_seq(1, 4)                           # 1 block
    assert pool.evict(0) == 3
    assert pool.stats.evictions == 3               # blocks, not sequences
    assert pool.stats.seq_evictions == 1
    assert pool.evict(1) == 1
    assert pool.stats.evictions == 4
    assert pool.stats.seq_evictions == 2
    assert pool.stats.cache_evictions == 0         # no prefix cache here
    pool.check_invariants()


def test_retract_frees_speculative_tail():
    """DESIGN §11 rollback: retract shrinks the table to the committed
    rows, returns the rejected tail to the free stack, and is a counted,
    idempotent no-op once the tail is gone."""
    pool = BlockPool(num_blocks=10, block_size=4)
    pool.alloc_seq(0, 6)                   # 2 blocks of committed rows
    pool.extend(0, 14)                     # +2 speculative tail blocks
    free_before = pool.n_free
    assert pool.retract(0, 7) == 2         # keep 7 rows -> 2 blocks
    assert pool.n_blocks_of(0) == 2
    assert pool.n_free == free_before + 2
    assert pool.stats.retracts == 1 and pool.stats.retracted_blocks == 2
    pool.check_invariants()
    assert pool.retract(0, 7) == 0         # nothing left to roll back
    assert pool.stats.retracts == 1        # no-ops are not counted
    with pytest.raises(BlockPoolError, match="needs"):
        pool.retract(0, 99)                # cannot retract UP
    with pytest.raises(BlockPoolError, match="unknown"):
        pool.retract(5, 0)
    pool.free_seq(0)
    pool.check_invariants()


def test_random_trace_with_retract_invariants():
    """Interleaved alloc/extend/retract/free/evict traces: rollback must
    never break the free/live partition or the refcounts (cache-less
    pool; the publish-interaction traces live in test_prefix_cache)."""
    for seed in range(12):
        rng = np.random.default_rng(seed)
        pool = BlockPool(num_blocks=int(rng.integers(4, 20)),
                         block_size=int(rng.integers(1, 6)), scale_exp=4)
        live: dict[int, int] = {}          # sid -> committed rows
        spec: dict[int, int] = {}          # sid -> grown (spec) rows
        next_sid = 0
        for _ in range(80):
            op = int(rng.integers(5))
            if op == 0:
                sid, next_sid = next_sid, next_sid + 1
                ntok = int(rng.integers(1, 24))
                if pool.can_alloc(pool.blocks_for(ntok)):
                    pool.alloc_seq(sid, ntok)
                    live[sid] = ntok
                    spec[sid] = ntok
            elif op == 1 and live:         # speculative growth
                sid = int(rng.choice(list(live)))
                want = spec[sid] + int(rng.integers(1, 8))
                try:
                    pool.extend(sid, want)
                    spec[sid] = max(spec[sid], want)
                except BlockPoolError:
                    pass
            elif op == 2 and live:         # rollback to committed rows
                sid = int(rng.choice(list(live)))
                keep = int(rng.integers(live[sid], spec[sid] + 1))
                freed = pool.retract(sid, keep)
                assert pool.n_blocks_of(sid) == pool.blocks_for(
                    max(keep, 1)) or keep == 0
                assert freed >= 0
                spec[sid] = max(keep, live[sid])
                live[sid] = min(live[sid], max(keep, 1))
            elif op == 3 and live:
                sid = int(rng.choice(list(live)))
                pool.free_seq(sid)
                del live[sid], spec[sid]
            elif op == 4 and live:
                sid = int(rng.choice(list(live)))
                pool.evict(sid)
                del live[sid], spec[sid]
            pool.check_invariants()
        for sid in list(live):
            pool.free_seq(sid)
        pool.check_invariants()
        assert pool.n_live == 0
