"""Data pipeline, optimizers, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import ShardedLoader, SyntheticLMStream
from repro.optim import (adamw, adafactor, clip_by_global_norm,
                         warmup_cosine, quantize_grads_po2,
                         dequantize_grads_po2)


def test_stream_deterministic():
    s = SyntheticLMStream(1000, 32, 4, seed=7)
    b1, b2 = s.batch(5), s.batch(5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s.batch(6)["tokens"], b1["tokens"])


def test_loader_prefetch_and_state_restore():
    s = SyntheticLMStream(1000, 16, 2, seed=3)
    loader = ShardedLoader(s, shardings={})
    step0, b0 = next(loader)
    step1, b1 = next(loader)
    state = loader.state()
    loader.close()
    loader2 = ShardedLoader.restore(SyntheticLMStream(1000, 16, 2), {}, state)
    step2, b2 = next(loader2)
    loader2.close()
    assert step2 == step1 + 1
    assert np.array_equal(np.asarray(b2["tokens"]),
                          s.batch(step2)["tokens"])


def _quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


@pytest.mark.parametrize("make_opt", [adamw, adafactor])
def test_optimizers_descend(make_opt):
    opt = make_opt()
    params = {"w": jnp.zeros((256, 256)), "b": jnp.zeros((4,))}
    state = opt.init(params)
    loss0 = float(_quad_loss(params))
    for _ in range(50):
        g = jax.grad(_quad_loss)(params)
        params, state = opt.update(g, state, params, 0.1)
    assert float(_quad_loss(params)) < 0.2 * loss0


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    n2 = jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree_util.tree_leaves(
        clipped)))
    assert abs(float(n2) - 1.0) < 1e-3


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < float(lr(50)) < float(lr(10))


def test_grad_compression_roundtrip():
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(64, 64)) * 0.01, jnp.float32),
             "b": jnp.asarray(rng.normal(size=(8,)) * 2.0, jnp.float32)}
    codes, ns = quantize_grads_po2(grads)
    back = dequantize_grads_po2(codes, ns)
    for k in grads:
        rel = float(jnp.linalg.norm(back[k] - grads[k]) /
                    jnp.linalg.norm(grads[k]))
        # po2 8-bit grid: step = 2^-n <= range/128 -> rel error ~3% on
        # gaussian grads (step/(sqrt(12) sigma))
        assert rel < 0.05, f"{k}: {rel}"
    # wire format is 8-bit even though codes ride in int32
    assert int(jnp.max(jnp.abs(codes["w"]))) <= 127


def test_compressed_psum_single_device():
    from repro.optim.compression import compressed_psum
    from jax.sharding import Mesh
    import jax.experimental.shard_map as shard_map
    mesh = jax.make_mesh((1,), ("d",))
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(32,)) * 0.1,
                          jnp.float32)}

    def f(g):
        return compressed_psum(g, "d")

    out = jax.jit(shard_map.shard_map(
        f, mesh=mesh, in_specs=(jax.sharding.PartitionSpec(),),
        out_specs=jax.sharding.PartitionSpec()))(g)
    rel = float(jnp.linalg.norm(out["w"] - g["w"]) /
                jnp.linalg.norm(g["w"]))
    assert rel < 0.05
