"""True-W8A8 serving differential parity rig (DESIGN §13).

The deploy path — pre-quantized int8 weight codes + activation quant at
module boundaries + the fused shift-requant matmul — must be BIT-EXACT
against the fp32 ``fake_quant`` dataflow oracle: a float implementation
of Eq. 1/3/5 (input/weight fake-quant, exact fp32 accumulate, bias
aligned to the accumulator grid, output rounded half-away onto the N_o
grid with int8 saturation).  At smoke scale every accumulator stays far
below 2^24 product-LSBs, so the float oracle's arithmetic is exact and
any code mismatch is a real dataflow divergence, not float noise.

Grid: {attention-proj, MLP, full layer, full model} modules bit-exact vs
the oracle; {greedy decode, spec-decode, prefix-shared prefill} engine
runs token-identical to the dense-INT reference engine (same calibrated
grids, weights quantized on the fly — the int8 passthrough makes the
codes identical by construction, so ANY drift is a kernel/container
bug) and within the calibrated error budget of the fp engine; plus the
§8 shard_map 4-device case on the CPU parity grid.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import qmodel
from repro.core.lm_calibrate import calibrate_lm
from repro.core.qmodel import (QuantContext, QuantMode, qlinear,
                               quantize_params)
from repro.core.qscheme import dequant, fake_quant, round_half_away
from repro.models import common as common_lib
from repro.models import model as M
from repro.models import transformer as tfm
from repro.serving import Request, ServingEngine

SCALE = dict(dtype="float32", n_layers=2, d_model=64, n_heads=4,
             n_kv_heads=2, d_ff=128, head_dim=16)


def _cfg(**kw):
    cfg = get_smoke_config("qwen3_1_7b").scaled(**SCALE)
    return dataclasses.replace(cfg, kv_cache_bits=8, **kw)


# ---------------------------------------------------------------------------
# the fp32 fake_quant oracle — Eq. 1/3/5 in float arithmetic
# ---------------------------------------------------------------------------

def oracle_qlinear(ctx, name, x, w, b=None, *, use_kernel=True):
    """Float reference of one unified module's integer dataflow.

    Mirrors ``int_linear`` step by step: Eq. 1 on input and weight, exact
    accumulate, Eq. 3 bias alignment (re-rounding when the bias grid is
    finer than the accumulator grid), Eq. 5 output requant with int8
    saturation.  Bit-exact vs the int path while accumulators < 2^24
    product-LSBs."""
    mb = ctx.bits_for(name)
    xq = fake_quant(x, mb.n_x, ctx.bits)
    if w.dtype == jnp.int8:
        wq = dequant(w, mb.n_w, out_dtype=jnp.float32)
    else:
        wq = fake_quant(w, mb.n_w, ctx.bits)
    y = xq.astype(jnp.float32) @ wq.astype(jnp.float32)
    if b is not None:
        n_b = mb.n_b if mb.n_b is not None else mb.n_w
        bq = fake_quant(b, n_b, ctx.bits).astype(jnp.float32)
        if mb.n_x + mb.n_w < n_b:
            # accumulator grid coarser than the bias grid: bias_align
            # right-shifts with round-half-away (integer_ops.bias_align)
            g = 2.0 ** (mb.n_x + mb.n_w)
            bq = round_half_away(bq * g) / g
        y = y + bq
    return fake_quant(y, mb.n_o, ctx.bits).astype(x.dtype)


def _codes(x, n):
    """Integer codes of a float tensor living on the 2^-n grid."""
    return np.asarray(jnp.round(x.astype(jnp.float32) * 2.0 ** n), np.int64)


@pytest.fixture(scope="module")
def cal():
    """One calibrated tiny model shared by the whole rig."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(2, 32)), jnp.int32)}
    ctx_cal, report = calibrate_lm(
        lambda p, b, c: M.forward(p, b, cfg, c), params, batch)
    ctx_int = dataclasses.replace(ctx_cal, mode=QuantMode.INT)
    qp = quantize_params(params, ctx_int)
    return dict(cfg=cfg, params=params, ctx_int=ctx_int, qp=qp,
                report=report, batch=batch)


def _logits(out):
    return out[0] if isinstance(out, tuple) else out


# ---------------------------------------------------------------------------
# module grid: attention projections / MLP / full layer / full model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["attn/wq", "attn/wk", "attn/wv",
                                  "attn/wo", "lm_head"])
def test_attention_proj_bit_exact_vs_oracle(cal, name):
    """Every projection module: INT path codes == fp32 oracle codes, with
    float weights AND with pre-quantized int8 codes (identical by the
    qlinear passthrough contract)."""
    ctx = cal["ctx_int"]
    mb = ctx.bits_for(name)
    rng = np.random.default_rng(hash(name) % 2**31)
    k = {"attn/wq": 64, "attn/wk": 64, "attn/wv": 64, "attn/wo": 64,
         "lm_head": 64}[name]
    n_out = 256 if name == "lm_head" else 64
    x = jnp.asarray(rng.normal(0, 2.0, size=(24, k)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.05, size=(k, n_out)), jnp.float32)
    got = qlinear(ctx, name, x, w)
    want = oracle_qlinear(ctx, name, x, w)
    assert np.array_equal(_codes(got, mb.n_o), _codes(want, mb.n_o))
    # pre-quantized codes produce the same output bit-for-bit
    from repro.core.qscheme import quant
    w_codes = quant(w, mb.n_w, ctx.bits)
    got_pre = qlinear(ctx, name, x, w_codes)
    assert np.array_equal(np.asarray(got), np.asarray(got_pre))


def test_mlp_bit_exact_vs_oracle(cal, monkeypatch):
    """The up/gate/down MLP through the INT path == the oracle dataflow
    (SiLU and the Hadamard product run in float between quant points on
    both sides)."""
    from repro.models import mlp as mlp_lib
    cfg, ctx = cal["cfg"], cal["ctx_int"]
    p = {k.split("/")[-1]: v for k, v in (
        ("w1", cal["qp"].tree["blocks"]["mlp"]["w1"][0]),
        ("w3", cal["qp"].tree["blocks"]["mlp"]["w3"][0]),
        ("w2", cal["qp"].tree["blocks"]["mlp"]["w2"][0]))}
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(0, 1.0, size=(2, 8, cfg.d_model)),
                    jnp.float32)
    got = mlp_lib.mlp(ctx, p, x, cfg.act)
    monkeypatch.setattr(common_lib, "qlinear", oracle_qlinear)
    p_f = {k.split("/")[-1]: v for k, v in (
        ("w1", cal["params"]["blocks"]["mlp"]["w1"][0]),
        ("w3", cal["params"]["blocks"]["mlp"]["w3"][0]),
        ("w2", cal["params"]["blocks"]["mlp"]["w2"][0]))}
    want = mlp_lib.mlp(ctx, p_f, x, cfg.act)
    n_o = ctx.bits_for("mlp/w2").n_o
    assert np.array_equal(_codes(got, n_o), _codes(want, n_o))


def test_full_layer_bit_exact_vs_oracle(cal, monkeypatch):
    """One dense transformer block (attn + MLP + residuals + norms): the
    INT path and the oracle path must agree on every module's codes, so
    the block outputs are identical floats (residual adds and norms are
    float on both sides)."""
    cfg, ctx = cal["cfg"], cal["ctx_int"]
    layer_q = jax.tree.map(lambda a: a[0], cal["qp"].tree["blocks"])
    layer_f = jax.tree.map(lambda a: a[0], cal["params"]["blocks"])
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(0, 1.0, size=(1, 16, cfg.d_model)),
                    jnp.float32)
    pos = jnp.arange(16)[None]
    got, _ = tfm.dense_block(ctx, layer_q, x, cfg, positions=pos)
    monkeypatch.setattr(common_lib, "qlinear", oracle_qlinear)
    want, _ = tfm.dense_block(ctx, layer_f, x, cfg, positions=pos)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_full_model_bit_exact_vs_oracle(cal, monkeypatch):
    """End-to-end forward: W8A8 logits (int8 codes, INT path) equal the
    fp32 fake_quant oracle's logits code-for-code on the lm_head grid —
    the fused dataflow implements Eq. 3/5, not an approximation."""
    cfg, ctx = cal["cfg"], cal["ctx_int"]
    got = _logits(M.forward(cal["qp"].tree, cal["batch"], cfg, ctx))
    monkeypatch.setattr(common_lib, "qlinear", oracle_qlinear)
    want = _logits(M.forward(cal["params"], cal["batch"], cfg, ctx))
    n_o = ctx.bits_for("lm_head").n_o
    assert np.array_equal(_codes(got, n_o), _codes(want, n_o))


def test_quantize_params_container(cal):
    """The deploy container: converts exactly the calibrated matmul
    weights to int8, leaves embeddings/norms/biases float, and records
    what it converted."""
    qp = cal["qp"]
    assert qp.converted, "nothing was converted"
    flat_q = dict(jax.tree_util.tree_flatten_with_path(qp.tree)[0])
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            cal["params"])[0]:
        nm = "/".join(str(getattr(p, "key", p)) for p in path)
        q_leaf = flat_q[path]
        if nm in qp.converted:
            assert q_leaf.dtype == jnp.int8, nm
            assert q_leaf.shape == leaf.shape, nm
        else:
            assert q_leaf.dtype == leaf.dtype, nm
            assert "embed" in nm or "norm" in nm or "ln" in nm \
                or leaf.ndim < 2 or qmodel.module_name_for_path(
                    nm, cal["ctx_int"].table) is None, nm


def test_int8_codes_refused_outside_int_mode(cal):
    """fp/fake forwards over a code tree are garbage — qlinear refuses."""
    w_codes = cal["qp"].tree["blocks"]["attn"]["wq"][0]
    x = jnp.ones((4, cal["cfg"].d_model), jnp.float32)
    with pytest.raises(ValueError, match="int8 weight codes"):
        qlinear(QuantContext(mode=QuantMode.FP), "attn/wq", x, w_codes)


# ---------------------------------------------------------------------------
# engine grid: greedy / spec-decode / prefix-shared prefill
# ---------------------------------------------------------------------------

def _workload(rng, n, vocab, *, prefix=0):
    pre = rng.integers(0, vocab, size=prefix).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, vocab, size=int(rng.integers(6, 14))
                            ).astype(np.int32)
        reqs.append(Request(
            rid=i, prompt=np.concatenate([pre, tail]) if prefix else tail,
            max_new_tokens=int(rng.integers(3, 7))))
    return reqs


def _run(cfg, params, ctx, reqs, **kw):
    eng = ServingEngine(cfg, params, ctx, n_slots=2, block_size=8,
                        max_model_len=48, chunk=8, **kw)
    rep = eng.run([dataclasses.replace(r) for r in reqs])
    return eng, rep


SCENARIOS = {
    "greedy": dict(),
    "spec": dict(spec_k=2),
    "prefix": dict(),
}


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_engine_w8a8_token_parity(cal, scenario):
    """The W8A8 engine (int8 weight codes, matmul_kernel='int8') emits
    EXACTLY the dense-INT reference engine's tokens across greedy,
    speculative, and prefix-shared serving — and stays within the
    calibrated error budget of the fp engine."""
    ctx = cal["ctx_int"]
    rng = np.random.default_rng(11)
    prefix = 16 if scenario == "prefix" else 0
    reqs = _workload(rng, 5, cal["cfg"].vocab_size, prefix=prefix)
    kw = SCENARIOS[scenario]

    cfg_w8 = _cfg(matmul_kernel="int8")
    eng_w8, rep_w8 = _run(cfg_w8, cal["qp"], ctx, reqs, **kw)
    eng_ref, _ = _run(cfg_w8, cal["params"], ctx, reqs, **kw)
    assert rep_w8["completed"] == len(reqs)
    for r in reqs:
        assert eng_w8.outputs()[r.rid].tolist() == \
            eng_ref.outputs()[r.rid].tolist(), f"req {r.rid} ({scenario})"

    hw = rep_w8["hwcost"]
    assert hw["w8a8"] and hw["requant_ops_forward"] > 0
    assert hw["energy_uj_forward_bit_shift"] > 0
    if scenario == "prefix":
        assert rep_w8["prefix_cache"]["hit_rate"] > 0
        assert hw["requant_ops_forward_avoided_prefix_cache"] > 0
    if scenario == "spec":
        assert rep_w8["spec_steps"] > 0

    # fp comparison: free-running greedy decode on a random-init smoke
    # model flips near-uniform argmaxes, so the budget is agreement well
    # above chance (1/vocab) plus the module-level calibration error bound
    eng_fp, _ = _run(_cfg(), cal["params"],
                     QuantContext(mode=QuantMode.FP), reqs, **kw)
    num = den = 0
    for r in reqs:
        a = eng_w8.outputs()[r.rid]
        b = eng_fp.outputs()[r.rid]
        n = min(len(a), len(b))
        num += int((a[:n] == b[:n]).sum())
        den += max(len(a), len(b))
    assert num / den > 0.2, f"{scenario}: fp agreement {num}/{den}"
    errs = sorted(r.error / max(r.fp_norm, 1e-9)
                  for r in cal["report"].results.values())
    assert errs[len(errs) // 2] < 0.2


def test_engine_w8a8_matches_dense_cache_oracle(cal):
    """Paged W8A8 engine vs the static dense-cache decode loop under the
    SAME quantized params and INT ctx: the pool/paged-attention plumbing
    must not perturb the W8A8 forward."""
    ctx = cal["ctx_int"]
    cfg_w8 = _cfg(matmul_kernel="int8")
    rng = np.random.default_rng(13)
    reqs = _workload(rng, 3, cfg_w8.vocab_size)
    eng, rep = _run(cfg_w8, cal["qp"], ctx, reqs)
    assert rep["completed"] == len(reqs)
    for r in reqs:
        p_len = len(r.prompt)
        logits, cache = M.prefill(
            cal["qp"].tree, {"tokens": jnp.asarray(r.prompt[None])},
            cfg_w8, ctx, max_seq=p_len + r.max_new_tokens)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        oracle = [int(tok[0, 0])]
        for i in range(r.max_new_tokens - 1):
            l, cache = M.decode_step(cal["qp"].tree, tok, cache,
                                     jnp.asarray(p_len + i, jnp.int32),
                                     cfg_w8, ctx)
            tok = jnp.argmax(l, -1)[:, None].astype(jnp.int32)
            oracle.append(int(tok[0, 0]))
        got = eng.outputs()[r.rid].tolist()
        assert got == oracle[:len(got)], f"req {r.rid}"


def test_engine_w8a8_shard_map_4dev(cal):
    """§8 composition: the W8A8 engine on a 4-way model-parallel mesh —
    int8 weight codes sharded exactly like their float counterparts,
    exponents as compile-time kernel constants — is token-identical to
    the single-device W8A8 engine."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices (conftest forces them on CPU)")
    # flash/ragged attention shards KV heads over 'model' — needs 4 | kvh
    cfg_w8 = dataclasses.replace(_cfg(matmul_kernel="int8"), n_kv_heads=4)
    params = M.init_params(cfg_w8, jax.random.PRNGKey(1))
    rng = np.random.default_rng(17)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg_w8.vocab_size, size=(2, 32)), jnp.int32)}
    ctx_cal, _ = calibrate_lm(
        lambda p, b, c: M.forward(p, b, cfg_w8, c), params, batch)
    ctx = dataclasses.replace(ctx_cal, mode=QuantMode.INT)
    qp = quantize_params(params, ctx)
    reqs = _workload(rng, 3, cfg_w8.vocab_size)
    eng_1, _ = _run(cfg_w8, qp, ctx, reqs)
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    eng_4, rep_4 = _run(cfg_w8, qp, ctx, reqs, mesh=mesh)
    assert rep_4["completed"] == len(reqs)
    for r in reqs:
        assert eng_4.outputs()[r.rid].tolist() == \
            eng_1.outputs()[r.rid].tolist(), f"req {r.rid}"


def test_build_time_validation():
    """matmul_kernel='int8' without an INT-mode context is a build error,
    and unknown values are rejected — never a silently-wrong forward."""
    from repro.launch import steps as S
    cfg = _cfg(matmul_kernel="int8")
    with pytest.raises(NotImplementedError, match="W8A8"):
        S.build_paged_step(cfg, QuantContext(mode=QuantMode.FP))
    with pytest.raises(ValueError, match="matmul_kernel"):
        S.build_paged_step(_cfg(matmul_kernel="nope"),
                           QuantContext(mode=QuantMode.FP))


def test_serve_engine_w8a8_entry(cal):
    """The launch wiring (serve --engine --w8a8): calibrates, quantizes,
    runs, and reports full-forward Table-5 energy."""
    from repro.launch.serve import serve_engine
    out = serve_engine("qwen3_1_7b", n_requests=3, rate=500.0, n_slots=2,
                       block_size=8, chunk=8, seed=3, w8a8=True,
                       cfg_overrides=dict(SCALE))
    hw = out["report"]["hwcost"]
    assert hw["w8a8"] and hw["requant_ops_forward"] > 0
    assert hw["energy_uj_forward_bit_shift"] > 0
    assert out["quantized"] is not None and out["quantized"].converted
    assert out["ctx"].mode is QuantMode.INT
    assert out["report"]["completed"] == 3
