"""Workload flight recorder (DESIGN §15): capture / replay.

Pure-python half: decision-line canonicalization, the unified decision
diff, record JSON round-tripping and version gating.  Engine half: one
module-scoped capture on a virtual-clock engine (speculation + prefix
cache on, so the decision stream covers admits, chunk boundaries,
cache publishes and spec verify), then the replay contract — token-
identical outputs, a ZERO-line scheduler-decision diff on an
identically-configured fresh engine, a NON-empty diff cross-config,
and a replayed trace that validates exactly like its source capture.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.obs.replay import (RECORD_VERSION, ReplayResult,
                              WorkloadRecord, build_requests,
                              capture_workload, decision_lines,
                              diff_decisions, engine_fingerprint,
                              engine_settings, replay_workload)
from repro.obs.trace import DECISION_CATS, Tracer, validate_chrome_trace


# ---------------------------------------------------------------------------
# pure python
# ---------------------------------------------------------------------------

def test_decision_lines_canonicalization():
    lines = decision_lines([
        ("sched.admit", {"rid": np.int64(3), "slot": 0,
                         "resume": False}),
        ("pool.free", {}),
        ("cache.publish", {"block": np.int32(7), "frac": 0.25}),
    ])
    # sorted keys, python scalars, no timestamps
    assert lines == ["sched.admit resume=false rid=3 slot=0",
                     "pool.free",
                     "cache.publish block=7 frac=0.25"]
    # numpy and python spellings of the same decision are EQUAL lines
    assert decision_lines([("e", {"x": np.float64(0.5)})]) == \
        decision_lines([("e", {"x": 0.5})])


def test_diff_decisions_empty_and_localized():
    a = [("sched.admit", {"rid": 0}), ("pool.alloc", {"seq": 0})]
    assert diff_decisions(a, list(a)) == []
    b = [("sched.admit", {"rid": 0}), ("pool.alloc", {"seq": 1})]
    diff = diff_decisions(a, b, label_a="run1", label_b="run2")
    assert diff[0].startswith("--- run1")
    assert diff[1].startswith("+++ run2")
    assert "-pool.alloc seq=0" in diff and "+pool.alloc seq=1" in diff
    assert not any(ln.startswith("-sched.admit") for ln in diff)


def test_decision_sink_tees_only_decision_cats():
    tr = Tracer(capacity=4, clock=lambda: 0.0, enabled=True)
    tr.decision_sink = []
    for cat in DECISION_CATS:
        tr.event(f"{cat}.x", cat, args={"i": 1})
    tr.event("ragged_step", "dispatch")        # not a decision
    tr.event("slo.alert", "slo")               # not a decision
    assert [n for n, _ in tr.decision_sink] == \
        [f"{c}.x" for c in DECISION_CATS]
    # the sink is UNBOUNDED — ring overflow must not eat decisions
    for i in range(50):
        tr.event("sched.admit", "sched", args={"order": i})
    assert len(tr.events) == 4                 # ring stayed bounded
    assert len(tr.decision_sink) == len(DECISION_CATS) + 50
    tr.reset()
    assert tr.decision_sink == []


def test_record_json_round_trip(tmp_path):
    rec = WorkloadRecord(
        version=RECORD_VERSION, fingerprint="ab" * 8,
        engine={"n_slots": 2}, requests=[
            {"rid": 0, "prompt": [1, 2, 3], "max_new_tokens": 4,
             "temperature": 0.0, "top_k": 0, "stop_token": None,
             "arrival": 0.001}],
        outputs={0: [5, 6]}, decisions=[["sched.admit", {"rid": 0}]],
        timelines={0: {"arrival": 0.001, "done": 0.01}},
        meta={"n_requests": 1})
    path = tmp_path / "rec.json"
    rec.save(str(path))
    back = WorkloadRecord.load(str(path))
    assert back == rec                         # int keys restored
    assert json.load(open(path))["outputs"] == {"0": [5, 6]}
    reqs = build_requests(back)
    assert reqs[0].rid == 0 and list(reqs[0].prompt) == [1, 2, 3]
    assert reqs[0].arrival == 0.001
    bad = rec.to_json() | {"version": RECORD_VERSION + 1}
    with pytest.raises(ValueError):
        WorkloadRecord.from_json(bad)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def _engine(**kw):
    import jax
    from repro.configs import get_smoke_config
    from repro.core.qmodel import QuantContext, QuantMode
    from repro.models import model as M
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(
        get_smoke_config("qwen3_1_7b").scaled(dtype="float32"),
        kv_cache_bits=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    base = dict(n_slots=2, block_size=8, max_model_len=64, spec_k=3,
                prefix_cache=True, record=True)
    base.update(kw)
    return ServingEngine(cfg, params, QuantContext(mode=QuantMode.FP),
                         **base)


def _workload(vocab):
    rng = np.random.default_rng(0)
    reqs, t = [], 0.0
    from repro.serving import Request
    for i in range(4):
        t += float(rng.exponential(0.02))
        prompt = (np.tile(rng.integers(0, vocab, size=3), 5)
                  if i == 1 else
                  rng.integers(0, vocab, size=int(rng.integers(5, 20))))
        reqs.append(Request(rid=i, prompt=prompt.astype(np.int32),
                            max_new_tokens=int(rng.integers(3, 9)),
                            arrival=t))
    return reqs


@pytest.fixture(scope="module")
def captured():
    eng = _engine()
    reqs = _workload(eng.cfg.vocab_size)
    eng.run(reqs)
    rec = capture_workload(eng, reqs)
    chrome = eng.tracer.to_chrome()
    return eng, rec, chrome


def test_capture_contents(captured):
    eng, rec, _ = captured
    assert rec.version == RECORD_VERSION
    assert rec.fingerprint == engine_fingerprint(eng)
    assert rec.meta["n_requests"] == 4
    assert rec.meta["n_decisions"] == len(rec.decisions) > 0
    assert rec.meta["wall_s_virtual"] > 0
    assert set(rec.outputs) == {0, 1, 2, 3}
    assert all(len(v) > 0 for v in rec.outputs.values())
    names = {n for n, _ in rec.decisions}
    assert "sched.admit" in names and "sched.prefill_chunk" in names
    assert "pool.alloc" in names
    # admission order is pinned explicitly in the stream
    orders = [a["order"] for n, a in rec.decisions if n == "sched.admit"]
    assert orders == sorted(orders) == list(range(len(orders)))
    # requests serialize sorted by arrival with plain-int prompts
    arrivals = [r["arrival"] for r in rec.requests]
    assert arrivals == sorted(arrivals)
    assert all(isinstance(t, int) for r in rec.requests
               for t in r["prompt"])
    # the record is genuinely portable
    json.dumps(rec.to_json())
    st = engine_settings(eng)
    assert st["spec_k"] == 3 and st["ragged"] is True


def test_capture_requires_record_mode():
    eng = _engine(record=False, spec_k=0, max_model_len=32)
    with pytest.raises(ValueError, match="record=True"):
        capture_workload(eng, [])
    with pytest.raises(ValueError, match="record=True"):
        replay_workload(
            WorkloadRecord(RECORD_VERSION, "x", {}, [], {}, [], {}, {}),
            eng)


def test_replay_same_engine_is_exact(captured):
    eng, rec, _ = captured
    res = replay_workload(rec, eng)            # reset + rerun in place
    assert isinstance(res, ReplayResult)
    assert res.token_identical and res.mismatched_rids == []
    assert res.decision_diff == []
    assert res.fingerprint_match
    assert res.ok


def test_replay_fresh_engine_after_json_round_trip(captured):
    _, rec, src_chrome = captured
    rec2 = WorkloadRecord.from_json(
        json.loads(json.dumps(rec.to_json())))
    fresh = _engine()
    res = replay_workload(rec2, fresh)
    assert res.ok and res.fingerprint_match
    assert res.outputs == rec.outputs
    # satellite: the REPLAYED run's trace validates identically to its
    # source capture — same verdict (clean) and same span population
    replayed_chrome = fresh.tracer.to_chrome()
    assert validate_chrome_trace(src_chrome) == []
    assert validate_chrome_trace(replayed_chrome) == []
    assert {e["name"] for e in src_chrome["traceEvents"]} == \
        {e["name"] for e in replayed_chrome["traceEvents"]}
    # virtual clock: replayed request timelines land on the SAME times
    # (the record rounds to 9 places in _canon)
    assert fresh.tracer.timelines[0].done == \
        pytest.approx(rec.timelines[0]["done"], abs=1e-9)


def test_replay_cross_config_diffs_but_keeps_greedy_tokens(captured):
    _, rec, _ = captured
    legacy = _engine(ragged=False)
    res = replay_workload(rec, legacy)
    assert not res.fingerprint_match           # config divergence seen
    assert res.token_identical                 # greedy fp32 parity
    assert res.decision_diff != []             # scheduling diverged
    assert not res.ok
    with pytest.raises(ValueError, match="fingerprint"):
        replay_workload(rec, legacy, strict_fingerprint=True)


def test_fingerprint_tracks_every_engine_knob(captured):
    eng, rec, _ = captured
    assert engine_fingerprint(eng) == rec.fingerprint
    for kw in (dict(spec_k=0), dict(n_slots=4), dict(block_size=16),
               dict(prefix_cache=False), dict(virtual_dt=2e-3)):
        other = _engine(**kw)
        assert engine_fingerprint(other) != rec.fingerprint, kw
