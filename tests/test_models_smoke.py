"""Per-architecture smoke tests (deliverable f): reduced configs, one
forward + one train step on CPU, shape and finiteness asserts, plus
prefill->decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.qmodel import QuantContext, QuantMode
from repro.models import model as M
from repro.optim import adamw

LM_ARCHS = [a for a in ARCH_IDS if a != "resnet_paper"]
CTX = QuantContext(mode=QuantMode.FP)


def _batch(cfg, b=2, s=64, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, s)), jnp.int32)
    batch = {"tokens": toks, "labels": toks,
             "mask": jnp.ones((b, s), jnp.float32)}
    if cfg.family == "audio":
        batch["encoder_features"] = jnp.asarray(
            rng.normal(size=(b, cfg.encdec.encoder_seq, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, _ = M.forward(params, batch, cfg, CTX)
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw()
    state = opt.init(params)
    batch = _batch(cfg)

    @jax.jit
    def step(p, s):
        (loss, _), g = jax.value_and_grad(
            lambda pp: M.loss_fn(pp, batch, cfg, CTX, remat=False),
            has_aux=True)(p)
        p2, s2 = opt.update(g, s, p, 1e-3)
        return p2, s2, loss

    p2, s2, loss = step(params, state)
    assert bool(jnp.isfinite(loss))
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "deepseek_v3_671b",
                                  "whisper_large_v3", "rwkv6_3b",
                                  "zamba2_2_7b", "granite_moe_3b_a800m"])
def test_prefill_decode_consistency(arch):
    """decode(t | prefill(0..t-1)) == forward(0..t)[-1] (fp32 exact)."""
    cfg = get_smoke_config(arch).scaled(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    batch = _batch(cfg)
    batch["tokens"] = toks
    logits_full, _ = M.forward(params, batch, cfg, CTX)
    pre = dict(batch)
    pre["tokens"] = toks[:, :s - 1]
    _, cache = M.prefill(params, pre, cfg, CTX, max_seq=s)
    logits_dec, _ = M.decode_step(params, toks[:, s - 1:], cache,
                                  jnp.asarray(s - 1), cfg, CTX)
    ref = logits_full[:, -1]
    rel = float(jnp.max(jnp.abs(logits_dec - ref)) /
                (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 1e-3


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "granite_moe_3b_a800m"])
def test_quant_modes_run_and_track_fp(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    out_fp, _ = M.forward(params, batch, cfg, CTX)
    # MoE top-k routing is discontinuous: 8-bit perturbations flip expert
    # choices on RANDOM weights, so correlation is intrinsically lower there
    # (trained models are far more stable — see test_system).
    floor = 0.6 if cfg.moe is not None else 0.8
    for mode in (QuantMode.FAKE, QuantMode.INT):
        ctx = QuantContext(mode=mode)
        out_q, _ = M.forward(params, batch, cfg, ctx)
        assert bool(jnp.all(jnp.isfinite(out_q.astype(jnp.float32))))
        # quantized logits correlate with fp logits
        a = np.asarray(out_fp.astype(jnp.float32)).ravel()
        bq = np.asarray(out_q.astype(jnp.float32)).ravel()
        corr = np.corrcoef(a, bq)[0, 1]
        assert corr > floor, f"{mode}: corr {corr}"
