"""SLO objectives + burn-rate monitoring (DESIGN §15).

Pure-python half: objective validation, the burn-rate arithmetic
(``burn = (bad/total)/budget_frac``), rolling-window trimming,
min-samples gating, fire/clear transitions and their tracer events,
gauge objectives through a ``value_fn``.  Engine half: a record-mode
(virtual clock) run with impossibly tight objectives must fire
deterministically, surface in the report's ``slo`` section, match the
golden schema with ``slo=True``, and reset cleanly.
"""
import dataclasses

import numpy as np
import pytest

from repro.obs.slo import (REQUEST_METRICS, SLObjective, SLOMonitor,
                           default_slos)
from repro.obs.trace import Tracer


def obj(**kw):
    base = dict(name="o", metric="ttft", target=1.0, budget_frac=0.25,
                window_s=10.0, burn_threshold=1.0, min_samples=1)
    base.update(kw)
    return SLObjective(**base)


# ---------------------------------------------------------------------------
# objectives
# ---------------------------------------------------------------------------

def test_objective_validation():
    with pytest.raises(ValueError):
        obj(name="")
    with pytest.raises(ValueError):
        obj(budget_frac=0.0)
    with pytest.raises(ValueError):
        obj(budget_frac=1.5)
    with pytest.raises(ValueError):
        obj(window_s=0.0)
    with pytest.raises(ValueError):
        obj(min_samples=0)
    assert obj(metric="ttft").kind == "request"
    assert obj(metric="e2e").kind == "request"
    assert obj(metric="pool.utilization").kind == "gauge"
    assert set(REQUEST_METRICS) == {"ttft", "tpot", "e2e"}


def test_default_slos_composition():
    objs = {o.name: o for o in default_slos()}
    assert set(objs) == {"ttft", "e2e", "pool_pressure"}
    assert objs["pool_pressure"].metric == "pool.utilization"
    objs = {o.name: o for o in default_slos(
        ttft_s=None, e2e_s=None, pool_utilization=None,
        tpot_s=0.01, energy_uj_per_token=200.0)}
    assert set(objs) == {"tpot", "energy_per_token"}
    assert objs["energy_per_token"].metric == "energy.proxy_uj_per_token"
    with pytest.raises(ValueError):
        SLOMonitor([obj(), obj()])             # duplicate names


# ---------------------------------------------------------------------------
# burn-rate arithmetic + windows
# ---------------------------------------------------------------------------

def test_burn_rate_math():
    mon = SLOMonitor([obj(budget_frac=0.25)])
    burn, total, bad = mon.burn_rate("o", 0.0)
    assert (burn, total, bad) == (None, 0, 0)
    for i in range(8):
        mon.observe("o", float(i), 0.5 if i % 4 else 2.0)  # 2 of 8 bad
    burn, total, bad = mon.burn_rate("o", 7.0)
    assert (total, bad) == (8, 2)
    assert burn == pytest.approx((2 / 8) / 0.25)           # == 1.0


def test_window_trims_old_observations():
    mon = SLOMonitor([obj(window_s=5.0)])
    mon.observe("o", 0.0, 99.0)                # bad, will age out
    for t in (4.0, 6.0, 8.0):
        mon.observe("o", t, 0.1)
    burn, total, bad = mon.burn_rate("o", 8.0)
    assert total == 3 and bad == 0 and burn == 0.0
    # advancing `now` alone trims too (burn_rate re-trims at read time)
    burn, total, _ = mon.burn_rate("o", 11.0)   # cutoff 6.0 keeps 6,8
    assert total == 2


def test_min_samples_gates_firing():
    mon = SLOMonitor([obj(min_samples=3)])
    mon.observe("o", 0.0, 9.0)                 # 100% bad, burn 4.0
    mon.evaluate(0.0)
    assert mon.alerts_fired == 0               # only 1 sample
    mon.observe("o", 0.1, 9.0)
    mon.observe("o", 0.2, 9.0)
    mon.evaluate(0.2)
    assert mon.alerts_fired == 1 and mon.alerts_active == 1


def test_fire_and_clear_emit_tracer_events():
    tr = Tracer(capacity=64, clock=lambda: 0.0, enabled=True)
    mon = SLOMonitor([obj(window_s=2.0)], tracer=tr)
    mon.observe("o", 0.0, 9.0)                 # violation
    mon.evaluate(0.0)
    assert mon.alerts_fired == 1 and mon.alerts_active == 1
    alert = mon.alerts[-1]
    assert alert["objective"] == "o" and alert["burn_rate"] == 4.0
    assert alert["window_total"] == 1 and alert["window_bad"] == 1
    mon.evaluate(0.5)                          # still firing: no re-fire
    assert mon.alerts_fired == 1
    mon.evaluate(5.0)                          # window empties -> clears
    assert mon.alerts_active == 0
    names = [name for (_ph, name, *_r) in tr.events]
    assert names.count("slo.alert") == 1
    assert names.count("slo.recover") == 1
    assert mon.worst_burn_rate() is None       # empty window: no burn
    st = mon.status()["o"]
    assert st["firing"] is False and st["window_total"] == 0


def test_request_objectives_ingest_from_timelines_once():
    tr = Tracer(capacity=8, enabled=False)     # timelines are always on
    mon = SLOMonitor(
        [obj(name="ttft", metric="ttft", target=0.05),
         obj(name="e2e", metric="e2e", target=10.0)], tracer=tr)
    tr.req_submit(0, arrival=0.0)
    tr.req_mark(0, "first_token", 0.2)         # TTFT 0.2 > 0.05: bad
    tr.req_done(0, 0.3, n_generated=2)
    tr.req_submit(1, arrival=0.0)              # never completes
    mon.evaluate(0.3)
    assert mon.burn_rate("ttft", 0.3)[1:] == (1, 1)
    assert mon.burn_rate("e2e", 0.3)[1:] == (1, 0)
    mon.evaluate(0.4)                          # done rids ingest ONCE
    assert mon.burn_rate("ttft", 0.4)[1] == 1
    assert mon.alerts_active == 1              # ttft firing, e2e not
    assert mon.status()["ttft"]["firing"] is True


def test_gauge_objectives_read_value_fn():
    vals = {"pool.utilization": 0.99}
    mon = SLOMonitor(
        [obj(name="pool", metric="pool.utilization", target=0.9),
         obj(name="missing", metric="not.registered", target=1.0),
         obj(name="undefined", metric="late.metric", target=1.0)],
        value_fn=lambda n: ({"late.metric": None} | vals)[n])
    mon.evaluate(1.0)
    assert mon.burn_rate("pool", 1.0)[1:] == (1, 1)
    # KeyError (unregistered) and None (not yet defined) both skip
    assert mon.burn_rate("missing", 1.0)[1] == 0
    assert mon.burn_rate("undefined", 1.0)[1] == 0
    vals["pool.utilization"] = 0.5
    mon.evaluate(2.0)
    assert mon.burn_rate("pool", 2.0)[1:] == (2, 1)
    mon.reset()
    assert mon.evaluations == 0 and mon.alerts_fired == 0
    assert mon.burn_rate("pool", 2.0)[1] == 0


# ---------------------------------------------------------------------------
# engine integration (virtual clock => deterministic alerting)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def slo_run():
    import jax
    from repro.configs import get_smoke_config
    from repro.core.qmodel import QuantContext, QuantMode
    from repro.models import model as M
    from repro.serving import Request, ServingEngine

    cfg = dataclasses.replace(
        get_smoke_config("qwen3_1_7b").scaled(dtype="float32"),
        kv_cache_bits=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tight = [SLObjective(name="ttft", metric="ttft", target=1e-6,
                         window_s=1.0),
             SLObjective(name="pool", metric="pool.utilization",
                         target=2.0, window_s=1.0)]   # never violated
    eng = ServingEngine(cfg, params, QuantContext(mode=QuantMode.FP),
                        n_slots=2, block_size=8, max_model_len=32,
                        record=True, slo=tight)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=6).astype(np.int32),
                    max_new_tokens=3, arrival=i * 0.001)
            for i in range(3)]
    rep = eng.run(reqs)
    return eng, rep


def test_engine_fires_deterministic_alert(slo_run):
    eng, rep = slo_run
    assert eng.slo is not None
    assert rep["slo"]["alerts_fired"] == 1          # ttft only
    assert rep["slo"]["alerts_active"] == 1
    assert rep["slo"]["worst_burn_rate"] >= 1.0
    assert rep["slo"]["evaluations"] == eng.slo.evaluations > 0
    st = rep["slo"]["status"]
    assert st["ttft"]["firing"] is True
    assert st["pool"]["firing"] is False and st["pool"]["window_bad"] == 0
    # the alert is traced on the slo lane with its structured payload
    alerts = [(name, args) for (_ph, name, _cat, _ts, _dur, args)
              in eng.tracer.events if name == "slo.alert"]
    assert len(alerts) == 1
    assert alerts[0][1]["objective"] == "ttft"


def test_engine_slo_matches_golden_schema(slo_run):
    from repro.obs.schema import diff_schema, schema_of
    eng, _ = slo_run
    errs = diff_schema(schema_of(eng.metrics), spec=False, slo=True)
    assert errs == [], "\n".join(errs)
    # and the default (slo=False) diff flags the extra section, so
    # existing engines without a monitor stay contract-clean
    assert any("slo." in e
               for e in diff_schema(schema_of(eng.metrics), spec=False))


def test_engine_slo_true_uses_default_objectives():
    import jax
    from repro.configs import get_smoke_config
    from repro.core.qmodel import QuantContext, QuantMode
    from repro.models import model as M
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(
        get_smoke_config("qwen3_1_7b").scaled(dtype="float32"),
        kv_cache_bits=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, QuantContext(mode=QuantMode.FP),
                        n_slots=2, block_size=8, max_model_len=32,
                        slo=True)
    assert set(eng.slo.objectives) == \
        {o.name for o in default_slos()}
    assert eng.report()["slo"]["alerts_fired"] == 0


def test_reset_clears_slo_state(slo_run):
    eng, _ = slo_run
    assert eng.slo.alerts_fired > 0
    eng.reset_metrics()
    assert eng.slo.alerts_fired == 0 and eng.slo.alerts_active == 0
    assert eng.slo.evaluations == 0
    assert eng.report()["slo"]["worst_burn_rate"] is None
