"""Fused flash-attention kernel vs the pure-JAX chunked oracle.

Kernels run in Pallas interpret mode on CPU — the kernel BODY executes
(tiling, online-softmax corrections, in-register int8 dequant, causal /
padding masks), which is what these tests validate; MXU lowering is the
TPU target.  ``chunked_attention`` stays the reference (DESIGN.md §2).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qscheme import dequant, quant
from repro.kernels import ops
from repro.models.attention import _repeat_kv, chunked_attention

NKV = 4  # Eq.-1 fractional bits for the int8 KV grid


def _mk(shape, seed, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), dtype)


def _make_qkv(seed, b, sq, skv, h, kvh, dk, dv, int8_kv):
    q = _mk((b, sq, h, dk), seed)
    kf = _mk((b, skv, kvh, dk), seed + 1)
    vf = _mk((b, skv, kvh, dv), seed + 2)
    if int8_kv:
        k, v = quant(kf, NKV, 8), quant(vf, NKV, 8)
        # the oracle sees the same values the kernel decodes — parity is
        # then exact up to fp reassociation, not quantization error
        kf, vf = dequant(k, NKV), dequant(v, NKV)
    else:
        k, v = kf, vf
    return q, k, v, kf, vf


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("groups", [1, 4])
@pytest.mark.parametrize("int8_kv", [False, True])
def test_flash_prefill_parity(causal, groups, int8_kv):
    b, sq, h, dk, dv = 2, 256, 4, 64, 64
    kvh = h // groups
    q, k, v, kf, vf = _make_qkv(7, b, sq, sq, h, kvh, dk, dv, int8_kv)
    out = ops.flash_attention(q, k, v, causal=causal,
                              kv_frac_bits=NKV if int8_kv else None)
    ref = chunked_attention(q, _repeat_kv(kf, groups), _repeat_kv(vf, groups),
                            causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("sq,skv", [(200, 333), (130, 513)])
def test_flash_prefill_ragged_lengths(sq, skv):
    """Non-multiple-of-block sequence lengths: wrapper pads, kernel masks."""
    b, h, kvh, dk, dv = 1, 4, 2, 64, 64
    q, k, v, kf, vf = _make_qkv(11, b, sq, skv, h, kvh, dk, dv, True)
    out = ops.flash_attention(q, k, v, causal=False, kv_frac_bits=NKV)
    ref = chunked_attention(q, _repeat_kv(kf, 2), _repeat_kv(vf, 2),
                            causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_prefill_nonsquare_head_dims():
    """MLA-style dk != dv and non-lane-multiple dk (padded inside)."""
    b, s, h = 1, 256, 2
    q, k, v, kf, vf = _make_qkv(13, b, s, s, h, h, 80, 64, False)
    out = ops.flash_attention(q, k, v, causal=True, scale=0.11)
    ref = chunked_attention(q, kf, vf, causal=True, scale=0.11)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("groups", [1, 4])
@pytest.mark.parametrize("int8_kv", [False, True])
def test_flash_decode_parity(groups, int8_kv):
    """q_len = 1 over a fixed-size cache, masked at a traced position.

    dk = dv = 128: the decode wrapper falls back to the chunked oracle for
    non-lane-multiple head dims (padding would copy the whole cache), so
    smaller dims here would compare the oracle against itself and never
    execute the kernel body.
    """
    b, s_max, h, dk, dv = 2, 256, 4, 128, 128
    kvh = h // groups
    q, k, v, kf, vf = _make_qkv(17, b, 1, s_max, h, kvh, dk, dv, int8_kv)
    for pos in (0, 100, s_max - 1):
        pos_t = jnp.asarray(pos, jnp.int32)   # traced like a decode step
        out = jax.jit(
            lambda q_, k_, v_, p: ops.flash_decode(
                q_, k_, v_, pos=p, kv_frac_bits=NKV if int8_kv else None)
        )(q, k, v, pos_t)
        ref = chunked_attention(q, _repeat_kv(kf, groups),
                                _repeat_kv(vf, groups), causal=True,
                                q_offset=pos_t)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5, err_msg=f"pos={pos}")


def test_flash_prefill_q_offset():
    """Chunked prefill continuation: q block at a nonzero static offset."""
    b, h, dk = 1, 2, 64
    skv, sq, off = 384, 128, 200
    q, k, v, kf, vf = _make_qkv(19, b, sq, skv, h, h, dk, dk, True)
    out = ops.flash_attention(q, k, v, causal=True, q_offset=off,
                              kv_frac_bits=NKV)
    ref = chunked_attention(q, kf, vf, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_prefill_grad_matches_reference():
    """The custom VJP recomputes the backward through the chunked oracle —
    gradients must match differentiating the oracle directly."""
    b, s, h, dk = 1, 128, 2, 64
    q, k, v, kf, vf = _make_qkv(29, b, s, s, h, h, dk, dk, False)

    def loss_flash(q_, k_, v_):
        return jnp.sum(ops.flash_attention(q_, k_, v_, causal=True) ** 2)

    def loss_ref(q_, k_, v_):
        return jnp.sum(chunked_attention(q_, k_, v_, causal=True) ** 2)

    g_fl = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_fl, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=5e-4, rtol=5e-4)


def test_flash_small_shapes_fall_back():
    """Shapes below the launch threshold route to the chunked reference."""
    q, k, v, kf, vf = _make_qkv(23, 1, 8, 64, 2, 2, 64, 64, True)
    out = ops.flash_attention(q, k, v, causal=True, kv_frac_bits=NKV)
    ref = chunked_attention(q, kf, vf, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_decode_head_dim_fallback():
    """Non-lane-multiple head dims take the dequant+chunked fallback (the
    kernel would otherwise copy the padded cache every step)."""
    q, k, v, kf, vf = _make_qkv(31, 1, 1, 256, 4, 2, 64, 64, True)
    pos = jnp.asarray(200, jnp.int32)
    out = ops.flash_decode(q, k, v, pos=pos, kv_frac_bits=NKV)
    ref = chunked_attention(q, _repeat_kv(kf, 2), _repeat_kv(vf, 2),
                            causal=True, q_offset=pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_int8_requires_frac_bits():
    """int8 codes without their fractional bit is a silent 2^N scale error —
    must raise instead."""
    q, k, v, _, _ = _make_qkv(37, 1, 128, 256, 2, 2, 128, 128, True)
    with pytest.raises(ValueError, match="kv_frac_bits"):
        ops.flash_attention(q, k, v, causal=True)
    with pytest.raises(ValueError, match="kv_frac_bits"):
        ops.flash_decode(q[:, :1], k, v, pos=jnp.asarray(5, jnp.int32))


def test_flash_end_to_end_int8_cache_decode():
    """Model-level: attn_kernel='flash' + int8 cache matches the chunked
    dequantize-then-attend path on the same weights.

    head_dim=128 and max_seq=128 so BOTH fused kernels genuinely launch
    (prefill: sq=120 >= 16, skv=128; decode: dk % 128 == 0, cache length
    with a tile divisor) — smaller smoke dims would silently compare the
    fallback against itself.
    """
    from repro.configs import get_smoke_config
    from repro.core.qmodel import QuantContext, QuantMode
    from repro.models import model as M
    ctx = QuantContext(mode=QuantMode.FP)
    cfg8 = dataclasses.replace(
        get_smoke_config("qwen3_1_7b").scaled(dtype="float32",
                                              head_dim=128),
        kv_cache_bits=8)
    cfg8f = dataclasses.replace(cfg8, attn_kernel="flash")
    params = M.init_params(cfg8, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 121), 0,
                              cfg8.vocab_size)
    pre = {"tokens": toks[:, :120]}
    _, cache = M.prefill(params, pre, cfg8, ctx, max_seq=128)
    _, cache_f = M.prefill(params, pre, cfg8f, ctx, max_seq=128)
    assert cache_f["kv"].k.dtype == jnp.int8
    l_ref, _ = M.decode_step(params, toks[:, 120:], cache, jnp.asarray(120),
                             cfg8, ctx)
    l_fl, _ = M.decode_step(params, toks[:, 120:], cache_f, jnp.asarray(120),
                            cfg8f, ctx)
    rel = float(jnp.linalg.norm(l_fl - l_ref) / jnp.linalg.norm(l_ref))
    assert rel < 1e-4, rel


def test_fused_kv_bytes_at_8k():
    """Acceptance: at S=8k the fused int8-KV path moves >= 3x fewer KV bytes
    than dequantize-then-attend (analytic HBM bytes model)."""
    s, kvh, dk, dv = 8192, 8, 128, 128
    fused = ops.attention_kv_bytes(s, kvh, dk, dv, kv_bits=8, fused=True)
    deq = ops.attention_kv_bytes(s, kvh, dk, dv, kv_bits=8, fused=False,
                                 groups=1)
    assert deq >= 3 * fused, (fused, deq)
    # and the ratio only grows once the fallback's groups-x repeat lands
    deq_g = ops.attention_kv_bytes(s, kvh, dk, dv, kv_bits=8, fused=False,
                                   groups=4)
    assert deq_g > deq
