"""Speculative decoding over the write-once int8-KV pool (DESIGN §11).

The load-bearing guarantee, held end-to-end: GREEDY speculative decode is
TOKEN-IDENTICAL to the non-speculative engine on the same pool/workload —
including through recompute preemption and prefix-cache sharing — and a
rejected draft's KV rows never publish to the prefix cache (commit covers
only accepted tokens; ``BlockPool.retract`` frees the rejected tail).
Plus: drafter units, the fused verifier's acceptance semantics, seed
reproducibility with sampling on, the ISSUE-5 top-k tie regression, and
the prefill zero-progress guard.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.qmodel import QuantContext, QuantMode
from repro.models import model as M
from repro.serving import CallableDrafter, NgramDrafter, Request, \
    ServingEngine
from repro.serving.engine import sample_tokens
from repro.serving.spec import apply_top_k, resolve_drafter, verify_tokens

CTX = QuantContext(mode=QuantMode.FP)


def _cfg(**kw):
    cfg = get_smoke_config("qwen3_1_7b").scaled(dtype="float32")
    return dataclasses.replace(cfg, kv_cache_bits=8, **kw)


def _params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _workload(rng, n, vocab, *, p_lo=5, p_hi=20, g_lo=4, g_hi=14):
    return [Request(
        rid=i, prompt=rng.integers(0, vocab, size=int(
            rng.integers(p_lo, p_hi))).astype(np.int32),
        max_new_tokens=int(rng.integers(g_lo, g_hi))) for i in range(n)]


def _outputs_equal(a: dict, b: dict):
    assert a.keys() == b.keys()
    for rid in a:
        assert np.array_equal(a[rid], b[rid]), \
            f"req {rid}: {a[rid].tolist()} vs {b[rid].tolist()}"


# -- drafters ---------------------------------------------------------------

def test_ngram_drafter_prompt_lookup():
    d = NgramDrafter(max_ngram=3, min_ngram=1)
    # history ends in [7, 8]; the earlier [7, 8] was followed by [9, 1, 2]
    hist = np.asarray([7, 8, 9, 1, 2, 7, 8], np.int32)
    assert d.draft(hist, 3).tolist() == [9, 1, 2]
    # a longer ask keeps following the matched continuation (which here
    # walks back into the repeated suffix itself)
    assert d.draft(hist, 5).tolist() == [9, 1, 2, 7, 8]
    # most RECENT occurrence wins: the later [5] is followed by 6
    hist = np.asarray([5, 4, 5, 6, 5], np.int32)
    assert d.draft(hist, 1).tolist() == [6]
    # no recurring suffix -> no draft
    assert d.draft(np.asarray([1, 2, 3], np.int32), 4).size == 0
    assert d.draft(np.asarray([1], np.int32), 4).size == 0
    assert d.draft(hist, 0).size == 0


def test_ngram_drafter_prefers_longest_ngram():
    # suffix [2, 3]: the 2-gram match (followed by 9) must beat the more
    # recent 1-gram match of [3] (followed by 7)
    hist = np.asarray([2, 3, 9, 3, 7, 2, 3], np.int32)
    assert NgramDrafter(max_ngram=3).draft(hist, 1).tolist() == [9]
    assert NgramDrafter(max_ngram=1).draft(hist, 1).tolist() == [7]


def test_resolve_drafter():
    assert isinstance(resolve_drafter("ngram"), NgramDrafter)
    hook = CallableDrafter(lambda h, k: [1, 2, 3, 4])
    assert resolve_drafter(hook) is hook
    assert hook.draft([0], 2).tolist() == [1, 2]
    with pytest.raises(ValueError, match="unknown drafter"):
        resolve_drafter("beam")
    with pytest.raises(TypeError, match="draft"):
        resolve_drafter(object())


# -- fused verifier ---------------------------------------------------------

def _logits_for(chain, v=16, peak=8.0):
    """(len(chain), V) logits whose argmax at position j is chain[j]."""
    out = np.zeros((len(chain), v), np.float32)
    for j, t in enumerate(chain):
        out[j, t] = peak
    return out


def test_verify_tokens_greedy_accepts_matching_prefix():
    v = 16
    # target chain after each fed token: 3, 5, 7, 2, 9
    logits = jnp.asarray(_logits_for([3, 5, 7, 2, 9], v))[None]
    key = jax.random.PRNGKey(0)
    temps = jnp.zeros((1,))
    # drafts [3, 5, 1, 4]: first two match, 1 != 7 -> n_acc = 2, the
    # correction is the argmax at the mismatch position (7)
    tokens = jnp.asarray([[0, 3, 5, 1, 4]], jnp.int32)
    out, n_acc = verify_tokens(logits, tokens, jnp.asarray([4]), key, temps)
    assert int(n_acc[0]) == 2
    assert out[0, :3].tolist() == [3, 5, 7]
    # all four accepted -> bonus token from the last position (9)
    tokens = jnp.asarray([[0, 3, 5, 7, 2]], jnp.int32)
    out, n_acc = verify_tokens(logits, tokens, jnp.asarray([4]), key, temps)
    assert int(n_acc[0]) == 4
    assert out[0, :5].tolist() == [3, 5, 7, 2, 9]
    # immediate mismatch -> plain-decode behavior (1 emitted)
    tokens = jnp.asarray([[0, 1, 5, 7, 2]], jnp.int32)
    out, n_acc = verify_tokens(logits, tokens, jnp.asarray([4]), key, temps)
    assert int(n_acc[0]) == 0 and out[0, 0] == 3
    # n_drafts caps acceptance even when later drafts would match
    tokens = jnp.asarray([[0, 3, 5, 7, 2]], jnp.int32)
    out, n_acc = verify_tokens(logits, tokens, jnp.asarray([1]), key, temps)
    assert int(n_acc[0]) == 1 and out[0, :2].tolist() == [3, 5]


def test_verify_tokens_sampling_rejects_outside_support():
    """With temperature on, a draft with ~zero target probability must be
    rejected and the resample must come from the remaining support."""
    v = 8
    logits = np.full((1, 3, v), -30.0, np.float32)
    logits[0, :, 2] = 5.0                   # nearly all mass on token 2
    logits[0, :, 3] = 4.0                   # the rest on token 3
    temps = jnp.ones((1,))
    for seed in range(8):
        out, n_acc = verify_tokens(
            jnp.asarray(logits), jnp.asarray([[0, 6, 6]], jnp.int32),
            jnp.asarray([2]), jax.random.PRNGKey(seed), temps)
        assert int(n_acc[0]) == 0           # p(6) ~ 0 -> rejected
        assert int(out[0, 0]) in (2, 3)     # residual: support minus draft
    # a draft ON the dominant token is accepted almost surely
    acc = [int(verify_tokens(
        jnp.asarray(logits), jnp.asarray([[0, 2, 2]], jnp.int32),
        jnp.asarray([2]), jax.random.PRNGKey(s), temps)[1][0])
        for s in range(8)]
    assert np.mean(acc) > 1.5


# -- sampler regression (ISSUE 5 satellite) ---------------------------------

def test_top_k_tie_semantics_exactly_k():
    """Tied logits at the top-k threshold: the candidate set must hold
    EXACTLY k tokens (the old ``logits < kth`` comparison kept every tied
    token, so k=2 over [1, 1, 1, 0] sampled from three candidates)."""
    logits = jnp.asarray([[1.0, 1.0, 1.0, 0.0]])
    masked = apply_top_k(logits, jnp.asarray([2]), k_cap=2)
    assert int(jnp.sum(jnp.isfinite(masked))) == 2
    seen = set()
    for s in range(40):
        tok = sample_tokens(logits, jax.random.PRNGKey(s),
                            jnp.asarray([1.0]), top_k=jnp.asarray([2]),
                            k_cap=2)
        seen.add(int(tok[0]))
    assert seen == {0, 1}                   # lowest-index ties win
    # k_cap=None (direct callers) still enforces exactly-k
    masked = apply_top_k(logits, jnp.asarray([2]))
    assert int(jnp.sum(jnp.isfinite(masked))) == 2


def test_top_k_zero_keeps_full_vocab_and_greedy_rows_unaffected():
    logits = jnp.asarray([[0.3, 0.1, 0.9, 0.2], [5.0, 1.0, 0.0, 0.0]])
    masked = apply_top_k(logits, jnp.asarray([0, 1]), k_cap=1)
    assert bool(jnp.all(jnp.isfinite(masked[0])))
    assert int(jnp.sum(jnp.isfinite(masked[1]))) == 1
    tok = sample_tokens(logits, jax.random.PRNGKey(0),
                        jnp.asarray([0.0, 0.0]), top_k=jnp.asarray([0, 1]),
                        k_cap=1)
    assert tok.tolist() == [2, 0]           # greedy rows ignore the mask


# -- engine end-to-end: the token-identity guarantee ------------------------

def test_spec_greedy_token_identical_to_plain_engine():
    cfg = _cfg()
    params = _params(cfg)
    mk = lambda: _workload(np.random.default_rng(0), 6, cfg.vocab_size)
    plain = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                          max_model_len=48, chunk=8)
    plain.run(mk())
    spec = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                         max_model_len=48, chunk=8, spec_k=4)
    rep = spec.run(mk())
    _outputs_equal(plain.outputs(), spec.outputs())
    spec.pool.check_invariants()
    assert spec.pool.n_live == 0
    s = rep["speculative"]
    assert s["verify_steps"] > 0 and s["drafted_tokens"] > 0
    assert s["emitted_tokens"] > 0
    # wasted ops = whole rejected rows, never more than what was drafted
    elems = cfg.n_layers * cfg.n_kv_heads * cfg.resolved_head_dim * 2
    assert s["requant_ops_wasted"] % elems == 0
    assert s["requant_ops_wasted"] <= s["drafted_tokens"] * elems
    # rejected-draft accounting is visible and consistent
    assert rep["hwcost"]["requant_ops_wasted_speculation"] == \
        s["requant_ops_wasted"]
    assert s["requant_ops_wasted"] <= rep["hwcost"]["requant_ops_performed"]


def test_spec_oracle_drafter_accepts_everything():
    """A CallableDrafter that proposes the plain engine's own future
    tokens must be accepted wholesale: acceptance rate 1.0, tokens/step
    > 1, and STILL token-identical output."""
    cfg = _cfg()
    params = _params(cfg)
    prompt = np.random.default_rng(7).integers(
        0, cfg.vocab_size, size=9).astype(np.int32)
    mk = lambda: [Request(rid=0, prompt=prompt.copy(), max_new_tokens=16)]
    plain = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                          max_model_len=32, chunk=8)
    plain.run(mk())
    future = plain.outputs()[0]

    def oracle(history, k):
        n_gen = len(history) - len(prompt)
        return future[n_gen:n_gen + k]

    spec = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                         max_model_len=32, chunk=8, spec_k=4,
                         drafter=CallableDrafter(oracle))
    rep = spec.run(mk())
    _outputs_equal(plain.outputs(), spec.outputs())
    s = rep["speculative"]
    assert s["acceptance_rate"] == 1.0
    assert s["tokens_per_step"] > 2.0
    # one request, all drafts accepted: far fewer steps than tokens
    assert rep["spec_steps"] + rep["decode_steps"] < len(future)


def test_spec_parity_through_preemption():
    """Undersized pool: speculation must survive mid-speculation
    preemption (uncommitted speculative rows die with the released
    blocks, committed published blocks survive for the resume) and still
    emit exactly the plain engine's tokens."""
    cfg = _cfg()
    params = _params(cfg)

    def mk():
        rng = np.random.default_rng(3)
        return [Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=14).astype(np.int32),
            max_new_tokens=12) for i in range(4)]

    w_plain, w_spec = mk(), mk()
    plain = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                          max_model_len=32, num_blocks=6, chunk=8)
    plain.run(w_plain)
    spec = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                         max_model_len=32, num_blocks=6, chunk=8, spec_k=4)
    rep = spec.run(w_spec)
    assert rep["completed"] == 4
    assert rep["preemptions"] > 0
    _outputs_equal(plain.outputs(), spec.outputs())
    spec.pool.check_invariants()
    assert spec.pool.n_live == 0


def test_spec_parity_with_prefix_sharing_and_no_rejected_publish():
    """Prefix-cache sharing + speculation: shared-prompt requests decode
    token-identically with spec on, and every published block's key
    re-derives from COMMITTED tokens only — a rejected draft never leaks
    into a content key."""
    cfg = _cfg()
    params = _params(cfg)
    rng = np.random.default_rng(21)
    shared = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)

    def mk():
        rng2 = np.random.default_rng(5)
        reqs = []
        for i in range(4):
            tail = rng2.integers(0, cfg.vocab_size, size=4).astype(np.int32)
            reqs.append(Request(
                rid=i, prompt=np.concatenate([shared, tail]),
                max_new_tokens=10, arrival=0.01 * i))
        return reqs

    w_plain, w_spec = mk(), mk()
    plain = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                          max_model_len=48, chunk=8)
    plain.run(w_plain)
    spec = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                         max_model_len=48, chunk=8, spec_k=4)
    rep = spec.run(w_spec)
    _outputs_equal(plain.outputs(), spec.outputs())
    assert rep["prefix_cache"]["hits"] > 0          # sharing happened
    spec.pool.check_invariants()
    # every surviving content key must re-derive from a chain of
    # COMMITTED token ids of some completed request: walk each request's
    # final (prompt + generated) stream and collect the reachable keys
    from repro.serving.prefix_cache import ROOT_KEY, block_key
    legal = set()
    for r in w_spec:
        toks = np.concatenate([r.prompt, spec.outputs()[r.rid]])
        parent = ROOT_KEY
        bs = spec.pool.block_size
        for b in range(len(toks) // bs):
            parent = block_key(parent, toks[b * bs:(b + 1) * bs],
                               spec.pool.default_scale_exp)
            legal.add(parent)
    cache = spec.pool.cache
    for blk in range(spec.pool.num_blocks):
        key = cache.key_of(blk)
        assert key is None or key in legal, \
            f"block {blk} published under a key not derivable from any " \
            f"committed token stream (speculative leak)"


def test_spec_seed_reproducible_with_sampling():
    """Same seed + workload -> identical tokens across passes, with
    speculation on and off (each mode is its own deterministic stream)."""
    cfg = _cfg()
    params = _params(cfg)
    mk = lambda s: [Request(
        rid=i, prompt=np.random.default_rng(s + i).integers(
            0, cfg.vocab_size, size=8).astype(np.int32),
        max_new_tokens=10, temperature=0.8) for i in range(3)]
    for spec_k in (0, 3):
        eng = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                            max_model_len=32, chunk=8, seed=11,
                            spec_k=spec_k)
        eng.run(mk(0))
        first = eng.outputs()
        eng.reset_metrics()
        eng.run(mk(0))
        _outputs_equal(first, eng.outputs())


def test_spec_with_stop_token_discards_overshoot():
    """A stop token accepted mid-chunk must finish the request and drop
    the rest of the verified chunk — never emit past the stop."""
    cfg = _cfg()
    params = _params(cfg)
    prompt = np.random.default_rng(2).integers(
        0, cfg.vocab_size, size=8).astype(np.int32)
    plain = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                          max_model_len=48, chunk=8)
    plain.run([Request(rid=0, prompt=prompt.copy(), max_new_tokens=24)])
    ref = plain.outputs()[0]
    stop = int(ref[len(ref) // 2])          # a token the model WILL emit
    mk = lambda: [Request(rid=0, prompt=prompt.copy(), max_new_tokens=24,
                          stop_token=stop)]
    plain2 = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                           max_model_len=48, chunk=8)
    plain2.run(mk())
    spec = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                         max_model_len=48, chunk=8, spec_k=4)
    spec.run(mk())
    _outputs_equal(plain2.outputs(), spec.outputs())
    got = spec.outputs()[0]
    assert int(got[-1]) == stop and stop not in got[:-1]


# -- prefill zero-progress guard (ISSUE 5 satellite) ------------------------

def test_prefill_zero_progress_guard_raises():
    """If a prefill chunk reports zero progress twice without the
    CoW-failure preemption flipping the request's state, the engine must
    fail fast instead of spinning forever.  (Legacy-path guard: the
    ragged work-list planner takes one chunk per request per step, so
    its only zero-progress outcome IS the preemption that drops the
    item; there is no retry loop to wedge.)"""
    cfg = _cfg()
    params = _params(cfg)
    eng = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                        max_model_len=32, chunk=8, ragged=False)
    eng.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                       max_new_tokens=2))
    eng._prefill_chunk = lambda req, budget: 0      # broken contract
    with pytest.raises(RuntimeError, match="no progress"):
        eng.step()
