"""LM-side Algorithm 1: capture -> grid search -> quantized execution."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.lm_calibrate import calibrate_lm
from repro.core.qmodel import QuantContext, QuantMode
from repro.data import SyntheticLMStream
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3_2_1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    stream = SyntheticLMStream(cfg.vocab_size, 64, 8, seed=0)
    batch = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
    ctx_cal, report = calibrate_lm(
        lambda p, b, c: M.forward(p, b, cfg, c), params, batch)
    return cfg, params, batch, ctx_cal, report


def test_capture_covers_all_linear_modules(setup):
    cfg, params, batch, ctx_cal, report = setup
    names = set(report.results)
    assert {"attn/wq", "attn/wk", "attn/wv", "attn/wo",
            "mlp/w1", "mlp/w3", "mlp/w2", "lm_head"} <= names


def test_calibrated_beats_default_bits(setup):
    cfg, params, batch, ctx_cal, report = setup
    lf, _ = M.forward(params, batch, cfg, QuantContext(mode=QuantMode.FP))

    def agree(ctx):
        lq, _ = M.forward(params, batch, cfg, ctx)
        return float(np.mean(np.argmax(np.asarray(lf, np.float32), -1) ==
                             np.argmax(np.asarray(lq, np.float32), -1)))

    assert agree(ctx_cal) >= agree(QuantContext(mode=QuantMode.FAKE)) - 0.02
    assert agree(ctx_cal) > 0.85


def test_int_deploy_close_to_fake(setup):
    cfg, params, batch, ctx_cal, report = setup
    lq, _ = M.forward(params, batch, cfg, ctx_cal)
    li, _ = M.forward(params, batch, cfg,
                      dataclasses.replace(ctx_cal, mode=QuantMode.INT))
    agree = float(np.mean(np.argmax(np.asarray(lq, np.float32), -1) ==
                          np.argmax(np.asarray(li, np.float32), -1)))
    assert agree > 0.9


def test_rel_errors_reported(setup):
    cfg, params, batch, ctx_cal, report = setup
    rels = [r.rel_error for r in report.results.values()]
    assert all(np.isfinite(rels))
    assert float(np.median(rels)) < 0.2
