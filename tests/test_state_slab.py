"""Property tests for the fixed-slab recurrent-state substrate (§16).

Runs under real hypothesis when installed, else the deterministic sampled
fallback in ``_hyp_stub`` (seeded rng — failures reproduce).  Locked in
permanently:

* the slab-pool partition invariant — after ANY random interleaving of
  alloc / free / evict, {free} ∪ {live} exactly covers the non-trash
  slabs, every live sequence owns exactly one slab, and no refcount
  exceeds 1 (recurrent state is never shared);
* exhaustion is a clean refusal (``BlockPoolError`` + an
  ``alloc_failures`` count), never a corrupt handout;
* the growing-substrate verbs — extend / retract / COW — raise outright
  on slabs, mirroring the scheduler-level guards one layer down;
* the per-slab scale exponent is admission-time metadata: fixed from
  alloc to free, re-assignable only to a NEW owner of the slab;
* the Eq.-1 round trip on a po2 grid with fractional bit n reconstructs
  every in-range value to within half a step, ``2^-(n+1)`` — the bound
  the once-per-step whole-state requantization (and DESIGN §16's error
  story) leans on.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container lacks hypothesis
    from _hyp_stub import given, settings, st

from repro.core import qscheme as Q
from repro.serving import (BlockPoolError, StateSlabPool, TRASH_SLAB,
                           substrate_for)
from repro.configs import get_smoke_config


# -- pool lifecycle ---------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(num_slabs=st.integers(2, 12), seed=st.integers(0, 10_000))
def test_random_lifecycle_preserves_invariants(num_slabs, seed):
    """Random alloc/free/evict interleavings never break the partition."""
    rng = np.random.default_rng(seed)
    pool = StateSlabPool(num_slabs, scale_exp=4)
    live: dict[int, int] = {}           # seq -> slab (reference model)
    next_sid = 0
    for _ in range(60):
        op = rng.integers(3)
        if op == 0:                      # alloc
            sid, next_sid = next_sid, next_sid + 1
            if pool.n_free == 0:
                before = pool.stats.alloc_failures
                with pytest.raises(BlockPoolError):
                    pool.alloc_slab(sid)
                assert pool.stats.alloc_failures == before + 1
            else:
                slab = pool.alloc_slab(sid)
                assert slab != TRASH_SLAB
                assert slab not in live.values()
                live[sid] = slab
        elif live and op == 1:           # free
            sid = int(rng.choice(list(live)))
            del live[sid]
            assert pool.free_seq(sid) == 1
        elif live and op == 2:           # evict (preemption path)
            sid = int(rng.choice(list(live)))
            del live[sid]
            before = pool.stats.seq_evictions
            assert pool.evict(sid) == 1
            assert pool.stats.seq_evictions == before + 1
        pool.check_invariants()
        assert pool.n_live == len(live)
        assert {s: b[0] for s, b in pool._seqs.items()} == live
    # drain and verify the pool returns to pristine capacity
    for sid in list(live):
        pool.free_seq(sid)
    pool.check_invariants()
    assert pool.n_free == num_slabs - 1 and pool.n_live == 0


def test_double_ops_raise():
    pool = StateSlabPool(4)
    pool.alloc_slab(7)
    with pytest.raises(BlockPoolError):
        pool.alloc_slab(7)              # one slab per sequence, ever
    pool.free_seq(7)
    with pytest.raises(BlockPoolError):
        pool.free_seq(7)                # double free
    with pytest.raises(BlockPoolError):
        pool.slab_of(7)                 # unknown after free


def test_growing_substrate_verbs_raise_on_slabs():
    pool = StateSlabPool(4)
    pool.alloc_slab(0)
    for verb, arg in (("extend", 32), ("retract", 8), ("cow", 0)):
        with pytest.raises(BlockPoolError, match="slab|shared"):
            getattr(pool, verb)(0, arg)
    pool.check_invariants()             # failed verbs left nothing behind


def test_scale_exp_fixed_per_owner():
    """The exponent is admission-time metadata: constant while owned,
    re-assignable only when the slab moves to a new sequence."""
    pool = StateSlabPool(3, scale_exp=4)
    s0 = pool.alloc_slab(0)             # default exponent
    s1 = pool.alloc_slab(1, scale_exp=6)
    assert pool.slab_exp(0) == 4 and pool.slab_exp(1) == 6
    pool.free_seq(1)
    s2 = pool.alloc_slab(2, scale_exp=2)
    assert s2 == s1                     # LIFO reuse of the freed slab
    assert pool.slab_exp(2) == 2        # new owner, new grid
    assert pool.slab_exp(0) == 4 and s0 != s2


def test_reset_free_order_restores_pristine_lifo():
    pool = StateSlabPool(5)
    order = [pool.alloc_slab(i) for i in range(3)]
    for i in (1, 0, 2):
        pool.free_seq(i)
    pool.reset_free_order()
    assert [pool.alloc_slab(10 + i) for i in range(3)] == sorted(order)


# -- substrate routing ------------------------------------------------------

def test_substrate_for_routes_by_family():
    att = substrate_for(get_smoke_config("qwen3_1_7b"))
    rec = substrate_for(get_smoke_config("rwkv6_3b"))
    hyb = substrate_for(get_smoke_config("zamba2_2_7b"))
    assert (att.kind, att.grows, att.fixed_state) == ("attention",
                                                      True, False)
    assert (rec.kind, rec.grows, rec.fixed_state) == ("recurrent",
                                                      False, True)
    assert (hyb.kind, hyb.grows, hyb.fixed_state) == ("hybrid",
                                                      True, True)
    # fixed state forbids everything the growing substrate supports
    for sub in (rec, hyb):
        assert not (sub.supports_spec or sub.supports_prefix_cache
                    or sub.supports_ragged)
    # snapshot preemption needs the WHOLE sequence state in the slab;
    # a hybrid's KV half recomputes, so it falls back to recompute
    assert rec.snapshot_preempt and not hyb.snapshot_preempt


# -- Eq.-1 round trip on the slab grid --------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(0, 7), seed=st.integers(0, 10_000))
def test_state_roundtrip_error_within_half_step(n, seed):
    """|dequant(quant(x, n)) - x| <= 2^-(n+1) for every representable x —
    the per-element bound of the once-per-step slab requantization."""
    rng = np.random.default_rng(seed)
    hi = 127.0 * 2.0 ** -n              # signed-8-bit representable range
    x = jnp.asarray(rng.uniform(-hi, hi, size=(4, 64)), jnp.float32)
    back = Q.dequant(Q.quant(x, n), n)
    err = np.abs(np.asarray(back - x))
    assert err.max() <= 2.0 ** -(n + 1) + 1e-7
    # and the grid is a fixed point: a second pass changes nothing
    again = Q.dequant(Q.quant(back, n), n)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(again))
